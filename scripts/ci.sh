#!/usr/bin/env sh
# Tier-1 CI step — the single source of truth; .github/workflows/ci.yml
# invokes this script.
#
# Deselects the genuinely environment-limited tests (marked env_limited in
# tests/, registered in pyproject.toml: XLA cost-model tolerances and the
# >1-device production-mesh dry-run) so the suite is green-on-regression on a
# single-device CPU runner, then smokes the benchmarks covering the batched
# estimation paths (point/range grid kernels AND the policy-aware sorted
# grid), the tuning curve, the end-to-end tuner comparison (which records
# the mixed-eps-kernel speedup to benchmarks/results/tuning_e2e.json),
# the join planner (incl. the join-tree budget-split section), the
# serving drift loop (adaptive-vs-static gates recorded to
# benchmarks/results/serving_drift.json), the write-path merge scheduler
# (CAM-vs-baselines gates recorded to benchmarks/results/write_path.json),
# the sharded fleet search
# (solved-boundaries-vs-even-split gates recorded to
# benchmarks/results/sharding.json), and the pricing-engine executor pair
# (fused-kernel-vs-host equivalence/speed gates recorded to
# benchmarks/results/engine_fused.json), and the device occupancy-profiling
# kernel (host-vs-device mixed-eps equivalence/speed gates recorded to
# benchmarks/results/profile_grid.json), verifies that every results JSON the
# workflow uploads actually got written (catches silently-skipped smoke
# sections), and finally runs EVERY example script in --smoke mode so the
# README quickstarts stay executable.
#
# DeprecationWarning raised FROM repro.* code is an error: internal code
# must not call the deprecated tuner/estimator shims.  The gate lives in
# pyproject.toml's filterwarnings (module-regex entry, which a -W flag
# could not express — -W escapes and end-anchors the module field), so
# EVERY pytest invocation enforces it; tests exercising the shims directly
# attribute the warning to the test module and stay exempt.
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q -m "not env_limited"
python -m benchmarks.run --smoke --only estimate_grid pgm_tuning_curve
python -m benchmarks.bench_tuning_e2e --smoke
python -m benchmarks.bench_join --smoke
python -m benchmarks.bench_serving_drift --smoke
python -m benchmarks.bench_write_path --smoke
python -m benchmarks.bench_sharding --smoke
python -m benchmarks.bench_engine --smoke
python -m benchmarks.bench_profile_grid --smoke

# every results JSON named in .github/workflows/ci.yml must exist after the
# bench step — a missing file means a smoke section silently skipped
for f in estimate_grid join_partition join_tree tuning_e2e serving_drift \
         write_path sharding engine_fused profile_grid; do
    if [ ! -f "benchmarks/results/$f.json" ]; then
        echo "MISSING benchmark result: benchmarks/results/$f.json" >&2
        exit 1
    fi
done

# every example must exit 0 at CI size (each accepts --smoke)
for ex in examples/*.py; do
    echo "== $ex --smoke"
    python "$ex" --smoke
done
