"""Synthetic key distributions shaped after the SOSD benchmark datasets
(books / fb / osm / wiki) the paper evaluates on (§VII-A).

Each generator produces sorted, distinct uint64 keys via cumulative sums of
positive gap samples whose law mimics the real dataset's local structure:

* books — Amazon sales ranks: lognormal gaps (moderate heavy tail).
* fb    — Facebook user ids: Pareto gaps (extreme heavy tail → hard-to-fit
          regions, large PLA segments variance).
* osm   — OpenStreetMap cell ids: dense clusters split by huge jumps (weak
          local structure — the paper's stress case, Table I).
* wiki  — edit timestamps: near-uniform with bursty regions.

Scaled down from the paper's 200M keys (CPU container); generators accept any
``n`` so the benchmarks can grow with ``--scale``.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

__all__ = ["make_dataset", "DATASETS"]


def _finalize(gaps: np.ndarray) -> np.ndarray:
    gaps = np.maximum(gaps.astype(np.uint64), 1)
    keys = np.cumsum(gaps)
    # cumsum of positive gaps is strictly increasing => already distinct/sorted
    return keys.astype(np.uint64)


def _books(n: int, rng: np.random.Generator) -> np.ndarray:
    gaps = rng.lognormal(mean=1.0, sigma=2.0, size=n)
    return _finalize(np.minimum(gaps, 1e9))


def _fb(n: int, rng: np.random.Generator) -> np.ndarray:
    gaps = rng.pareto(a=1.05, size=n) + 1.0
    return _finalize(np.minimum(gaps, 1e12))


def _osm(n: int, rng: np.random.Generator) -> np.ndarray:
    # Clusters of ~geometric(1/800) length with tiny in-cluster gaps and huge
    # inter-cluster jumps.
    n_clusters = max(2, n // 800)
    boundaries = np.sort(rng.choice(n - 1, size=n_clusters, replace=False))
    gaps = rng.integers(1, 4, size=n).astype(np.float64)
    jumps = rng.pareto(a=0.8, size=n_clusters) * 1e6 + 1e5
    gaps[boundaries] += np.minimum(jumps, 1e13)
    return _finalize(gaps)


def _wiki(n: int, rng: np.random.Generator) -> np.ndarray:
    # Doubly-stochastic exponential gaps: slowly varying burst rate.
    n_phases = max(2, n // 5000)
    rates = rng.lognormal(0.0, 1.0, size=n_phases)
    phase = np.repeat(rates, -(-n // n_phases))[:n]
    gaps = rng.exponential(scale=50.0, size=n) / phase + 1.0
    return _finalize(np.minimum(gaps, 1e9))


DATASETS: Dict[str, Callable[[int, np.random.Generator], np.ndarray]] = {
    "books": _books,
    "fb": _fb,
    "osm": _osm,
    "wiki": _wiki,
}


def make_dataset(name: str, n: int, seed: int = 0) -> np.ndarray:
    """Sorted distinct uint64 keys of the named synthetic family."""
    try:
        gen = DATASETS[name]
    except KeyError:
        raise ValueError(f"unknown dataset {name!r}; one of {sorted(DATASETS)}") from None
    return gen(n, np.random.default_rng(seed))
