"""Data substrate: synthetic SOSD-style datasets, workload mixtures, and the
LM token pipeline."""
from repro.data import datasets, workloads

__all__ = ["datasets", "workloads"]
