"""Deterministic LM token pipeline: sharded, resumable, elastic.

Batches are a pure function of (seed, step) — counter-based generation, no
iterator state — so failure replay (Supervisor) and elastic re-scaling resume
exactly without data loss or duplication.  On a real cluster each host slices
its batch shard by process index from the same function.

The stream is synthetic zipf-mixture tokens (this container has no corpus);
a tokenized corpus would keep the same step->batch contract via an index
file, which is the property fault tolerance actually relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    num_codebooks: int = 0       # audio family
    vlm_tokens: int = 0          # vision slots (vlm family)
    patch_dim: int = 0

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        shape = (self.global_batch, self.seq_len + 1)
        if self.num_codebooks:
            shape = shape + (self.num_codebooks,)
        # zipf head + uniform tail mixture, clipped to vocab
        z = rng.zipf(1.4, size=shape)
        u = rng.integers(0, self.vocab_size, size=shape)
        pick = rng.random(shape) < 0.5
        tokens = np.where(pick, np.minimum(z, self.vocab_size - 1), u)
        batch = {"tokens": tokens.astype(np.int32)}
        if self.vlm_tokens:
            batch["vision_embeds"] = rng.standard_normal(
                (self.global_batch, self.vlm_tokens, self.patch_dim)
            ).astype(np.float32)
            batch["positions_3d"] = np.broadcast_to(
                np.arange(self.seq_len, dtype=np.int32)[None, None],
                (3, self.global_batch, self.seq_len)).copy()
        return batch

    def host_shard(self, batch: Dict[str, np.ndarray], process_index: int,
                   process_count: int) -> Dict[str, np.ndarray]:
        """Slice the per-host shard (multi-host clusters)."""
        out = {}
        for k, v in batch.items():
            ax = 1 if k == "positions_3d" else 0
            n = v.shape[ax] // process_count
            sl = [slice(None)] * v.ndim
            sl[ax] = slice(process_index * n, (process_index + 1) * n)
            out[k] = v[tuple(sl)]
        return out
