"""Workload generators (paper §VII-A, Table III).

Point/join probe keys come from a three-component mixture over the key set:
hotspot (contiguous high-skew ranges → locality), Zipf over the full domain
(skew without locality), and a uniform residual.  w1–w6 are the paper's
mixture proportions.  Range workloads pair mixture-sampled lower bounds with
random lengths.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = ["MIXTURES", "WorkloadSpec", "point_positions", "point_workload",
           "range_workload", "join_outer_keys"]

# (hotspot, zipf, uniform) proportions — Table III.
MIXTURES: Dict[str, Tuple[float, float, float]] = {
    "w1": (0.0, 0.0, 1.0),
    "w2": (0.0, 1.0, 0.0),
    "w3": (1.0, 0.0, 0.0),
    "w4": (0.4, 0.3, 0.3),
    "w5": (0.2, 0.2, 0.6),
    "w6": (0.1, 0.1, 0.8),
}


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str = "w4"
    n_hotspots: int = 8
    hotspot_frac: float = 0.001   # fraction of the position domain per hotspot
    zipf_a: float = 1.3           # numpy zipf shape (a > 1)
    seed: int = 0


def point_positions(n: int, n_queries: int, spec: WorkloadSpec) -> np.ndarray:
    """Sample query *positions* (ranks in the sorted key array)."""
    try:
        mix = MIXTURES[spec.name]
    except KeyError:
        raise ValueError(f"unknown workload {spec.name!r}") from None
    rng = np.random.default_rng(spec.seed)
    counts = rng.multinomial(n_queries, mix)
    parts = []
    if counts[0]:  # hotspot: uniform inside a few contiguous windows
        width = max(1, int(n * spec.hotspot_frac))
        starts = rng.integers(0, max(1, n - width), size=spec.n_hotspots)
        which = rng.integers(0, spec.n_hotspots, size=counts[0])
        offs = rng.integers(0, width, size=counts[0])
        parts.append(starts[which] + offs)
    if counts[1]:  # zipf over the full domain, scattered via permutation hash
        ranks = rng.zipf(spec.zipf_a, size=counts[1]).astype(np.int64)
        ranks = np.minimum(ranks - 1, n - 1)
        # Affine permutation scatters popular ranks across the key space
        # (skew without locality), keeping generation O(Q) and seed-stable.
        a = 6364136223846793005
        parts.append(((ranks * a + 1442695040888963407) % n).astype(np.int64))
    if counts[2]:  # uniform residual
        parts.append(rng.integers(0, n, size=counts[2]))
    pos = np.concatenate(parts) if parts else np.zeros(0, np.int64)
    rng.shuffle(pos)
    return pos.astype(np.int64)


def point_workload(keys: np.ndarray, n_queries: int, spec: WorkloadSpec):
    """(query_keys, true_positions) for a point-lookup workload."""
    pos = point_positions(keys.shape[0], n_queries, spec)
    return keys[pos], pos


def range_workload(
    keys: np.ndarray, n_queries: int, spec: WorkloadSpec, max_len: int = 2048
):
    """(lo_keys, hi_keys, lo_pos, hi_pos) — mixture lows, uniform lengths."""
    n = keys.shape[0]
    rng = np.random.default_rng(spec.seed + 7)
    lo_pos = point_positions(n, n_queries, spec)
    lengths = rng.integers(1, max_len + 1, size=n_queries)
    hi_pos = np.minimum(lo_pos + lengths, n - 1)
    return keys[lo_pos], keys[hi_pos], lo_pos, hi_pos


def join_outer_keys(
    inner_keys: np.ndarray,
    n_outer: int,
    spec: WorkloadSpec,
    miss_frac: float = 0.1,
) -> np.ndarray:
    """Outer relation for A ⋈ B: mixture-sampled inner keys + non-matching
    keys drawn between inner keys (probes that find nothing still do I/O)."""
    rng = np.random.default_rng(spec.seed + 13)
    n_miss = int(n_outer * miss_frac)
    pos = point_positions(inner_keys.shape[0], n_outer - n_miss, spec)
    hits = inner_keys[pos]
    base = inner_keys[
        rng.integers(0, inner_keys.shape[0] - 1, size=n_miss)
    ]
    misses = base + 1  # may or may not exist; realistic near-miss probes
    outer = np.concatenate([hits, misses])
    rng.shuffle(outer)
    return outer
