"""ShardingSession — fleet-wide CAM pricing and the joint shard search.

Shard boundaries are just another knob.  Where a replay-based shard
designer prices each candidate partition by re-running a trace per node,
CAM's closed forms price the entire joint space in batched solves:

1. **Route** every candidate boundary vector through the vectorized
   partition kernel (``Workload.split_at`` + local translation, see
   ``sharding/route.py``) — cheap array work, no model calls.
2. **Profile once** — ONE :meth:`CostSession.grid_profiles_grouped` pass
   builds every (boundary, shard) sub-workload's capacity-independent
   knob profiles in one concatenated :class:`GridProfiles`.
3. **Solve once** — per-shard (knob × budget-share) tables are assembled
   with :meth:`CamTuner.assemble_table` (``index_in_split=True``: a
   shard's share of the fleet pool must house its index AND its buffer)
   and concatenated, then priced by ONE :meth:`CostSession.solve_profiles`
   call — a single ``hit_rate_grid`` dispatch over every
   (boundary × shard × knob × share) cell.
4. **Argmin** — the fleet budget split is a fraction simplex: ``grid``
   units composed over shards (the JoinTreeSession buffer-split trick
   lifted from join-tree levels to fleet nodes), so the final joint
   (boundary × knob × share) choice is pure array lookups.  Zero
   per-shard model calls, structurally asserted in
   ``tests/test_sharding.py``.

Per-shard knob results come out of the same code path the single-node
``TuningSession.tune_from_profiles`` runs — :meth:`CamTuner.assemble_table`
plus :meth:`CamTuner.finish_from_solution` on each shard's slice of the
one solved table — so every :class:`ShardPlan` carries a real
:class:`TuneResult`.

Skew is first-class: :meth:`ShardingSession.rebalance` compares observed
per-shard query mass (from a serving sketch summary via
``serving.sketch.shard_page_masses``, or by routing the live workload)
against the plan's, names the hot shard, re-solves with the current
boundaries among the candidates, and gates the boundary move on the PR-6
economics — switch only when horizon I/O savings repay data movement
plus per-shard index rebuild plus cold-buffer refill.
"""
from __future__ import annotations

import dataclasses
import math
import time
from itertools import combinations
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.session import CostSession, SkippedCandidate, System
from repro.core.workload import Workload
from repro.engine import PriceTable
from repro.tuning.session import (CamTuner, IndexBuilder, SizeModel,
                                  SplitTable, TuneResult, TuningSession,
                                  _feasibility_split)

from .route import RouteStats, boundary_candidates, route
from .system import ShardedSystem

__all__ = ["ShardPlan", "FleetPlan", "RebalanceResult", "ShardingSession"]


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """One node's slice of the winning fleet configuration."""

    index: int                       # shard position in the fleet
    point: Dict[str, object]         # chosen knob point (name -> value)
    knob: object                     # the knob key (bare value / tuple)
    fraction: float                  # share of the fleet memory pool
    capacity_pages: int              # buffer pages after the index's cut
    est_io: float                    # expected physical I/Os per query
    n_queries: int                   # routed query pieces on this shard
    tune: Optional[TuneResult]       # None only for traffic-less shards


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """The solved joint (boundary × knob × budget-share) configuration."""

    boundaries: Tuple[int, ...]
    fractions: Tuple[float, ...]
    shards: Tuple[ShardPlan, ...]
    fleet_io: float                  # expected total physical I/Os
    io_per_query: float
    total_queries: int
    shard_masses: Tuple[float, ...]  # routed query-mass fraction per shard
    route_stats: RouteStats
    boundaries_searched: Tuple[Tuple[int, ...], ...]
    boundary_totals: Tuple[float, ...]   # best fleet I/O per candidate
    cells_solved: int
    skipped: Tuple[SkippedCandidate, ...]
    solve_seconds: float

    @property
    def n_shards(self) -> int:
        return len(self.shards)


@dataclasses.dataclass(frozen=True)
class RebalanceResult:
    """A priced boundary-move proposal (the TuneResult of rebalancing).

    ``switched`` is the PR-6 gate verdict: adopt ``plan`` only when the
    predicted horizon savings repay ``move_io`` (data movement + affected
    shards' index rebuild + cold-buffer refill).  ``io_current`` is the
    best the fleet can do WITHOUT moving data — current boundaries, knobs
    and budget shares re-tuned in place (those are free; only boundary
    moves ship pages).
    """

    hot_shard: int
    shard_masses: Tuple[float, ...]
    tv: float                        # TV distance vs. the plan's masses
    io_current: float                # per query, boundaries kept
    io_candidate: float              # per query, best candidate plan
    move_io: float                   # one-time cost of the boundary move
    horizon_queries: float
    predicted_savings: float         # (io_current - io_candidate) * horizon
    switched: bool
    from_boundaries: Tuple[int, ...]
    to_boundaries: Tuple[int, ...]
    plan: FleetPlan


class ShardingSession:
    """Joint (shard-boundary × per-shard knob × fleet-budget) search.

    Binds a node :class:`System` template (geometry, policy, per-node
    budget), an :class:`IndexBuilder` over the GLOBAL key file, and a
    fleet width.  The fleet memory pool defaults to ``n_shards`` node
    budgets; it is split across shards on a ``grid``-unit simplex, each
    share housing that shard's index and buffer.

    Only uniform-eps candidate families are accepted (PGM, RadixSpline):
    a pre-built global index's page windows are meaningless on a
    shard-local key file, and the uniform-eps profile kernels need no
    index at all.  Per-shard index footprints are priced with the global
    size model — conservative (a shard's index over fewer keys is no
    larger), and exact for the 1-shard fleet.
    """

    def __init__(self, node: System, builder: IndexBuilder, n_shards: int,
                 *, fleet_budget_bytes: Optional[float] = None,
                 grid: int = 8,
                 overrides: Optional[Dict[str, object]] = None,
                 size_model: Optional[SizeModel] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if grid < n_shards:
            raise ValueError(f"budget grid ({grid}) needs at least one unit "
                             f"per shard ({n_shards})")
        self.node = node
        self.builder = builder
        self.n_shards = int(n_shards)
        self.grid = int(grid)
        self.n = int(len(builder.keys))
        self.fleet_budget_bytes = float(
            fleet_budget_bytes if fleet_budget_bytes is not None
            else node.memory_budget_bytes * n_shards)
        self.fleet_system = dataclasses.replace(
            node, memory_budget_bytes=self.fleet_budget_bytes)
        self.cost = CostSession(self.fleet_system)
        self.space = builder.knob_space(overrides)
        self.size_model = size_model
        # candidate fleet-pool shares, in simplex units: with S shards each
        # taking >= 1 of `grid` units, no shard can hold more than
        # grid - S + 1 units.
        self.max_share = self.grid - self.n_shards + 1
        self.splits = tuple(j / self.grid
                            for j in range(1, self.max_share + 1))

    def fleet(self, boundaries: Sequence[int] = ()) -> ShardedSystem:
        return ShardedSystem(self.node, self.n, tuple(boundaries),
                             self.fleet_budget_bytes)

    # ------------------------------------------------------------------ solve
    def solve(self, workload: Workload,
              boundary_candidates_: Optional[
                  Sequence[Sequence[int]]] = None, *,
              sample_rate: float = 1.0, seed: int = 0) -> FleetPlan:
        """One profile pass + one solve pass over the whole joint space."""
        t0 = time.perf_counter()
        if workload.n is not None and workload.n != self.n:
            raise ValueError(f"workload n={workload.n} != key file "
                             f"n={self.n}")
        if boundary_candidates_ is None:
            bcands = boundary_candidates(workload, self.n, self.n_shards)
        else:
            bcands = tuple(tuple(int(c) for c in b)
                           for b in boundary_candidates_)
        for b in bcands:
            if len(b) != self.n_shards - 1:
                raise ValueError(f"boundary candidate {b} has {len(b)} cuts; "
                                 f"a {self.n_shards}-shard fleet needs "
                                 f"{self.n_shards - 1}")
        if not bcands:
            raise ValueError("no boundary candidates")

        # ---- knob candidates, shared by every (boundary, shard) ----------
        size_model = self.size_model if self.size_model is not None \
            else self.builder.size_model()
        feasible, skipped = _feasibility_split(
            self.space.points(), self.space, size_model, self.fleet_system)
        if not feasible:
            raise ValueError("fleet budget too small for any candidate "
                             "index")
        cands = [self.builder.candidate(pt, size) for pt, size in feasible]
        for c in cands:
            if c.index is not None:
                raise ValueError(
                    "ShardingSession requires uniform-eps candidates "
                    f"({self.builder.family!r} supplied a pre-built index); "
                    "a global index's page windows are meaningless on a "
                    "shard-local key file")
        points = {self.space.key(pt): pt for pt, _size in feasible}
        min_pt, min_size = min(feasible, key=lambda fs: fs[1])

        # ---- route every boundary candidate (array work, no model) -------
        fleets = [self.fleet(b) for b in bcands]
        routed = [route(workload, f) for f in fleets]

        # ---- ONE profile pass over every busy (boundary, shard) ----------
        groups = []
        for bi, (locals_, _stats) in enumerate(routed):
            for si, wl in enumerate(locals_):
                if wl.n_queries > 0:
                    groups.append(((bi, si), cands, wl))
        profiles = self.cost.grid_profiles_grouped(groups, sample_rate, seed)
        skipped.extend(profiles.skipped)

        # ---- per-(boundary, shard) tables, concatenated ------------------
        M = self.fleet_budget_bytes
        pb = self.node.geom.page_bytes
        tables: Dict[Tuple[int, int], Tuple[SplitTable, int]] = {}
        parts, offset = [], 0
        for key, _c, _wl in groups:
            pts = {(key, kn): pt for kn, pt in points.items()}
            tab = PriceTable.from_profiles(
                profiles, pts, splits=self.splits, budget_bytes=M,
                page_bytes=pb, index_in_split=True,
                include_max_split=False)
            tables[key] = (tab, offset)
            parts.append(tab)
            offset += len(tab)
        fleet_table = PriceTable.concat(parts)

        # ---- ONE engine call prices every cell ---------------------------
        if len(fleet_table):
            sol = self.cost.engine.price(fleet_table, objective="io")
            h, n_distinct, io = sol.hit_rates, sol.distinct, sol.io
        else:
            h = n_distinct = io = np.zeros(0, np.float64)

        # ---- cost tensor: best knob per (boundary, shard, share) ---------
        B, S = len(bcands), self.n_shards
        nq = np.zeros((B, S), np.int64)
        for bi, (locals_, _stats) in enumerate(routed):
            for si, wl in enumerate(locals_):
                nq[bi, si] = wl.n_queries
        C = np.full((B, S, self.max_share), np.inf)
        for (bi, si), (tab, off) in tables.items():
            shares = np.round(tab.fracs * self.grid).astype(np.int64)
            cell_cost = nq[bi, si] * io[off:off + len(tab)]
            for t in range(len(tab)):
                j = shares[t] - 1
                if cell_cost[t] < C[bi, si, j]:
                    C[bi, si, j] = cell_cost[t]
        # traffic-less shards cost nothing wherever the smallest index fits
        for bi in range(B):
            for si in range(S):
                if (bi, si) not in tables and nq[bi, si] == 0:
                    for j, f in enumerate(self.splits):
                        if (f * M - min_size) // pb >= 1:
                            C[bi, si, j] = 0.0

        # ---- fraction-simplex argmin (the JoinTree composition trick) ----
        comps = np.asarray(
            [np.diff(np.asarray((0,) + c + (self.grid,), np.int64))
             for c in combinations(range(1, self.grid), S - 1)],
            np.int64)
        best_total, best_bi, best_comp = np.inf, -1, None
        totals_by_boundary = []
        for bi in range(B):
            totals = C[bi][np.arange(S)[None, :], comps - 1].sum(axis=1)
            k = int(np.argmin(totals))
            totals_by_boundary.append(float(totals[k]))
            if totals[k] < best_total:
                best_total, best_bi, best_comp = float(totals[k]), bi, comps[k]
        if not np.isfinite(best_total):
            raise ValueError("no feasible fleet configuration: every "
                             "(boundary, budget split) leaves some busy "
                             "shard without a fitting index")

        # ---- winner assembly: per-shard TuneResults, array lookups only --
        tsession = TuningSession(self.fleet_system, splits=self.splits)
        tuner = CamTuner()
        plans = []
        for si in range(S):
            u = int(best_comp[si])
            f = u / self.grid
            key = (best_bi, si)
            if key not in tables:
                plans.append(ShardPlan(
                    index=si, point=dict(min_pt),
                    knob=self.space.key(min_pt), fraction=f,
                    capacity_pages=int((f * M - min_size) // pb),
                    est_io=0.0, n_queries=0, tune=None))
                continue
            tab, off = tables[key]
            shares = np.round(tab.fracs * self.grid).astype(np.int64)
            sel = np.where(shares == u)[0]
            sub = tab.subset(sel)
            tune = tuner.finish_from_solution(
                tsession, self.builder, self.space, profiles, sub,
                h[off + sel], n_distinct[off + sel], objective="io",
                size_model=size_model, skipped=(), t0=t0,
                batched_solves=1)
            plans.append(ShardPlan(
                index=si, point=dict(tune.best), knob=tune.best_knob[1],
                fraction=f, capacity_pages=tune.capacity_pages,
                est_io=tune.est_io, n_queries=int(nq[best_bi, si]),
                tune=tune))

        total_q = int(nq[best_bi].sum())
        return FleetPlan(
            boundaries=bcands[best_bi],
            fractions=tuple(p.fraction for p in plans),
            shards=tuple(plans),
            fleet_io=best_total,
            io_per_query=best_total / max(total_q, 1),
            total_queries=total_q,
            shard_masses=tuple(nq[best_bi] / max(total_q, 1)),
            route_stats=routed[best_bi][1],
            boundaries_searched=bcands,
            boundary_totals=tuple(totals_by_boundary),
            cells_solved=len(fleet_table),
            skipped=tuple(skipped),
            solve_seconds=time.perf_counter() - t0)

    # -------------------------------------------------------------- rebalance
    def rebalance(self, workload: Workload, current: FleetPlan, *,
                  horizon_queries: float,
                  summary: Optional[Dict[str, np.ndarray]] = None,
                  boundary_candidates_: Optional[
                      Sequence[Sequence[int]]] = None,
                  sample_rate: float = 1.0,
                  seed: int = 0) -> RebalanceResult:
        """Detect a hot shard and price a boundary move against its cost.

        ``summary`` is a serving sketch summary (``WindowSketch.summary``);
        when given, per-shard masses come off its page-popularity
        histogram via ``shard_page_masses`` — no routing pass.  Otherwise
        ``workload`` (the observed traffic) is routed through the current
        boundaries.  The candidate plan always includes the current
        boundaries, so ``io_current`` (boundaries kept, knobs and budget
        shares re-tuned for free) is read off the same single solved
        table as the best move.
        """
        cur_b = tuple(current.boundaries)
        fleet_cur = self.fleet(cur_b)
        if summary is not None:
            from repro.serving.sketch import shard_page_masses
            masses = shard_page_masses(
                summary, fleet_cur.boundary_pages,
                self.node.geom.num_pages(self.n))
        else:
            locals_, _stats = route(workload, fleet_cur)
            tot = max(1, sum(w.n_queries for w in locals_))
            masses = tuple(w.n_queries / tot for w in locals_)
        delta = np.asarray(masses) - np.asarray(current.shard_masses)
        hot = int(np.argmax(delta))
        tv = 0.5 * float(np.abs(delta).sum())

        if boundary_candidates_ is None:
            cands = list(boundary_candidates(workload, self.n,
                                             self.n_shards))
        else:
            cands = [tuple(int(c) for c in b)
                     for b in boundary_candidates_]
        if cur_b not in cands:
            cands.insert(0, cur_b)
        plan = self.solve(workload, cands, sample_rate=sample_rate,
                          seed=seed)

        total_q = max(plan.total_queries, 1)
        io_cur = plan.boundary_totals[
            plan.boundaries_searched.index(cur_b)] / total_q
        io_new = plan.io_per_query
        to_b = plan.boundaries
        if to_b == cur_b:
            move_io, savings, switched = 0.0, 0.0, False
        else:
            move_io = self._move_io(cur_b, to_b, plan)
            savings = (io_cur - io_new) * horizon_queries
            switched = savings > move_io
        return RebalanceResult(
            hot_shard=hot, shard_masses=tuple(float(m) for m in masses),
            tv=tv, io_current=float(io_cur), io_candidate=float(io_new),
            move_io=float(move_io), horizon_queries=float(horizon_queries),
            predicted_savings=float(savings), switched=switched,
            from_boundaries=cur_b, to_boundaries=to_b, plan=plan)

    def _move_io(self, old: Tuple[int, ...], new: Tuple[int, ...],
                 plan: FleetPlan) -> float:
        """One-time I/O of moving boundaries ``old`` -> ``new``.

        Moved key ranges ship as pages (read on the donor, write on the
        receiver); every shard whose edge moved also rebuilds its index
        (scan its local pages + write the index file) and refills its
        buffer cold — the PR-6 ``rebuild_io`` model applied per affected
        shard.
        """
        geom = self.node.geom
        pb = geom.page_bytes
        moved = sum(math.ceil(abs(a - b) / geom.c_ipp)
                    for a, b in zip(old, new))
        cost = 2.0 * moved
        old_edges = (0,) + old + (self.n,)
        new_edges = (0,) + new + (self.n,)
        size_model = self.size_model if self.size_model is not None \
            else self.builder.size_model()
        shards_new = self.fleet(new).shards
        for si in range(self.n_shards):
            if (old_edges[si] == new_edges[si]
                    and old_edges[si + 1] == new_edges[si + 1]):
                continue
            sp = plan.shards[si]
            size = float(size_model(**sp.point))
            distinct = 0.0
            if sp.tune is not None:
                distinct = sp.tune.estimates[sp.tune.best_knob].distinct_pages
            cost += (shards_new[si].num_pages
                     + math.ceil(size / pb)
                     + min(sp.capacity_pages, distinct))
        return float(cost)
