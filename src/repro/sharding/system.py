"""ShardedSystem — a fleet of CAM nodes over one key-space partition.

A sharded deployment splits the sorted key file at rank boundaries: shard
``j`` owns the contiguous global ranks ``[cut[j-1], cut[j])`` (implicit
edges 0 and n) and serves them from its own node — same page geometry and
cache policy everywhere (one :class:`~repro.core.session.System` template),
but each node runs its own learned index over its local key file and its
own buffer pool, carved out of ONE fleet-level memory budget by a
fraction simplex (the :class:`~repro.join.tree.JoinTreeSession` budget-split
idea lifted from join-tree levels to shard nodes).

Page ownership follows the index-data separation layout: the global data
file is paged once (``page = rank // c_ipp``), and shard ``j`` owns every
page any of its ranks lives on — ``[lo_rank // c_ipp, (hi_rank-1) // c_ipp]``
inclusive.  A cut that is NOT page-aligned therefore REPLICATES its
boundary page on both neighbors (each holds the half it owns plus the
page's other residents), which is the ``boundary-page double-count`` the
routing invariants account for: per-shard logical page references sum to
the unsharded count plus one reference per mid-page boundary crossing.
Shard-local coordinates subtract ``page_lo * c_ipp``, so a local rank's
page is exactly its global page minus ``page_lo`` — local profiles are
global profiles translated, nothing re-derived.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.session import System

__all__ = ["Shard", "ShardedSystem", "even_boundaries"]


@dataclasses.dataclass(frozen=True)
class Shard:
    """One node's slice of the key space.

    ``[lo_rank, hi_rank)`` are the global ranks owned; ``[page_lo,
    page_hi]`` (inclusive) the global data pages served, and ``n_local``
    the local key-file size in shard coordinates (rank - page_lo * c_ipp)
    — sized from the page floor so local page ids are dense from 0.
    """

    lo_rank: int
    hi_rank: int
    page_lo: int
    page_hi: int
    n_local: int

    @property
    def n_ranks(self) -> int:
        return self.hi_rank - self.lo_rank

    @property
    def num_pages(self) -> int:
        return self.page_hi - self.page_lo + 1

    def localize(self, positions: np.ndarray, c_ipp: int) -> np.ndarray:
        """Global ranks -> shard-local ranks (page-floor translation)."""
        return np.asarray(positions, np.int64) - self.page_lo * c_ipp


def even_boundaries(n: int, n_shards: int) -> Tuple[int, ...]:
    """The even key-split baseline: cuts at j * n / S."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    return tuple(int(j * n // n_shards) for j in range(1, n_shards))


@dataclasses.dataclass(frozen=True)
class ShardedSystem:
    """N nodes sharing geometry/policy, split at ``boundaries``.

    ``node`` is the per-node System template (geometry, cache policy,
    device model); ``fleet_budget_bytes`` the TOTAL memory across nodes —
    the pool the per-shard budget simplex splits (defaults to the
    template's budget, i.e. "shard an existing node's budget").  With no
    boundaries this is a 1-shard fleet, golden-equivalent to the plain
    ``System``/``CostSession`` path.
    """

    node: System
    n: int
    boundaries: Tuple[int, ...] = ()
    fleet_budget_bytes: Optional[float] = None

    def __post_init__(self):
        cuts = tuple(int(c) for c in self.boundaries)
        object.__setattr__(self, "boundaries", cuts)
        if any(b <= a for a, b in zip((0,) + cuts, cuts + (self.n,))):
            raise ValueError(
                f"boundaries must be strictly increasing ranks inside "
                f"(0, {self.n}); got {list(cuts)}")
        if self.fleet_budget_bytes is None:
            object.__setattr__(self, "fleet_budget_bytes",
                               float(self.node.memory_budget_bytes))

    @property
    def n_shards(self) -> int:
        return len(self.boundaries) + 1

    @property
    def shards(self) -> Tuple[Shard, ...]:
        c_ipp = self.node.geom.c_ipp
        edges = (0,) + self.boundaries + (self.n,)
        out = []
        for lo, hi in zip(edges[:-1], edges[1:]):
            page_lo = lo // c_ipp
            page_hi = (hi - 1) // c_ipp
            out.append(Shard(lo, hi, page_lo, page_hi,
                             n_local=hi - page_lo * c_ipp))
        return tuple(out)

    @property
    def boundary_pages(self) -> Tuple[int, ...]:
        """Global page of each cut (the page a mid-page cut replicates)."""
        return tuple(c // self.node.geom.c_ipp for c in self.boundaries)

    @property
    def replicated_cuts(self) -> Tuple[int, ...]:
        """Cuts that are NOT page-aligned: their boundary page lives on
        both neighbors, and every window crossing them re-references it."""
        c_ipp = self.node.geom.c_ipp
        return tuple(c for c in self.boundaries if c % c_ipp != 0)

    def system_for(self, budget_bytes: float) -> System:
        """A node System owning ``budget_bytes`` of the fleet pool."""
        return dataclasses.replace(self.node,
                                   memory_budget_bytes=float(budget_bytes))

    def with_boundaries(self, boundaries: Sequence[int]) -> "ShardedSystem":
        return dataclasses.replace(self, boundaries=tuple(boundaries))
