"""Sharded CAM: fleet-wide I/O pricing and the joint shard search.

``ShardedSystem`` describes N nodes over one key-space partition;
``ShardingSession`` solves the joint (shard-boundary × per-shard knob ×
fleet-budget-split) search with one profile pass and one solve pass, and
``rebalance`` prices hot-shard boundary moves against the rebuild gate.
"""
from .route import RouteStats, boundary_candidates, quantile_boundaries, route
from .session import (FleetPlan, RebalanceResult, ShardPlan,
                      ShardingSession)
from .system import Shard, ShardedSystem, even_boundaries

__all__ = [
    "Shard",
    "ShardedSystem",
    "even_boundaries",
    "route",
    "RouteStats",
    "quantile_boundaries",
    "boundary_candidates",
    "ShardingSession",
    "ShardPlan",
    "FleetPlan",
    "RebalanceResult",
]
