"""Routing — send one global Workload through a shard fleet.

``route`` is ``Workload.split_at`` (the vectorized repeat + prefix-scan
partition kernel, same idiom as ``join.hybrid.partition_probes``) followed
by a coordinate translation into each shard's local rank space.  Every
point query lands on exactly one shard; range and sorted windows crossing
a boundary are clipped into per-shard pieces, and ``RouteStats`` carries
the exact accounting the invariants need:

* ``boundary_splits`` — how many extra probe pieces the cuts created
  (sum of routed query counts minus the original count);
* ``boundary_page_overlap`` — the double-count term: one extra logical
  page reference per window crossing a NON-page-aligned cut, because the
  cut's page is replicated on both neighbors and both clipped pieces
  touch it.  At eps=0 the per-shard page-reference totals sum to the
  unsharded total plus exactly this term.

Boundary candidates come from workload *query quantiles* — equal query
mass per shard — blended toward the even key split, so the search grid
spans "balance keys" to "balance traffic".
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.workload import MIXED, Workload

from .system import ShardedSystem, even_boundaries

__all__ = ["RouteStats", "route", "quantile_boundaries",
           "boundary_candidates"]


@dataclasses.dataclass(frozen=True)
class RouteStats:
    """Bookkeeping from one routing pass (global counts, not per shard)."""

    boundary_splits: int
    boundary_page_overlap: int


def _localize(workload: Workload, offset: int, n_local: int) -> Workload:
    """Translate a global-coordinate segment into shard-local ranks."""
    if workload.kind == MIXED:
        return dataclasses.replace(
            workload, n=n_local,
            parts=tuple(_localize(p, offset, n_local) for p in workload.parts))
    shift = lambda a: None if a is None else a - offset  # noqa: E731
    return dataclasses.replace(
        workload, n=n_local,
        positions=shift(workload.positions),
        hi_positions=shift(workload.hi_positions))


def _overlap(workload: Workload, cuts: np.ndarray) -> int:
    """Windows crossing each replicated cut (lo < cut <= hi), summed."""
    if workload.kind == MIXED:
        return sum(_overlap(p, cuts) for p in workload.parts)
    if workload.hi_positions is None or workload.n_queries == 0 or not cuts.size:
        return 0
    lo = workload.positions[:, None]
    hi = workload.hi_positions[:, None]
    return int(np.sum((lo < cuts[None, :]) & (hi >= cuts[None, :])))


def route(workload: Workload, sharded: ShardedSystem,
          ) -> Tuple[Tuple[Workload, ...], RouteStats]:
    """Partition ``workload`` across the fleet; returns local sub-workloads.

    Sub-workload ``j`` is shard ``j``'s traffic in LOCAL coordinates
    (ranks relative to ``page_lo * c_ipp``, key-file size ``n_local``) —
    ready to profile against a shard-local index with no further
    translation.  With one shard this is the identity (offset 0, same n),
    which is what makes the 1-shard fleet golden-equivalent to the
    unsharded path.
    """
    if workload.n is not None and workload.n != sharded.n:
        raise ValueError(
            f"workload n={workload.n} != fleet n={sharded.n}")
    c_ipp = sharded.node.geom.c_ipp
    segments = workload.split_at(np.asarray(sharded.boundaries, np.int64)) \
        if sharded.boundaries else (workload,)
    shards = sharded.shards
    locals_ = tuple(
        _localize(seg, sh.page_lo * c_ipp, sh.n_local)
        for seg, sh in zip(segments, shards))
    splits = sum(s.n_queries for s in segments) - workload.n_queries
    overlap = _overlap(workload,
                       np.asarray(sharded.replicated_cuts, np.int64))
    return locals_, RouteStats(boundary_splits=int(splits),
                               boundary_page_overlap=overlap)


# --------------------------------------------------------------- candidates
def _mass_positions(workload: Workload) -> List[np.ndarray]:
    if workload.kind == MIXED:
        out: List[np.ndarray] = []
        for p in workload.parts:
            out.extend(_mass_positions(p))
        return out
    if workload.positions is None or workload.n_queries == 0:
        return []
    if workload.hi_positions is None:
        return [workload.positions]
    # a window contributes mass at both ends, so wide scans pull cuts too
    return [workload.positions, workload.hi_positions]


def _normalize(cuts: np.ndarray, n: int) -> Optional[Tuple[int, ...]]:
    """Clamp into (0, n) and force strict increase; None if impossible."""
    cuts = np.sort(np.asarray(cuts, np.int64))
    cuts = np.clip(cuts, 1, n - 1)
    for i in range(1, cuts.size):          # nudge duplicates forward
        if cuts[i] <= cuts[i - 1]:
            cuts[i] = cuts[i - 1] + 1
    if cuts.size and cuts[-1] >= n:
        return None
    return tuple(int(c) for c in cuts)


def quantile_boundaries(workload: Workload, n: int, n_shards: int,
                        ) -> Optional[Tuple[int, ...]]:
    """Cuts at query-mass quantiles: each shard gets ~equal traffic."""
    if n_shards < 2:
        return ()
    mass = _mass_positions(workload)
    if not mass:
        return _normalize(np.asarray(even_boundaries(n, n_shards)), n)
    pos = np.sort(np.concatenate(mass))
    qs = np.arange(1, n_shards) / n_shards
    cuts = np.quantile(pos, qs, method="nearest").astype(np.int64)
    return _normalize(cuts, n)


def boundary_candidates(workload: Workload, n: int, n_shards: int,
                        blends: Tuple[float, ...] = (0.5,),
                        ) -> Tuple[Tuple[int, ...], ...]:
    """The boundary search grid: even split, traffic quantiles, blends.

    Blend ``t`` interpolates cut-by-cut between the even key split
    (t=0) and the pure quantile split (t=1); duplicates after rounding
    and normalization are dropped, order preserved.
    """
    if n_shards < 2:
        return ((),)
    even = np.asarray(even_boundaries(n, n_shards), np.float64)
    quant = quantile_boundaries(workload, n, n_shards)
    cands: List[Tuple[int, ...]] = []
    seen = set()

    def _add(c: Optional[Tuple[int, ...]]):
        if c is not None and c not in seen:
            seen.add(c)
            cands.append(c)

    _add(_normalize(even, n))
    if quant is not None:
        qarr = np.asarray(quant, np.float64)
        for t in blends:
            _add(_normalize(np.round((1 - t) * even + t * qarr), n))
        _add(quant)
    return tuple(cands)
