"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "decode_attention_ref", "che_sums_ref"]


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,Sq,H,D); k/v: (B,Skv,Hk,D) with H % Hk == 0. f32 softmax."""
    b, sq, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, sq, hk, g, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: (B,1,H,D); caches: (B,S,Hk,D); lengths: (B,)."""
    b, _, h, d = q.shape
    hk = k_cache.shape[2]
    g = h // hk
    qg = q.reshape(b, 1, hk, g, d)[:, 0].astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(d))
    mask = jnp.arange(k_cache.shape[1])[None] < lengths[:, None]
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def che_sums_ref(probs, t_candidates):
    """sum_i (1 - exp(-p_i * t_k)) for each candidate k. (K,) f32."""
    p = probs.astype(jnp.float32)[None, :]
    t = t_candidates.astype(jnp.float32)[:, None]
    return jnp.sum(-jnp.expm1(-p * t), axis=1)
