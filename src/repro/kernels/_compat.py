"""Pallas API compatibility shims shared by the kernel modules.

jax >= 0.5 renamed ``pltpu.TPUCompilerParams`` to ``CompilerParams``;
resolve whichever this toolchain provides once, here, so every kernel
lowers on either version.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
