"""Pallas TPU kernels for the perf-critical hot spots.

* flash_attention  — prefill/train blockwise attention (MXU-tiled).
* decode_attention — split-K single-token GQA decode.
* che_solver       — multi-candidate Che fixed-point evaluation (the CAM
                     tuning hot loop; K candidates per HBM pass).

Each kernel ships with ops.py (jit'd wrapper, auto interpret off-TPU) and
ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
