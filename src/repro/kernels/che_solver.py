"""Pallas TPU multi-candidate Che-consistency evaluator (the CAM hot loop).

The paper's tuner solves  C = sum_i (1 - exp(-p_i * T))  once per
(eps, memory-budget, policy) candidate — a memory-bound reduction over the
page-popularity array repeated ~64x by scalar bisection.  TPU adaptation:
evaluate K candidate characteristic times per HBM pass (the p_i block is
loaded into VMEM once and reused for all K exponentials), turning K-1 of
every K passes into pure VPU work.  An interval-subdivision search with K=8
needs ~20 passes for f32 precision vs 64 for scalar bisection — a ~3.2x HBM
traffic reduction on the dominant term.

Grid: (N/block_n,) over the (N/128, 128)-reshaped popularity array; the (1,K)
output tile is revisited by every program ("arbitrary" semantics) and
accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["che_sums", "che_solve"]

_LANES = 128


def _kernel(p_ref, t_ref, o_ref, *, k: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    p = p_ref[...].astype(jnp.float32)                     # (rows, 128)
    t = t_ref[...].astype(jnp.float32)                     # (1, K)
    # (rows, 128, K): one exp per (page, candidate); padded pages have p=0
    # and contribute exactly 0 via expm1.
    contrib = -jnp.expm1(-p[..., None] * t[0][None, None, :])
    o_ref[...] += jnp.sum(contrib, axis=(0, 1))[None, :]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def che_sums(probs, t_candidates, *, block_rows: int = 256,
             interpret: bool = False):
    """sum_i (1 - exp(-p_i * t_k)) for each of K candidates, one HBM pass.

    probs: (N,) float32; t_candidates: (K,). Returns (K,) float32.
    """
    n = probs.shape[0]
    k = t_candidates.shape[0]
    rows = -(-n // _LANES)
    pad = rows * _LANES - n
    p2 = jnp.pad(probs.astype(jnp.float32), (0, pad)).reshape(rows, _LANES)
    row_pad = (-rows) % block_rows
    if row_pad:
        p2 = jnp.pad(p2, ((0, row_pad), (0, 0)))
    t2 = t_candidates.astype(jnp.float32).reshape(1, k)
    grid = ((rows + row_pad) // block_rows,)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(p2, t2)
    return out[0]


@functools.partial(jax.jit, static_argnames=("k", "iters", "interpret"))
def che_solve(probs, capacity, *, k: int = 8, iters: int = 20,
              interpret: bool = False):
    """Solve C = sum_i (1 - exp(-p_i T)) by K-way interval subdivision.

    Each iteration shrinks the bracket by (K+1)x with ONE pass over probs.
    """
    probs = probs.astype(jnp.float32)
    capacity = jnp.asarray(capacity, jnp.float32)
    pmin = jnp.maximum(jnp.min(jnp.where(probs > 0, probs, jnp.inf)), 1e-30)
    hi0 = jnp.maximum(4.0 * capacity / pmin, 1.0)
    # The bracket can span 20+ orders of magnitude (pmin is tiny for zipf
    # popularity), so subdivide in LOG space: each pass cuts the log-range
    # by (K+1)x, converging in ~5 passes where linear subdivision needs 40+.
    lo0 = hi0 * jnp.float32(1e-30)

    def body(_, bracket):
        log_lo, log_hi = bracket
        fracs = jnp.arange(1, k + 1, dtype=jnp.float32) / (k + 1)
        log_ts = log_lo + (log_hi - log_lo) * fracs
        sums = che_sums(probs, jnp.exp(log_ts), interpret=interpret)
        below = sums < capacity                    # monotone increasing in T
        # rightmost candidate still below C bounds the solution from the left
        idx = jnp.sum(below.astype(jnp.int32))     # in [0, K]
        grid_pts = jnp.concatenate([log_lo[None], log_ts, log_hi[None]])
        return grid_pts[idx], grid_pts[idx + 1]

    log_lo, log_hi = jax.lax.fori_loop(
        0, iters, body, (jnp.log(lo0), jnp.log(hi0)))
    return jnp.exp(0.5 * (log_lo + log_hi))
