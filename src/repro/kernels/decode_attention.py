"""Pallas TPU flash-decode: single-query GQA attention over a static KV cache.

Grid: (batch*heads, Skv/block_kv) — split-K over the cache with running
(m, l, acc) scratch, length-masked per batch element.  The q block is a
single row; VMEM traffic is dominated by streaming the KV cache once, which
is exactly the decode roofline (memory-bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["decode_attention"]

_NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_kv: int, seq_kv: int):
    ki = pl.program_id(1)
    n_kv = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                       # (1, D)
    k = k_ref[0].astype(jnp.float32)                       # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (k_pos < len_ref[0, 0]) & (k_pos < seq_kv)
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, block_kv: int = 512,
                     interpret: bool = False):
    """q: (B,1,H,D); caches: (B,S,Hk,D); lengths: (B,). Returns (B,1,H,D)."""
    b, _, h, d = q.shape
    skv, hk = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    block_kv = min(block_kv, skv)
    pad_kv = (-skv) % block_kv
    qq = q.reshape(b * h, 1, d)
    kk = jnp.moveaxis(k_cache, 2, 1).reshape(b * hk, skv, d)
    vv = jnp.moveaxis(v_cache, 2, 1).reshape(b * hk, skv, d)
    if pad_kv:
        kk = jnp.pad(kk, ((0, 0), (0, pad_kv), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, pad_kv), (0, 0)))
    lens = jnp.repeat(lengths.astype(jnp.int32), h).reshape(b * h, 1)
    grid = (b * h, (skv + pad_kv) // block_kv)

    def kv_map(bh, ki):
        return (bh // h) * hk + (bh % h) // g, ki, 0

    kernel = functools.partial(_kernel, scale=1.0 / (d ** 0.5),
                               block_kv=block_kv, seq_kv=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_kv, d), kv_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qq, kk, vv)
    return out.reshape(b, h, 1, d).transpose(0, 2, 1, 3)
