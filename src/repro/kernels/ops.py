"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the wrappers run the kernels in interpret mode when
``interpret=None`` (auto); on TPU they compile natively.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import che_solver as _che
from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa

__all__ = ["flash_attention", "decode_attention", "che_sums", "che_solve"]


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_kv: int = 512, interpret: Optional[bool] = None):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv,
                               interpret=_auto_interpret(interpret))


def decode_attention(q, k_cache, v_cache, lengths, *, block_kv: int = 512,
                     interpret: Optional[bool] = None):
    return _dec.decode_attention(q, k_cache, v_cache, lengths,
                                 block_kv=block_kv,
                                 interpret=_auto_interpret(interpret))


def che_sums(probs, t_candidates, *, interpret: Optional[bool] = None):
    return _che.che_sums(probs, t_candidates,
                         interpret=_auto_interpret(interpret))


def che_solve(probs, capacity, *, k: int = 8, iters: int = 20,
              interpret: Optional[bool] = None):
    return _che.che_solve(probs, capacity, k=k, iters=iters,
                          interpret=_auto_interpret(interpret))
