"""Fused PriceTable solve: policy fixed point + sorted/mixed composition +
objective argmin in ONE pallas launch (the DeviceExecutor hot path).

Generalizes ``che_solver.py``'s K-candidates-per-HBM-pass idiom from one
histogram x K characteristic times to K histograms x C capacities: each
grid program loads ONE profile row's popularity histogram into VMEM and
prices ALL of that row's table cells against it — the Che/Fricker
bisection (or the LFU top-C mass) runs lockstep over the row's C
capacities as (C, P) VPU work on the resident block, the policy-aware
sorted-scan model and the mixed composition of
``cache_models.hit_rate_grid`` apply in place, and each program folds its
row's objective minimum into a revisited (1, 1) accumulator tile with a
lowest-cell-id tie-break.  A (knob x split x capacity) table therefore
prices in a single launch — one HBM pass over the histograms, no
per-stage XLA round trips.

Semantics mirror ``cache_models.hit_rate_grid`` branch for branch
(compulsory closed form where ``cap >= N`` in exact int32 compares, zero
below one page, thrash/frequency/compulsory sorted regimes, expected-miss
composition); equivalence is float32-tolerance only (summation order),
pinned by tests/test_engine.py against the host executor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["price_grid", "PAD_ID"]

_LANES = 128
#: Cell id marking a padded (row, slot) cell; valid ids are always below it.
PAD_ID = 2**31 - 1

_F32_COLS = 16   # packed per-row float32 scalars (see _price_kernel)
_I32_COLS = 8    # packed per-row int32 scalars


def _price_kernel(*refs, policy: str, has_sorted: bool, has_write: bool,
                  iters: int, n_in: int):
    """One program = one profile row priced at all its C cells.

    ``policy`` is one of the static ``cache_models.POLICIES`` (the whole
    launch shares one fixed point) or ``"multi"``: each program reads its
    OWN policy id from i32 column 3 (``POLICIES`` order: 0 lru, 1 fifo,
    2 lfu) and selects between the recency bisection and the LFU top-C
    mass — one launch pricing a multi-policy table side by side.

    With ``has_write`` the row's probabilities are the COMBINED read+write
    request stream (the executor folds them before normalizing, mirroring
    ``hit_rate_grid``); the kernel additionally prices the dirty-eviction
    writeback stream at the SAME characteristic time the read fixed point
    already solved — no second bisection — and subtracts it from ``h``, so
    ``(1 - h)`` counts fetches and flushes together.

    Packed scalar columns (one row each per program):
      f32: 0 sample_refs, 1 full_refs, 2 n_distinct, 3 pmin,
           4 sorted_refs, 5 sorted_full_refs, 6 sorted_distinct,
           7 sorted_pinned, 8 objective_scale
      i32: 0 n_distinct, 1 sorted_distinct, 2 sorted_min_capacity,
           3 policy id (read iff policy == "multi")
    """
    ins, outs = refs[:n_in], refs[n_in:]
    it = iter(ins)
    lfu_read = policy in ("lfu", "multi")
    p = next(it)[...]                                       # (1, P) probs
    sp = next(it)[...] if lfu_read else None                # (1, P) desc
    cov = (next(it)[...] if (has_sorted and lfu_read)
           else None)                                       # (1, P) desc
    w = next(it)[...] if has_write else None                # (1, P) wprobs
    wq = (next(it)[...] if (has_write and lfu_read)
          else None)                                        # (1, P) by -p
    f = next(it)[...]                                       # (1, 16) f32
    z = next(it)[...]                                       # (1, 8) i32
    caps_f = next(it)[...]                                  # (1, C)
    caps_i = next(it)[...]                                  # (1, C)
    ids = next(it)[...]                                     # (1, C)
    h_ref, bv_ref, bi_ref = outs

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        bv_ref[...] = jnp.full_like(bv_ref, jnp.inf)
        bi_ref[...] = jnp.full_like(bi_ref, jnp.int32(PAD_ID))

    sample_refs, full, n_f, pmin = f[0, 0], f[0, 1], f[0, 2], f[0, 3]
    n_i = z[0, 0]
    c_eff = jnp.maximum(caps_f, 1.0)                        # (1, C)
    c_t = c_eff.T                                           # (C, 1)

    # -- policy fixed point, lockstep over the row's C capacities ----------
    pol_id = z[0, 3] if policy == "multi" else None
    if policy in ("lru", "fifo", "multi"):
        hi = jnp.maximum(4.0 * c_t / pmin, 1.0)
        lo = jnp.zeros_like(hi)

        def occ(t):                                         # (C, 1) -> (C, P)
            if policy == "lru":
                return -jnp.expm1(-p * t)
            if policy == "fifo":
                return p * t / (1.0 - p + p * t)
            # multi: per-program scalar select between the recency forms
            # (the bisected objective stays monotone either way)
            return jnp.where(pol_id == 0, -jnp.expm1(-p * t),
                             p * t / (1.0 - p + p * t))

        def body(_, st):
            lo, hi = st
            mid = 0.5 * (lo + hi)
            val = jnp.sum(occ(mid), axis=1, keepdims=True) - c_t
            lo = jnp.where(val < 0.0, mid, lo)
            hi = jnp.where(val < 0.0, hi, mid)
            return lo, hi

        lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
        t_c = 0.5 * (lo + hi)
        h_pol = jnp.sum(p * occ(t_c), axis=1, keepdims=True).T   # (1, C)
    if policy in ("lfu", "multi"):                          # lfu: top-C mass
        iota = jax.lax.broadcasted_iota(jnp.int32, (caps_i.shape[1],
                                                    p.shape[1]), 1)
        mask = iota < jnp.maximum(caps_i, 1).T              # (C, P)
        h_lfu = jnp.sum(jnp.where(mask, sp, 0.0), axis=1,
                        keepdims=True).T
        h_pol = (h_lfu if policy == "lfu"
                 else jnp.where(pol_id == 2, h_lfu, h_pol))

    floor = 0.0
    if has_write:
        # dirty-eviction writeback at the SAME t_c / top-C set the read
        # solve produced (cache_models._writeback_terms, lockstep over C)
        w_mass = jnp.sum(w)
        if policy in ("lru", "fifo", "multi"):
            r = jnp.maximum(p - w, 0.0)
            dirty = w + r * -jnp.expm1(-w * t_c)            # (C, P)
            wb = jnp.sum((1.0 - occ(t_c)) * dirty, axis=1,
                         keepdims=True).T                   # (1, C)
        if lfu_read:
            wiota = jax.lax.broadcasted_iota(
                jnp.int32, (caps_i.shape[1], p.shape[1]), 1)
            kept = jnp.sum(jnp.where(wiota < jnp.maximum(caps_i, 1).T,
                                     wq, 0.0), axis=1, keepdims=True).T
            wb_lfu = w_mass - kept
            wb = (wb_lfu if policy == "lfu"
                  else jnp.where(pol_id == 2, wb_lfu, wb))
        h_pol = h_pol - wb
        floor = -w_mass                 # cap < 1: every write flushes

    h_comp = jnp.where(full > 0, (full - n_f) / jnp.maximum(full, 1.0), 0.0)
    h = jnp.where(caps_i >= n_i, h_comp, h_pol)
    h = jnp.where(caps_i < 1, floor, h)
    h = jnp.where(sample_refs > 0, h, 0.0)

    # -- sorted-scan model + mixed composition (hit_rate_grid tail) --------
    if has_sorted:
        s_r, s_full, s_n, pinned = f[0, 4], f[0, 5], f[0, 6], f[0, 7]
        s_n_i, s_min_i = z[0, 1], z[0, 2]
        if policy in ("lru", "fifo"):
            miss = jnp.zeros_like(caps_f) + s_n
        else:
            iota = jax.lax.broadcasted_iota(jnp.int32, (caps_i.shape[1],
                                                        p.shape[1]), 1)
            topc = jnp.sum(jnp.where(iota < caps_i.T, cov, 0.0), axis=1,
                           keepdims=True).T
            freq = jnp.clip(jnp.minimum(s_r - topc, s_r - pinned), s_n, s_r)
            miss = jnp.where(caps_i >= s_n_i, s_n, freq)
            if policy == "multi":   # recency rows take the compulsory form
                miss = jnp.where(pol_id == 2, miss,
                                 jnp.zeros_like(caps_f) + s_n)
        thrash = jnp.clip(s_r - pinned, s_n, s_r)
        miss = jnp.where(caps_i < s_min_i, thrash, miss)
        h_s = jnp.where(s_r > 0, (s_r - miss) / jnp.maximum(s_r, 1.0), 0.0)
        total = full + s_full
        miss_mix = (1.0 - h) * full + (1.0 - h_s) * s_full
        h = jnp.where(total > 0, 1.0 - miss_mix / jnp.maximum(total, 1.0),
                      0.0)

    h_ref[...] = h

    # -- objective + argmin folded into the revisited accumulator tile -----
    obj = jnp.where(ids < PAD_ID, (1.0 - h) * f[0, 8], jnp.inf)
    minv = jnp.min(obj)
    minid = jnp.min(jnp.where(obj == minv, ids, jnp.int32(PAD_ID)))
    prev_v, prev_i = bv_ref[0, 0], bi_ref[0, 0]
    better = (minv < prev_v) | ((minv == prev_v) & (minid < prev_i))
    bv_ref[0, 0] = jnp.where(better, minv, prev_v)
    bi_ref[0, 0] = jnp.where(better, minid, prev_i)


@functools.partial(jax.jit, static_argnames=("policy", "has_sorted",
                                             "has_write", "iters",
                                             "interpret"))
def price_grid(policy: str, probs, sorted_probs, cov_desc, f32s, i32s,
               caps_f, caps_i, ids, wprobs=None, wprobs_q=None, *,
               has_sorted: bool, has_write: bool = False, iters: int = 64,
               interpret: bool = False):
    """Price a (K rows x C cells-per-row) padded table in one launch.

    Args:
      policy: a ``cache_models.POLICIES`` name (uniform launch) or
        ``"multi"`` — each row reads its own policy id from i32 column 3,
        so one launch prices lru/fifo/lfu rows side by side.
      probs: (K, P) float32 request probabilities per profile row —
        COMBINED read+write stream when ``has_write`` (the caller folds
        write counts into the histogram before normalizing).
      sorted_probs: (K, P) descending-sorted ``probs`` (read iff lfu or
        multi).
      cov_desc: (K, P) descending-sorted sorted-scan coverage (read iff
        (lfu or multi) AND ``has_sorted``).
      f32s / i32s: (K, 16) / (K, 8) packed per-row scalars (layout in
        :func:`_price_kernel`).
      caps_f / caps_i / ids: (K, C) per-cell capacities (float32 /
        exact int32) and global cell ids; padded cells carry
        ``caps_i = -1`` and ``ids = PAD_ID``.
      wprobs: (K, P) write-reference probabilities under the SAME combined
        normalizer (read iff ``has_write``).
      wprobs_q: (K, P) ``wprobs`` permuted by descending combined ``probs``
        (the LFU resident set's order; read iff ``has_write`` and lfu or
        multi).

    Returns:
      (h (K, C) float32, best_val (1, 1) float32, best_id (1, 1) int32) —
      ``best_id`` is the global objective argmin over valid cells
      (lowest id on ties, i.e. first cell in table order).
    """
    k, p_width = probs.shape
    c = caps_f.shape[1]
    if has_write and wprobs is None:
        raise ValueError("has_write=True needs wprobs (and wprobs_q for "
                         "lfu/multi launches)")
    pad_p = (-p_width) % _LANES
    pad_c = (-c) % _LANES
    if pad_p:
        probs = jnp.pad(probs, ((0, 0), (0, pad_p)))
        sorted_probs = jnp.pad(sorted_probs, ((0, 0), (0, pad_p)))
        cov_desc = jnp.pad(cov_desc, ((0, 0), (0, pad_p)))
        if has_write:
            wprobs = jnp.pad(wprobs, ((0, 0), (0, pad_p)))
            if wprobs_q is not None:
                wprobs_q = jnp.pad(wprobs_q, ((0, 0), (0, pad_p)))
    if pad_c:
        caps_f = jnp.pad(caps_f, ((0, 0), (0, pad_c)),
                         constant_values=-1.0)
        caps_i = jnp.pad(caps_i, ((0, 0), (0, pad_c)), constant_values=-1)
        ids = jnp.pad(ids, ((0, 0), (0, pad_c)), constant_values=PAD_ID)
    pp, cc = p_width + pad_p, c + pad_c

    inputs, in_specs = [probs], [pl.BlockSpec((1, pp), lambda i: (i, 0))]
    if policy in ("lfu", "multi"):
        inputs.append(sorted_probs)
        in_specs.append(pl.BlockSpec((1, pp), lambda i: (i, 0)))
    if has_sorted and policy in ("lfu", "multi"):
        inputs.append(cov_desc)
        in_specs.append(pl.BlockSpec((1, pp), lambda i: (i, 0)))
    if has_write:
        inputs.append(wprobs)
        in_specs.append(pl.BlockSpec((1, pp), lambda i: (i, 0)))
        if policy in ("lfu", "multi"):
            inputs.append(wprobs_q)
            in_specs.append(pl.BlockSpec((1, pp), lambda i: (i, 0)))
    inputs += [f32s, i32s, caps_f, caps_i, ids]
    in_specs += [
        pl.BlockSpec((1, _F32_COLS), lambda i: (i, 0)),
        pl.BlockSpec((1, _I32_COLS), lambda i: (i, 0)),
        pl.BlockSpec((1, cc), lambda i: (i, 0)),
        pl.BlockSpec((1, cc), lambda i: (i, 0)),
        pl.BlockSpec((1, cc), lambda i: (i, 0)),
    ]

    h, best_val, best_id = pl.pallas_call(
        functools.partial(_price_kernel, policy=policy,
                          has_sorted=has_sorted, has_write=has_write,
                          iters=iters, n_in=len(inputs)),
        grid=(k,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, cc), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, cc), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*inputs)
    return h[:, :c], best_val, best_id
