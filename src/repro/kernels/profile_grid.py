"""TPU-native mixed-eps occupancy: the device half of the profiling side.

``core/page_ref.py::point_page_refs_mixed_eps_grid`` — the §V-C grouped
mixture-histogram kernel behind every RMI branch-grid profile — is
deliberately host-side: one LUT-row gather plus one weighted
``np.bincount`` per eps class, which beats XLA CPU scatters ~10x but caps
tuning-loop and drift-retune scale exactly where the ROADMAP's
"device-resident tuning fabric, leg 2" says it does.  This module is the
TPU-native counterpart: per-eps-class page occupancy as banded ONE-HOT
MATMULS over device-resident position arrays, so the histograms are born
in HBM and can chain straight into the fused pricing kernel
(``kernels/price_grid.py``) without ever visiting the host.

The factorization replaces both host gathers with MXU contractions.  With
queries grouped by pow2 leaf-eps class exactly like the host path
(``page_ref.mixed_eps_class_codes`` — the SAME helper), stack every
class's Eq. 12 LUT, centered on the grid-wide max radius D, into one

    lutstack[d, c * C_ipp + s] = LUT_c[s, d - (D - D_c)]      (W, n_c*C_ipp)

and encode each query as the combined key ``code * C_ipp + slot``.  Then
for one candidate row and one query tile:

    SEL[cs, q] = [key_q == cs]              one-hot     (n_c*C_ipp, QT)
    T1         = lutstack @ SEL             banded mass (W, QT)
    counts[page_q + d] += T1[d, q]          for d in [0, W)

and the scatter in the last line is itself W one-hot matmuls
``T1[d] @ [page_q + d == j]`` — no gathers, no scatters, pure iota
compares and MXU work.  Padded queries carry key -1 and never match.

The output is the SAME padded ``(K, P + 2D)`` layout the host kernel
accumulates into (out-of-range window mass lands in the pad and is
sliced off); :func:`point_page_refs_mixed_eps_grid` mirrors the host
function's signature and slicing exactly.  Equivalence: exact for
integer-mass inputs (every LUT entry 0 or 1 — f32 sums of integers), and
float32-tolerance otherwise; pinned host-vs-device by
tests/test_kernels.py across families x policies x workloads.

Grid = (K rows, page tiles, query tiles); each program owns one
candidate row x one page-tile block of the padded histogram and
accumulates its query tiles into the revisited block (zero-initialized on
the first visit), so VMEM stays bounded whatever the workload size.
Interpret mode off-TPU via the shared ``kernels.ops._auto_interpret``
rule.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import page_ref
from repro.kernels import ops as kernel_ops
from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["profile_grid", "point_page_refs_mixed_eps_grid"]

_LANES = 128
_SUBLANES = 8
_Q_TILE = 512        # queries resident per program
_P_TILE = 2048       # padded-histogram columns per program


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _occupancy_kernel(keys_ref, pages_ref, lut_ref, out_ref, *,
                      width: int, n_cc: int, q_tile: int, p_tile: int):
    """One program = one candidate row x one page tile x one query tile."""
    pt_i = pl.program_id(1)
    qt_i = pl.program_id(2)

    @pl.when(qt_i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]                                    # (1, QT) int32
    pages = pages_ref[...]                                  # (1, QT) int32
    lut = lut_ref[...]                                      # (Wp, CCp) f32

    # one-hot over the combined (class, slot) key; pad queries (key -1)
    # match nothing, so their T1 column is zero and they contribute nothing
    sel = (jax.lax.broadcasted_iota(jnp.int32, (n_cc, q_tile), 0)
           == keys).astype(jnp.float32)
    t1 = jnp.dot(lut, sel, preferred_element_type=jnp.float32)  # (Wp, QT)

    page_col = pages.T                                      # (QT, 1)
    base = (jax.lax.broadcasted_iota(jnp.int32, (q_tile, p_tile), 1)
            + pt_i * p_tile)                                # global column
    acc = out_ref[...]
    for d in range(width):
        oh = (base == page_col + d).astype(jnp.float32)     # (QT, PT)
        acc = acc + jnp.dot(t1[d:d + 1, :], oh,
                            preferred_element_type=jnp.float32)
    out_ref[...] = acc


@functools.partial(jax.jit,
                   static_argnames=("width", "pad", "interpret"))
def profile_grid(keys, pages, lutstack, *, width: int, pad: int,
                 interpret: bool = False) -> jnp.ndarray:
    """Banded one-hot occupancy of a whole candidate grid in one launch.

    Args:
      keys: (K, Q) int32 combined ``class_code * C_ipp + slot`` per query
        (per candidate row); padded queries carry -1.
      pages: (1, Q) int32 shared query pages (any value where keys == -1).
      lutstack: (W', CC') float32 stacked per-class LUTs, centered on the
        grid-wide max radius (layout in the module docstring); W' / CC'
        may carry zero padding to sublane / lane multiples.
      width: the true band width ``2 * max_radius + 1`` (<= W').
      pad: the true padded histogram width ``num_pages + 2 * max_radius``.

    Returns:
      (K, pad) float32 — the SAME padded layout the host kernel
      accumulates into; callers slice ``[:, D : D + num_pages]``.
    """
    k, q = keys.shape
    q_tile = min(_Q_TILE, _ceil_to(q, _LANES))
    qp = _ceil_to(q, q_tile)
    p_tile = min(_P_TILE, _ceil_to(pad, _LANES))
    pp = _ceil_to(pad, p_tile)
    if qp > q:
        keys = jnp.pad(keys, ((0, 0), (0, qp - q)), constant_values=-1)
        pages = jnp.pad(pages, ((0, 0), (0, qp - q)), constant_values=-1)
    n_cc = int(lutstack.shape[1])

    out = pl.pallas_call(
        functools.partial(_occupancy_kernel, width=width, n_cc=n_cc,
                          q_tile=q_tile, p_tile=p_tile),
        grid=(k, pp // p_tile, qp // q_tile),
        in_specs=[
            pl.BlockSpec((1, q_tile), lambda i, p, t: (i, t)),
            pl.BlockSpec((1, q_tile), lambda i, p, t: (0, t)),
            pl.BlockSpec(lutstack.shape, lambda i, p, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p_tile), lambda i, p, t: (i, p)),
        out_shape=jax.ShapeDtypeStruct((k, pp), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(keys, pages, lutstack)
    return out[:, :pad]


def _lut_stack(class_eps, c_ipp: int, max_radius: int) -> np.ndarray:
    """Stack per-class Eq. 12 LUTs centered on the grid-wide max radius.

    Centering reproduces the host kernel's ``base + (D - D_c) + d'``
    offset arithmetic: class c's width-``2*D_c+1`` band sits at rows
    ``[D - D_c, D + D_c]`` of the shared width-``2*D+1`` band, and all
    other rows are zero — so one uniform ``page + d`` target rule serves
    every class.
    """
    width = 2 * max_radius + 1
    wp = _ceil_to(width, _SUBLANES)
    ccp = _ceil_to(len(class_eps) * c_ipp, _LANES)
    stack = np.zeros((wp, ccp), np.float32)
    for ci, eps in enumerate(class_eps):
        radius = page_ref.lut_radius(eps, c_ipp)
        lut = page_ref._point_lut_np(eps, c_ipp)       # (C_ipp, 2*D_c+1)
        off = max_radius - radius
        stack[off:off + 2 * radius + 1,
              ci * c_ipp:(ci + 1) * c_ipp] = lut.T.astype(np.float32)
    return stack


def point_page_refs_mixed_eps_grid(
    positions: np.ndarray,
    eps_rows: np.ndarray,
    c_ipp: int,
    num_pages: int,
    *,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, np.ndarray]:
    """Device counterpart of ``page_ref.point_page_refs_mixed_eps_grid``.

    Same signature, same grouping (one shared class-code pass through
    ``page_ref.mixed_eps_class_codes``), same padded-accumulate-then-slice
    semantics — but the histograms are computed on-device and RETURNED as a
    device array, so a caller chaining into the fused pricing kernel never
    round-trips them through the host.

    Returns (counts (K, num_pages) float32 device array, totals (K,)
    float64 host array) — shapes and meaning identical to the host kernel.
    """
    positions = np.asarray(positions, np.int64)
    eps_rows = np.maximum(np.asarray(eps_rows, np.int64), 1)
    k, q_n = eps_rows.shape
    if positions.shape[0] != q_n:
        raise ValueError(f"eps_rows has {q_n} columns for "
                         f"{positions.shape[0]} positions")
    page = (positions // c_ipp).astype(np.int32)
    slot = (positions - page.astype(np.int64) * c_ipp).astype(np.int32)
    max_radius = page_ref.lut_radius(int(eps_rows.max()), c_ipp)
    pad = num_pages + 2 * max_radius

    codes, classes = page_ref.mixed_eps_class_codes(eps_rows.ravel())
    present = np.flatnonzero(np.bincount(codes))
    class_eps = [page_ref.mixed_eps_class_eps(c, classes) for c in present]
    # dense-rank the (possibly sparse) codes into lutstack column groups
    dense = np.searchsorted(present, codes.astype(np.int64)).astype(np.int32)
    keys = dense.reshape(k, q_n) * np.int32(c_ipp) + slot[None, :]

    padded = profile_grid(
        jnp.asarray(keys), jnp.asarray(page[None, :]),
        jnp.asarray(_lut_stack(class_eps, c_ipp, max_radius)),
        width=2 * max_radius + 1, pad=pad,
        interpret=kernel_ops._auto_interpret(interpret))
    counts = padded[:, max_radius:max_radius + num_pages]
    totals = np.asarray(jnp.sum(counts, axis=1), np.float64)
    return counts, totals
