"""Pallas TPU flash attention (forward): blockwise streaming softmax.

Grid: (batch*heads, Sq/block_q, Skv/block_kv) with the KV axis innermost
("arbitrary" semantics) — each (bh, qi) tile revisits its output while the
(m, l, acc) running-softmax state lives in VMEM scratch.  GQA is handled in
the k/v index_map (query head -> kv head).

BlockSpec tiling: q (1, block_q, D), k/v (1, block_kv, D), out (1, block_q, D).
With the default 512/512 blocks and D<=128 the VMEM working set
(q+k+v+p+acc in f32) is ~3.5 MB — comfortably inside the 16 MB v5e VMEM with
double buffering.

Validated in interpret mode against ref.flash_attention_ref (this container
is CPU-only); on TPU the same kernel replaces the lax.scan blockwise path via
``Recipe(attn_impl="pallas")``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_kv: int,
            seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal early-out: a KV block strictly above the diagonal contributes
    # nothing — skip its MXU work entirely.  Recovers the ~2x "causal waste"
    # the lax.scan blockwise path pays (EXPERIMENTS.md §Roofline: prefill
    # useful/HLO 0.56-0.76), which XLA cannot skip with static shapes.
    live = (not causal) or (ki * block_kv <= qi * block_q + block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # (bq, D)
        k = k_ref[0].astype(jnp.float32)                   # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_kv
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def _kv_index_map(h: int, hk: int):
    g = h // hk

    def index_map(bh, qi, ki):
        batch = bh // h
        head = bh % h
        return batch * hk + head // g, ki, 0

    return index_map


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_kv: int = 512, interpret: bool = False):
    """q: (B,Sq,H,D); k/v: (B,Skv,Hk,D). Returns (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    skv, hk = k.shape[1], k.shape[2]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    qq = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kk = jnp.moveaxis(k, 2, 1).reshape(b * hk, skv, d)
    vv = jnp.moveaxis(v, 2, 1).reshape(b * hk, skv, d)
    if pad_q:
        qq = jnp.pad(qq, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kk = jnp.pad(kk, ((0, 0), (0, pad_kv), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, pad_kv), (0, 0)))
    grid = (b * h, (sq + pad_q) // block_q, (skv + pad_kv) // block_kv)

    kernel = functools.partial(
        _kernel, scale=1.0 / (d ** 0.5), causal=causal,
        block_q=block_q, block_kv=block_kv, seq_kv=skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, d), _kv_index_map(h, hk)),
            pl.BlockSpec((1, block_kv, d), _kv_index_map(h, hk)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qq, kk, vv)
    out = out[:, :sq].reshape(b, h, sq, d)
    return jnp.moveaxis(out, 1, 2)
