"""Gradient compression for the slow cross-pod (DCN) axis.

int8 quantization with error feedback: each pod quantizes its local gradient
(plus the carried quantization residual), the int8 payloads cross the DCN via
an explicit shard_map all-gather (4x fewer wire bytes than an f32 all-reduce),
and every pod dequantizes + averages locally.  The residual ``ef`` makes the
compression unbiased over time (error-feedback SGD).

Used by train_step when ``recipe.compress_pod_grads`` and the mesh has a
"pod" axis; the byte reduction is directly visible to the roofline collective
parser (§Perf).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["compressed_pod_mean", "quantize_int8", "dequantize_int8"]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _pod_gather_mean(leaf: jnp.ndarray, ef: jnp.ndarray, n_pods: int):
    """Runs INSIDE shard_map over the 'pod' axis: local quantize -> int8
    all-gather across pods -> dequantized mean; returns new error residual."""
    local = leaf + ef
    q, s = quantize_int8(local)
    deq_local = dequantize_int8(q, s)
    new_ef = local - deq_local
    q_all = jax.lax.all_gather(q, "pod")            # (n_pods, ...) int8 wire
    s_all = jax.lax.all_gather(s, "pod")            # (n_pods,) f32
    mean = jnp.tensordot(s_all / n_pods,
                         q_all.astype(jnp.float32), axes=([0], [0]))
    return mean, new_ef


def compressed_pod_mean(grads: Any, ef: Any, mesh) -> Tuple[Any, Any]:
    """Average per-pod gradients across the 'pod' axis with int8 payloads.

    ``grads`` leaves must be identical in sharding across pods except for the
    pod axis itself (i.e. per-pod partial gradients).  ``ef`` matches grads.
    """
    n_pods = mesh.shape["pod"]
    auto = frozenset(n for n in mesh.axis_names if n != "pod")

    def one(g, e):
        fn = jax.shard_map(
            lambda gg, ee: _pod_gather_mean(gg, ee, n_pods),
            mesh=mesh,
            in_specs=(P("pod"), P("pod")),
            out_specs=(P(), P("pod")),
            check_vma=False,
            axis_names=frozenset({"pod"}),
        )
        # leaves enter with a leading per-pod axis (n_pods, ...)
        return fn(g, e)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_ef = jax.tree.unflatten(tdef, [o[1] for o in out])
    return means, new_ef
