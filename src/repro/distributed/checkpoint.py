"""Sharded checkpointing: atomic, resumable, mesh-portable.

Format: one ``.npz`` per host (this container: one) holding flattened
key-path -> array entries, plus ``meta.json`` with the step and tree layout.
Writes go to a temp directory renamed into place (atomic on POSIX), so a
failure mid-save never corrupts the latest checkpoint.  Restore returns
host numpy trees; ``elastic.reshard`` places them onto any mesh — the
checkpoint is sharding-agnostic (elastic re-scaling = restore + new specs).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "flatten_tree", "unflatten_tree"]

_SEP = "/"


def flatten_tree(tree) -> Dict[str, np.ndarray]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
        else:
            flat[prefix] = np.asarray(node)

    walk("", tree)
    return flat


def unflatten_tree(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    tree: Dict[str, Any] = {}
    for path, arr in flat.items():
        parts = path.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree


def save_checkpoint(ckpt_dir: str, step: int, trees: Dict[str, Any],
                    extra_meta: Optional[dict] = None) -> str:
    """trees: e.g. {"params": ..., "opt_state": ...} (nested dicts/arrays)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    for name, tree in trees.items():
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        np.savez(os.path.join(tmp, f"{name}.npz"), **flatten_tree(host))
    meta = {"step": int(step), "time": time.time(), "trees": sorted(trees),
            **(extra_meta or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "meta.json"))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None
                       ) -> Tuple[int, Dict[str, Any]]:
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    trees = {}
    for name in meta["trees"]:
        with np.load(os.path.join(path, f"{name}.npz")) as z:
            trees[name] = unflatten_tree({k: z[k] for k in z.files})
    return int(meta["step"]), trees
