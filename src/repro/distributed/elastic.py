"""Elastic re-scaling: restore any checkpoint onto any mesh.

Checkpoints are sharding-agnostic (global arrays per key path), so scaling
from N to M devices is: restore -> rebuild PartitionSpecs for the new mesh
via the same ParamDef templates -> device_put.  Dims that no longer divide
the new axis group fall back automatically inside ShardingCtx._resolve, so a
recipe tuned for 256 chips loads cleanly on 8 (degraded parallelism, same
math) — the elastic-scaling path a preempted-pod restart takes.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Recipe, ShardingCtx, tree_shardings
from repro.models import params as params_mod

__all__ = ["reshard_params", "reshard_tree"]


def reshard_tree(host_tree, shardings):
    """device_put a host (numpy) tree onto the sharding tree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jnp.asarray(x), s) if s is not None
        else jnp.asarray(x),
        host_tree, shardings)


def reshard_params(host_params: Dict[str, Any], cfg: ModelConfig,
                   mesh, recipe: Recipe):
    """Place restored params onto a (possibly different) mesh."""
    ctx = ShardingCtx(mesh, recipe)
    defs = params_mod.param_defs(cfg)
    shardings = tree_shardings(ctx, defs)
    return reshard_tree(host_params, shardings)
