"""Distributed runtime: sharding recipes, checkpointing, fault tolerance,
elastic re-meshing, gradient compression."""
from repro.distributed import sharding

__all__ = ["sharding"]
