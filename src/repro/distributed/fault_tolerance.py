"""Fault-tolerant training supervision: checkpoint/restart, failure
detection, straggler mitigation.

The ``Supervisor`` wraps any step callable.  On a (real or injected) failure
it restores the latest checkpoint and replays the data pipeline to the
restored step — the data pipeline is a pure function of the step index, so
replay is exact.  Straggler mitigation tracks a robust step-time EMA and
flags steps exceeding ``straggler_factor``x the median; the mitigation hook
(re-dispatch on a real cluster, recorded + skipped-backup here) is pluggable.

At 1000+ nodes the same structure holds: per-host checkpoint shards, a
coordinator watching heartbeats, and deterministic step->batch mapping for
replay; see DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.distributed import checkpoint as ckpt_mod

__all__ = ["SimulatedFailure", "FailureInjector", "Supervisor"]


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / preemption in tests."""


@dataclasses.dataclass
class FailureInjector:
    """Raises SimulatedFailure at the given global steps (once each)."""

    fail_at: tuple = ()
    delays: Dict[int, float] = dataclasses.field(default_factory=dict)
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.delays:
            time.sleep(self.delays[step])
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerStats:
    times: List[float] = dataclasses.field(default_factory=list)
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float, factor: float) -> bool:
        self.times.append(seconds)
        if len(self.times) >= 5:
            med = sorted(self.times[-50:])[len(self.times[-50:]) // 2]
            if seconds > factor * med:
                self.flagged.append(step)
                return True
        return False


class Supervisor:
    """Run a step function with checkpoint/restart + straggler tracking."""

    def __init__(self, step_fn: Callable, state: Dict[str, Any],
                 batch_for_step: Callable[[int], Any], ckpt_dir: str,
                 ckpt_every: int = 50, max_restarts: int = 5,
                 straggler_factor: float = 3.0,
                 injector: Optional[FailureInjector] = None,
                 on_straggler: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.state = state                 # {"params":..., "opt_state":...}
        self.batch_for_step = batch_for_step
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.on_straggler = on_straggler
        self.straggler_factor = straggler_factor
        self.stats = StragglerStats()
        self.restarts = 0
        self.start_step = 0

    def _save(self, step: int):
        ckpt_mod.save_checkpoint(self.ckpt_dir, step, self.state)

    def _restore(self) -> int:
        step, trees = ckpt_mod.restore_checkpoint(self.ckpt_dir)
        self.state = trees
        return step

    def run(self, num_steps: int) -> Dict[str, Any]:
        step = self.start_step
        if ckpt_mod.latest_step(self.ckpt_dir) is not None:
            step = self._restore()          # auto-resume
        if step == 0:
            self._save(0)
        losses = []
        while step < num_steps:
            t0 = time.perf_counter()
            try:
                if self.injector:
                    self.injector.check(step)
                batch = self.batch_for_step(step)
                self.state, metrics = self.step_fn(self.state, batch)
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                step = self._restore()      # roll back + replay pipeline
                continue
            dt = time.perf_counter() - t0
            if self.stats.observe(step, dt, self.straggler_factor):
                if self.on_straggler:
                    self.on_straggler(step)
            losses.append(float(metrics.get("loss", 0.0)))
            step += 1
            if step % self.ckpt_every == 0:
                self._save(step)
        self._save(num_steps)
        return {"losses": losses, "restarts": self.restarts,
                "stragglers": list(self.stats.flagged), "final_step": step}
