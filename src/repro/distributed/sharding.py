"""Logical-axis sharding: recipes, param definitions, and the sharding context.

A ``Recipe`` maps logical dimension roles to mesh axes (MaxText-style rules
table).  Dims are only sharded when evenly divisible by the axis-group size —
XLA GSPMD rejects uneven *input* shardings — with automatic fallback to the
largest feasible prefix of the axis group, then to replication.

Roles:
  weights:     "fsdp" (d_model/storage dim), "tp" (heads*head_dim / d_ff /
               vocab), "ep" (experts), None (replicated: norms, small vectors)
  activations: "batch", "seq" (sequence parallelism), "heads", "kv_seq"
               (decode-cache length), None
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["Recipe", "ParamDef", "ShardingCtx", "axis_group_size"]


@dataclasses.dataclass(frozen=True)
class Recipe:
    """Distribution recipe — the hillclimbing knobs live here."""

    batch_axes: Tuple[str, ...] = ("data",)
    fsdp_axes: Tuple[str, ...] = ("data",)
    tp_axes: Tuple[str, ...] = ("model",)
    ep_axes: Tuple[str, ...] = ("model",)
    seq_axes: Tuple[str, ...] = ()            # activation sequence parallelism
    act_embed_axes: Tuple[str, ...] = ()      # weight-stationary decode: shard
                                              # the residual's d_model instead
                                              # of gathering weights per layer
    kv_batch_axes: Optional[Tuple[str, ...]] = None  # cache batch (defaults
                                              # to batch_axes)
    kv_seq_axes: Tuple[str, ...] = ("model",)
    remat: str = "block"                      # none | block | nested
    microbatch: int = 1                       # gradient-accumulation steps
    grad_dtype: str = "float32"               # gradient accumulation dtype
    kv_cache_dtype: str = "bfloat16"          # bfloat16 | int8 (decode cache)
    param_dtype: str = "float32"              # master param storage (train)
    unroll_microbatches: bool = False         # python loop vs lax.scan accum
    attn_impl: str = "blockwise"              # blockwise | dense | pallas
    block_kv: int = 1024
    compress_pod_grads: bool = False
    moment_dtype: Optional[str] = None        # override cfg.opt_moment_dtype
    scan_layers: bool = True

    def role_axes(self, role: Optional[str]) -> Tuple[str, ...]:
        return {
            None: (),
            "fsdp": self.fsdp_axes,
            "tp": self.tp_axes,
            "ep": self.ep_axes,
            "batch": self.batch_axes,
            "seq": self.seq_axes,
            "heads": self.tp_axes,
            "act_embed": self.act_embed_axes,
            "kv_batch": (self.kv_batch_axes if self.kv_batch_axes is not None
                         else self.batch_axes),
            "kv_seq": self.kv_seq_axes,
        }[role]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Shape + logical roles + initializer for one parameter tensor."""

    shape: Tuple[int, ...]
    dims: Tuple[Optional[str], ...]     # role per dim ("fsdp"/"tp"/"ep"/None)
    init: str = "normal"                # normal | zeros | ones
    scale: float = -1.0                 # -1 -> 1/sqrt(fan_in) heuristic

    def fan_in(self) -> int:
        return self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]


def axis_group_size(mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


class ShardingCtx:
    """Threads (mesh, recipe) through model code; no-ops when mesh is None."""

    def __init__(self, mesh=None, recipe: Recipe = Recipe()):
        self.mesh = mesh
        self.recipe = recipe

    # -- spec construction -------------------------------------------------
    def _resolve(self, size: int, role: Optional[str]):
        if self.mesh is None or role is None:
            return None
        axes = self.recipe.role_axes(role)
        while axes:
            group = axis_group_size(self.mesh, axes)
            if group > 1 and size % group == 0:
                return axes if len(axes) > 1 else axes[0]
            axes = axes[:-1]  # drop trailing axis, retry with smaller group
        return None

    def spec(self, shape: Tuple[int, ...], dims: Tuple[Optional[str], ...]) -> P:
        assert len(shape) == len(dims), (shape, dims)
        entries = [self._resolve(s, d) for s, d in zip(shape, dims)]
        # one mesh axis may appear at most once in a spec: drop duplicates
        used = set()
        clean = []
        for e in entries:
            names = e if isinstance(e, tuple) else (e,) if e else ()
            if any(n in used for n in names):
                clean.append(None)
            else:
                used.update(names)
                clean.append(e)
        return P(*clean)

    def sharding(self, shape, dims) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(shape, dims))

    # -- activation constraints --------------------------------------------
    def constrain(self, x, *dims):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.spec(x.shape, tuple(dims)))


def tree_specs(ctx: ShardingCtx, defs: Dict[str, Any]):
    """Map a (nested) dict of ParamDef to PartitionSpecs."""
    return jax.tree.map(
        lambda d: ctx.spec(d.shape, d.dims),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def tree_shardings(ctx: ShardingCtx, defs):
    return jax.tree.map(
        lambda d: ctx.sharding(d.shape, d.dims),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )
