"""The pricing engine: one profile -> solve -> argmin pipeline.

Every session (tuning, serving retune, sharded fleet search, join-tree
cost curves, plain grid estimation) builds a :class:`PriceTable` and hands
it to a :class:`PricingEngine`; interchangeable executors —
:class:`~repro.engine.host.HostExecutor` (golden reference) and
:class:`~repro.engine.device.DeviceExecutor` (fused pallas launch) — do
the solving.  See ``docs/architecture.md`` ("The pricing engine").
"""
from repro.engine.host import HostExecutor
from repro.engine.table import PriceSolution, PriceTable, PricingEngine

__all__ = ["PriceTable", "PriceSolution", "PricingEngine", "HostExecutor",
           "DeviceExecutor"]


def __getattr__(name):
    # DeviceExecutor pulls in the pallas kernel stack; keep it lazy so
    # host-only use never touches kernels at import time.
    if name == "DeviceExecutor":
        from repro.engine.device import DeviceExecutor
        return DeviceExecutor
    raise AttributeError(name)
