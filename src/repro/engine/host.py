"""HostExecutor — the golden-reference PriceTable executor.

One delegation: ``CostSession.solve_profiles`` (one batched
``hit_rate_grid`` dispatch over the gathered rows).  This IS the
pre-engine code path, so results are bit-identical to the legacy
per-session table assembly — the equivalence suite pins the fused
DeviceExecutor against it.
"""
from __future__ import annotations

import numpy as np

__all__ = ["HostExecutor"]


class HostExecutor:
    """Solve a PriceTable through the session's batched host pipeline."""

    name = "host"

    def solve(self, engine, table, row_scale):
        # Looked up on the session instance so monkeypatched counters
        # (class- or instance-level) keep observing the one solve call.
        # Multi-policy tables hand the per-cell policy column through;
        # solve_profiles groups by policy internally (one hit_rate_grid
        # dispatch per distinct policy), still ONE solve_profiles call.
        h, n_distinct = engine.cost.solve_profiles(
            table.profiles, table.caps, rows=table.rows,
            policies=table.pols)
        # No device-side argmin: the engine ranks on the host.
        return (np.asarray(h, np.float64),
                np.asarray(n_distinct, np.float64), None)
