"""The PriceTable IR and the PricingEngine behind every session's solve.

CAM's value proposition is pricing whole candidate tables — (knob x split x
capacity x policy) — without trace replay.  Before this layer, each session
re-implemented the same pipeline around ``grid_profiles``/``solve_profiles``:
table layout, row/capacity indexing, and objective argmin.  The engine names
the pieces once:

* :class:`PriceTable` — the canonical table IR: ``rows[t]`` names the
  :class:`~repro.core.session.GridProfiles` row cell ``t`` prices, ``caps[t]``
  its capacity, ``fracs[t]`` the budget fraction it realizes, ``spans`` each
  knob's contiguous ``[a, b)`` cell range.  Builders cover every session's
  table shape: :meth:`from_profiles` (the tuner's joint knob x split grid,
  and — with ``index_in_split=True`` — the sharded fleet's per-shard share
  tables), :meth:`max_capacity` (plain grid estimation: one cell per knob at
  its full-budget capacity), :meth:`from_cells` (explicit capacity curves,
  the join-tree shape), :meth:`concat` (many tables solved as one), and
  :meth:`subset` (slice a solved table back out — the sharded winner
  rehydration).
* :class:`PricingEngine` — profile -> solve -> argmin behind ONE call:
  ``engine.price(table)`` returns a :class:`PriceSolution` with per-cell hit
  rates, I/O, seconds, the objective vector and its argmin.  ``calls``
  counts engine invocations, which is what the sessions' "one solve per
  search" structural tests assert against.

Two interchangeable executors do the solving:

* ``"host"`` — :class:`~repro.engine.host.HostExecutor`, the golden
  reference: delegates to ``CostSession.solve_profiles`` (one batched
  ``hit_rate_grid``), bit-identical to the pre-engine sessions.
* ``"device"`` — :class:`~repro.engine.device.DeviceExecutor`, the fused
  pallas path: histograms stay device-resident and the policy fixed point,
  the sorted/mixed composition and the objective argmin run in one kernel
  launch (float32-equivalent; interpret mode off-TPU).

Dispatch rule: an explicit ``executor=`` argument wins, then the
``REPRO_ENGINE_EXECUTOR`` environment variable (``host`` / ``device``), then
the engine's constructor default, then auto — ``device`` on a TPU backend,
``host`` everywhere else (mirroring ``kernels.ops._auto_interpret``).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["PriceTable", "PriceSolution", "PricingEngine"]


@dataclasses.dataclass(frozen=True)
class PriceTable:
    """The assembled solve table — pure arrays, NO model calls.

    One cell per enumerated (knob, buffer-capacity) pair over one
    :class:`~repro.core.session.GridProfiles`.  Tables concatenate (cells
    are independent), which is how the sharded fleet search solves every
    (boundary x shard x knob x budget-share) cell of ALL its per-shard
    tables in ONE engine call.
    """

    rows: np.ndarray
    caps: np.ndarray
    fracs: np.ndarray
    spans: Dict[object, Tuple[int, int]]
    points_of: Dict[object, Dict[str, object]]
    profiles: Optional[object] = None      # GridProfiles the rows index into
    #: Per-cell eviction-policy ids indexing ``cache_models.POLICIES``
    #: (-1 = the pricing session's configured policy).  ``None`` — the
    #: default for every builder — means every cell prices under the
    #: session policy; :meth:`cross_policies` fills the column in.
    pols: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return int(self.rows.shape[0])

    # ------------------------------------------------------------- builders
    @classmethod
    def from_profiles(cls, profiles, points, *, splits, budget_bytes,
                      page_bytes, index_in_split: bool = False,
                      include_max_split: bool = True) -> "PriceTable":
        """The joint (knob x split) table — pure array assembly, NO solves.

        Default semantics (the single-node tuner): each split fraction
        ``f`` names a BUFFER slice ``floor(f * M / B)`` pages, enumerated
        per knob when it undercuts that knob's maximal feasible capacity;
        the maximal split (all memory the index does not claim) is listed
        first so objective ties resolve toward the larger buffer.

        ``index_in_split=True`` is the fleet semantics the sharded search
        uses: ``f`` is a shard's share of the FLEET budget and must house
        the shard's index AND its buffer, so the cell capacity is
        ``floor((f * M - size) / B)`` — infeasible shares (< 1 page) are
        dropped rather than clamped.  ``include_max_split=False`` skips
        the implicit maximal-split row (a fleet shard can never take the
        whole pool; its candidate shares are exactly ``splits``).
        """
        row_of = {kn: i for i, kn in enumerate(profiles.knobs)}
        rows, caps, fracs, spans = [], [], [], {}
        points_of = {}
        for knob, pt in points.items():
            if knob not in row_of:
                continue                   # profile-skipped (typed reason)
            i = row_of[knob]
            size = float(profiles.sizes[i])
            cap_max = int(profiles.caps[i])
            start = len(rows)
            if include_max_split:
                # Maximal split first: objective ties resolve to the largest
                # buffer, reproducing the legacy always-max-split tuners.
                rows.append(i)
                caps.append(cap_max)
                fracs.append((budget_bytes - size) / budget_bytes)
            for f in splits:
                if index_in_split:
                    c = int((f * budget_bytes - size) // page_bytes)
                    ok = c >= 1 and (not include_max_split or c < cap_max)
                else:
                    c = int(f * budget_bytes // page_bytes)
                    ok = 1 <= c < cap_max  # c >= cap_max: index won't fit
                if ok:
                    rows.append(i)
                    caps.append(c)
                    fracs.append(f)
            if len(rows) > start:
                spans[knob] = (start, len(rows))
                points_of[knob] = pt
        return cls(np.asarray(rows, np.int64), np.asarray(caps, np.int64),
                   np.asarray(fracs, np.float64), spans, points_of, profiles)

    @classmethod
    def max_capacity(cls, profiles,
                     budget_bytes: Optional[float] = None) -> "PriceTable":
        """One cell per knob at its full-budget capacity (``profiles.caps``)
        — the plain grid-estimation table (``CostSession.estimate_grid``)."""
        k = len(profiles.knobs)
        sizes = np.asarray(profiles.sizes, np.float64)
        fracs = ((budget_bytes - sizes) / budget_bytes
                 if budget_bytes else np.ones(k, np.float64))
        return cls(np.arange(k, dtype=np.int64),
                   np.asarray(profiles.caps, np.int64),
                   np.asarray(fracs, np.float64),
                   {kn: (i, i + 1) for i, kn in enumerate(profiles.knobs)},
                   {kn: {} for kn in profiles.knobs}, profiles)

    @classmethod
    def from_cells(cls, profiles, cells: Sequence[Tuple[object, int,
                                                        np.ndarray]]
                   ) -> "PriceTable":
        """Explicit (knob, profile row, capacity vector) cells — the
        capacity-curve shape (a join-tree level priced at every candidate
        pool share)."""
        rows, caps, spans, points_of = [], [], {}, {}
        for knob, row, cvec in cells:
            cvec = np.asarray(cvec, np.int64).ravel()
            start = len(rows)
            rows.extend([int(row)] * cvec.shape[0])
            caps.extend(cvec.tolist())
            spans[knob] = (start, len(rows))
            points_of[knob] = {}
        return cls(np.asarray(rows, np.int64), np.asarray(caps, np.int64),
                   np.zeros(len(rows), np.float64), spans, points_of,
                   profiles)

    def cross_policies(self, policies: Sequence[str]) -> "PriceTable":
        """Replicate every cell per eviction policy — policy becomes a knob.

        The p-th copy's cells carry policy id ``POLICIES.index(p)``; spans
        are re-keyed ``(policy, knob)`` and each knob point gains a
        ``"policy"`` entry, so the downstream argmin / ``TuneResult``
        treats the eviction policy exactly like any other knob axis.  One
        engine call then prices lru/fifo/lfu side-by-side — on the
        ``DeviceExecutor`` in ONE fused launch (the kernel's ``"multi"``
        mode selects the fixed point per row by policy id).
        """
        from repro.core.cache_models import POLICIES
        policies = tuple(policies)
        if not policies:
            raise ValueError("cross_policies needs at least one policy")
        unknown = [p for p in policies if p not in POLICIES]
        if unknown:
            raise ValueError(f"unknown policies {unknown!r}; expected a "
                             f"subset of {POLICIES}")
        if len(set(policies)) != len(policies):
            raise ValueError(f"duplicate policies in {policies!r}")
        if self.pols is not None:
            raise ValueError("table already carries policy ids; "
                             "cross_policies must start from a plain table")
        n = len(self)
        reps = len(policies)
        spans, points_of = {}, {}
        for j, p in enumerate(policies):
            for kn, (a, b) in self.spans.items():
                spans[(p, kn)] = (a + j * n, b + j * n)
                points_of[(p, kn)] = dict(self.points_of[kn], policy=p)
        pols = np.repeat(np.asarray([POLICIES.index(p) for p in policies],
                                    np.int16), n)
        return PriceTable(np.tile(self.rows, reps), np.tile(self.caps, reps),
                          np.tile(self.fracs, reps), spans, points_of,
                          self.profiles, pols)

    # ---------------------------------------------------------- composition
    @classmethod
    def concat(cls, tables: Sequence["PriceTable"]) -> "PriceTable":
        """Concatenate tables over ONE shared ``GridProfiles`` — the
        sharded fleet shape: every per-shard table's cells price in a
        single engine call.  Knob keys must be globally unique."""
        tables = list(tables)
        if not tables:
            return cls(np.zeros(0, np.int64), np.zeros(0, np.int64),
                       np.zeros(0, np.float64), {}, {}, None)
        prof = tables[0].profiles
        if any(t.profiles is not prof for t in tables):
            raise ValueError("concat needs tables over one shared "
                             "GridProfiles (solve alignment)")
        spans, points_of, off = {}, {}, 0
        for t in tables:
            for kn, (a, b) in t.spans.items():
                if kn in spans:
                    raise ValueError(f"duplicate knob key {kn!r} across "
                                     "concatenated tables")
                spans[kn] = (a + off, b + off)
                points_of[kn] = t.points_of[kn]
            off += len(t)
        if all(t.pols is None for t in tables):
            pols = None
        else:
            # -1 (session default) fills plain tables so mixed concats keep
            # every cell's policy semantics
            pols = np.concatenate([
                t.pols if t.pols is not None
                else np.full(len(t), -1, np.int16) for t in tables])
        return cls(np.concatenate([t.rows for t in tables]),
                   np.concatenate([t.caps for t in tables]),
                   np.concatenate([t.fracs for t in tables]),
                   spans, points_of, prof, pols)

    def subset(self, sel) -> "PriceTable":
        """Slice cells back out of a (possibly concatenated) table.

        Each selected cell becomes a singleton span keyed by its owning
        knob — the sharded winner rehydration: after the fleet argmin picks
        a budget share, the cells at that share form a one-split-per-knob
        sub-table that ``finish_from_solution`` turns into a TuneResult.
        """
        sel = np.asarray(sel, np.int64)
        knob_of = {}
        for kn, (a, b) in self.spans.items():
            for t in range(a, b):
                knob_of[t] = kn
        return PriceTable(
            rows=self.rows[sel], caps=self.caps[sel], fracs=self.fracs[sel],
            spans={knob_of[int(t)]: (k, k + 1) for k, t in enumerate(sel)},
            points_of={knob_of[int(t)]: self.points_of[knob_of[int(t)]]
                       for t in sel},
            profiles=self.profiles,
            pols=None if self.pols is None else self.pols[sel])


@dataclasses.dataclass(frozen=True)
class PriceSolution:
    """One executor pass over a :class:`PriceTable` — all arrays cell-aligned.

    ``best_cell`` is the global objective argmin (first cell on ties, i.e.
    table order — which ``from_profiles``' max-split-first layout makes the
    largest buffer, reproducing the legacy tuners' tie-break).
    """

    table: PriceTable
    hit_rates: np.ndarray            # (T,) float64
    distinct: np.ndarray             # (T,) float64 distinct pages
    io: np.ndarray                   # (T,) (1 - h) * E[DAC] per query
    seconds: np.ndarray              # (T,) device-model objective
    objective: np.ndarray            # (T,) the ranked objective values
    objective_name: str
    best_cell: int
    executor: str

    def subset(self, sel) -> "PriceSolution":
        """The solution slice aligned with ``table.subset(sel)``."""
        sel = np.asarray(sel, np.int64)
        obj = self.objective[sel]
        return PriceSolution(
            self.table.subset(sel), self.hit_rates[sel], self.distinct[sel],
            self.io[sel], self.seconds[sel], obj, self.objective_name,
            int(np.argmin(obj)) if obj.shape[0] else -1, self.executor)


class PricingEngine:
    """profile -> solve -> argmin behind ONE call, bound to a CostSession.

    ``executor`` pins an executor for every ``price`` call (``"host"`` /
    ``"device"`` / an executor instance); ``None`` resolves per call — the
    ``REPRO_ENGINE_EXECUTOR`` env var if set, else ``device`` on a TPU
    backend and ``host`` everywhere else.  ``calls`` counts ``price``
    invocations: every session runs exactly one per search, structurally
    asserted in the test suite.
    """

    def __init__(self, cost, executor=None):
        self.cost = cost
        self.executor = executor
        self.calls = 0
        self._instances: Dict[str, object] = {}

    # ------------------------------------------------------------- dispatch
    def _resolve(self, executor):
        if executor is None:
            executor = os.environ.get("REPRO_ENGINE_EXECUTOR") or None
        if executor is None:
            executor = self.executor
        if executor is None:
            import jax
            executor = "device" if jax.default_backend() == "tpu" else "host"
        if not isinstance(executor, str):
            return executor
        if executor not in self._instances:
            if executor == "host":
                from repro.engine.host import HostExecutor
                self._instances[executor] = HostExecutor()
            elif executor == "device":
                from repro.engine.device import DeviceExecutor
                self._instances[executor] = DeviceExecutor()
            else:
                raise ValueError(f"unknown executor {executor!r}; expected "
                                 "'host' or 'device'")
        return self._instances[executor]

    # ---------------------------------------------------------------- price
    def price(self, table: PriceTable, *, objective: str = "io",
              executor=None) -> PriceSolution:
        """Solve every cell of ``table`` and rank by ``objective``.

        ``objective`` is ``"io"`` (expected physical I/Os per query,
        Eq. 15/16) or ``"seconds"`` (device-model-aware, §III-A
        composition).  Custom callable objectives stay downstream
        (``CamTuner.finish_from_solution`` evaluates them over the
        returned per-cell entries — still zero model calls).
        """
        if table.profiles is None:
            raise ValueError("PriceTable has no profiles attached; build it "
                             "with a GridProfiles (from_profiles / "
                             "max_capacity / from_cells)")
        if len(table) == 0:
            raise ValueError("cannot price an empty PriceTable")
        if objective not in ("io", "seconds"):
            raise ValueError(f"unknown objective {objective!r}; expected "
                             "'io' or 'seconds'")
        profiles = table.profiles
        dacs = np.asarray(profiles.dacs, np.float64)
        device = self.cost.system.device
        if device is None:
            run_cost = dacs
        else:
            run_cost = np.asarray([float(device.cost([d])) for d in dacs])
        row_scale = run_cost if objective == "seconds" else dacs

        exec_obj = self._resolve(executor)
        self.calls += 1
        h, n_distinct, best = exec_obj.solve(self, table, row_scale)
        h = np.asarray(h, np.float64)
        n_distinct = np.asarray(n_distinct, np.float64)
        io = (1.0 - h) * dacs[table.rows]
        seconds = io if device is None else (1.0 - h) * run_cost[table.rows]
        obj = io if objective == "io" else seconds
        if best is None:
            best = int(np.argmin(obj))
        return PriceSolution(table, h, n_distinct, io, seconds, obj,
                             objective, int(best), exec_obj.name)
