"""DeviceExecutor — the fused, device-resident PriceTable executor.

Marshals a PriceTable into the padded (row x cell-slot) layout of
``kernels/price_grid.py`` and solves the whole table in one pallas
launch: histograms stay device-resident, the policy fixed point, the
sorted/mixed composition and the objective argmin fuse into a single
kernel (interpret mode off-TPU, via the same auto rule as the other
kernels).  Preprocessing mirrors ``CostSession.solve_profiles`` exactly —
zero-part substitution for sorted composition, the compulsory-equivalent
coverage surrogate for legacy coverage-less parts, exact int32 capacity
clamps — so results are float32-equivalent to the HostExecutor (pinned by
tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.session import SortedScanPart, _compulsory_coverage
from repro.kernels import ops as kernel_ops
from repro.kernels import price_grid as _pg

__all__ = ["DeviceExecutor"]

_CAP_MAX = 2**31 - 129   # matches core.session._exact_cap_array


def _exact_i32(values) -> np.ndarray:
    arr = np.floor(np.asarray(values, np.float64))
    return np.clip(arr, -1, _CAP_MAX).astype(np.int32)


class DeviceExecutor:
    """Solve a PriceTable through the fused price-grid kernel."""

    name = "device"

    def __init__(self, interpret: Optional[bool] = None):
        self.interpret = interpret

    def solve(self, engine, table, row_scale):
        from repro.core.cache_models import POLICIES
        profiles = table.profiles
        rows = np.asarray(table.rows, np.int64)
        t = rows.shape[0]

        # ---- per-cell policies: group by (profile row, policy) ----------
        # A kernel program owns ONE fixed point, so multi-policy tables
        # split a profile row into one program per policy it prices under;
        # single-policy tables reduce to the plain per-row grouping.
        base_code = POLICIES.index(engine.cost.system.policy)
        if table.pols is None:
            cell_pols = np.full(t, base_code, np.int64)
        else:
            cell_pols = np.asarray(table.pols, np.int64)
            cell_pols = np.where(cell_pols < 0, base_code, cell_pols)
        ukeys, inv = np.unique(rows * len(POLICIES) + cell_pols,
                               return_inverse=True)
        urows = ukeys // len(POLICIES)
        upols = (ukeys % len(POLICIES)).astype(np.int32)
        k = urows.shape[0]
        upol_set = set(upols.tolist())
        policy = (POLICIES[upol_set.pop()] if len(upol_set) == 1
                  else "multi")

        # ---- cell layout: group cells by profile row, keep table order --
        per_row = np.bincount(inv, minlength=k)
        c_max = int(per_row.max())
        order = np.argsort(inv, kind="stable")
        starts = np.zeros(k, np.int64)
        starts[1:] = np.cumsum(per_row)[:-1]
        slot = np.empty(t, np.int64)
        slot[order] = np.arange(t) - starts[inv[order]]

        caps_i = np.full((k, c_max), -1, np.int32)
        ids = np.full((k, c_max), _pg.PAD_ID, np.int32)
        caps_i[inv, slot] = _exact_i32(table.caps)
        ids[inv, slot] = np.arange(t, dtype=np.int32)
        caps_f = caps_i.astype(np.float32)

        # ---- per-row statistics (solve_profiles preprocessing) ----------
        counts = profiles.counts[jnp.asarray(urows)]            # (K, P)
        num_pages = int(profiles.counts.shape[1])
        sample_f = np.asarray(profiles.totals, np.float64)[urows]
        sample_f = sample_f.astype(np.float32)
        full_f = sample_f * np.float32(profiles.scale)
        wps = ([profiles.wparts[i] for i in urows]
               if profiles.wparts else [])
        has_write = any(wp is not None for wp in wps)
        if has_write:
            # fold the write stream into the request histogram BEFORE
            # normalizing (hit_rate_grid order): writes fault their pages
            # like reads, and probs/n_distinct/pmin describe the mix.
            zero_w = jnp.zeros((num_pages,), jnp.float32)
            w_counts = jnp.stack(
                [jnp.asarray(wp.counts, jnp.float32) if wp is not None
                 else zero_w for wp in wps])
            w_refs = np.asarray([wp.total_refs if wp is not None else 0.0
                                 for wp in wps], np.float32)
            counts = counts + w_counts
            sample_f = sample_f + w_refs
            full_f = full_f + w_refs * np.float32(profiles.scale)
        probs = counts / jnp.maximum(
            jnp.asarray(sample_f)[:, None], 1e-30)
        nd_i = np.asarray(jnp.sum(counts > 0, axis=1), np.int64)
        pmin = np.asarray(jnp.maximum(
            jnp.min(jnp.where(probs > 0, probs, jnp.inf), axis=1), 1e-30),
            np.float32)
        scale = np.asarray(row_scale, np.float64)[urows].astype(np.float32)

        sparts = [profiles.sparts[i] for i in urows]
        has_sorted = any(sp is not None for sp in sparts)
        surrogate = {}
        f32s = np.zeros((k, _pg._F32_COLS), np.float32)
        i32s = np.zeros((k, _pg._I32_COLS), np.int32)
        f32s[:, 0], f32s[:, 1] = sample_f, full_f
        f32s[:, 2] = nd_i.astype(np.float32)
        f32s[:, 3], f32s[:, 8] = pmin, scale
        i32s[:, 0] = _exact_i32(nd_i)
        i32s[:, 3] = upols                  # read iff policy == "multi"

        dummy = jnp.zeros((k, 1), jnp.float32)
        cov = cov_desc = dummy
        if has_sorted:
            zero = SortedScanPart(0.0, 0.0, 1,
                                  jnp.zeros((num_pages,), jnp.float32), 0.0)
            sps = [sp if sp is not None else zero for sp in sparts]
            for i, sp in enumerate(sps):
                if sp.coverage is None:
                    surrogate[i] = sp.distinct_pages
                    sps[i] = dataclasses.replace(
                        sp, coverage=_compulsory_coverage(sp, num_pages))
            f32s[:, 4] = [sp.total_refs for sp in sps]
            f32s[:, 5] = f32s[:, 4] * np.float32(profiles.scale)
            i32s[:, 1] = _exact_i32([sp.distinct_pages for sp in sps])
            f32s[:, 6] = i32s[:, 1].astype(np.float32)
            f32s[:, 7] = [sp.pinned_retouches for sp in sps]
            i32s[:, 2] = _exact_i32([sp.min_capacity for sp in sps])
            cov = jnp.stack([jnp.asarray(sp.coverage, jnp.float32)
                             for sp in sps])
            if policy in ("lfu", "multi"):
                cov_desc = -jnp.sort(-cov, axis=1)
        sorted_probs = (-jnp.sort(-probs, axis=1)
                        if policy in ("lfu", "multi") else dummy)
        wprobs = wprobs_q = None
        if has_write:
            wprobs = w_counts / jnp.maximum(
                jnp.asarray(sample_f)[:, None], 1e-30)
            if policy in ("lfu", "multi"):
                # the LFU resident set is the top-C of the COMBINED stream;
                # permute write mass into that order (argsort tie-break
                # matches cache_models._writeback_terms)
                wprobs_q = jnp.take_along_axis(
                    wprobs, jnp.argsort(-probs, axis=1), axis=1)

        # ---- one fused launch -------------------------------------------
        h2, _, best_id = _pg.price_grid(
            policy, probs, sorted_probs, cov_desc,
            jnp.asarray(f32s), jnp.asarray(i32s), jnp.asarray(caps_f),
            jnp.asarray(caps_i), jnp.asarray(ids), wprobs, wprobs_q,
            has_sorted=has_sorted, has_write=has_write,
            interpret=kernel_ops._auto_interpret(self.interpret))
        h = np.asarray(h2, np.float64)[inv, slot]

        # ---- distinct pages (host-side closed forms, as solve_profiles) -
        if has_sorted:
            nd_row = np.asarray(
                jnp.sum((counts > 0) | (cov > 0), axis=1), np.float64)
            for i, true_n in surrogate.items():
                nd_row[i] = float(nd_i[i]) + true_n
        else:
            nd_row = nd_i.astype(np.float64)

        best = int(np.asarray(best_id)[0, 0])
        return h, nd_row[inv], (best if best < _pg.PAD_ID else None)
