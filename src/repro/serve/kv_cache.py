"""Paged KV-cache block pool with host offload (vLLM-style paging, CAM-sized).

The HBM pool holds ``num_blocks`` KV blocks of ``block_tokens`` tokens each;
overflow blocks live in host memory and are fetched on reference.  Logical
block references come from decode attention (every live request touches its
context blocks each step) and prefix-shared blocks are hot across requests —
exactly the buffered-disk structure of the paper, with HBM as the page buffer
and PCIe/DMA as the "disk".  ``serve/planner.py`` sizes this pool with the
CAM machinery; this module is the runtime that the planner's predictions are
validated against (tests replay real traces through it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core import replay as replay_mod

__all__ = ["PagedKVPool", "BlockTrace"]


@dataclasses.dataclass
class PagedKVPool:
    """Accounting model of the HBM block pool (eviction + transfer stats)."""

    num_blocks: int
    block_tokens: int
    bytes_per_block: int
    policy: str = "lru"

    def __post_init__(self):
        self.buffer = replay_mod.make_buffer(self.policy, self.num_blocks)
        self.logical_refs = 0
        self.host_fetches = 0

    def reference(self, block_id: int) -> bool:
        self.logical_refs += 1
        hit = self.buffer.access(block_id)
        if not hit:
            self.host_fetches += 1
        return hit

    @property
    def transfer_bytes(self) -> int:
        return self.host_fetches * self.bytes_per_block

    @property
    def hit_rate(self) -> float:
        return 1.0 - self.host_fetches / max(self.logical_refs, 1)


class BlockTrace:
    """Builds logical block-reference traces for decode workloads.

    Requests share a common prefix of ``shared_prefix`` tokens (system
    prompt / few-shot header) and then diverge; every decode step references
    all context blocks of the scheduled request (attention reads the whole
    KV), so hot shared blocks dominate — the popularity skew CAM models.
    """

    def __init__(self, block_tokens: int):
        self.block_tokens = block_tokens
        self._next_private = 1_000_000

    def request_blocks(self, shared_prefix: int, private_len: int,
                       request_id: int) -> List[int]:
        n_shared = shared_prefix // self.block_tokens
        n_private = -(-private_len // self.block_tokens)
        shared = list(range(n_shared))
        private = [self._private_id(request_id, i) for i in range(n_private)]
        return shared + private

    def _private_id(self, request_id: int, i: int) -> int:
        return 1_000_000 + request_id * 10_000 + i

    def decode_trace(self, schedule: List[Tuple[int, int, int]]
                     ) -> List[int]:
        """schedule: [(request_id, shared_prefix, context_len)] per decode
        step (round-robin batched decode); returns the flat block refs."""
        refs: List[int] = []
        for rid, shared, ctx in schedule:
            refs.extend(self.request_blocks(shared, ctx, rid))
        return refs
