"""Serving plane: batched engine, paged KV pool, CAM-guided pool planner."""
from repro.serve import engine, kv_cache, planner

__all__ = ["engine", "kv_cache", "planner"]
