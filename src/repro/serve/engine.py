"""Batched serving engine: prefill + greedy decode loop over the unified
model API.  Runs real generation for CPU-sized models (examples/serve demo)
and carries the CAM-planned paged-KV accounting for long-context offload.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import Recipe, ShardingCtx
from repro.models import model as model_mod

__all__ = ["ServeEngine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # (B, prompt + generated)
    prefill_seconds: float
    decode_seconds: float
    steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, mesh=None,
                 recipe: Recipe = Recipe(remat="none"), max_seq: int = 512):
        self.cfg = cfg
        self.params = params
        self.ctx = ShardingCtx(mesh, recipe)
        self.max_seq = max_seq
        self._decode = jax.jit(
            lambda p, b, c: model_mod.decode_fn(p, cfg, b, c, self.ctx))
        self._prefill = jax.jit(
            lambda p, b: model_mod.prefill_fn(p, cfg, b, self.ctx))

    def _empty_cache(self, batch: int):
        shape = ShapeSpec("serve", "decode", self.max_seq, batch)
        sds = model_mod.cache_specs(self.cfg, shape)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16
                 ) -> GenerationResult:
        """prompts: (B, S0) int32 (audio: (B, S0, C)). Greedy decoding."""
        b = prompts.shape[0]
        s0 = prompts.shape[1]
        t0 = time.perf_counter()
        logits, prefill_cache = self._prefill(self.params,
                                              {"tokens": jnp.asarray(prompts)})
        t_prefill = time.perf_counter() - t0

        cache = self._empty_cache(b)
        cache = _splice_prefill(cache, prefill_cache, self.cfg)
        lengths = jnp.full((b,), s0, jnp.int32)
        audio = self.cfg.family == "audio"
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B[,C])

        out = [jnp.asarray(prompts)]
        t0 = time.perf_counter()
        for _ in range(max_new_tokens):
            tok = next_tok[:, None] if not audio else next_tok[:, None, :]
            out.append(tok)
            logits, cache = self._decode(
                self.params, {"tokens": tok, "lengths": lengths}, cache)
            lengths = lengths + 1
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_decode = time.perf_counter() - t0
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens, t_prefill, t_decode, max_new_tokens)


def _splice_prefill(cache, prefill_cache, cfg: ModelConfig):
    """Copy prefill KV/state into the (larger) decode cache buffers."""
    def splice(dst, src):
        if dst.ndim >= 3 and src.ndim == dst.ndim and src.shape != dst.shape:
            # seq-extended buffers: write src into the leading slice
            sl = tuple(slice(0, s) for s in src.shape)
            return dst.at[sl].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    return jax.tree.map(splice, cache, prefill_cache)
