"""CAM-guided KV-pool planning (the paper's Eq. 15 applied to serving).

Decision: under an HBM budget M shared between resident weights and the KV
block pool, choose the block size theta that minimizes expected host-transfer
bytes per decode step:

    Cost(theta; M) = (1 - h(pool_blocks(theta))) * E[refs(theta)] * bytes(theta)

 — the exact analogue of Cost_CAM = (1 - h(M - M_idx)) * E[DAC]: block size
plays epsilon's role (bigger blocks -> fewer, larger transfers and fewer pool
slots), the pool plays the page buffer, and h comes from the SAME
cache_models estimators (Che/Fricker/LFU), fed by the block-popularity
distribution implied by the request mix.  No trace replay needed — the
popularity distribution is derived structurally from (shared_prefix,
context-length distribution), like CAM derives page popularity from index
geometry.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cache_models

__all__ = ["RequestMix", "block_popularity", "plan_kv_pool", "PlanResult"]


@dataclasses.dataclass(frozen=True)
class RequestMix:
    """Decode workload description (no trace needed)."""

    n_requests: int
    shared_prefix: int            # tokens shared by every request
    mean_context: int             # private context tokens per request
    decode_steps: int             # scheduled decode steps per request
    kv_bytes_per_token: int       # 2 * L * Hk * Dh * bytes


def block_popularity(mix: RequestMix, block_tokens: int
                     ) -> Tuple[np.ndarray, float]:
    """(Pr_req over distinct blocks, logical refs per decode step).

    Each decode step of any request references all shared blocks + its own
    private blocks; shared blocks are referenced by every request.
    """
    n_shared = mix.shared_prefix // block_tokens
    n_private = -(-mix.mean_context // block_tokens)
    shared_refs = np.full(max(n_shared, 0), float(mix.n_requests))
    private_refs = np.full(n_private * mix.n_requests, 1.0)
    counts = np.concatenate([shared_refs, private_refs])
    total = counts.sum()
    refs_per_step = n_shared + n_private     # per scheduled request step
    return counts / max(total, 1e-30), float(refs_per_step)


def structural_hit_rate(mix: RequestMix, block_tokens: int,
                        pool_blocks: int) -> float:
    """Closed-form hit rate for ROUND-ROBIN decode scheduling.

    The paper's §III-C insight transfers: batched decode references private
    blocks cyclically (period = n_requests * private_blocks), and cyclic
    streams make IRM estimators overestimate — LRU/FIFO get ~zero reuse on a
    cycle longer than capacity (Belady), while the shared prefix stays
    resident.  So, beyond compulsory misses:

      * shared refs hit iff pool >= n_shared (they recur every step),
      * private refs hit iff the whole cycle fits the remaining pool.

    Validated against PagedKVPool replay in tests/test_serve.py — the IRM
    (Che) estimate is ~0.19 too high on this trace; this closed form lands
    within ~0.03.
    """
    n_shared = mix.shared_prefix // block_tokens
    n_private = -(-mix.mean_context // block_tokens)
    cycle = mix.n_requests * n_private
    refs_shared = n_shared * mix.n_requests * mix.decode_steps
    refs_private = n_private * mix.n_requests * mix.decode_steps
    total = refs_shared + refs_private
    hits = 0.0
    if pool_blocks >= n_shared:
        hits += max(refs_shared - n_shared, 0)        # one compulsory each
    if pool_blocks - n_shared >= cycle:
        hits += max(refs_private - cycle, 0)
    return hits / max(total, 1)


@dataclasses.dataclass
class PlanResult:
    block_tokens: int
    pool_blocks: int
    hit_rate: float
    transfer_bytes_per_step: float
    candidates: Dict[int, float]


def plan_kv_pool(mix: RequestMix, hbm_budget_bytes: float,
                 weight_bytes: float,
                 block_candidates: Sequence[int] = (16, 32, 64, 128, 256),
                 policy: str = "lru",
                 scheduling: str = "round_robin") -> PlanResult:
    pool_budget = max(hbm_budget_bytes - weight_bytes, 0.0)
    best = None
    cands: Dict[int, float] = {}
    for bt in block_candidates:
        bytes_per_block = bt * mix.kv_bytes_per_token
        pool_blocks = int(pool_budget // bytes_per_block)
        if pool_blocks < 1:
            continue
        probs, refs_per_step = block_popularity(mix, bt)
        n_distinct = probs.shape[0]
        if pool_blocks >= n_distinct:
            h = 1.0   # everything resident after compulsory fill
        elif scheduling == "round_robin":
            h = structural_hit_rate(mix, bt, pool_blocks)
        else:  # irm: random scheduling / no cyclic structure
            h = float(cache_models.hit_rate(
                policy, pool_blocks, jnp.asarray(probs, jnp.float32),
                total_requests=refs_per_step * mix.n_requests * mix.decode_steps,
                distinct_pages=n_distinct))
        cost = (1.0 - h) * refs_per_step * bytes_per_block
        cands[bt] = cost
        if best is None or cost < cands[best[0]]:
            best = (bt, pool_blocks, h, cost)
    if best is None:
        raise ValueError("HBM budget too small for any block size")
    return PlanResult(best[0], best[1], best[2], best[3], cands)
