"""JoinSession — plan-based join API on the CostSession nouns (paper §VI).

The paper frames the hybrid join as "the same modeling principle" applied to
joins; this module makes that literal.  A :class:`JoinSession` binds the two
session nouns the estimation side already uses — an
:class:`~repro.core.session.IndexModel` for the inner relation and a
:class:`~repro.core.session.System` for where it runs — and splits the join
into the classic planner/executor pair:

* ``plan(outer, strategy)``   -> :class:`JoinPlan`: typed segments plus a
  model-predicted :class:`~repro.core.session.PlanCost`.  The four classic
  strategies (INLJ / point-only / range-only / hybrid) are all just plans —
  the pure strategies are single-segment degenerate cases of the hybrid
  partitioning.
* ``execute(plan)``           -> :class:`JoinStats`: ONE execution path
  replays any plan through the simulated buffered disk.
* ``choose(outer)``           -> :class:`ChooseResult`: CAM-predicted costs
  for every strategy, with the cheapest plan selected *up front* — the
  model drives the plan, it doesn't just report on it afterwards.

Cost predictions compose Eq. 17's fitted coefficients with CAM's cache-aware
miss estimates rather than charging the fitted constants blindly:

* sorted streams price point probing through the shared policy-aware
  sorted-scan model (``cache_models.sorted_scan_misses`` — the same model
  behind ``CostSession``'s sorted branch): one compulsory miss per distinct
  page under recency eviction (Theorem III.1), the frequency-aware closed
  form under LFU-like policies, and the thrash regime when the buffer
  cannot hold a probe window (every logical reference misses);
* the unsorted INLJ stream is priced through the full CostSession IRM
  hit-rate machinery (Algorithm 1) on the outer point workload.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import cache_models, page_ref
from repro.core.session import (CostSession, GridProfiles, PlanCost,
                                SortedScanPart, System)
from repro.core.workload import Workload, locate
from repro.engine import PriceTable
from repro.index.adapters import wrap_index
from repro.join.calibrate import calibrate_system
from repro.join.hybrid import (JoinCostParams, Segment, partition_probes,
                               segment_costs)
from repro.sim.machine import BufferedDisk, MachineParams

__all__ = ["JoinPlan", "JoinStats", "ChooseResult", "JoinCostCurve",
           "JoinSession", "STRATEGIES"]

STRATEGIES = ("inlj", "point-only", "range-only", "hybrid")


@dataclasses.dataclass
class JoinStats:
    """Replayed (ground-truth) execution outcome of one plan."""

    strategy: str
    seconds: float          # simulated end-to-end time
    physical_ios: int
    logical_refs: int
    matches: int
    n_segments: int = 1
    n_range_segments: int = 0
    wall_seconds: float = 0.0


@dataclasses.dataclass(frozen=True)
class JoinPlan:
    """An executable join plan: probe order, page windows, typed segments.

    ``segments`` reuse the Algorithm 2 :class:`Segment` type; pure strategies
    carry exactly one.  ``cost`` is the model prediction this plan was ranked
    by; ``thrash`` records whether the buffer was below the Theorem III.1
    capacity premise when the point-miss terms were priced.
    """

    strategy: str
    outer_keys: np.ndarray            # in probe order (sorted unless inlj)
    page_lo: np.ndarray
    page_hi: np.ndarray
    segments: Tuple[Segment, ...]
    sorted_stream: bool
    cost: PlanCost
    params: JoinCostParams
    capacity: int
    thrash: bool = False

    @property
    def n_range_segments(self) -> int:
        return sum(1 for s in self.segments if s.use_range)


@dataclasses.dataclass(frozen=True)
class JoinCostCurve:
    """Per-strategy predicted cost as a FUNCTION of buffer capacity.

    ``plan`` collapses the model to one scalar at one capacity;
    budget-split solvers (:class:`repro.join.tree.JoinTreeSession`) need the
    whole curve so they can trade capacity between competing levels.  All
    K capacities of one outer stream are priced by ONE
    :class:`repro.engine.PricingEngine` solve over a two-row
    :class:`repro.engine.PriceTable` (the sorted point-probe stream and the
    unsorted INLJ stream, each at every capacity) — never a per-capacity
    Python loop or replay.

    ``seconds[s][k]`` / ``physical_ios[s][k]`` is strategy ``s`` priced at
    ``capacities[k]`` buffer pages.  Curves are non-increasing in capacity
    (more buffer never costs more under the model — the budget-split
    monotonicity tests pin this).
    """

    capacities: np.ndarray                    # (K,) buffer pages
    seconds: Dict[str, np.ndarray]            # strategy -> (K,)
    physical_ios: Dict[str, np.ndarray]       # strategy -> (K,)
    n_probes: int

    def best_at(self, k: int, objective: str = "seconds") -> Tuple[str, float]:
        """(strategy, cost) minimizing ``objective`` at capacity index k."""
        table = self.seconds if objective == "seconds" else self.physical_ios
        s = min(table, key=lambda name: table[name][k])
        return s, float(table[s][k])


@dataclasses.dataclass(frozen=True)
class ChooseResult:
    """Outcome of model-guided strategy selection.

    All candidate plans are retained — executing a non-chosen strategy for
    comparison reuses the plan built during selection instead of re-planning.
    """

    plan: JoinPlan
    costs: Dict[str, PlanCost]        # every strategy's predicted cost
    plans: Dict[str, JoinPlan] = dataclasses.field(default_factory=dict)

    @property
    def strategy(self) -> str:
        return self.plan.strategy


@dataclasses.dataclass(frozen=True)
class _CurveState:
    """Capacity-independent statistics of ONE outer stream — the profiling
    half of :meth:`JoinSession.cost_curve`, split from the pricing half so a
    join tree can batch every level's streams into ONE engine solve."""

    n: int                                 # probe count
    refs: float                            # total window page references
    span: int                              # coalesced range-scan span (pages)
    min_cap: int                           # Thm III.1 capacity premise
    sorted_part: SortedScanPart            # (R, N, coverage, pinned)
    num_pages: int                         # inner relation's page count
    page_lo: np.ndarray                    # sorted probe windows (for the
    page_hi: np.ndarray                    # hybrid partitioning)
    inlj_prof: Optional[object] = None     # PageRefProfile, unsorted stream
    inlj_scale: float = 1.0                # CAM-x full/sample ratio

    @property
    def r(self) -> float:
        return self.sorted_part.total_refs

    @property
    def nd(self) -> float:
        return self.sorted_part.distinct_pages


def curve_price_table(states, caps: np.ndarray) -> PriceTable:
    """ONE PriceTable covering every stream's sorted + INLJ curve cells.

    Each ``(label, state)`` contributes up to two GridProfiles rows, each
    priced at every capacity in ``caps``:

    * ``(label, "sorted")`` — a pure sorted-scan row (empty IRM histogram
      plus the stream's :class:`SortedScanPart`): the composed
      ``hit_rate_grid`` collapses to the policy-aware sorted-scan model, so
      ``(1 - h) * R`` IS the old ``sorted_scan_miss_curve``.
    * ``(label, "inlj")`` — the unsorted stream's IRM histogram.  CAM-x
      scales are baked in per row: counts AND totals are both multiplied by
      the row's own scale, which leaves the IRM probabilities unchanged
      while the full-volume request mass comes out right under the shared
      ``scale=1.0`` profile — levels sampled at different rates can still
      share one GridProfiles (and therefore one engine call).
    """
    width = max(st.num_pages for _, st in states)

    def pad(arr):
        arr = jnp.asarray(arr, jnp.float32)
        w = int(arr.shape[-1])
        return arr if w == width else jnp.pad(arr, (0, width - w))

    knobs, counts, totals, dacs, sparts, cells = [], [], [], [], [], []
    for label, st in states:
        sp = st.sorted_part
        if sp.coverage is not None:
            sp = dataclasses.replace(sp, coverage=pad(sp.coverage))
        knobs.append((label, "sorted"))
        counts.append(jnp.zeros((width,), jnp.float32))
        totals.append(0.0)
        dacs.append(st.r / max(st.n, 1))
        sparts.append(sp)
        cells.append((knobs[-1], len(knobs) - 1, caps))
        if st.inlj_prof is not None:
            s = np.float32(st.inlj_scale)
            knobs.append((label, "inlj"))
            counts.append(pad(st.inlj_prof.counts) * s)
            totals.append(float(st.inlj_prof.total_refs) * float(s))
            dacs.append(float(st.inlj_prof.expected_dac))
            sparts.append(None)
            cells.append((knobs[-1], len(knobs) - 1, caps))
    k = len(knobs)
    profiles = GridProfiles(
        knobs=tuple(knobs), counts=jnp.stack(counts),
        totals=np.asarray(totals, np.float64),
        dacs=np.asarray(dacs, np.float64), sizes=np.zeros(k, np.float64),
        caps=np.full(k, int(np.max(caps)), np.int64), sparts=tuple(sparts),
        skipped=(), scale=1.0, n_queries=sum(st.n for _, st in states))
    return PriceTable.from_cells(profiles, cells)


def _stream_curves(sol, label, st: _CurveState,
                   caps: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Read one stream's (sorted miss curve, INLJ I/O curve) back out of
    the engine solution by its span keys."""
    a, b = sol.table.spans[(label, "sorted")]
    miss_curve = (1.0 - sol.hit_rates[a:b]) * st.r
    if st.inlj_prof is None:
        # No key file to locate against: every probe window priced cold
        # (upper bound, biased against INLJ — as _inlj_misses).
        io_inlj = np.full(caps.shape, st.refs)
    else:
        a, b = sol.table.spans[(label, "inlj")]
        io_inlj = ((1.0 - sol.hit_rates[a:b])
                   * st.inlj_prof.expected_dac * st.n)
    return miss_curve, io_inlj


def _union_size(page_lo: np.ndarray, page_hi: np.ndarray) -> int:
    """|union of inclusive page intervals| — exact for any order (sorts by
    lo, then the running-frontier sweep of Theorem III.1)."""
    if page_lo.shape[0] == 0:
        return 0
    order = np.argsort(page_lo, kind="stable")
    lo, hi = page_lo[order], page_hi[order]
    cm = np.maximum.accumulate(hi)
    prev = np.concatenate([[lo[0] - 1], cm[:-1]])
    return int(np.maximum(0, hi - np.maximum(lo, prev + 1) + 1).sum())


def _count_matches(inner_keys: np.ndarray, outer_keys: np.ndarray) -> int:
    pos = np.searchsorted(inner_keys, outer_keys)
    pos = np.minimum(pos, inner_keys.shape[0] - 1)
    return int((inner_keys[pos] == outer_keys).sum())


class JoinSession:
    """Join planner/executor bound to (inner IndexModel, System).

    ``inner`` may be a raw index (PGM / RMI / RadixSpline) or an adapter;
    it is normalized through :func:`repro.index.adapters.wrap_index`.
    ``inner_keys`` (the sorted key file) enables match counting and the
    INLJ CostSession estimate; planning and execution of sorted strategies
    work without it.
    """

    def __init__(self, inner, system: System,
                 inner_keys: Optional[np.ndarray] = None,
                 machine: MachineParams = MachineParams(),
                 params: Optional[JoinCostParams] = None):
        self.inner = wrap_index(inner)
        self.system = system
        self.inner_keys = None if inner_keys is None else np.asarray(inner_keys)
        self.machine = machine
        self.layout = system.layout()
        self.capacity = max(1, system.capacity_for(self.inner.size_bytes))
        self.num_pages = self.layout.num_pages(self.inner.n)
        self._params = params
        self._cost_session = CostSession(system)
        self._capped_sessions: Dict[int, CostSession] = {}

    # ------------------------------------------------------------ calibration
    @property
    def params(self) -> JoinCostParams:
        """Eq. 17 coefficients; lazily calibrated against the machine."""
        if self._params is None:
            if self.inner_keys is None:
                self._params = JoinCostParams()
            else:
                self._params = self.calibrate()
        return self._params

    def calibrate(self, seed: int = 0) -> JoinCostParams:
        """Fit Eq. 17 against the simulated machine (join/calibrate.py)."""
        self._params = calibrate_system(self.inner, self.inner_keys,
                                        self.system, machine=self.machine,
                                        seed=seed)
        return self._params

    # --------------------------------------------------------------- planning
    def plan(self, outer: Union[np.ndarray, Workload], strategy: str = "hybrid",
             n_min: int = 1024, k_max: int = 8192, gamma: float = 0.05,
             params: Optional[JoinCostParams] = None,
             sample_rate: float = 1.0,
             capacity: Optional[int] = None) -> JoinPlan:
        """Build a typed plan with model-predicted per-segment costs.

        ``capacity`` caps the buffer externally (in pages): a join tree
        sharing one pool across levels plans each level at its allotted
        slice instead of the session default (the System's full leftover
        budget).  The capacity is baked into the plan and honoured by
        ``execute``.
        """
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; expected one "
                             f"of {STRATEGIES}")
        cap = self.capacity if capacity is None else max(1, int(capacity))
        outer_keys = self._outer_keys(outer)
        p = params or self.params
        sorted_stream = strategy != "inlj"
        probe = np.sort(outer_keys) if sorted_stream else outer_keys
        plo, phi = self.inner.probe_windows(probe, self.system.geom)
        # Thrash regime = the buffer cannot hold a *typical* probe window
        # (99th-percentile width, so one badly-predicted outlier window —
        # e.g. a single poor RMI leaf — does not flip the whole plan onto
        # worst-case pricing).
        widths = phi - plo + 1
        typical_w = int(np.quantile(widths, 0.99)) if widths.size else 0
        thrash = cap < typical_w + 1
        n = probe.shape[0]
        refs = int(widths.sum())
        miss_scale = (1.0 if thrash or not sorted_stream
                      else self._policy_miss_scale(plo, phi, cap))

        if strategy == "hybrid":
            # Bias Algorithm 2's point/range decisions by the same policy
            # correction the prediction uses.
            p_eff = (p if miss_scale == 1.0 else dataclasses.replace(
                p, lambda_point=p.lambda_point * miss_scale))
            segments = tuple(partition_probes(plo, phi, p_eff, n_min=n_min,
                                              k_max=k_max, gamma=gamma,
                                              thrash=thrash))
        else:
            distinct = _union_size(plo, phi)
            span = (int(phi.max()) - int(plo.min()) + 1) if n else 0
            miss = refs if thrash else distinct
            cost_p, cost_r = segment_costs(n, miss, span, p)
            segments = (Segment(0, n, int(plo.min()) if n else 0,
                                int(phi.max()) if n else 0, n, distinct,
                                strategy == "range-only", cost_p, cost_r,
                                refs),)

        cost = self._predict(strategy, segments, probe, p, thrash, sample_rate,
                             miss_scale, cap)
        return JoinPlan(strategy, probe, plo, phi, segments, sorted_stream,
                        cost, p, cap, thrash)

    def choose(self, outer: Union[np.ndarray, Workload],
               n_min: int = 1024, k_max: int = 8192, gamma: float = 0.05,
               params: Optional[JoinCostParams] = None,
               sample_rate: float = 1.0,
               capacity: Optional[int] = None) -> ChooseResult:
        """CAM-predicted plan selection: price all strategies, pick cheapest.

        This replaces "run all four and compare" — the model selects the
        strategy up front; tests validate the pick against exhaustive
        replay (§VII-D).  ``sample_rate`` prices the INLJ hit-rate estimate
        from a CAM-x workload sample; ``capacity`` externally caps the
        buffer as in :meth:`plan`.
        """
        plans = {s: self.plan(outer, s, n_min=n_min, k_max=k_max, gamma=gamma,
                              params=params, sample_rate=sample_rate,
                              capacity=capacity)
                 for s in STRATEGIES}
        costs = {s: pl.cost for s, pl in plans.items()}
        best = min(costs, key=lambda s: costs[s].seconds)
        return ChooseResult(plans[best], costs, plans)

    def cost_curve(self, outer: Union[np.ndarray, Workload],
                   capacities, n_min: int = 1024, k_max: int = 8192,
                   gamma: float = 0.05,
                   params: Optional[JoinCostParams] = None,
                   sample_rate: float = 1.0) -> JoinCostCurve:
        """Predicted cost of every strategy across a capacity vector.

        The curve form of :meth:`plan`'s scalar prediction, for budget-split
        solvers: all K capacities of both model streams — the policy-aware
        sorted point-probe stream (shared by point-only and the hybrid's
        point segments) and the unsorted INLJ stream — price through ONE
        ``engine.price`` call over a :func:`curve_price_table`, with no
        per-capacity Python loop or replay.

        Capacity enters the hybrid's *partitioning* only through the thrash
        flag and the LFU miss scale, so the curve partitions once at the
        largest grid capacity and re-prices the fixed segments along the
        miss curve; the final plan built at the chosen capacity
        re-partitions exactly (``plan(..., capacity=...)``), so the
        approximation only affects which split the solver prefers, not the
        cost of the plan it returns.
        """
        caps = np.atleast_1d(np.asarray(capacities, np.int64))
        if caps.size == 0 or (caps < 1).any():
            raise ValueError("capacities must be >= 1 buffer page")
        p = params or self.params
        st = self._curve_state(outer, sample_rate)
        sol = self._cost_session.engine.price(
            curve_price_table([("outer", st)], caps))
        miss_curve, io_inlj = _stream_curves(sol, "outer", st, caps)
        return self._curve_from_solution(st, caps, miss_curve, io_inlj,
                                         n_min, k_max, gamma, p)

    def _curve_state(self, outer: Union[np.ndarray, Workload],
                     sample_rate: float = 1.0) -> _CurveState:
        """Profile the outer stream once, independent of capacity: sorted
        probe-window statistics plus the unsorted stream's IRM profile."""
        outer_keys = self._outer_keys(outer)
        probe = np.sort(outer_keys)
        plo, phi = self.inner.probe_windows(probe, self.system.geom)
        widths = phi - plo + 1
        n = probe.shape[0]
        typical_w = int(np.quantile(widths, 0.99)) if widths.size else 0
        r, nd, coverage, pinned = page_ref.sorted_workload_stats(
            jnp.asarray(plo), jnp.asarray(phi), self.num_pages)
        spart = SortedScanPart(float(r), float(nd), typical_w + 1,
                               coverage, float(pinned))
        prof, scale = None, 1.0
        if self.inner_keys is not None:
            wl = Workload.point(locate(self.inner_keys, outer_keys),
                                n=self.inner.n, query_keys=outer_keys)
            if sample_rate < 1.0:
                wl = wl.sample(sample_rate)
            prof = self.inner.page_ref_profile(wl, self.system.geom)
            scale = float(wl.scale)
        return _CurveState(
            n=n, refs=float(widths.sum()),
            span=(int(phi.max()) - int(plo.min()) + 1) if n else 0,
            min_cap=typical_w + 1, sorted_part=spart,
            num_pages=self.num_pages, page_lo=plo, page_hi=phi,
            inlj_prof=prof, inlj_scale=scale)

    def _curve_from_solution(self, st: _CurveState, caps: np.ndarray,
                             miss_curve: np.ndarray, io_inlj: np.ndarray,
                             n_min: int, k_max: int, gamma: float,
                             p: JoinCostParams) -> JoinCostCurve:
        """Assemble the four strategy curves from the solved miss curves —
        pure Eq. 17 arithmetic, every model solve already behind the
        engine call that produced ``miss_curve`` / ``io_inlj``."""
        n, r, nd = st.n, st.r, st.nd
        seconds: Dict[str, np.ndarray] = {}
        ios: Dict[str, np.ndarray] = {}
        sort_s = n * p.sort_per_key

        # point-only: one segment over the whole sorted stream.
        seconds["point-only"] = (sort_s + p.delta + p.alpha * n
                                 + p.lambda_point * miss_curve)
        ios["point-only"] = miss_curve.copy()

        # range-only: one coalesced scan — capacity-independent.
        sec_r = (sort_s + p.eta + (p.beta + p.lambda_range) * st.span
                 + 0.25 * p.alpha * n)
        seconds["range-only"] = np.full(caps.shape, sec_r)
        ios["range-only"] = np.full(caps.shape, float(st.span))

        # inlj: IRM hit-rate curve of the unsorted stream.
        seconds["inlj"] = p.delta + p.alpha * n + p.lambda_point * io_inlj
        ios["inlj"] = io_inlj

        # hybrid: fixed segments from the largest-capacity partitioning,
        # point segments re-priced along the sorted miss curve.  The
        # reference policy scale is read off the miss curve already in
        # hand (miss/N at the largest capacity — the same ratio
        # _policy_miss_scale would solve for), not re-solved.
        k_ref = int(np.argmax(caps))
        ref_cap = int(caps[k_ref])
        scale_ref = (1.0 if ref_cap < st.min_cap
                     else max(1.0, float(miss_curve[k_ref]) / max(nd, 1.0)))
        p_eff = (p if scale_ref == 1.0 else dataclasses.replace(
            p, lambda_point=p.lambda_point * scale_ref))
        segments = partition_probes(st.page_lo, st.page_hi, p_eff,
                                    n_min=n_min, k_max=k_max, gamma=gamma,
                                    thrash=ref_cap < st.min_cap)
        pt = [s for s in segments if not s.use_range]
        rg = [s for s in segments if s.use_range]
        d_pt = float(sum(s.distinct_pages for s in pt))
        r_pt = float(sum(s.total_refs for s in pt))
        n_pt = float(sum(s.n_keys for s in pt))
        # per-capacity miss of the point segments: the whole-stream policy
        # scale applied to their distinct mass, clamped by their refs, with
        # the thrash regime charged in full below the premise.
        scale_curve = np.where(miss_curve >= float(r),
                               np.inf,  # thrash: every reference misses
                               miss_curve / max(nd, 1.0))
        miss_pt = np.minimum(np.maximum(d_pt * scale_curve, d_pt), r_pt) \
            if pt else np.zeros(caps.shape)
        sec_hy = np.full(caps.shape, sort_s)
        for s in rg:
            sp = s.page_hi - s.page_lo + 1
            sec_hy += (p.eta + (p.beta + p.lambda_range) * sp
                       + 0.25 * p.alpha * s.n_keys)
        sec_hy += len(pt) * p.delta + p.alpha * n_pt \
            + p.lambda_point * miss_pt
        io_hy = miss_pt + float(sum(s.page_hi - s.page_lo + 1 for s in rg))
        seconds["hybrid"] = sec_hy
        ios["hybrid"] = io_hy

        return JoinCostCurve(caps, seconds, ios, n)

    # -------------------------------------------------------------- execution
    def execute(self, plan: JoinPlan) -> JoinStats:
        """Replay ANY plan through the buffered disk — the single execution
        path that subsumes the four legacy executors.

        The buffer is sized from ``plan.capacity`` (the capacity the plan
        was priced at — the session default unless the plan came from an
        externally-capped budget, e.g. a join-tree slice)."""
        t0 = time.perf_counter()
        m = self.machine
        disk = BufferedDisk(self.num_pages, plan.capacity, self.system.policy)
        plo, phi = plan.page_lo, plan.page_hi
        seconds = plan.outer_keys.shape[0] * m.sort_per_key \
            if plan.sorted_stream else 0.0
        n_range = 0
        for seg in plan.segments:
            if seg.use_range:
                n_range += 1
                misses = disk.fetch_window(seg.page_lo, seg.page_hi)
                span = seg.page_hi - seg.page_lo + 1
                seconds += (m.range_op_setup + span * m.cpu_per_page_scan
                            + misses * m.miss_latency_range
                            + seg.n_keys * m.cpu_per_key * 0.25)
            else:
                for a, b in zip(plo[seg.start:seg.end], phi[seg.start:seg.end]):
                    misses = disk.fetch_window(int(a), int(b))
                    seconds += (m.cpu_per_key + m.point_op_setup
                                + misses * m.miss_latency_point)
        matches = (_count_matches(self.inner_keys, plan.outer_keys)
                   if self.inner_keys is not None else 0)
        return JoinStats(plan.strategy, seconds, disk.physical_reads,
                         disk.logical_reads, matches,
                         n_segments=len(plan.segments),
                         n_range_segments=n_range,
                         wall_seconds=time.perf_counter() - t0)

    def run(self, outer: Union[np.ndarray, Workload],
            strategy: Optional[str] = None, **plan_kwargs) -> JoinStats:
        """plan (or choose, when ``strategy`` is None) + execute."""
        if strategy is None:
            return self.execute(self.choose(outer, **plan_kwargs).plan)
        return self.execute(self.plan(outer, strategy, **plan_kwargs))

    # -------------------------------------------------------------- internals
    def _outer_keys(self, outer: Union[np.ndarray, Workload]) -> np.ndarray:
        if isinstance(outer, Workload):
            if outer.parts:        # mixed read-blend: concatenate the parts
                return np.concatenate(
                    [self._outer_keys(p) for p in outer.parts])
            if outer.query_keys is None:
                raise ValueError("outer Workload needs query_keys (the join "
                                 "probes the inner index with them)")
            return np.asarray(outer.query_keys)
        return np.asarray(outer)

    def _predict(self, strategy: str, segments: Tuple[Segment, ...],
                 probe: np.ndarray, p: JoinCostParams, thrash: bool,
                 sample_rate: float = 1.0, miss_scale: float = 1.0,
                 capacity: Optional[int] = None) -> PlanCost:
        """Eq. 17 composed with CAM miss estimates, per strategy."""
        n = probe.shape[0]
        refs = float(sum(s.total_refs for s in segments))
        if strategy == "inlj":
            io = self._inlj_misses(probe, sample_rate, capacity)
            seconds = p.delta + p.alpha * n + p.lambda_point * io
            return PlanCost(strategy, seconds, io, refs)
        seconds = n * p.sort_per_key
        io = 0.0
        for s in segments:
            if s.use_range:
                span = s.page_hi - s.page_lo + 1
                io += span
                seconds += (p.eta + (p.beta + p.lambda_range) * span
                            + 0.25 * p.alpha * s.n_keys)   # result extraction
            else:
                miss = (s.total_refs if thrash
                        else min(s.distinct_pages * miss_scale, s.total_refs))
                io += miss
                seconds += p.delta + p.alpha * s.n_keys + p.lambda_point * miss
        return PlanCost(strategy, seconds, io, refs)

    def _policy_miss_scale(self, plo: np.ndarray, phi: np.ndarray,
                           capacity: Optional[int] = None) -> float:
        """Policy correction for sorted streams (point probing).

        Theorem III.1's one-compulsory-miss-per-distinct-page closed form
        relies on recency-based eviction keeping the sliding probe window
        resident; frequency-based LFU evicts the advancing frontier (and
        resets its count) so it misses more.  The segment miss terms are
        scaled by the ratio of the shared sorted-scan model's policy-aware
        miss count (``cache_models.sorted_scan_misses`` on the
        window-coverage histogram) to the compulsory count — the SAME model
        ``CostSession._finish`` applies to sorted workloads, so planner and
        estimator can no longer disagree on one stream.
        """
        cap = self.capacity if capacity is None else capacity
        if self.system.policy in cache_models.RECENCY_POLICIES \
                or plo.shape[0] == 0:
            return 1.0
        r, nd, coverage, pinned = page_ref.sorted_workload_stats(
            jnp.asarray(plo), jnp.asarray(phi), self.num_pages)
        r, nd = float(r), float(nd)
        if nd == 0 or r <= 0:
            return 1.0
        miss = cache_models.sorted_scan_misses(
            self.system.policy, cap, total_refs=r,
            distinct_pages=nd, coverage=coverage,
            pinned_retouches=float(pinned))
        return max(1.0, miss / nd)

    def _session_at(self, capacity: Optional[int]) -> CostSession:
        """CostSession whose System view yields exactly ``capacity`` buffer
        pages for this inner index (the session default when None)."""
        if capacity is None or capacity == self.capacity:
            return self._cost_session
        cached = self._capped_sessions.get(capacity)
        if cached is None:
            view = self.system.with_budget_fraction(
                1.0, pool_bytes=capacity * self.system.geom.page_bytes,
                resident_bytes=self.inner.size_bytes)
            cached = CostSession(view)
            if len(self._capped_sessions) >= 16:
                self._capped_sessions.pop(next(iter(self._capped_sessions)))
            self._capped_sessions[capacity] = cached
        return cached

    def _inlj_misses(self, probe: np.ndarray, sample_rate: float = 1.0,
                     capacity: Optional[int] = None) -> float:
        """Expected INLJ physical I/O via the full Algorithm 1 pipeline
        (structural page refs -> IRM hit rate) on the unsorted stream."""
        if self.inner_keys is None:
            # No key file to locate against: assume every probe window is
            # cold (upper bound) — keeps planning possible, biased against
            # INLJ, which exhaustive replay tests tolerate.
            plo, phi = self.inner.probe_windows(probe, self.system.geom)
            return float((phi - plo + 1).sum())
        wl = Workload.point(locate(self.inner_keys, probe),
                            n=self.inner.n, query_keys=probe)
        est = self._session_at(capacity).estimate(self.inner, wl,
                                                  sample_rate=sample_rate)
        return est.io_per_query * probe.shape[0]
