"""CAM-guided joins (paper §VI): two-way JoinSession plans + multi-way
JoinTreeSession trees sharing one buffer budget."""
from repro.join import calibrate, executors, hybrid, session, tree
from repro.join.session import (ChooseResult, JoinCostCurve, JoinPlan,
                                JoinSession, JoinStats)
from repro.join.tree import JoinTreeSession, TreePlan, TreeStats

__all__ = ["calibrate", "executors", "hybrid", "session", "tree",
           "JoinSession", "JoinPlan", "JoinStats", "ChooseResult",
           "JoinCostCurve", "JoinTreeSession", "TreePlan", "TreeStats"]
