"""CAM-guided hybrid join (paper §VI)."""
from repro.join import calibrate, executors, hybrid

__all__ = ["calibrate", "executors", "hybrid"]
