"""CAM-guided hybrid join (paper §VI) behind the JoinSession plan API."""
from repro.join import calibrate, executors, hybrid, session
from repro.join.session import (ChooseResult, JoinPlan, JoinSession,
                                JoinStats)

__all__ = ["calibrate", "executors", "hybrid", "session", "JoinSession",
           "JoinPlan", "JoinStats", "ChooseResult"]
