"""JoinTreeSession — multi-way left-deep join trees under ONE buffer budget.

Real query plans join more than two relations; what couples the levels of a
join tree is not the probe streams (each level probes its own inner
relation's pages) but the MEMORY: every inner index is resident and the
remaining buffer pool is shared by all levels' caches.  CAM already owns
per-stream miss curves — the policy-aware sorted-scan family and the IRM
fixed points — so the pool split is a modeling problem, not a replay
problem:

* ``plan``    — derive each level's probe stream by threading match keys
  level-to-level (the locate-once discipline: key containment is a CPU
  operation on resident key files, only page fetches cost I/O), price every
  level's four strategies across the whole candidate-capacity grid through
  ONE :class:`repro.engine.PricingEngine` solve (every level's sorted and
  INLJ streams at every candidate capacity batched into a single
  :func:`repro.join.session.curve_price_table` — no per-level or per-split
  model call), then pick the budget split by enumerating the fraction
  simplex over the precomputed curve tables (pure array lookups).
* ``choose``  — the per-level strategy falls out of the same tables: at the
  chosen split each level takes the strategy minimizing its composed
  Eq. 17 cost at its capacity slice.
* ``execute`` — one pipelined replay path: each level's
  :class:`~repro.join.session.JoinPlan` (hybrid segments materialized
  through ``partition_probes``) replays through the single
  ``JoinSession.execute`` machinery against its slice of the pool.

The per-level systems are :meth:`repro.core.session.System.with_budget_fraction`
views of the ONE shared System, and the tree's predicted cost is the
:meth:`repro.core.session.PlanCost.compose` sum of its level costs.
"""
from __future__ import annotations

import dataclasses
from itertools import combinations
from typing import Callable, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.session import CostSession, PlanCost, System
from repro.core.workload import Workload
from repro.index.adapters import wrap_index
from repro.join.hybrid import JoinCostParams
from repro.join.session import (STRATEGIES, JoinCostCurve, JoinPlan,
                                JoinSession, JoinStats, _stream_curves,
                                curve_price_table)
from repro.sim.machine import MachineParams

__all__ = ["TreePlan", "TreeStats", "JoinTreeSession"]


@dataclasses.dataclass(frozen=True)
class TreePlan:
    """An executable multi-way plan: one budget split + one plan per level.

    ``fractions`` is the chosen split of the shared buffer pool (summing to
    1), ``capacities`` its page-count realization, ``levels`` the typed
    :class:`JoinPlan` each level replays (each carries its capacity and
    chosen strategy).  ``cost`` is the composed model prediction the split
    was ranked by; ``curves`` keeps every level's full cost curve so
    callers can inspect the trade the solver made.
    """

    fractions: Tuple[float, ...]
    capacities: Tuple[int, ...]
    levels: Tuple[JoinPlan, ...]
    cost: PlanCost
    objective: str
    curves: Tuple[JoinCostCurve, ...] = ()

    @property
    def strategies(self) -> Tuple[str, ...]:
        return tuple(pl.strategy for pl in self.levels)


@dataclasses.dataclass
class TreeStats:
    """Replayed execution outcome of a whole tree (levels summed)."""

    seconds: float
    physical_ios: int
    logical_refs: int
    matches: int                       # rows surviving the final level
    per_level: Tuple[JoinStats, ...] = ()


def _matched_keys(inner_keys: np.ndarray, probe: np.ndarray) -> np.ndarray:
    """Probe keys present in the sorted inner key file (order preserved)."""
    if probe.shape[0] == 0 or inner_keys.shape[0] == 0:
        return probe[:0]
    pos = np.searchsorted(inner_keys, probe)
    pos = np.minimum(pos, inner_keys.shape[0] - 1)
    return probe[inner_keys[pos] == probe]


class JoinTreeSession:
    """Left-deep join tree of N inner IndexModels bound to one System.

    ``inners[i]`` is the inner relation of level i (raw index or adapter,
    normalized through ``wrap_index`` by the per-level
    :class:`JoinSession`); ``inner_keys[i]`` its sorted key file — required,
    because chaining the probe stream level-to-level needs key containment
    and the INLJ estimate needs true positions.  ``probe_maps[i]`` (optional,
    default identity) maps the keys matched at level i to the probe keys of
    level i+1 — identity models a star join on one shared attribute; a
    fact-table payload lookup would supply a real mapping.

    All levels share the ONE ``system``: its memory budget holds every
    inner index plus a single buffer pool, and planning decides how the
    pool is split.
    """

    def __init__(self, inners: Sequence, system: System,
                 inner_keys: Sequence[np.ndarray],
                 machine: MachineParams = MachineParams(),
                 params: Optional[JoinCostParams] = None,
                 probe_maps: Optional[Sequence[Callable[[np.ndarray],
                                                        np.ndarray]]] = None):
        if len(inners) == 0:
            raise ValueError("join tree needs at least one inner relation")
        if len(inner_keys) != len(inners):
            raise ValueError(f"{len(inners)} inners but {len(inner_keys)} "
                             "key files; every level needs its sorted keys")
        if any(k is None for k in inner_keys):
            raise ValueError("every tree level needs inner_keys (probe "
                             "chaining and INLJ estimates locate against "
                             "them)")
        n_levels = len(inners)
        if probe_maps is None:
            probe_maps = [None] * (n_levels - 1)
        if len(probe_maps) != n_levels - 1:
            raise ValueError(f"{n_levels}-level tree needs {n_levels - 1} "
                             f"probe maps, got {len(probe_maps)}")
        self.system = system
        self.machine = machine
        self.probe_maps = tuple(probe_maps)
        page_bytes = system.geom.page_bytes

        # ONE shared pool: whatever the budget leaves after ALL inner
        # indexes are resident.  Each level's session gets a
        # with_budget_fraction view (even split as the pre-plan default;
        # plan() overrides per-level capacities with the solved split).
        wrapped = [wrap_index(inner) for inner in inners]
        index_bytes = sum(w.size_bytes for w in wrapped)
        self.pool_bytes = system.memory_budget_bytes - index_bytes
        self.pool_pages = int(self.pool_bytes // page_bytes)
        if self.pool_pages < n_levels:
            raise ValueError(
                f"memory budget {system.memory_budget_bytes:.0f} B leaves a "
                f"{max(self.pool_pages, 0)}-page pool after "
                f"{index_bytes:.0f} B of resident indexes — a {n_levels}-"
                "level tree needs at least one page per level")
        self.sessions: Tuple[JoinSession, ...] = tuple(
            JoinSession(w,
                        system.with_budget_fraction(
                            1.0 / n_levels, pool_bytes=self.pool_bytes,
                            resident_bytes=w.size_bytes),
                        inner_keys=np.asarray(keys), machine=machine,
                        params=params)
            for w, keys in zip(wrapped, inner_keys))
        # The tree's own pricing surface: plan() batches EVERY level's curve
        # cells into one PriceTable and solves them through this session's
        # engine in a single call.
        self._cost_session = CostSession(system)

    @property
    def n_levels(self) -> int:
        return len(self.sessions)

    # ------------------------------------------------------------ calibration
    def calibrate(self, seed: int = 0) -> JoinCostParams:
        """Fit Eq. 17 once (the machine constants are global) and share the
        coefficients across every level's session."""
        params = self.sessions[0].calibrate(seed=seed)
        for sess in self.sessions[1:]:
            sess._params = params
        return params

    # --------------------------------------------------------------- planning
    def probe_streams(self, outer: Union[np.ndarray, Workload]
                      ) -> Tuple[np.ndarray, ...]:
        """Per-level probe key arrays, chained by key containment.

        Level 0 probes the outer stream; level i+1 probes the keys that
        matched at level i, passed through ``probe_maps[i]``.  This is the
        join-tree analog of locate-once: containment is computed against
        the resident key files, so planning never touches the buffer.
        """
        probe = self.sessions[0]._outer_keys(outer)
        streams = []
        for i, sess in enumerate(self.sessions):
            streams.append(probe)
            if i + 1 < self.n_levels:
                matched = _matched_keys(sess.inner_keys, probe)
                fn = self.probe_maps[i]
                probe = matched if fn is None else np.asarray(fn(matched))
        return tuple(streams)

    def plan(self, outer: Union[np.ndarray, Workload], *, grid: int = 8,
             objective: str = "seconds", n_min: int = 1024,
             k_max: int = 8192, gamma: float = 0.05,
             params: Optional[JoinCostParams] = None,
             sample_rate: float = 1.0) -> TreePlan:
        """Solve (budget split, per-level strategy) as one batched grid.

        ``grid`` is the split resolution: candidate fractions are j/grid,
        and the solver enumerates every composition of ``grid`` shares into
        ``n_levels`` positive parts.  The expensive part — every level's
        four-strategy cost at every candidate capacity — prices through
        ONE engine call: each level's capacity-independent stream profile
        (:meth:`JoinSession._curve_state`) becomes two PriceTable rows, the
        whole fleet of (level x stream x capacity) cells solves as a single
        batched table, and the simplex enumeration is then pure array
        arithmetic over the resulting curves.  ``objective`` ranks splits
        by predicted ``"seconds"`` (Eq. 17) or predicted physical ``"io"``.
        """
        n_levels = self.n_levels
        if grid < n_levels:
            raise ValueError(f"grid={grid} cannot split the pool across "
                             f"{n_levels} levels (need grid >= n_levels)")
        if objective not in ("seconds", "io"):
            raise ValueError(f"objective must be 'seconds' or 'io', "
                             f"got {objective!r}")
        streams = self.probe_streams(outer)
        # Resolve Eq. 17 coefficients ONCE (level 0 lazily calibrates if
        # needed) and pass them explicitly — the machine constants are
        # global, so per-level re-calibration would be pure waste.
        params = params if params is not None else self.sessions[0].params
        # A grid finer than the pool would need sub-page shares whose
        # 1-page floor could overcommit the pool; clamp so every share is
        # at least one whole page (the constructor guarantees
        # pool_pages >= n_levels, so the clamp keeps grid >= n_levels).
        grid = min(grid, self.pool_pages)
        # Candidate capacities: j shares of grid, j = 1 .. grid-(L-1)
        # (every other level keeps at least one share).  With
        # pool_pages >= grid each share is >= 1 page and any composition's
        # capacities sum to <= pool_pages — the ONE-pool invariant.
        n_shares = grid - n_levels + 1
        shares = np.arange(1, n_shares + 1)
        caps = ((shares * self.pool_pages) // grid).astype(np.int64)

        if (caps < 1).any():
            raise ValueError("capacities must be >= 1 buffer page")
        # ONE solve for the whole tree: every level's sorted + INLJ stream
        # at every candidate capacity, batched into a single PriceTable.
        states = [sess._curve_state(streams[lvl], sample_rate)
                  for lvl, sess in enumerate(self.sessions)]
        sol = self._cost_session.engine.price(
            curve_price_table(list(enumerate(states)), caps))

        curves: list[JoinCostCurve] = []
        cost_tab = np.empty((n_levels, n_shares))
        strat_tab = np.empty((n_levels, n_shares), np.int64)
        for lvl, sess in enumerate(self.sessions):
            miss_curve, io_inlj = _stream_curves(sol, lvl, states[lvl], caps)
            curve = sess._curve_from_solution(
                states[lvl], caps, miss_curve, io_inlj, n_min, k_max, gamma,
                params)
            curves.append(curve)
            table = curve.seconds if objective == "seconds" \
                else curve.physical_ios
            stacked = np.stack([table[s] for s in STRATEGIES])  # (S, K)
            cost_tab[lvl] = stacked.min(axis=0)
            strat_tab[lvl] = stacked.argmin(axis=0)

        # Every composition of `grid` into n_levels positive shares, as a
        # (M, L) matrix of share counts — the split solve is a fancy-indexed
        # sum over the precomputed tables, not a per-split model call.
        if n_levels == 1:
            comps = np.array([[grid]])
        else:
            bars = np.array(list(combinations(range(1, grid), n_levels - 1)))
            edges = np.concatenate(
                [np.zeros((bars.shape[0], 1), np.int64), bars,
                 np.full((bars.shape[0], 1), grid)], axis=1)
            comps = np.diff(edges, axis=1)
        idx = comps - 1                                       # share -> column
        totals = cost_tab[np.arange(n_levels)[None, :], idx].sum(axis=1)
        best = int(np.argmin(totals))
        chosen = comps[best]

        level_plans = []
        for lvl, sess in enumerate(self.sessions):
            j = int(chosen[lvl]) - 1
            strategy = STRATEGIES[int(strat_tab[lvl, j])]
            level_plans.append(sess.plan(
                streams[lvl], strategy, n_min=n_min, k_max=k_max,
                gamma=gamma, params=params, sample_rate=sample_rate,
                capacity=int(caps[j])))
        return TreePlan(
            fractions=tuple(float(c) / grid for c in chosen),
            capacities=tuple(int(caps[c - 1]) for c in chosen),
            levels=tuple(level_plans),
            cost=PlanCost.compose("tree", [pl.cost for pl in level_plans]),
            objective=objective,
            curves=tuple(curves))

    def choose(self, outer: Union[np.ndarray, Workload],
               **plan_kwargs) -> TreePlan:
        """Alias of :meth:`plan` — for a tree, the budget split and the
        per-level strategies are ONE joint model-predicted choice."""
        return self.plan(outer, **plan_kwargs)

    # -------------------------------------------------------------- execution
    def execute(self, tree_plan: TreePlan) -> TreeStats:
        """Pipelined replay: every level's plan runs through the single
        ``JoinSession.execute`` path against its slice of the pool, and the
        surviving match keys thread into the next level (materialized at
        plan time — replay is deterministic, so the planned streams ARE the
        executed streams)."""
        if len(tree_plan.levels) != self.n_levels:
            raise ValueError(f"plan has {len(tree_plan.levels)} levels, "
                             f"session has {self.n_levels}")
        per_level = tuple(sess.execute(pl) for sess, pl
                          in zip(self.sessions, tree_plan.levels))
        return TreeStats(
            seconds=sum(st.seconds for st in per_level),
            physical_ios=sum(st.physical_ios for st in per_level),
            logical_refs=sum(st.logical_refs for st in per_level),
            matches=per_level[-1].matches,
            per_level=per_level)

    def run(self, outer: Union[np.ndarray, Workload],
            **plan_kwargs) -> TreeStats:
        """plan + execute."""
        return self.execute(self.plan(outer, **plan_kwargs))
