"""Legacy join-executor entry points (paper §VI-A, §VII-D).

The four strategies — INLJ, POINT-ONLY, RANGE-ONLY, HYBRID — are now
degenerate plans of :class:`repro.join.session.JoinSession`; these wrappers
keep the original loose-argument signatures for callers that still think in
``(layout, capacity, policy)`` tuples and route everything through the one
session execution path.  New code should construct a ``JoinSession`` with a
:class:`repro.core.session.System` directly.

Any index family (raw index or IndexModel adapter) is accepted: windows are
normalized by ``wrap_index`` / ``probe_windows``, so there is no per-design
tuple-shape special casing here anymore.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.cam import CamGeometry
from repro.core.session import System
from repro.index.adapters import wrap_index
from repro.index.disk_layout import PageLayout
from repro.join.hybrid import JoinCostParams
from repro.join.session import JoinSession, JoinStats
from repro.sim.machine import MachineParams

__all__ = ["JoinStats", "inlj", "point_only", "range_only", "hybrid_join",
           "session_for"]


def session_for(index, inner_keys: np.ndarray, layout: PageLayout,
                capacity: int, policy: str = "lru",
                machine: MachineParams = MachineParams(),
                params: Optional[JoinCostParams] = None) -> JoinSession:
    """Bridge loose (layout, capacity, policy) arguments to a JoinSession.

    The synthesized System's memory budget is exactly ``capacity`` buffer
    pages once the index footprint is charged (the half-page slack absorbs
    float rounding in ``size_bytes``).
    """
    model = wrap_index(index)
    geom = CamGeometry(c_ipp=layout.c_ipp, page_bytes=layout.page_bytes)
    budget = (capacity + 0.5) * layout.page_bytes + float(model.size_bytes)
    system = System(geom=geom, memory_budget_bytes=budget, policy=policy)
    return JoinSession(model, system, inner_keys=inner_keys, machine=machine,
                       params=params)


def inlj(index, inner_keys, outer_keys, layout: PageLayout, capacity: int,
         policy: str = "lru", machine: MachineParams = MachineParams()) -> JoinStats:
    s = session_for(index, inner_keys, layout, capacity, policy, machine,
                    params=JoinCostParams())
    return s.run(outer_keys, "inlj")


def point_only(index, inner_keys, outer_keys, layout: PageLayout, capacity: int,
               policy: str = "lru", machine: MachineParams = MachineParams()) -> JoinStats:
    s = session_for(index, inner_keys, layout, capacity, policy, machine,
                    params=JoinCostParams())
    return s.run(outer_keys, "point-only")


def range_only(index, inner_keys, outer_keys, layout: PageLayout, capacity: int,
               policy: str = "lru", machine: MachineParams = MachineParams()) -> JoinStats:
    s = session_for(index, inner_keys, layout, capacity, policy, machine,
                    params=JoinCostParams())
    return s.run(outer_keys, "range-only")


def hybrid_join(index, inner_keys, outer_keys, layout: PageLayout, capacity: int,
                policy: str = "lru", machine: MachineParams = MachineParams(),
                params: Optional[JoinCostParams] = None,
                n_min: int = 1024, k_max: int = 8192, gamma: float = 0.05) -> JoinStats:
    s = session_for(index, inner_keys, layout, capacity, policy, machine,
                    params=params or JoinCostParams())
    return s.run(outer_keys, "hybrid", n_min=n_min, k_max=k_max, gamma=gamma)
