"""Learned-index join executors (paper §VI-A, §VII-D).

Four strategies over a simulated buffered disk:

* INLJ       — index nested-loop join, original (unsorted) probe order.
* POINT-ONLY — sort outer keys, one indexed point lookup per key.
* RANGE-ONLY — sort outer keys, one coalesced range scan between the
               workload's two endpoint windows (sort-merge flavored).
* HYBRID     — Algorithm 2 partitioning; per-segment point/range selection.

Physical I/O is exact (true replay through the buffer); time comes from the
simulated machine constants.  All executors also verify join results against
a numpy oracle in tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.index.disk_layout import PageLayout
from repro.join.hybrid import JoinCostParams, Segment, partition_probes
from repro.sim.machine import BufferedDisk, MachineParams

__all__ = ["JoinStats", "inlj", "point_only", "range_only", "hybrid_join"]


@dataclasses.dataclass
class JoinStats:
    strategy: str
    seconds: float          # simulated end-to-end time
    physical_ios: int
    logical_refs: int
    matches: int
    n_segments: int = 1
    n_range_segments: int = 0
    wall_seconds: float = 0.0


def _probe_windows(index, outer_keys: np.ndarray, layout: PageLayout):
    """Per-probe inclusive page intervals from the index's last-mile windows."""
    out = index.window(outer_keys)
    wlo, whi = out[0], out[1]  # PGM returns 2-tuple, RMI returns 3-tuple
    return wlo // layout.c_ipp, whi // layout.c_ipp


def _count_matches(inner_keys: np.ndarray, outer_keys: np.ndarray) -> int:
    pos = np.searchsorted(inner_keys, outer_keys)
    pos = np.minimum(pos, inner_keys.shape[0] - 1)
    return int((inner_keys[pos] == outer_keys).sum())


def _execute_points(disk: BufferedDisk, plo, phi, machine: MachineParams):
    seconds = 0.0
    for a, b in zip(plo, phi):
        misses = disk.fetch_window(int(a), int(b))
        seconds += (machine.cpu_per_key + machine.point_op_setup
                    + misses * machine.miss_latency_point)
    return seconds


def _execute_range(disk: BufferedDisk, page_lo: int, page_hi: int,
                   n_keys: int, machine: MachineParams):
    misses = disk.fetch_window(int(page_lo), int(page_hi))
    span = page_hi - page_lo + 1
    return (machine.range_op_setup
            + span * machine.cpu_per_page_scan
            + misses * machine.miss_latency_range
            + n_keys * machine.cpu_per_key * 0.25)  # result extraction


def _make_disk(layout: PageLayout, n: int, capacity: int, policy: str):
    return BufferedDisk(layout.num_pages(n), capacity, policy)


def inlj(index, inner_keys, outer_keys, layout: PageLayout, capacity: int,
         policy: str = "lru", machine: MachineParams = MachineParams()) -> JoinStats:
    t0 = time.perf_counter()
    disk = _make_disk(layout, len(inner_keys), capacity, policy)
    plo, phi = _probe_windows(index, outer_keys, layout)
    seconds = _execute_points(disk, plo, phi, machine)
    return JoinStats("inlj", seconds, disk.physical_reads, disk.logical_reads,
                     _count_matches(inner_keys, outer_keys),
                     wall_seconds=time.perf_counter() - t0)


def point_only(index, inner_keys, outer_keys, layout: PageLayout, capacity: int,
               policy: str = "lru", machine: MachineParams = MachineParams()) -> JoinStats:
    t0 = time.perf_counter()
    outer = np.sort(outer_keys)
    disk = _make_disk(layout, len(inner_keys), capacity, policy)
    plo, phi = _probe_windows(index, outer, layout)
    seconds = len(outer) * machine.sort_per_key
    seconds += _execute_points(disk, plo, phi, machine)
    return JoinStats("point-only", seconds, disk.physical_reads, disk.logical_reads,
                     _count_matches(inner_keys, outer),
                     wall_seconds=time.perf_counter() - t0)


def range_only(index, inner_keys, outer_keys, layout: PageLayout, capacity: int,
               policy: str = "lru", machine: MachineParams = MachineParams()) -> JoinStats:
    t0 = time.perf_counter()
    outer = np.sort(outer_keys)
    disk = _make_disk(layout, len(inner_keys), capacity, policy)
    plo, phi = _probe_windows(index, outer, layout)
    seconds = len(outer) * machine.sort_per_key
    seconds += _execute_range(disk, int(plo.min()), int(phi.max()), len(outer), machine)
    return JoinStats("range-only", seconds, disk.physical_reads, disk.logical_reads,
                     _count_matches(inner_keys, outer),
                     wall_seconds=time.perf_counter() - t0)


def hybrid_join(index, inner_keys, outer_keys, layout: PageLayout, capacity: int,
                policy: str = "lru", machine: MachineParams = MachineParams(),
                params: Optional[JoinCostParams] = None,
                n_min: int = 1024, k_max: int = 8192, gamma: float = 0.05) -> JoinStats:
    t0 = time.perf_counter()
    outer = np.sort(outer_keys)
    disk = _make_disk(layout, len(inner_keys), capacity, policy)
    plo, phi = _probe_windows(index, outer, layout)
    params = params or JoinCostParams()
    segments: List[Segment] = partition_probes(plo, phi, params,
                                               n_min=n_min, k_max=k_max, gamma=gamma)
    seconds = len(outer) * machine.sort_per_key
    n_range = 0
    for seg in segments:
        if seg.use_range:
            n_range += 1
            seconds += _execute_range(disk, seg.page_lo, seg.page_hi,
                                      seg.n_keys, machine)
        else:
            seconds += _execute_points(disk, plo[seg.start:seg.end],
                                       phi[seg.start:seg.end], machine)
    return JoinStats("hybrid", seconds, disk.physical_reads, disk.logical_reads,
                     _count_matches(inner_keys, outer),
                     n_segments=len(segments), n_range_segments=n_range,
                     wall_seconds=time.perf_counter() - t0)
