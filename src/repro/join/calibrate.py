"""Cost-model calibration for the hybrid join (paper §VI-B, §VII-D).

Fits the Eq. 17 parameters from short calibration runs against the simulated
machine, following the paper's procedure:

1. lambda_point / lambda_range = median ratio of observed I/O time to physical
   I/O count across calibration probes;
2. subtract the fitted I/O component from end-to-end time, then fit the CPU
   coefficients (alpha, delta) and (beta, eta) by ordinary least squares.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.index.disk_layout import PageLayout
from repro.join.hybrid import JoinCostParams
from repro.sim.machine import BufferedDisk, MachineParams
from repro.tuning.fit import ols

__all__ = ["calibrate", "calibrate_system"]


def _point_runs(index, layout, capacity, policy, machine, rng, inner_n, n_runs=24):
    """Execute small point-probe batches; observe (N, misses, io_t, total_t)."""
    rows = []
    for _ in range(n_runs):
        n_keys = int(rng.integers(64, 1024))
        start = int(rng.integers(0, inner_n - 2 * n_keys - 1))
        pos = np.sort(rng.integers(start, start + 64 * n_keys, size=n_keys)) % inner_n
        disk = BufferedDisk(layout.num_pages(inner_n), capacity, policy)
        misses = 0
        for p in np.sort(pos):
            w = max(0, p - 8), min(inner_n - 1, p + 8)
            misses += disk.fetch_window(w[0] // layout.c_ipp, w[1] // layout.c_ipp)
        io_t = misses * machine.miss_latency_point
        total = n_keys * (machine.cpu_per_key + machine.point_op_setup) + io_t
        rows.append((n_keys, misses, io_t, total))
    return np.asarray(rows, np.float64)


def _range_runs(index, layout, capacity, policy, machine, rng, inner_n, n_runs=24):
    rows = []
    num_pages = layout.num_pages(inner_n)
    for _ in range(n_runs):
        span = int(rng.integers(8, 4096))
        start = int(rng.integers(0, max(1, num_pages - span - 1)))
        disk = BufferedDisk(num_pages, capacity, policy)
        misses = disk.fetch_window(start, start + span - 1)
        io_t = misses * machine.miss_latency_range
        total = machine.range_op_setup + span * machine.cpu_per_page_scan + io_t
        rows.append((span, misses, io_t, total))
    return np.asarray(rows, np.float64)


def calibrate_system(
    index,
    inner_keys: np.ndarray,
    system,
    machine: MachineParams = MachineParams(),
    seed: int = 0,
) -> JoinCostParams:
    """CostSession-era entry point: derive layout, capacity and policy from a
    :class:`repro.core.session.System` instead of four loose arguments.

    ``index`` may be a raw index or an IndexModel adapter — anything with
    ``size_bytes`` charges its footprint against the memory budget.  This is
    the primary entry point (``JoinSession.calibrate`` routes through it);
    the loose-argument ``calibrate`` below remains for legacy callers.
    """
    index_bytes = float(getattr(index, "size_bytes", 0.0))
    capacity = max(1, system.capacity_for(index_bytes))
    return calibrate(index, inner_keys, system.layout(), capacity,
                     policy=system.policy, machine=machine, seed=seed)


def calibrate(
    index,
    inner_keys: np.ndarray,
    layout: PageLayout,
    capacity: int,
    policy: str = "lru",
    machine: MachineParams = MachineParams(),
    seed: int = 0,
) -> JoinCostParams:
    rng = np.random.default_rng(seed)
    n = len(inner_keys)

    pt = _point_runs(index, layout, capacity, policy, machine, rng, n)
    rg = _range_runs(index, layout, capacity, policy, machine, rng, n)

    # Step 1: per-miss latencies = median(io_time / misses).
    lam_p = float(np.median(pt[:, 2] / np.maximum(pt[:, 1], 1)))
    lam_r = float(np.median(rg[:, 2] / np.maximum(rg[:, 1], 1)))

    # Step 2: subtract I/O, OLS the CPU terms.
    cpu_p = pt[:, 3] - lam_p * pt[:, 1]
    coef_p = ols(np.stack([pt[:, 0], np.ones(len(pt))], axis=1), cpu_p)
    cpu_r = rg[:, 3] - lam_r * rg[:, 1]
    coef_r = ols(np.stack([rg[:, 0], np.ones(len(rg))], axis=1), cpu_r)

    return JoinCostParams(
        alpha=max(float(coef_p[0]), 1e-9),
        delta=max(float(coef_p[1]), 0.0),
        beta=max(float(coef_r[0]), 1e-9),
        eta=max(float(coef_r[1]), 0.0),
        lambda_point=max(lam_p, 1e-9),
        lambda_range=max(lam_r, 1e-9),
    )
