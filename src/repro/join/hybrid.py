"""CAM-guided hybrid join: greedy probe partitioning (paper §VI, Algorithm 2).

The sorted probe stream is split into segments; each segment is executed with
point probes or one coalesced range probe, whichever the fitted cost model
(Eq. 17) predicts cheaper:

    Cost_point(S) = delta + alpha * N_S + lambda_point * d_S
    Cost_range(S) = eta + (beta + lambda_range) * K_S

d_S (distinct pages under point probing) uses the sorted-workload theorem:
one compulsory miss per distinct page.  The greedy pass closes a segment when
its range span hits K_max or range probing wins by margin gamma once N_min
probes have accumulated.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = ["JoinCostParams", "Segment", "partition_probes", "segment_costs"]


@dataclasses.dataclass(frozen=True)
class JoinCostParams:
    """Eq. 17 coefficients (fit by calibration, see join/calibrate.py)."""

    alpha: float = 1.64e-6         # per-key CPU
    beta: float = 1.72e-6          # per-page scan/filter CPU
    delta: float = 0.30e-6         # point-probe intercept
    eta: float = 4.42e-6           # range-probe intercept
    lambda_point: float = 11.9e-6  # per physical miss (random)
    lambda_range: float = 4.66e-6  # per physical miss (sequential)


@dataclasses.dataclass(frozen=True)
class Segment:
    start: int          # probe index range [start, end)
    end: int
    page_lo: int        # page span covered by the range probe
    page_hi: int
    n_keys: int
    distinct_pages: int
    use_range: bool
    cost_point: float
    cost_range: float


def segment_costs(
    n_keys: int, distinct_pages: int, span: int, params: JoinCostParams
) -> Tuple[float, float]:
    cost_p = params.delta + params.alpha * n_keys + params.lambda_point * distinct_pages
    cost_r = params.eta + (params.beta + params.lambda_range) * span
    return cost_p, cost_r


def partition_probes(
    page_lo: np.ndarray,
    page_hi: np.ndarray,
    params: JoinCostParams,
    n_min: int = 1024,
    k_max: int = 8192,
    gamma: float = 0.05,
) -> List[Segment]:
    """Algorithm 2 over per-probe page intervals of the *sorted* outer keys."""
    lo = np.asarray(page_lo, np.int64)
    hi = np.asarray(page_hi, np.int64)
    n = lo.shape[0]
    segments: List[Segment] = []
    i = 0
    while i < n:
        seg_lo = int(lo[i])
        seg_hi = int(hi[i])
        covered_hi = int(hi[i])          # rightmost page seen (for distinct count)
        distinct = seg_hi - seg_lo + 1
        j = i + 1
        cost_p, cost_r = segment_costs(1, distinct, seg_hi - seg_lo + 1, params)
        while j < n:
            l, h = int(lo[j]), int(hi[j])
            new_lo = min(seg_lo, l)
            new_hi = max(seg_hi, h)
            # incremental distinct-page union (sorted stream => windows only
            # extend to the right of what previous windows covered)
            distinct += max(0, h - max(l, covered_hi + 1) + 1)
            covered_hi = max(covered_hi, h)
            seg_lo, seg_hi = new_lo, new_hi
            n_keys = j - i + 1
            span = seg_hi - seg_lo + 1
            if n_keys >= n_min:
                cost_p, cost_r = segment_costs(n_keys, distinct, span, params)
                if span >= k_max or cost_r <= (1.0 - gamma) * cost_p:
                    j += 1
                    break
            j += 1
        n_keys = j - i
        span = seg_hi - seg_lo + 1
        cost_p, cost_r = segment_costs(n_keys, distinct, span, params)
        use_range = (n_keys >= n_min) and (cost_r <= (1.0 - gamma) * cost_p)
        segments.append(Segment(i, j, seg_lo, seg_hi, n_keys, distinct,
                                use_range, cost_p, cost_r))
        i = j
    return segments
