"""CAM-guided hybrid join: greedy probe partitioning (paper §VI, Algorithm 2).

The sorted probe stream is split into segments; each segment is executed with
point probes or one coalesced range probe, whichever the fitted cost model
(Eq. 17) predicts cheaper:

    Cost_point(S) = delta + alpha * N_S + lambda_point * miss_S
    Cost_range(S) = eta + (beta + lambda_range) * K_S

miss_S is CAM's cache-aware physical-miss estimate for point-probing the
segment: with enough buffer capacity for one probe window (the Theorem III.1
premise) it is d_S, the distinct-page union — one compulsory miss per
distinct page; below that capacity every logical reference misses, so
miss_S = R_S, the segment's total window mass.  Under frequency-based
eviction (LFU) the session scales lambda_point by the shared sorted-scan
model's miss/compulsory ratio (``cache_models.sorted_scan_misses`` — see
``JoinSession._policy_miss_scale``) before partitioning, so the point/range
decisions price the same policy pathology the estimator predicts.  The
greedy pass closes a segment when its range span hits K_max or range probing
wins by margin gamma once N_min probes have accumulated.

``partition_probes`` is the vectorized two-pass kernel (prefix-scan
distinct-page union + segment-boundary selection over numpy arrays, scanned
in geometrically growing chunks); ``partition_probes_loop`` keeps the
original per-probe Python loop as the golden reference.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = ["JoinCostParams", "Segment", "partition_probes",
           "partition_probes_loop", "segment_costs"]


@dataclasses.dataclass(frozen=True)
class JoinCostParams:
    """Eq. 17 coefficients (fit by calibration, see join/calibrate.py)."""

    alpha: float = 1.64e-6         # per-key CPU
    beta: float = 1.72e-6          # per-page scan/filter CPU
    delta: float = 0.30e-6         # point-probe intercept
    eta: float = 4.42e-6           # range-probe intercept
    lambda_point: float = 11.9e-6  # per physical miss (random)
    lambda_range: float = 4.66e-6  # per physical miss (sequential)
    sort_per_key: float = 0.12e-6  # outer-relation sort, amortized per key


@dataclasses.dataclass(frozen=True)
class Segment:
    start: int          # probe index range [start, end)
    end: int
    page_lo: int        # page span covered by the range probe
    page_hi: int
    n_keys: int
    distinct_pages: int
    use_range: bool
    cost_point: float
    cost_range: float
    total_refs: int = 0  # sum of per-probe window widths (R_S)


def segment_costs(
    n_keys: int, distinct_pages: int, span: int, params: JoinCostParams
) -> Tuple[float, float]:
    cost_p = params.delta + params.alpha * n_keys + params.lambda_point * distinct_pages
    cost_r = params.eta + (params.beta + params.lambda_range) * span
    return cost_p, cost_r


def partition_probes_loop(
    page_lo: np.ndarray,
    page_hi: np.ndarray,
    params: JoinCostParams,
    n_min: int = 1024,
    k_max: int = 8192,
    gamma: float = 0.05,
    thrash: bool = False,
) -> List[Segment]:
    """Algorithm 2 as the original per-probe Python loop (golden reference)."""
    lo = np.asarray(page_lo, np.int64)
    hi = np.asarray(page_hi, np.int64)
    n = lo.shape[0]
    segments: List[Segment] = []
    i = 0
    while i < n:
        seg_lo = int(lo[i])
        seg_hi = int(hi[i])
        covered_hi = int(hi[i])          # rightmost page seen (for distinct count)
        distinct = seg_hi - seg_lo + 1
        refs = seg_hi - seg_lo + 1
        j = i + 1
        while j < n:
            l, h = int(lo[j]), int(hi[j])
            new_lo = min(seg_lo, l)
            new_hi = max(seg_hi, h)
            # incremental distinct-page union (sorted stream => windows only
            # extend to the right of what previous windows covered)
            distinct += max(0, h - max(l, covered_hi + 1) + 1)
            refs += h - l + 1
            covered_hi = max(covered_hi, h)
            seg_lo, seg_hi = new_lo, new_hi
            n_keys = j - i + 1
            span = seg_hi - seg_lo + 1
            if n_keys >= n_min:
                miss = refs if thrash else distinct
                cost_p, cost_r = segment_costs(n_keys, miss, span, params)
                if span >= k_max or cost_r <= (1.0 - gamma) * cost_p:
                    j += 1
                    break
            j += 1
        n_keys = j - i
        span = seg_hi - seg_lo + 1
        miss = refs if thrash else distinct
        cost_p, cost_r = segment_costs(n_keys, miss, span, params)
        use_range = (n_keys >= n_min) and (cost_r <= (1.0 - gamma) * cost_p)
        segments.append(Segment(i, j, seg_lo, seg_hi, n_keys, distinct,
                                use_range, cost_p, cost_r, refs))
        i = j
    return segments


def partition_probes(
    page_lo: np.ndarray,
    page_hi: np.ndarray,
    params: JoinCostParams,
    n_min: int = 1024,
    k_max: int = 8192,
    gamma: float = 0.05,
    thrash: bool = False,
) -> List[Segment]:
    """Algorithm 2, vectorized: per-probe work becomes prefix scans.

    Segment boundaries are inherently sequential (each segment's start is the
    previous one's end), but everything *inside* a segment is a prefix
    computation over the probe stream: the covered-page frontier is a running
    max of ``page_hi``, the distinct-page union is a cumulative sum of
    clamped window increments against that frontier, and the close condition
    is an elementwise predicate.  So the kernel scans forward from each
    segment start in geometrically growing numpy chunks — pass 1 builds the
    prefix scans for the chunk, pass 2 selects the first index where the
    close predicate fires — and only the (rare) segment boundaries run in
    Python.  Every segment except the last holds >= n_min probes, so the
    boundary loop executes at most ceil(n / n_min) + 1 times.

    ``thrash=True`` composes Eq. 17 with CAM's below-capacity regime: when
    the buffer cannot hold one probe window, every logical reference is a
    physical miss, so the point-cost miss term uses R_S instead of d_S
    (see JoinSession, which sets this from the Theorem III.1 premise).

    Output is segment-for-segment identical to ``partition_probes_loop``.
    """
    lo = np.asarray(page_lo, np.int64)
    hi = np.asarray(page_hi, np.int64)
    n = lo.shape[0]
    widths = hi - lo + 1
    lam_r = params.beta + params.lambda_range
    segments: List[Segment] = []
    i = 0
    while i < n:
        # carry state: segment stats over [i, pos) so far
        pos = i + 1
        seg_lo = int(lo[i])
        cm = int(hi[i])                     # covered frontier == running max hi
        distinct = int(cm - seg_lo + 1)
        refs = distinct
        end = None
        chunk = max(int(n_min), 256)
        while pos < n and end is None:
            a, b = pos, min(n, pos + chunk)
            l, h = lo[a:b], hi[a:b]
            inc_cm = np.maximum.accumulate(h)           # frontier incl. probe
            prev_cm = np.empty_like(inc_cm)             # frontier before probe
            prev_cm[0] = cm
            np.maximum(inc_cm[:-1], cm, out=prev_cm[1:])
            inc_cm = np.maximum(inc_cm, cm)
            run_lo = np.minimum.accumulate(l)
            np.minimum(run_lo, seg_lo, out=run_lo)
            d_cum = distinct + np.cumsum(
                np.maximum(0, h - np.maximum(l, prev_cm + 1) + 1))
            r_cum = refs + np.cumsum(widths[a:b])
            n_keys = np.arange(a - i + 1, b - i + 1)
            span = inc_cm - run_lo + 1
            miss = r_cum if thrash else d_cum
            cost_p = params.delta + params.alpha * n_keys \
                + params.lambda_point * miss
            cost_r = params.eta + lam_r * span
            stop = (n_keys >= n_min) & ((span >= k_max)
                                        | (cost_r <= (1.0 - gamma) * cost_p))
            k = int(np.argmax(stop))
            if stop[k]:
                end = a + k + 1
            else:
                k = b - a - 1                           # chunk exhausted: carry
                pos = b
                chunk *= 2
            seg_lo = int(run_lo[k])
            cm = int(inc_cm[k])
            distinct = int(d_cum[k])
            refs = int(r_cum[k])
        end = n if end is None else end
        n_keys = end - i
        span = cm - seg_lo + 1
        miss = refs if thrash else distinct
        cost_p, cost_r = segment_costs(n_keys, miss, span, params)
        use_range = (n_keys >= n_min) and (cost_r <= (1.0 - gamma) * cost_p)
        segments.append(Segment(i, end, seg_lo, cm, n_keys, distinct,
                                use_range, cost_p, cost_r, refs))
        i = end
    return segments
