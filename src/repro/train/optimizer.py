"""AdamW with sharded, dtype-configurable moments (fp32 / bf16 / int8).

int8 moments ("8-bit Adam") store per-tensor absmax scales — at 405B params
the fp32-moment footprint alone (12.7 GB/chip on a 256-chip pod) would blow
the v5e HBM budget; int8 moments cut optimizer state 4x.  Moment states
inherit the parameter's sharding, i.e. ZeRO-style: each device only holds the
moments for its parameter shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # float32 | bfloat16 | int8
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: AdamWConfig) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return lr


# ---------------------------------------------------------------------------
# Quantized moment storage
# ---------------------------------------------------------------------------

def _quantize(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _dequantize(m: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return m["q"].astype(jnp.float32) * m["s"]


def _store(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _load(m, dtype: str) -> jnp.ndarray:
    if dtype == "int8":
        return _dequantize(m)
    return m.astype(jnp.float32)


def adamw_init(params, cfg: AdamWConfig):
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _store(z, cfg.moment_dtype)

    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig,
                 lr_fn: Callable) -> Tuple[Any, Any, Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_fn(step)
    is_q = cfg.moment_dtype == "int8"

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = _load(m, cfg.moment_dtype)
        v_f = _load(v, cfg.moment_dtype)
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        t = step.astype(jnp.float32)
        m_hat = m_f / (1 - cfg.b1**t)
        v_hat = v_f / (1 - cfg.b2**t)
        upd = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, _store(m_f, cfg.moment_dtype), _store(v_f, cfg.moment_dtype)

    quant_leaf = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"], is_leaf=quant_leaf) if is_q \
        else jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"], is_leaf=quant_leaf) if is_q \
        else jax.tree.leaves(opt_state["v"])
    out = [leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
