"""Train step builder: microbatched gradient accumulation, AdamW update,
optional int8+error-feedback gradient compression across the pod (DCN) axis.

The returned step has signature (params, opt_state, batch) -> (params,
opt_state, metrics) and is what launch/dryrun.py lowers for every
(arch x train shape x mesh) cell.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import compression
from repro.distributed.sharding import Recipe, ShardingCtx
from repro.models import model as model_mod
from repro.train import optimizer as opt_mod

__all__ = ["make_train_step", "split_microbatches"]


def _batch_axis(key: str) -> int:
    return 1 if key == "positions_3d" else 0


def split_microbatches(batch: Dict[str, Any], mb: int) -> Dict[str, Any]:
    """Reshape each input so dim 0 indexes the microbatch."""
    out = {}
    for k, x in batch.items():
        ax = _batch_axis(k)
        b = x.shape[ax]
        assert b % mb == 0, (k, b, mb)
        new_shape = x.shape[:ax] + (mb, b // mb) + x.shape[ax + 1:]
        x = x.reshape(new_shape)
        out[k] = jnp.moveaxis(x, ax, 0)
    return out


def _cast_compute(params, cfg: ModelConfig):
    """Mixed precision: fp32 master params compute in bf16 (halves the FSDP
    gather payload and every activation)."""
    if cfg.dtype != "bfloat16":
        return params
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
        params)


def _grads_fn(params, batch, cfg: ModelConfig, ctx: ShardingCtx):
    """Microbatched value_and_grad.

    Gradients are taken w.r.t. the bf16 COMPUTE copy of the params and
    accumulated in ``recipe.grad_dtype`` — with fp32 accumulation a 405B
    model carries 2 x 6.3 GiB/device of gradient state through the scan; bf16
    halves it (update math still runs in f32 inside AdamW).
    """
    mb = ctx.recipe.microbatch
    gdt = jnp.bfloat16 if ctx.recipe.grad_dtype == "bfloat16" else jnp.float32
    loss_of = lambda p, b: model_mod.loss_fn(p, cfg, b, ctx)

    params_c = _cast_compute(params, cfg)
    if mb <= 1:
        loss, g = jax.value_and_grad(loss_of)(params_c, batch)
        return loss, jax.tree.map(lambda x: x.astype(gdt), g)
    split = split_microbatches(batch, mb)
    if ctx.recipe.unroll_microbatches:
        loss_sum = jnp.zeros(())
        g_sum = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
        for i in range(mb):
            mb_batch = {k: v[i] for k, v in split.items()}
            loss, g = jax.value_and_grad(loss_of)(params_c, mb_batch)
            g_sum = jax.tree.map(lambda a, b: a + b.astype(gdt), g_sum, g)
            loss_sum = loss_sum + loss
        inv = 1.0 / mb
        return loss_sum * inv, jax.tree.map(lambda g: (g * inv).astype(gdt), g_sum)

    def accum(carry, mb_batch):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(loss_of)(params_c, mb_batch)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(gdt), g_acc, g)
        return (loss_acc + loss, g_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
    (loss_sum, g_sum), _ = jax.lax.scan(accum, (jnp.zeros(()), zeros), split)
    inv = 1.0 / mb
    return loss_sum * inv, jax.tree.map(lambda g: (g * inv).astype(gdt), g_sum)


def _strip_pod(recipe: Recipe) -> Recipe:
    """Inside shard_map the 'pod' axis is Manual; inner model constraints
    must only reference the remaining (Auto) axes."""
    f = lambda axes: tuple(a for a in axes if a != "pod")
    return dataclasses.replace(
        recipe,
        batch_axes=f(recipe.batch_axes), fsdp_axes=f(recipe.fsdp_axes),
        tp_axes=f(recipe.tp_axes), ep_axes=f(recipe.ep_axes),
        seq_axes=f(recipe.seq_axes), act_embed_axes=f(recipe.act_embed_axes),
        kv_batch_axes=(f(recipe.kv_batch_axes)
                       if recipe.kv_batch_axes is not None else None),
        kv_seq_axes=f(recipe.kv_seq_axes))


def make_train_step(cfg: ModelConfig, recipe: Recipe, mesh,
                    opt_cfg: opt_mod.AdamWConfig):
    lr_fn = opt_mod.cosine_schedule(opt_cfg)
    compress = (recipe.compress_pod_grads and mesh is not None
                and "pod" in mesh.axis_names)
    ctx = ShardingCtx(mesh, _strip_pod(recipe) if compress else recipe)

    def body(params, opt_state, batch):
        loss, grads = _grads_fn(params, batch, cfg, ctx)
        if compress:
            grads, new_ef = compression_tree(grads, opt_state["ef"])
            opt_state = dict(opt_state, ef=new_ef)
        core = {k: opt_state[k] for k in ("m", "v", "step")}
        new_params, new_core, metrics = opt_mod.adamw_update(
            grads, core, params, opt_cfg, lr_fn)
        new_opt = dict(opt_state, **new_core)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    if not compress:
        return body

    n_pods = mesh.shape["pod"]

    def compression_tree(grads, ef):
        def one(g, e):
            mean, new_e = compression._pod_gather_mean(g, e, n_pods)
            return mean, new_e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
                jax.tree.unflatten(tdef, [o[1] for o in outs]))

    def pod_specs(batch):
        return {k: P(*([None] * _batch_axis(k) + ["pod"])) for k in batch}

    def stepped(params, opt_state, batch):
        wrapped = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), pod_specs(batch)),
            out_specs=(P(), P(), P()),
            axis_names=frozenset({"pod"}),
            check_vma=False,
        )
        return wrapped(params, opt_state, batch)

    return stepped


def init_opt_state(params, cfg: ModelConfig, recipe: Recipe,
                   opt_cfg: opt_mod.AdamWConfig):
    state = opt_mod.adamw_init(params, opt_cfg)
    if recipe.compress_pod_grads:
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state
