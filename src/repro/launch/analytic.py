"""Analytic per-device roofline accounting.

Why analytic: XLA's ``compiled.cost_analysis()`` counts a while-loop body
ONCE, so any scan (layers, KV blocks, SSD chunks, microbatches) makes its
FLOPs/bytes meaningless at production depth (verified empirically — see
EXPERIMENTS.md §Roofline methodology).  We therefore account the dominant
terms in closed form from the einsum dimensions — the same arithmetic the
lowered HLO performs — and cross-validate against ``cost_analysis`` on small
unrolled configs in tests/test_roofline_validation.py.

Accounting policy (documented, deliberately conservative):
 * FLOPs: every matmul/einsum at 2*m*k*n; attention counted as implemented
   (full S^2, no causal pruning — the blockwise scan really does that);
   train = 3x forward matmul FLOPs (bwd two matmuls per fwd matmul);
   elementwise/norm/rope excluded (<3%).
 * HBM bytes: weights touched per step (FSDP-gathered copies read once per
   microbatch, x2 for nested-remat recompute), activations at major-op
   read+write granularity, KV-cache/state traffic, optimizer state traffic.
 * Collectives: FSDP weight all-gathers, gradient reduce-scatter+all-gather
   (or DCN all-reduce across pods), TP activation all-reduces, vocab-parallel
   logits reductions, decode split-K softmax reductions.

Everything is PER DEVICE, matching the SPMD per-device program.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import Recipe
from repro.models.params import padded_experts

__all__ = ["CellCost", "cell_cost"]

BF16 = 2
F32 = 4


@dataclasses.dataclass
class CellCost:
    flops: float                 # per device
    hbm_bytes: float             # per device
    collective_bytes: float      # per device (ICI)
    dcn_bytes: float             # per device (cross-pod)
    model_flops: float           # 6*N*D (train) / 2*N_active*tokens (serve), global
    breakdown: Dict[str, float]

    def terms(self, hw, n_devices: int) -> Dict[str, float]:
        return {
            "compute_s": self.flops / hw.peak_flops,
            "memory_s": self.hbm_bytes / hw.hbm_bw,
            "collective_s": self.collective_bytes / hw.ici_bw
            + self.dcn_bytes / hw.dcn_bw,
        }


def _mesh_sizes(recipe: Recipe, mesh_shape: Dict[str, int]):
    dp = int(np.prod([mesh_shape.get(a, 1) for a in recipe.batch_axes]))
    fsdp = int(np.prod([mesh_shape.get(a, 1) for a in recipe.fsdp_axes]))
    tp = int(np.prod([mesh_shape.get(a, 1) for a in recipe.tp_axes]))
    pods = mesh_shape.get("pod", 1)
    return dp, fsdp, tp, pods


def _layer_matmul_flops_per_token(cfg: ModelConfig) -> float:
    """2 * (active matmul params per layer) — projections only."""
    d, hd = cfg.d_model, cfg.head_dim
    attn = d * (cfg.num_heads * hd) + 2 * d * (cfg.num_kv_heads * hd) \
        + (cfg.num_heads * hd) * d
    mult = 3 if cfg.mlp_type == "swiglu" else 2
    if cfg.is_moe:
        fe = cfg.moe_d_ff or cfg.d_ff
        eff_experts = cfg.experts_per_tok * cfg.capacity_factor
        mlp = (eff_experts + cfg.num_shared_experts) * mult * d * fe \
            + d * cfg.num_experts
    else:
        mlp = mult * d * cfg.d_ff
    return 2.0 * (attn + mlp)


def _rwkv_layer_flops_per_token(cfg: ModelConfig) -> float:
    d, f = cfg.d_model, cfg.d_ff
    h, hd = cfg.num_heads, cfg.ssm_head_dim
    c = cfg.chunk_size
    proj = 2.0 * (5 * d * d + d * 5 * 32 * 2 + d * 64 * 2)        # r,k,v,g,out + loras
    # chunked WKV per token: att row (c * hd), state in/out (2 * hd^2), pv (c * hd)
    wkv = 2.0 * h * (2 * c * hd + 3 * hd * hd)
    cm = 2.0 * (d * f * 2 + d * d)
    return proj + wkv + cm


def _mamba_layer_flops_per_token(cfg: ModelConfig) -> float:
    d = cfg.d_model
    din = cfg.expand * d
    n = cfg.ssm_state_dim
    h = din // cfg.ssm_head_dim
    p = cfg.ssm_head_dim
    c = cfg.chunk_size
    proj = 2.0 * (2 * d * din + 2 * d * n + d * h + din * d)
    conv = 2.0 * cfg.conv_width * din
    # chunked SSD per token: cb (c*n), att*x (c*h*p), state io (2*h*p*n)
    ssd = 2.0 * (c * n + c * h * p + 3 * h * p * n)
    return proj + conv + ssd


def _attn_quadratic_flops(cfg: ModelConfig, tokens: float, kv_len: float) -> float:
    """scores + pv: 4 * H * Dh per (token x kv) pair — as implemented (no
    causal pruning in the blockwise scan)."""
    return 4.0 * cfg.num_heads * cfg.head_dim * tokens * kv_len


def _param_bytes(cfg: ModelConfig, dtype_bytes: int) -> float:
    return float(cfg.param_count()) * dtype_bytes


def cell_cost(cfg: ModelConfig, shape: ShapeSpec, recipe: Recipe,
              mesh_shape: Dict[str, int]) -> CellCost:
    dp, fsdp, tp, pods = _mesh_sizes(recipe, mesh_shape)
    n_dev = int(np.prod(list(mesh_shape.values())))
    b, s = shape.global_batch, shape.seq_len
    l = cfg.num_layers
    d, v = cfg.d_model, cfg.vocab_size

    # --- tokens processed this step
    if shape.kind == "train":
        tokens = float(b) * s
    elif shape.kind == "prefill":
        tokens = float(b) * s
    else:
        tokens = float(b)

    # --- per-layer forward matmul flops per token
    if cfg.family == "rwkv":
        per_layer = _rwkv_layer_flops_per_token(cfg)
    elif cfg.family == "hybrid":
        g = l // cfg.shared_attn_every
        per_layer = _mamba_layer_flops_per_token(cfg)  # for each mamba layer
    else:
        per_layer = _layer_matmul_flops_per_token(cfg)

    fwd = per_layer * l * tokens
    if cfg.family == "hybrid":
        g = l // cfg.shared_attn_every
        fwd += g * _layer_matmul_flops_per_token(cfg) * tokens  # shared blocks

    # attention quadratic term
    kv_len = float(s) if shape.kind != "decode" else float(s)
    attn_q = 0.0
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        per_tok_kv = kv_len
        attn_q = _attn_quadratic_flops(cfg, tokens, per_tok_kv) * l
    elif cfg.family == "hybrid":
        g = l // cfg.shared_attn_every
        attn_q = _attn_quadratic_flops(cfg, tokens, kv_len) * g

    # lm head (+ embedding matmul-free)
    heads = max(1, cfg.num_codebooks or 1)
    if shape.kind == "train":
        head_flops = 2.0 * tokens * d * v * heads
    elif shape.kind == "prefill":
        head_flops = 2.0 * b * d * v * heads          # last position only
    else:
        head_flops = 2.0 * b * d * v * heads

    mult = 3.0 if shape.kind == "train" else 1.0
    total_flops = mult * (fwd + attn_q + head_flops)
    flops_dev = total_flops / n_dev

    # --- model flops (the "useful" 6ND yardstick)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * tokens

    # --- HBM bytes per device
    p_total = float(cfg.param_count())
    p_dev = p_total / (fsdp * tp)                      # 2D-sharded storage
    bk = {}
    if shape.kind == "train":
        mb = max(recipe.microbatch, 1)
        remat_mult = 2.0 if recipe.remat in ("block", "nested") else 1.0
        # weights: gathered bf16 copy read per microbatch (and per remat pass)
        w_bytes = p_total / tp * BF16 * mb * (1 + remat_mult)  # fwd + bwd reads
        opt_state_b = {"int8": 2, "bfloat16": 4}.get(
            recipe.moment_dtype or cfg.opt_moment_dtype, 8)
        master_b = BF16 if recipe.param_dtype == "bfloat16" else F32
        grad_b = BF16 if recipe.grad_dtype == "bfloat16" else F32
        opt_bytes = p_dev * (master_b * 2 + opt_state_b * 2 + grad_b * 2 + BF16)
        act_elems = tokens * d * (14 if not cfg.is_moe else 20) * l
        act_bytes = act_elems * BF16 * remat_mult / n_dev
        logits_bytes = 2 * tokens * v * F32 / n_dev * heads
        hbm = w_bytes / 1 + opt_bytes + act_bytes + logits_bytes
        bk.update(weights=w_bytes, opt=opt_bytes, acts=act_bytes,
                  logits=logits_bytes)
    elif shape.kind == "prefill":
        w_bytes = p_total / tp * BF16
        act_bytes = tokens * d * 14 * l * BF16 / n_dev
        cache_bytes = 2.0 * l * b * s * cfg.num_kv_heads * cfg.head_dim * BF16 / n_dev
        hbm = w_bytes + act_bytes + cache_bytes
        bk.update(weights=w_bytes, acts=act_bytes, cache=cache_bytes)
    else:  # decode
        w_bytes = p_total / (fsdp * tp) * BF16         # every param read once
        kv_b = 1 if recipe.kv_cache_dtype == "int8" else BF16
        if cfg.family == "rwkv":
            state = l * b * cfg.num_heads * cfg.ssm_head_dim**2 * F32 * 2
        elif cfg.family == "hybrid":
            g = l // cfg.shared_attn_every
            din = cfg.expand * d
            state = l * b * (din // cfg.ssm_head_dim) * cfg.ssm_head_dim \
                * cfg.ssm_state_dim * F32 * 2
            state += 2.0 * g * b * s * cfg.num_kv_heads * cfg.head_dim * kv_b
        else:
            # int8 cache adds per-(token,head) f32 scales (~Dh/4 overhead)
            scale_b = (F32 / cfg.head_dim) if kv_b == 1 else 0.0
            state = 2.0 * l * b * s * cfg.num_kv_heads * cfg.head_dim * (kv_b + scale_b)
        hbm = w_bytes + state / n_dev   # state is global, sharded over devices
        bk.update(weights=w_bytes, cache=state / n_dev)

    # --- collective bytes per device
    ici = 0.0
    dcn = 0.0
    if shape.kind == "train":
        mb = max(recipe.microbatch, 1)
        # FSDP all-gather: each device receives the other shards, per mb and
        # again for the remat backward pass.
        gather_passes = mb * (2 if recipe.remat != "none" else 1) + mb  # fwd(+remat) + bwd
        ici += (p_total / tp * BF16) * (1 - 1 / fsdp) * gather_passes
        # grad reduce-scatter + all-gather of updates (~2x shard traffic)
        ici += 2.0 * (p_total / tp * F32) * (1 - 1 / fsdp)
        # TP activation all-reduces: 2 sublayers per layer, ring 2x payload
        tp_payload = tokens / dp / max(pods, 1) * d * BF16
        if tp > 1:
            ici += 2.0 * l * mb * 2.0 * (tp_payload / mb) * (1 - 1 / tp)
        # logits reduction (vocab-parallel softmax): per token scalar-ish — skip
        if pods > 1:
            grad_payload = p_total / (fsdp * tp) * (1 if recipe.compress_pod_grads else 4)
            dcn += 2.0 * grad_payload * (1 - 1 / pods)
    else:
        # serving: weight gathers only if fsdp-sharded storage feeds compute;
        # the weight-stationary recipe (act_embed sharding) replaces them
        # with per-layer activation all-reduces.
        if recipe.act_embed_axes:
            layers_n = cfg.num_layers
            ici += 2.0 * layers_n * (tokens * cfg.d_model * BF16) * (1 - 1 / fsdp)
        elif fsdp > 1:
            ici += (p_total / tp * BF16) * (1 - 1 / fsdp)
        if tp > 1:
            tp_payload = tokens / dp / max(pods, 1) * d * BF16
            n_attn = (cfg.num_layers if cfg.family not in ("rwkv", "hybrid")
                      else (cfg.num_layers // max(cfg.shared_attn_every, 1)
                            if cfg.family == "hybrid" else 0))
            layers_with_tp = cfg.num_layers
            ici += 2.0 * layers_with_tp * tp_payload * (1 - 1 / tp)
        if shape.kind == "decode" and cfg.family not in ("rwkv",):
            # split-K softmax partials across kv_seq shards
            ici += b / dp * cfg.num_heads * cfg.head_dim * F32 * \
                cfg.num_layers * (1 - 1 / tp) * 2

    return CellCost(
        flops=flops_dev,
        hbm_bytes=hbm,
        collective_bytes=ici,
        dcn_bytes=dcn,
        model_flops=model_flops,
        breakdown=bk,
    )
