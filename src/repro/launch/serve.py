"""Serving launcher: batched greedy generation with the unified engine +
CAM-guided KV pool planning report.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.planner import RequestMix, plan_kv_pool


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-34b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    full_cfg = cfg
    if args.reduced:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params,
                         max_seq=args.prompt_len + args.new_tokens + 8)

    rng = np.random.default_rng(args.seed)
    shape = (args.batch, args.prompt_len)
    if cfg.family == "audio":
        shape = shape + (cfg.num_codebooks,)
    prompts = rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)
    res = engine.generate(prompts, max_new_tokens=args.new_tokens)
    print(f"arch={cfg.name}: generated {res.steps} tokens/seq for "
          f"{args.batch} seqs; prefill={res.prefill_seconds:.2f}s "
          f"decode={res.decode_seconds:.2f}s "
          f"({args.batch * res.steps / max(res.decode_seconds, 1e-9):.1f} tok/s)")

    # CAM-guided KV pool plan for the FULL config at production scale
    kv_bpt = 2 * full_cfg.num_layers * full_cfg.num_kv_heads * \
        full_cfg.head_dim * 2
    mix = RequestMix(n_requests=64, shared_prefix=2048, mean_context=8192,
                     decode_steps=256, kv_bytes_per_token=kv_bpt)
    weight_bytes = full_cfg.param_count() * 2 / 256     # bf16, sharded
    plan = plan_kv_pool(mix, hbm_budget_bytes=16 * 2**30,
                        weight_bytes=weight_bytes)
    print(f"CAM KV plan ({full_cfg.name}): block={plan.block_tokens} tokens, "
          f"pool={plan.pool_blocks} blocks, est hit={plan.hit_rate:.3f}, "
          f"est transfer/step={plan.transfer_bytes_per_step/2**20:.2f} MiB")


if __name__ == "__main__":
    main()
