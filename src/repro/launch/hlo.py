"""HLO text analysis: collective-byte accounting for the roofline.

``cost_analysis()`` does not report communication, so we parse the compiled
module text and sum the output-shape bytes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
Ops inside while-loop bodies (lax.scan) appear once in the text — the
roofline driver compensates with the unroll-delta extrapolation, so this
parser is only ever pointed at straight-line (unrolled) modules for counting,
and at scanned modules for the *schedule* (which collectives exist).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["collective_bytes", "collective_schedule", "parse_shape_bytes"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],\s{}/#*]+\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)


def parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of one 'dtype[d,d,...]' (or tuple of them)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",") if d]))
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_schedule(hlo_text: str) -> List[Tuple[str, int]]:
    """[(op_kind, output_bytes)] for every collective in program order.
    '-start'/'-done' async pairs are counted once (at start)."""
    out = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if m:
            out.append((m.group(2).lower(), parse_shape_bytes(m.group(1))))
    return out


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Total output bytes per collective kind (+ 'total')."""
    totals: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for kind, nbytes in collective_schedule(hlo_text):
        totals[kind] += nbytes
    totals["total"] = sum(totals[k] for k in _COLLECTIVES)
    return totals
