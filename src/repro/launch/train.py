"""Training launcher.

CPU-runnable end-to-end (reduced configs by default) and cluster-shaped: the
same Supervisor/checkpoint/pipeline path the production meshes use.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.data.pipeline import TokenPipeline
from repro.distributed.fault_tolerance import FailureInjector, Supervisor
from repro.distributed.sharding import Recipe, ShardingCtx
from repro.models.params import init_params
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


def build_trainer(cfg, recipe, opt_cfg, mesh=None):
    step_fn = ts_mod.make_train_step(cfg, recipe, mesh, opt_cfg)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    def supervised_step(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = jit_step(state["params"],
                                              state["opt_state"], batch)
        return {"params": params, "opt_state": opt_state}, metrics

    return supervised_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject SimulatedFailure at these steps (FT demo)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    recipe = Recipe(remat="block", microbatch=args.microbatch)
    opt_cfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=5,
                                  total_steps=args.steps,
                                  moment_dtype=cfg.opt_moment_dtype
                                  if not args.reduced else "float32")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params:,}")

    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, args.seed,
                         num_codebooks=cfg.num_codebooks,
                         vlm_tokens=cfg.num_vision_tokens if cfg.family == "vlm" else 0,
                         patch_dim=cfg.vision_patch_dim)
    opt_state = ts_mod.init_opt_state(params, cfg, recipe, opt_cfg)
    step_fn = build_trainer(cfg, recipe, opt_cfg)
    sup = Supervisor(step_fn, {"params": params, "opt_state": opt_state},
                     pipe.batch_for_step, args.ckpt_dir,
                     ckpt_every=args.ckpt_every,
                     injector=FailureInjector(tuple(args.fail_at)))
    t0 = time.perf_counter()
    result = sup.run(args.steps)
    dt = time.perf_counter() - t0
    losses = result["losses"]
    print(f"steps={result['final_step']} restarts={result['restarts']} "
          f"stragglers={result['stragglers']} time={dt:.1f}s")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"(decreasing={losses[-1] < losses[0]})")


if __name__ == "__main__":
    main()
