"""Cell construction: (arch x shape x mesh x recipe) -> lowerable jit'd step.

Shared by dryrun.py (compile + memory/collective capture), roofline.py, and
the launchers.  Everything uses ShapeDtypeStructs — no allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import ParamDef, Recipe, ShardingCtx, tree_shardings
from repro.models import model as model_mod
from repro.models import params as params_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod

__all__ = ["default_recipe", "build_cell", "cell_skip_reason", "CellSpec"]


@dataclasses.dataclass(frozen=True)
class CellSpec:
    arch: str
    shape: str
    multi_pod: bool = False
    recipe_overrides: Tuple[Tuple[str, Any], ...] = ()

    def resolve(self):
        cfg = get_config(self.arch)
        shape = SHAPES[self.shape]
        return cfg, shape


def cell_skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("skipped: pure full-attention arch; long_500k requires "
                "sub-quadratic attention (see DESIGN.md §Arch-applicability)")
    return None


def optimized_overrides(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Beyond-baseline recipe per cell, from the §Perf hillclimb:

    * decode, wide dense models — weight-stationary decode (B1): shard the
      residual d_model on "data" instead of all-gathering 50 GB of weights
      per token step (86x less in-loop collective traffic);
    * decode, any transformer — int8 KV cache with per-(token,head) scales
      (C1): halves the dominant cache-streaming HBM term;
    * train, d_model >= 16384 — bf16 master params (A5) on top of the
      baseline's bf16 grad accumulation.
    """
    ov: Dict[str, Any] = {}
    if shape.kind == "decode" and cfg.family in ("dense", "moe", "vlm", "audio"):
        ov["kv_cache_dtype"] = "int8"
        if cfg.d_model >= 7168:
            ov.update(batch_axes=(), act_embed_axes=("data",),
                      kv_batch_axes=("data",))
    if shape.kind == "train" and cfg.d_model >= 16384:
        ov["param_dtype"] = "bfloat16"
    return ov


def default_recipe(cfg: ModelConfig, shape: ShapeSpec,
                   multi_pod: bool = False, **overrides) -> Recipe:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    kw: Dict[str, Any] = dict(batch_axes=batch_axes)
    if shape.kind == "train":
        # Gradient accumulation sized so activation checkpoints fit HBM:
        # target tokens/device/microbatch by model width.
        dp = 32 if multi_pod else 16
        per_dev_batch = max(1, shape.global_batch // dp)
        target_tokens = 16384 if cfg.d_model <= 3072 else \
            8192 if cfg.d_model <= 8192 else 4096
        want_mb = max(1, (per_dev_batch * shape.seq_len) // target_tokens)
        mb = 1
        while mb * 2 <= min(want_mb, per_dev_batch):
            mb *= 2
        kw["microbatch"] = mb
        kw["remat"] = "nested" if cfg.num_layers >= 32 else "block"
        if cfg.d_model >= 8192:
            kw["grad_dtype"] = "bfloat16"
    else:
        kw["remat"] = "none"
    kw.update(overrides)
    return Recipe(**kw)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _opt_shardings(ctx: ShardingCtx, defs, moment_dtype: str, compress: bool):
    p_sh = tree_shardings(ctx, defs)
    rep = _replicated(ctx.mesh)
    if moment_dtype == "int8":
        moments = jax.tree.map(lambda s: {"q": s, "s": rep}, p_sh,
                               is_leaf=lambda x: isinstance(x, NamedSharding))
    else:
        moments = p_sh
    state = {"m": moments, "v": moments, "step": rep}
    if compress:
        state["ef"] = p_sh
    return state


def _batch_shardings(ctx: ShardingCtx, cfg, shape):
    sds = model_mod.input_specs(cfg, shape)
    dims = model_mod.input_dims(cfg, shape)
    return {k: ctx.sharding(sds[k].shape, dims[k]) for k in sds}


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, recipe: Recipe):
    """Returns (jitted_fn, args_sds: tuple, in_shardings: tuple)."""
    ctx = ShardingCtx(mesh, recipe)
    defs = params_mod.param_defs(cfg)

    if shape.kind == "train":
        pdt = jnp.bfloat16 if recipe.param_dtype == "bfloat16" else jnp.float32
        params_sds = params_mod.param_shapes(cfg, pdt)
        param_sh = tree_shardings(ctx, defs)
        moment_dtype = recipe.moment_dtype or cfg.opt_moment_dtype
        opt_cfg = opt_mod.AdamWConfig(moment_dtype=moment_dtype)
        opt_sds = jax.eval_shape(
            lambda p: ts_mod.init_opt_state(p, cfg, recipe, opt_cfg), params_sds)
        opt_sh = _opt_shardings(ctx, defs, moment_dtype,
                                recipe.compress_pod_grads and mesh is not None
                                and "pod" in mesh.axis_names)
        batch_sds = model_mod.input_specs(cfg, shape)
        batch_sh = _batch_shardings(ctx, cfg, shape)
        step = ts_mod.make_train_step(cfg, recipe, mesh, opt_cfg)
        fn = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh),
                     donate_argnums=(0, 1))
        return fn, (params_sds, opt_sds, batch_sds)

    params_sds = params_mod.param_shapes(cfg, jnp.bfloat16)
    param_sh = tree_shardings(ctx, defs)
    batch_sds = model_mod.input_specs(cfg, shape)
    batch_sh = _batch_shardings(ctx, cfg, shape)

    if shape.kind == "prefill":
        def prefill(params, batch):
            return model_mod.prefill_fn(params, cfg, batch, ctx)

        fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh))
        return fn, (params_sds, batch_sds)

    # decode
    kv_dtype = jnp.int8 if recipe.kv_cache_dtype == "int8" else jnp.bfloat16
    cache_sds = model_mod.cache_specs(cfg, shape, kv_dtype)
    cdims = model_mod.cache_dims(cfg)
    cache_sh = {k: ctx.sharding(cache_sds[k].shape, cdims[k]) for k in cache_sds}

    def decode(params, batch, cache):
        return model_mod.decode_fn(params, cfg, batch, cache, ctx)

    fn = jax.jit(decode, in_shardings=(param_sh, batch_sh, cache_sh),
                 donate_argnums=(2,))
    return fn, (params_sds, batch_sds, cache_sds)
