"""Roofline analysis driver (§Roofline of EXPERIMENTS.md).

Per (arch x shape) on the single-pod mesh:
  compute_s    = FLOPs_per_device / 197e12        (bf16 peak, v5e)
  memory_s     = HBM_bytes_per_device / 819e9
  collective_s = ICI_bytes_per_device / 50e9 (+ DCN term if pods > 1)

FLOPs/bytes/collective-bytes come from launch/analytic.py (closed-form einsum
accounting) because XLA's cost_analysis counts while-loop bodies ONCE — with
scan-over-layers the numbers are off by ~L at production depth.  The analytic
model is validated against cost_analysis on L=1 configs (where scan body ==
whole depth) in tests/test_roofline_validation.py, and the dry-run captures
the real compiled collective schedule per cell.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --all --out results/roofline.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.launch import analytic
from repro.launch.cells import (cell_skip_reason, default_recipe,
                                optimized_overrides)
from repro.launch.mesh import V5E

__all__ = ["roofline_cell", "roofline_table"]


def roofline_cell(arch: str, shape_name: str, multi_pod: bool = False,
                  recipe_overrides: Optional[dict] = None,
                  optimized: bool = False) -> Dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    if optimized:
        recipe_overrides = {**optimized_overrides(cfg, shape),
                            **(recipe_overrides or {})}
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    skip = cell_skip_reason(cfg, shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec
    mesh_shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                  else {"data": 16, "model": 16})
    n_dev = int(np.prod(list(mesh_shape.values())))
    recipe = default_recipe(cfg, shape, multi_pod, **(recipe_overrides or {}))
    cost = analytic.cell_cost(cfg, shape, recipe, mesh_shape)
    terms = cost.terms(V5E, n_dev)
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())     # perfect-overlap lower bound
    hlo_flops_global = cost.flops * n_dev
    rec.update(
        status="ok",
        recipe={"microbatch": recipe.microbatch, "remat": recipe.remat},
        flops_per_device=cost.flops,
        hbm_bytes_per_device=cost.hbm_bytes,
        ici_bytes_per_device=cost.collective_bytes,
        dcn_bytes_per_device=cost.dcn_bytes,
        terms_s={k: float(v) for k, v in terms.items()},
        dominant=dominant,
        model_flops=cost.model_flops,
        useful_flops_ratio=float(cost.model_flops / max(hlo_flops_global, 1.0)),
        # roofline fraction: useful model FLOP/s achieved at the bound
        # implied by the dominant term, vs chip peak.
        roofline_fraction=float(
            cost.model_flops / max(step_time, 1e-12) / (n_dev * V5E.peak_flops)),
        step_time_lower_bound_s=float(step_time),
        breakdown={k: float(v) for k, v in cost.breakdown.items()},
    )
    return rec


def roofline_table(multi_pod: bool = False, optimized: bool = False):
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            rows.append(roofline_cell(arch, shape, multi_pod,
                                      optimized=optimized))
    return rows


def _fmt_row(r: Dict) -> str:
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — |")
    t = r["terms_s"]
    return ("| {arch} | {shape} | {c:.2e} | {m:.2e} | {x:.2e} | {dom} | "
            "{ratio:.2f} | {rf:.1%} |".format(
                arch=r["arch"], shape=r["shape"], c=t["compute_s"],
                m=t["memory_s"], x=t["collective_s"], dom=r["dominant"],
                ratio=r["useful_flops_ratio"], rf=r["roofline_fraction"]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()
    if args.all:
        rows = roofline_table(args.multi_pod, args.optimized)
    else:
        rows = [roofline_cell(args.arch, args.shape, args.multi_pod,
                              optimized=args.optimized)]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| useful/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(_fmt_row(r))


if __name__ == "__main__":
    main()
