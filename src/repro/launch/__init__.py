"""Launch layer: meshes, dry-run, roofline, train/serve CLIs.

NOTE: do not import repro.launch.dryrun from here — it sets XLA_FLAGS at
import time and must only be imported as the program entry point.
"""
from repro.launch import analytic, cells, hlo, mesh

__all__ = ["analytic", "cells", "hlo", "mesh"]
