"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: 16x16 = 256 chips (v5e pod, 2D ICI torus).  Multi-pod:
2 pods x 256 chips with a leading "pod" axis over DCN.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API; older versions predate AxisType
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

__all__ = ["make_production_mesh", "HardwareSpec", "V5E"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


class HardwareSpec:
    """Roofline constants for the target chip."""

    def __init__(self, name: str, peak_flops: float, hbm_bw: float,
                 ici_bw: float, hbm_bytes: float, dcn_bw: float = 25e9):
        self.name = name
        self.peak_flops = peak_flops      # bf16 FLOP/s per chip
        self.hbm_bw = hbm_bw              # bytes/s per chip
        self.ici_bw = ici_bw              # bytes/s per ICI link
        self.hbm_bytes = hbm_bytes        # HBM capacity per chip
        self.dcn_bw = dcn_bw              # bytes/s per chip across pods


V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                   ici_bw=50e9, hbm_bytes=16 * 2**30)
