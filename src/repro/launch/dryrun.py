import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax locks
the device count at first init, and the production meshes need 512 host
placeholder devices.

Per cell we record: compile success, per-device memory analysis (argument /
output / temp / peak bytes — the "fits in HBM" proof), cost_analysis (with
its scan-body caveat), and the collective schedule parsed from the compiled
HLO.  Results append to a JSONL so the sweep is resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.jsonl]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES
from repro.launch import hlo as hlo_mod
from repro.launch.cells import (build_cell, cell_skip_reason, default_recipe,
                                optimized_overrides)
from repro.launch.mesh import V5E, make_production_mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             recipe_overrides=None, verbose: bool = True,
             optimized: bool = False) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    if optimized:
        recipe_overrides = {**optimized_overrides(cfg, shape),
                            **(recipe_overrides or {})}
    rec = {"arch": arch, "shape": shape_name, "optimized": optimized,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    skip = cell_skip_reason(cfg, shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec
    t0 = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        recipe = default_recipe(cfg, shape, multi_pod,
                                **(recipe_overrides or {}))
        with jax.set_mesh(mesh):
            fn, args = build_cell(cfg, shape, mesh, recipe)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            text = compiled.as_text()
        coll = hlo_mod.collective_bytes(text)
        sched = hlo_mod.collective_schedule(text)
        rec.update(
            status="ok",
            compile_seconds=round(time.perf_counter() - t0, 2),
            recipe={"microbatch": recipe.microbatch, "remat": recipe.remat,
                    "batch_axes": recipe.batch_axes,
                    "fsdp_axes": recipe.fsdp_axes,
                    "compress_pod_grads": recipe.compress_pod_grads},
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_bytes": ma.peak_memory_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            cost={"flops_per_device_scanbody": ca.get("flops", 0.0),
                  "bytes_accessed_scanbody": ca.get("bytes accessed", 0.0)},
            collectives={"kinds": sorted({k for k, _ in sched}),
                         "n_ops": len(sched), **coll},
        )
        # live-bytes estimate: args are resident (params/opt/cache), temps peak
        resident = ma.argument_size_in_bytes + ma.output_size_in_bytes \
            - ma.alias_size_in_bytes
        rec["memory"]["resident_plus_temp"] = resident + ma.temp_size_in_bytes
        rec["memory"]["fits_16g"] = bool(
            resident + ma.temp_size_in_bytes <= V5E.hbm_bytes)
    except Exception as e:  # noqa: BLE001 — sweep must survive cell failures
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:],
                   compile_seconds=round(time.perf_counter() - t0, 2))
    if verbose:
        mem = rec.get("memory", {})
        print(f"[{rec['mesh']}] {arch} x {shape_name}: {rec['status']}"
              + (f" peak={mem.get('peak_bytes', 0)/2**30:.2f}GiB"
                 f" resident+temp={mem.get('resident_plus_temp', 0)/2**30:.2f}GiB"
                 f" fits16G={mem.get('fits_16g')}"
                 f" t={rec.get('compile_seconds')}s"
                 if rec["status"] == "ok" else f" {rec.get('reason') or rec.get('error')}"),
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the hillclimbed per-cell recipe overrides")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    cells = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for mp in meshes:
            for arch in ARCHS:
                for shape in SHAPES:
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
    with open(args.out, "a") as f:
        for arch, shape, mp in cells:
            key = (arch, shape, "2x16x16" if mp else "16x16")
            if key in done:
                continue
            rec = run_cell(arch, shape, mp, optimized=args.optimized)
            f.write(json.dumps(rec) + "\n")
            f.flush()


if __name__ == "__main__":
    main()
