"""RadixSpline (Kipf et al., aiDM'20): single-pass error-bounded spline index.

Third index family under CAM (after PGM and RMI), demonstrating the paper's
index-agnosticism claim (§I property i): RadixSpline is error-bounded like
PGM — a greedy spline corridor guarantees |interp(k) - rank(k)| <= eps — so
the SAME CAM estimators apply with its fixed eps, no new modeling needed.

Build: one pass maintaining the feasible slope corridor from the last spline
knot; a radix table over key prefixes narrows the knot search at lookup.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["RadixSplineIndex", "build_radixspline"]


@dataclasses.dataclass(frozen=True)
class RadixSplineIndex:
    knots_key: np.ndarray       # (K,) spline knot keys
    knots_pos: np.ndarray       # (K,) knot ranks (float64)
    radix_table: np.ndarray     # (2^bits + 1,) knot index per key prefix
    radix_bits: int
    shift: int
    min_key: int
    eps: int
    n: int

    @property
    def size_bytes(self) -> int:
        return 16 * len(self.knots_key) + 4 * len(self.radix_table)

    def predict(self, query_keys: np.ndarray) -> np.ndarray:
        q = np.asarray(query_keys)
        # The radix table narrows the knot search on a real implementation
        # (its size is charged to the index footprint); the vectorized
        # reference path searches the knots directly — same result.
        idx = np.clip(np.searchsorted(self.knots_key, q, side="right") - 1,
                      0, len(self.knots_key) - 2)
        x0 = self.knots_key[idx].astype(np.float64)
        x1 = self.knots_key[idx + 1].astype(np.float64)
        y0 = self.knots_pos[idx]
        y1 = self.knots_pos[idx + 1]
        t = np.where(x1 > x0, (q.astype(np.float64) - x0) / (x1 - x0), 0.0)
        pred = y0 + np.clip(t, 0.0, 1.0) * (y1 - y0)
        return np.clip(np.floor(pred), 0, self.n - 1).astype(np.int64)

    def window(self, query_keys: np.ndarray):
        pred = self.predict(query_keys)
        lo = np.clip(pred - self.eps, 0, self.n - 1)
        hi = np.clip(pred + self.eps, 0, self.n - 1)
        return lo, hi


def build_radixspline(keys: np.ndarray, eps: int,
                      radix_bits: int = 16) -> RadixSplineIndex:
    """Greedy spline corridor (one pass) + radix table over key prefixes."""
    keys = np.asarray(keys)
    n = keys.shape[0]
    knots = [0]
    last = 0
    lo_s, hi_s = -np.inf, np.inf
    kf = keys.astype(np.float64)
    # GreedySplineCorridor: the line base->candidate must stay inside the
    # corridor accumulated from every interior point; tighten afterwards.
    for i in range(1, n):
        dx = kf[i] - kf[last]
        if dx <= 0:
            continue
        s = (i - last) / dx                     # slope of base -> candidate
        if s < lo_s or s > hi_s:
            knots.append(i - 1)                 # previous point becomes a knot
            last = i - 1
            dx = kf[i] - kf[last]
            lo_s, hi_s = -np.inf, np.inf
            if dx <= 0:
                continue
        lo_s = max(lo_s, (i - last - eps) / dx)
        hi_s = min(hi_s, (i - last + eps) / dx)
    if knots[-1] != n - 1:
        knots.append(n - 1)
    knot_idx = np.asarray(knots, np.int64)
    knots_key = keys[knot_idx]
    knots_pos = knot_idx.astype(np.float64)

    min_key = int(keys[0])
    key_range = int(keys[-1]) - min_key + 1
    shift = max(0, int(np.ceil(np.log2(max(key_range, 2)))) - radix_bits)
    prefixes = ((knots_key.astype(np.uint64) - np.uint64(min_key))
                >> np.uint64(shift)).astype(np.int64)
    table = np.zeros(2**radix_bits + 1, np.int64)
    np.maximum.at(table, prefixes + 1, np.arange(len(knots_key)))
    table = np.maximum.accumulate(table)
    return RadixSplineIndex(knots_key, knots_pos, table, radix_bits, shift,
                            min_key, int(eps), n)
