"""Two-layer RMI (Kraska et al., SIGMOD'18) with linear-spline leaf models.

Root: a linear CDF model routes a key to one of ``b`` leaves.  Leaves: per-leaf
linear least squares, fit with grouped closed-form regression (vectorized via
bincount — no per-leaf Python loop).  Unlike PGM there is no global error
bound: each leaf exposes its empirical max error ``eps_j`` (paper §V-C), and
the last-mile window for a query routed to leaf j is ±eps_j.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["RMIIndex", "build_rmi", "rmi_size_bytes"]

_BYTES_PER_LEAF = 24   # slope f8 + intercept f8 + eps i8
_BYTES_ROOT = 16


def rmi_size_bytes(branch: int) -> int:
    """Footprint of a branch-factor candidate WITHOUT building it.

    Root and per-leaf parameters are fixed-size, so RMI's size model is
    exact and analytic — which is what lets tuners drop budget-infeasible
    branches before paying an O(n) construction.
    """
    return _BYTES_ROOT + _BYTES_PER_LEAF * int(branch)


@dataclasses.dataclass(frozen=True)
class RMIIndex:
    root_slope: float
    root_intercept: float
    branch: int
    leaf_slope: np.ndarray      # (b,)
    leaf_intercept: np.ndarray  # (b,)
    leaf_x0: np.ndarray         # (b,) per-leaf centering key (first key)
    leaf_eps: np.ndarray        # (b,) int64 empirical max abs error
    n: int

    @property
    def size_bytes(self) -> int:
        return _BYTES_ROOT + _BYTES_PER_LEAF * self.branch

    def route(self, query_keys: np.ndarray) -> np.ndarray:
        q = np.asarray(query_keys).astype(np.float64)
        pos = self.root_slope * q + self.root_intercept
        leaf = np.floor(pos * self.branch / max(self.n, 1)).astype(np.int64)
        return np.clip(leaf, 0, self.branch - 1)

    def predict(self, query_keys: np.ndarray) -> np.ndarray:
        q = np.asarray(query_keys)
        leaf = self.route(q)
        dx = q.astype(np.float64) - self.leaf_x0[leaf]
        pred = self.leaf_slope[leaf] * dx + self.leaf_intercept[leaf]
        return np.clip(np.floor(pred), 0, self.n - 1).astype(np.int64)

    def window(self, query_keys: np.ndarray):
        """Per-query last-mile windows using the routed leaf's error bound."""
        q = np.asarray(query_keys)
        leaf = self.route(q)
        eps = self.leaf_eps[leaf]
        pred = self.predict(q)
        lo = np.clip(pred - eps, 0, self.n - 1)
        hi = np.clip(pred + eps, 0, self.n - 1)
        return lo, hi, eps

    def leaf_weights(self, query_keys: np.ndarray) -> np.ndarray:
        """Empirical routing distribution w_j of a workload (§V-C)."""
        leaf = self.route(query_keys)
        counts = np.bincount(leaf, minlength=self.branch).astype(np.float64)
        return counts / max(counts.sum(), 1.0)


def build_rmi(keys: np.ndarray, branch: int) -> RMIIndex:
    keys = np.asarray(keys)
    n = keys.shape[0]
    kf = keys.astype(np.float64)
    ranks = np.arange(n, dtype=np.float64)

    # Root linear CDF model (fit over all keys; closed form).
    kc = kf - kf.mean()
    denom = float((kc * kc).sum())
    root_slope = float((kc * ranks).sum() / denom) if denom > 0 else 0.0
    root_intercept = float(ranks.mean() - root_slope * kf.mean())

    leaf = np.clip(
        np.floor((root_slope * kf + root_intercept) * branch / n).astype(np.int64),
        0, branch - 1,
    )
    # Router is monotone (root_slope >= 0 on sorted keys), so each leaf owns a
    # contiguous key range; grouped least squares per leaf via bincount sums.
    cnt = np.bincount(leaf, minlength=branch).astype(np.float64)
    first_idx = np.searchsorted(leaf, np.arange(branch), side="left")
    x0 = kf[np.clip(first_idx, 0, n - 1)]
    xc = kf - x0[leaf]
    sx = np.bincount(leaf, weights=xc, minlength=branch)
    sy = np.bincount(leaf, weights=ranks, minlength=branch)
    sxx = np.bincount(leaf, weights=xc * xc, minlength=branch)
    sxy = np.bincount(leaf, weights=xc * ranks, minlength=branch)
    denom = cnt * sxx - sx * sx
    safe = denom > 1e-30
    slope = np.where(safe, (cnt * sxy - sx * sy) / np.where(safe, denom, 1.0), 0.0)
    intercept = np.where(cnt > 0, (sy - slope * sx) / np.maximum(cnt, 1.0), 0.0)
    # Empty leaves inherit the nearest populated leaf's prediction surface so
    # routed queries still produce sane windows.
    if (cnt == 0).any():
        populated = np.flatnonzero(cnt > 0)
        nearest = populated[
            np.clip(np.searchsorted(populated, np.arange(branch)), 0, populated.size - 1)
        ]
        slope = np.where(cnt > 0, slope, slope[nearest])
        intercept = np.where(cnt > 0, intercept, intercept[nearest])
        x0 = np.where(cnt > 0, x0, x0[nearest])

    idx = RMIIndex(
        root_slope=root_slope,
        root_intercept=root_intercept,
        branch=int(branch),
        leaf_slope=slope,
        leaf_intercept=intercept,
        leaf_x0=x0,
        leaf_eps=np.zeros(branch, np.int64),
        n=int(n),
    )
    # Empirical per-leaf max error over the indexed keys (vectorized).
    pred = idx.predict(keys)
    err = np.abs(pred - np.arange(n, dtype=np.int64))
    leaf_eps = np.zeros(branch, np.int64)
    np.maximum.at(leaf_eps, leaf, err)
    leaf_eps = np.maximum(leaf_eps, 1)  # window of at least one position
    return dataclasses.replace(idx, leaf_eps=leaf_eps)
