"""Disk-oriented PGM-index (Ferragina & Vinciguerra, VLDB'20).

Recursive ε-PLA: level 0 segments the data keys, level ℓ+1 segments the
first-keys of level ℓ, until one segment remains.  Index-data separation
(§II-B): the PGM levels live in memory; data pages live on "disk".  Only the
leaf-level prediction drives I/O — traversal is in-memory and O(log log n).

Lookup guarantee: |predict(k) - rank(k)| <= eps for every indexed key.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.index import pla

__all__ = ["PGMIndex", "build_pgm"]


@dataclasses.dataclass(frozen=True)
class PGMIndex:
    levels: List[pla.Segments]   # levels[0] = leaf level over the data keys
    eps: int
    n: int

    @property
    def size_bytes(self) -> int:
        return int(sum(level.bytes for level in self.levels))

    @property
    def num_segments(self) -> int:
        return len(self.levels[0])

    def predict(self, query_keys: np.ndarray) -> np.ndarray:
        """Leaf-level position prediction (vectorized, error within ±eps)."""
        return pla.predict_pla(self.levels[0], query_keys, self.n)

    def window(self, query_keys: np.ndarray):
        """Last-mile search windows [pred-eps, pred+eps], clipped."""
        pred = self.predict(query_keys)
        lo = np.clip(pred - self.eps, 0, self.n - 1)
        hi = np.clip(pred + self.eps, 0, self.n - 1)
        return lo, hi


def build_pgm(keys: np.ndarray, eps: int, eps_internal: int | None = None) -> PGMIndex:
    keys = np.asarray(keys)
    levels = [pla.build_pla(keys, eps)]
    eps_int = eps if eps_internal is None else eps_internal
    while len(levels[-1]) > 1:
        level_keys = levels[-1].first_key
        levels.append(pla.build_pla(level_keys, max(1, eps_int)))
        if len(levels[-1]) >= len(levels[-2]):  # degenerate (tiny inputs)
            break
    return PGMIndex(levels=levels, eps=int(eps), n=int(keys.shape[0]))
