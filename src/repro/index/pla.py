"""Greedy error-bounded piecewise linear approximation (ε-PLA).

The feasible-slope-window algorithm (FITing-Tree / swing-filter style): a
segment anchored at its first point maintains the interval of slopes that keep
every covered point within ±eps; the segment closes when the interval empties.
Guarantees |f(k) - rank(k)| <= eps for every indexed key, with segment counts
within a small constant of the optimal (O'Rourke) PLA — sufficient for the
paper's size-model fitting (M_idx ∝ |K| / 2eps, §V-B).

The inner feasibility scan is vectorized with a doubling window so the Python
loop runs once per *segment*, not per key.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["Segments", "build_pla", "predict_pla"]


@dataclasses.dataclass(frozen=True)
class Segments:
    """Arrays-of-struct PLA: predict(k) = slope*(k - first_key) + intercept."""

    first_key: np.ndarray   # (S,) uint64/float64 — segment anchor keys
    slope: np.ndarray       # (S,) float64
    intercept: np.ndarray   # (S,) float64 — global rank of the anchor
    eps: int

    def __len__(self) -> int:
        return int(self.first_key.shape[0])

    @property
    def bytes(self) -> int:
        # key (8B) + slope (4B) + intercept (4B), matching the PGM layout.
        return 16 * len(self)


def _first_violation(
    x: np.ndarray, j: int, hi_idx: int, eps: float
) -> Tuple[int, float]:
    """Extend the segment anchored at j as far as feasible within x[j:hi_idx].

    Returns (end_exclusive, slope): the segment covers [j, end_exclusive) and
    ``slope`` is a feasible midpoint slope for it.
    """
    n = x.shape[0]
    lo_run, hi_run = -np.inf, np.inf  # feasible slope interval so far
    slope = 0.0
    i = j + 1
    window = 64
    while i < n:
        stop = min(n, i + window)
        dx = (x[i:stop] - x[j]).astype(np.float64)
        dy = np.arange(i - j, stop - j, dtype=np.float64)
        lo_s = np.maximum.accumulate((dy - eps) / dx)
        hi_s = np.minimum.accumulate((dy + eps) / dx)
        lo_s = np.maximum(lo_s, lo_run)
        hi_s = np.minimum(hi_s, hi_run)
        bad = lo_s > hi_s
        if bad.any():
            v = int(np.argmax(bad))  # first violation inside this chunk
            if v > 0:
                lo_run, hi_run = float(lo_s[v - 1]), float(hi_s[v - 1])
            slope = 0.5 * (lo_run + hi_run) if np.isfinite(lo_run) else 0.0
            return i + v, slope
        lo_run, hi_run = float(lo_s[-1]), float(hi_s[-1])
        i = stop
        window = min(window * 2, 1 << 20)
    slope = 0.5 * (lo_run + hi_run) if np.isfinite(lo_run) else 0.0
    return n, slope


def build_pla(keys: np.ndarray, eps: int) -> Segments:
    """Segment sorted, distinct ``keys`` with error bound ``eps``."""
    keys = np.asarray(keys)
    n = keys.shape[0]
    if n == 0:
        raise ValueError("empty key set")
    firsts, slopes, intercepts = [], [], []
    j = 0
    while j < n:
        end, slope = _first_violation(keys, j, n, float(eps))
        firsts.append(keys[j])
        slopes.append(slope)
        intercepts.append(float(j))
        j = end
    return Segments(
        first_key=np.asarray(firsts, keys.dtype),
        slope=np.asarray(slopes, np.float64),
        intercept=np.asarray(intercepts, np.float64),
        eps=int(eps),
    )


def predict_pla(seg: Segments, query_keys: np.ndarray, n: int) -> np.ndarray:
    """Vectorized position prediction, clipped to [0, n-1]."""
    q = np.asarray(query_keys)
    idx = np.clip(np.searchsorted(seg.first_key, q, side="right") - 1, 0, None)
    dx = (q - seg.first_key[idx]).astype(np.float64)
    pred = seg.slope[idx] * dx + seg.intercept[idx]
    return np.clip(np.floor(pred), 0, n - 1).astype(np.int64)
