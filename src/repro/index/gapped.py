"""Updatable leaf layouts: ALEX-style gapped arrays and B+-tree leaves.

Read-only learned indexes pack keys densely; updatable ones buy cheap
inserts with slack space, and CAM must price what that slack does to BOTH
I/O streams:

* the READ side — slack inflates the on-disk footprint (``slots > n``), so
  every probe window covers more pages (the ``to_slot_space`` remap);
* the WRITE side — an insert shifts elements until it finds a gap (gapped
  array) or amortizes node splits (B+-tree), dirtying more than one page
  (the ``*_write_amp`` closed forms).

:class:`GappedArray` is a small explicit-occupancy simulator, NOT a real
index: it exists so the analytic forms the adapters price with have a
replayable ground truth (property-tested invariants: inserts never shrink
the layout; ``merge`` restores the fill-factor bound).  The adapters in
``repro.index.adapters`` use only the closed forms.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.workload import MIXED, Workload

__all__ = ["GappedArray", "gapped_slots", "btree_slots",
           "gapped_write_amp", "btree_write_amp", "to_slot_space"]


def gapped_slots(n: int, gap_density: float) -> int:
    """Slot count of a gapped layout holding ``n`` keys at the target
    density (``gap_density`` = fraction of slots left empty)."""
    if not 0.0 <= gap_density < 1.0:
        raise ValueError(f"gap_density must be in [0, 1), got {gap_density}")
    return max(int(math.ceil(n / max(1.0 - gap_density, 1e-9))), n + 1)


def btree_slots(n: int, fill_factor: float) -> int:
    """Slot count of B+-tree leaves holding ``n`` keys at ``fill_factor``."""
    if not 0.0 < fill_factor <= 1.0:
        raise ValueError(f"fill_factor must be in (0, 1], got {fill_factor}")
    return max(int(math.ceil(n / fill_factor)), n)


def gapped_write_amp(gap_density: float, c_ipp: int) -> float:
    """Expected pages dirtied per gapped-array insert.

    With gaps uniform at density ``g``, the shift to the nearest gap scans a
    geometric number of slots (mean ``1/g``), so an insert dirties the
    target page plus ``(1/g) / c_ipp`` shift-span pages in expectation.
    ``g -> 0`` diverges (a packed array shifts O(n)); clamp to one page of
    span so degenerate knobs stay finite.
    """
    span = 1.0 / max(gap_density, 1.0 / max(c_ipp, 1))
    return 1.0 + span / max(c_ipp, 1)


def btree_write_amp(fill_factor: float, c_ipp: int) -> float:
    """Expected pages dirtied per B+-tree insert.

    The leaf write is 1 page; a split (2 page writes + parent update ~ 3)
    amortizes over the ``(1 - f) * c_ipp`` free slots the split opened."""
    free = max((1.0 - fill_factor) * max(c_ipp, 1), 1.0)
    return 1.0 + 3.0 / free


def to_slot_space(workload: Workload, n: int, slots: int) -> Workload:
    """Remap a rank-space workload onto a slack layout's slot space.

    Ranks scale by ``slots / n`` (monotone, order-preserving — the sorted
    closed forms survive the remap), so probe windows cover the extra pages
    the slack costs.  Applied recursively to mixed parts.
    """
    if workload.kind == MIXED:
        return Workload(MIXED, parts=tuple(
            to_slot_space(p, n, slots) for p in workload.parts), n=slots)

    def remap(a: Optional[np.ndarray]) -> Optional[np.ndarray]:
        if a is None:
            return None
        scaled = (np.asarray(a, np.int64) * int(slots)) // max(int(n), 1)
        return np.minimum(scaled, int(slots) - 1)

    return dataclasses.replace(workload, positions=remap(workload.positions),
                               hi_positions=remap(workload.hi_positions),
                               n=slots)


class GappedArray:
    """Explicit-occupancy gapped-array simulator (the adapters' oracle).

    Tracks which slots hold keys.  ``insert`` places a key at its fractional
    target position, shifting to the nearest gap (ALEX's in-leaf shift);
    ``merge`` rebuilds the layout at the target gap density (the delta-merge
    / SMO the scheduler prices).  Page counts derive from the slot span, so
    the two scheduler-relevant invariants are directly observable:
    inserting can only grow the layout, merging restores the fill bound.
    """

    def __init__(self, n: int, gap_density: float):
        self.gap_density = float(gap_density)
        self.count = int(n)
        slots = gapped_slots(self.count, self.gap_density)
        self.occupied = np.zeros(slots, bool)
        if self.count:
            self.occupied[(np.arange(self.count, dtype=np.int64)
                           * slots) // self.count] = True

    @property
    def slots(self) -> int:
        return int(self.occupied.shape[0])

    def fill_factor(self) -> float:
        return self.count / max(self.slots, 1)

    def pages(self, c_ipp: int) -> int:
        return int(math.ceil(self.slots / max(c_ipp, 1)))

    def insert(self, frac: float) -> int:
        """Insert at fractional position ``frac``; returns slots dirtied
        (the shifted span plus the landing slot)."""
        if not 0.0 <= frac < 1.0:
            raise ValueError(f"frac must be in [0, 1), got {frac}")
        if self.occupied.all():
            # full leaf: expand with trailing gaps (the no-merge fallback a
            # real tree resolves with a split — layout only ever grows)
            grown = gapped_slots(self.count + 1, self.gap_density)
            pad = np.zeros(max(grown - self.slots, 1), bool)
            self.occupied = np.concatenate([self.occupied, pad])
        slot = min(int(frac * self.slots), self.slots - 1)
        free_right = np.nonzero(~self.occupied[slot:])[0]
        if free_right.size:
            gap = slot + int(free_right[0])
        else:
            gap = int(np.nonzero(~self.occupied[:slot])[0][-1])
        lo, hi = min(slot, gap), max(slot, gap)
        self.occupied[lo:hi + 1] = True
        self.count += 1
        return hi - lo + 1

    def merge(self) -> int:
        """Rebuild at the target gap density (delta merge / SMO); returns
        slots written (the whole new layout — a sorted-scan burst)."""
        slots = gapped_slots(self.count, self.gap_density)
        self.occupied = np.zeros(slots, bool)
        if self.count:
            self.occupied[(np.arange(self.count, dtype=np.int64)
                           * slots) // self.count] = True
        return slots
