"""Page layout + leaf-page fetching strategies (paper §II-B, Fig. 4).

Index-data separation: sorted records live in fixed-size pages on "disk";
the learned index (in memory) yields a position window per lookup, which the
fetch strategy translates into page requests:

* S2 all-at-once — one coalesced read of every page overlapping the window
  (the paper's default; one larger sequential I/O).
* S1 one-by-one  — dependent probes: read the page at the window's lower
  bound, then walk toward the key (sortedness tells the direction after each
  page), stopping at the page containing the true position.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["PageLayout", "fetch_all_at_once", "fetch_one_by_one_counts"]


@dataclasses.dataclass(frozen=True)
class PageLayout:
    c_ipp: int = 256
    page_bytes: int = 4096

    def num_pages(self, n: int) -> int:
        return -(-n // self.c_ipp)

    def page_of(self, positions: np.ndarray) -> np.ndarray:
        return np.asarray(positions, np.int64) // self.c_ipp


def fetch_all_at_once(
    window_lo: np.ndarray, window_hi: np.ndarray, layout: PageLayout
) -> Tuple[np.ndarray, np.ndarray]:
    """S2: inclusive page interval [page(lo), page(hi)] per query."""
    return layout.page_of(window_lo), layout.page_of(window_hi)


def fetch_one_by_one_counts(
    window_lo: np.ndarray, true_pos: np.ndarray, layout: PageLayout
) -> np.ndarray:
    """S1: pages actually probed walking up from the window's lower bound.

    Matches the Lemma III.3 counting: 1 + floor((offset(lo) + dist)/C_ipp)
    == page(true) - page(lo) + 1.
    """
    start = layout.page_of(window_lo)
    stop = layout.page_of(true_pos)
    return (stop - start + 1).astype(np.int64)
