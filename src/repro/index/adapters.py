"""IndexModel adapters: one estimation surface over PGM, RMI and RadixSpline.

Each adapter exposes the :class:`repro.core.session.IndexModel` protocol —
``size_bytes``, knob metadata, and ``page_ref_profile(workload, geom)``
returning the Eq. 12/13/14 histograms — so a :class:`CostSession` can price
any of the three families (and grid-tune their knobs) without knowing which
design it is holding.  ``window()`` exposes the last-mile search windows the
replay oracle needs, making every adapter directly checkable against ground
truth.

PGM and RadixSpline are uniformly error-bounded, so both delegate to the
shared ``uniform_eps_profile`` — RadixSpline's greedy spline corridor gives
the same |predict - rank| <= eps guarantee, which is exactly the paper's
index-agnosticism claim (§I property i) and what makes RadixSpline *tunable*
here for the first time: eps is its knob, same as PGM's.

RMI has no global bound; its profile is the §V-C workload-weighted mixture of
per-leaf Eq. 12 patterns with leaf error bounds quantized up to powers of two
(bounds LUT instantiations at ~log2(max_eps), windows stay conservative).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core import dac as dac_mod
from repro.core import page_ref
from repro.core.cam import CamGeometry
from repro.core.session import (PageRefProfile, UnsupportedWorkloadError,
                                sorted_stream_profile, uniform_eps_profile)
from repro.core.workload import POINT, SORTED, Workload, locate
from repro.index import pgm as pgm_mod
from repro.index import radixspline as rs_mod
from repro.index import rmi as rmi_mod
from repro.index.gapped import (btree_slots, btree_write_amp, gapped_slots,
                                gapped_write_amp, to_slot_space)

__all__ = ["PGMAdapter", "RMIAdapter", "RadixSplineAdapter", "ALEXAdapter",
           "BTreeAdapter", "quantize_eps",
           "ADAPTERS", "wrap_index", "sqrt2_grid", "pow2_grid",
           "DEFAULT_EPS_GRID", "DEFAULT_BRANCH_GRID",
           "DEFAULT_RADIX_BITS_GRID", "DEFAULT_GAP_DENSITY_GRID",
           "DEFAULT_FILL_FACTOR_GRID"]


def sqrt2_grid(lo: int = 4, hi: int = 4096) -> tuple:
    """Dense sqrt(2)-spaced grid (the ONE implementation — the deprecated
    ``pgm_tuner.default_eps_grid`` shim delegates here)."""
    grid, e = [], float(lo)
    while e <= hi:
        grid.append(int(round(e)))
        e *= np.sqrt(2.0)
    return tuple(dict.fromkeys(grid))


def pow2_grid(lo: int = 2**6, hi: int = 2**16) -> tuple:
    """Doubling grid (the ONE implementation behind branch-factor grids)."""
    grid, b = [], int(lo)
    while b <= hi:
        grid.append(b)
        b *= 2
    return tuple(grid)


#: Default knob grids advertised through ``knobs()`` metadata.  A tuner's
#: ``KnobSpace`` is derived from these (``repro.tuning.session``); they are
#: deliberately denser than what replay-based tuning could afford, because
#: grid candidates price through the batched estimators, not execution.
DEFAULT_EPS_GRID = sqrt2_grid()                        # sqrt(2)-spaced 4..4096
DEFAULT_BRANCH_GRID = pow2_grid()                      # doubling 64..65536
DEFAULT_RADIX_BITS_GRID = (8, 10, 12, 14, 16, 18)
DEFAULT_GAP_DENSITY_GRID = (0.05, 0.1, 0.15, 0.2, 0.3, 0.4, 0.5)
DEFAULT_FILL_FACTOR_GRID = (0.55, 0.6, 0.67, 0.75, 0.85, 0.95)


def quantize_eps(eps: np.ndarray) -> np.ndarray:
    """Round leaf error bounds up to powers of two (conservative windows)."""
    eps = np.maximum(np.asarray(eps, np.int64), 1)
    return (2 ** np.ceil(np.log2(eps))).astype(np.int64)


def _probe_windows(adapter, query_keys: np.ndarray, geom: CamGeometry):
    """Shared ``probe_windows`` body: adapter windows -> inclusive page
    intervals, clipped to the valid page range (PAGEINTERVALS in Alg. 2)."""
    lo, hi = adapter.window(query_keys)
    num_pages = geom.num_pages(adapter.n)
    page_lo = np.asarray(lo, np.int64) // geom.c_ipp
    page_hi = np.minimum(np.asarray(hi, np.int64) // geom.c_ipp, num_pages - 1)
    return page_lo, np.maximum(page_hi, page_lo)


@dataclasses.dataclass(frozen=True)
class PGMAdapter:
    """Disk-based PGM-index under the IndexModel protocol (knob: eps)."""

    index: pgm_mod.PGMIndex
    family: str = "pgm"

    @classmethod
    def build(cls, keys: np.ndarray, eps: int) -> "PGMAdapter":
        return cls(pgm_mod.build_pgm(keys, eps))

    @property
    def size_bytes(self) -> float:
        return float(self.index.size_bytes)

    @property
    def eps(self) -> int:
        return self.index.eps

    @property
    def n(self) -> int:
        return self.index.n

    @classmethod
    def knob_metadata(cls) -> Dict[str, object]:
        """Knob space metadata without a built instance (tuner-facing)."""
        return {"eps": {"kind": "error_bound", "tunable": True,
                        "grid": DEFAULT_EPS_GRID}}

    def knobs(self) -> Dict[str, object]:
        return {"eps": {"value": self.index.eps, "kind": "error_bound",
                        "tunable": True, "grid": DEFAULT_EPS_GRID}}

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile:
        return uniform_eps_profile(workload, self.index.eps, geom, self.index.n)

    def window(self, query_keys: np.ndarray):
        return self.index.window(query_keys)

    def probe_windows(self, query_keys: np.ndarray, geom: CamGeometry):
        return _probe_windows(self, query_keys, geom)


@dataclasses.dataclass(frozen=True)
class RadixSplineAdapter:
    """RadixSpline under the IndexModel protocol (knob: corridor eps).

    The fixed-eps spline corridor makes the whole uniform-eps machinery —
    including batched grid tuning — apply unchanged.
    """

    index: rs_mod.RadixSplineIndex
    family: str = "radixspline"

    @classmethod
    def build(cls, keys: np.ndarray, eps: int,
              radix_bits: int = 16) -> "RadixSplineAdapter":
        return cls(rs_mod.build_radixspline(keys, eps, radix_bits))

    @property
    def size_bytes(self) -> float:
        return float(self.index.size_bytes)

    @property
    def eps(self) -> int:
        return self.index.eps

    @property
    def n(self) -> int:
        return self.index.n

    @classmethod
    def knob_metadata(cls) -> Dict[str, object]:
        """2-D knob space: corridor eps x radix table width.

        ``radix_bits`` is a REAL tuning knob under a shared memory budget —
        the table costs 4 * (2^bits + 1) bytes of footprint that competes
        with buffer pages, so a tight budget prefers a narrow table even
        though the in-memory knot search gets a little wider.
        """
        return {"eps": {"kind": "error_bound", "tunable": True,
                        "grid": DEFAULT_EPS_GRID},
                "radix_bits": {"kind": "lookup_accel", "tunable": True,
                               "grid": DEFAULT_RADIX_BITS_GRID}}

    def knobs(self) -> Dict[str, object]:
        return {"eps": {"value": self.index.eps, "kind": "error_bound",
                        "tunable": True, "grid": DEFAULT_EPS_GRID},
                "radix_bits": {"value": self.index.radix_bits,
                               "kind": "lookup_accel", "tunable": True,
                               "grid": DEFAULT_RADIX_BITS_GRID}}

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile:
        return uniform_eps_profile(workload, self.index.eps, geom, self.index.n)

    def window(self, query_keys: np.ndarray):
        return self.index.window(query_keys)

    def probe_windows(self, query_keys: np.ndarray, geom: CamGeometry):
        return _probe_windows(self, query_keys, geom)


@dataclasses.dataclass(frozen=True)
class RMIAdapter:
    """Two-layer RMI under the IndexModel protocol (knob: branch factor)."""

    index: rmi_mod.RMIIndex
    family: str = "rmi"
    # Routing memo: (id(query_keys), c_ipp, strategy) -> (keys ref, eps row,
    # E[DAC]).  Routing depends only on (index, workload), yet a tuning loop
    # re-prices the same workload under many (budget, policy) Systems; the
    # strong reference in the value keeps the id valid for the entry's
    # lifetime, and the FIFO bound keeps a long-lived adapter from pinning
    # arbitrary query arrays.  Excluded from eq/repr (pure cache).
    _ref_cache: dict = dataclasses.field(default_factory=dict, init=False,
                                         repr=False, compare=False)
    _REF_CACHE_MAX = 4

    @classmethod
    def build(cls, keys: np.ndarray, branch: int) -> "RMIAdapter":
        return cls(rmi_mod.build_rmi(keys, branch))

    @property
    def size_bytes(self) -> float:
        return float(self.index.size_bytes)

    @property
    def n(self) -> int:
        return self.index.n

    @classmethod
    def knob_metadata(cls) -> Dict[str, object]:
        return {"branch": {"kind": "fanout", "tunable": True,
                           "grid": DEFAULT_BRANCH_GRID}}

    def knobs(self) -> Dict[str, object]:
        return {"branch": {"value": self.index.branch, "kind": "fanout",
                           "tunable": True, "grid": DEFAULT_BRANCH_GRID}}

    def point_ref_eps(self, workload: Workload, geom: CamGeometry):
        """Per-query quantized leaf error bounds + E[DAC] (§V-C inputs).

        This is what the batched mixed-eps grid kernel
        (``page_ref.point_page_refs_mixed_eps_grid``) consumes: routing is
        host-side and cheap, so a whole branch grid can collect every
        candidate's (eps row, E[DAC]) first and profile them in ONE grouped
        pass instead of per-branch mixture histograms.
        """
        if workload.kind != POINT or workload.query_keys is None:
            raise UnsupportedWorkloadError(
                workload.kind,
                detail="RMI profiling needs a point workload with "
                       "query_keys (the root must route them)")
        key = (id(workload.query_keys), geom.c_ipp, geom.strategy)
        hit = self._ref_cache.get(key)
        if hit is not None:
            return hit[1], hit[2]
        index = self.index
        leaf = index.route(workload.query_keys)
        eps_q = quantize_eps(index.leaf_eps[leaf])
        weights = np.bincount(leaf, minlength=index.branch).astype(np.float64)
        weights /= max(weights.sum(), 1.0)
        e_dac = float(dac_mod.expected_dac_rmi(
            index.leaf_eps, weights, geom.c_ipp, geom.strategy))
        while len(self._ref_cache) >= self._REF_CACHE_MAX:
            self._ref_cache.pop(next(iter(self._ref_cache)))
        self._ref_cache[key] = (workload.query_keys, eps_q, e_dac)
        return eps_q, e_dac

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile:
        """§V-C mixture: per-query leaf error bounds, quantized to pow2.

        Sorted probe streams carry explicit position windows, so they need
        no routing — RMI prices them through the same shared sorted-stream
        profile as the uniformly error-bounded families (the capacity
        premise read off the widest observed window).
        """
        if workload.kind == SORTED:
            return sorted_stream_profile(workload, geom,
                                         geom.num_pages(self.index.n))
        eps_q, e_dac = self.point_ref_eps(workload, geom)
        counts, total = page_ref.point_page_refs_mixed_eps(
            workload.positions, eps_q, geom.c_ipp,
            geom.num_pages(self.index.n))
        return PageRefProfile(counts, float(total), e_dac)

    def window(self, query_keys: np.ndarray):
        lo, hi, _ = self.index.window(query_keys)
        return lo, hi

    def probe_windows(self, query_keys: np.ndarray, geom: CamGeometry):
        return _probe_windows(self, query_keys, geom)


@dataclasses.dataclass(frozen=True)
class ALEXAdapter:
    """ALEX-style gapped-array updatable index (knob: gap density).

    Writes become first-class: leaves keep ``gap_density`` of their slots
    empty so inserts shift only to the nearest gap instead of rewriting the
    tail.  The knob trades the two I/O streams against each other —

    * more gaps: CHEAPER writes (short shifts, low
      ``gapped_write_amp``) but a BIGGER footprint, so probe windows span
      more pages and the same buffer caches a smaller fraction;
    * fewer gaps: dense reads, expensive shifts.

    Both sides flow through one profile: the read-side refs are the shared
    ``uniform_eps_profile`` in SLOT space (the ``to_slot_space`` remap from
    ``repro.index.gapped``), and the write stream rides its ``write_amp``
    hook, so :class:`~repro.tuning.session.TuningSession` tunes the knob
    with the machinery it already has.

    Model error is treated as uniformly bounded (``eps``): the gapped remap
    is monotone, so the per-leaf linear models keep their corridor in slot
    space.  ``keys`` is kept (when built from data) only for ``window()`` —
    the replay oracle's ground-truth probe windows.
    """

    n: int
    gap_density: float
    eps: int = 64
    keys: "np.ndarray | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    family: str = "alex"

    @classmethod
    def build(cls, keys: np.ndarray, gap_density: float,
              eps: int = 64) -> "ALEXAdapter":
        keys = np.asarray(keys)
        return cls(n=int(keys.shape[0]), gap_density=float(gap_density),
                   eps=int(eps), keys=keys)

    @property
    def slots(self) -> int:
        return gapped_slots(self.n, self.gap_density)

    @property
    def size_bytes(self) -> float:
        # per-leaf linear models over ~1k-slot nodes (slope+intercept+bounds
        # ~ 48 B) plus a root model: slack grows the leaf count, so the knob
        # also competes for the Eq. 15 memory budget
        return 48.0 * float(np.ceil(self.slots / 1024.0)) + 64.0

    @classmethod
    def knob_metadata(cls) -> Dict[str, object]:
        return {"gap_density": {"kind": "slack", "tunable": True,
                                "grid": DEFAULT_GAP_DENSITY_GRID}}

    def knobs(self) -> Dict[str, object]:
        return {"gap_density": {"value": self.gap_density, "kind": "slack",
                                "tunable": True,
                                "grid": DEFAULT_GAP_DENSITY_GRID}}

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile:
        slots = self.slots
        return uniform_eps_profile(
            to_slot_space(workload, self.n, slots), self.eps, geom, slots,
            write_amp=gapped_write_amp(self.gap_density, geom.c_ipp))

    def window(self, query_keys: np.ndarray):
        if self.keys is None:
            raise UnsupportedWorkloadError(
                "window", detail="ALEXAdapter built without keys cannot "
                "produce ground-truth windows; use ALEXAdapter.build")
        slots = self.slots
        slot = (locate(self.keys, np.asarray(query_keys))
                * slots) // max(self.n, 1)
        return (np.maximum(slot - self.eps, 0),
                np.minimum(slot + self.eps, slots - 1))

    def probe_windows(self, query_keys: np.ndarray, geom: CamGeometry):
        return _probe_windows(self, query_keys, geom)


@dataclasses.dataclass(frozen=True)
class BTreeAdapter:
    """Disk B+-tree baseline (knob: leaf fill factor).

    The classic updatable baseline the paper's learned indexes displace.
    Inner nodes are assumed memory-resident (they are tiny and hot), so a
    probe touches exactly the leaf page holding the key: ``eps = 0`` in the
    shared profile — the tree pays no model-error fan-out, it pays FOOTPRINT
    (leaves are only ``fill_factor`` full, so the key space spreads over
    ``1/fill_factor`` more pages) and amortized split I/O on inserts
    (``btree_write_amp``).  High fill reads densely but splits constantly;
    low fill wastes cache on slack — the same two-stream trade as ALEX with
    the opposite lever.
    """

    n: int
    fill_factor: float = 0.7
    keys: "np.ndarray | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    family: str = "btree"
    eps: int = 0

    @classmethod
    def build(cls, keys: np.ndarray, fill_factor: float = 0.7,
              **_ignored) -> "BTreeAdapter":
        keys = np.asarray(keys)
        return cls(n=int(keys.shape[0]), fill_factor=float(fill_factor),
                   keys=keys)

    @property
    def slots(self) -> int:
        return btree_slots(self.n, self.fill_factor)

    @property
    def size_bytes(self) -> float:
        # resident inner nodes: ~16 B (separator + child pointer) per leaf
        # of ~256 slots, times ~1/(1-1/fanout) for upper levels ~ 1.01
        return 16.0 * float(np.ceil(self.slots / 256.0)) + 64.0

    @classmethod
    def knob_metadata(cls) -> Dict[str, object]:
        return {"fill_factor": {"kind": "slack", "tunable": True,
                                "grid": DEFAULT_FILL_FACTOR_GRID}}

    def knobs(self) -> Dict[str, object]:
        return {"fill_factor": {"value": self.fill_factor, "kind": "slack",
                                "tunable": True,
                                "grid": DEFAULT_FILL_FACTOR_GRID}}

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile:
        slots = self.slots
        return uniform_eps_profile(
            to_slot_space(workload, self.n, slots), 0, geom, slots,
            write_amp=btree_write_amp(self.fill_factor, geom.c_ipp))

    def window(self, query_keys: np.ndarray):
        if self.keys is None:
            raise UnsupportedWorkloadError(
                "window", detail="BTreeAdapter built without keys cannot "
                "produce ground-truth windows; use BTreeAdapter.build")
        slots = self.slots
        slot = (locate(self.keys, np.asarray(query_keys))
                * slots) // max(self.n, 1)
        return slot, slot

    def probe_windows(self, query_keys: np.ndarray, geom: CamGeometry):
        return _probe_windows(self, query_keys, geom)


ADAPTERS = {"pgm": PGMAdapter, "rmi": RMIAdapter,
            "radixspline": RadixSplineAdapter, "alex": ALEXAdapter,
            "btree": BTreeAdapter}

_RAW_CLASSES = {pgm_mod.PGMIndex: PGMAdapter, rmi_mod.RMIIndex: RMIAdapter,
                rs_mod.RadixSplineIndex: RadixSplineAdapter}


def wrap_index(index) -> "PGMAdapter | RMIAdapter | RadixSplineAdapter":
    """Normalize a raw index or adapter to the IndexModel protocol.

    This is what lets execution paths (join executors, replay harnesses)
    accept any index family without per-design tuple-shape special cases:
    whatever comes in, what comes out has ``probe_windows`` / ``window``
    with one uniform signature.
    """
    if hasattr(index, "probe_windows"):
        return index
    for raw_cls, adapter_cls in _RAW_CLASSES.items():
        if isinstance(index, raw_cls):
            return adapter_cls(index)
    raise TypeError(
        f"cannot adapt {type(index).__name__} to the IndexModel "
        f"protocol; expected one of {[c.__name__ for c in _RAW_CLASSES]} "
        "or an object exposing probe_windows()")
