"""Learned-index substrate: ε-PLA, PGM, RMI, RadixSpline, disk layout,
and the IndexModel adapters that plug every family into CostSession."""
from repro.index import adapters, disk_layout, pgm, pla, radixspline, rmi

__all__ = ["adapters", "disk_layout", "pgm", "pla", "radixspline", "rmi"]
