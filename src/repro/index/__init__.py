"""Learned-index substrate: ε-PLA, PGM, RMI, RadixSpline, disk layout."""
from repro.index import disk_layout, pgm, pla, radixspline, rmi

__all__ = ["disk_layout", "pgm", "pla", "radixspline", "rmi"]
