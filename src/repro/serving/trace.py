"""Trace frontend — the op-log schema and its compilation into Workloads.

A live system does not hand us a :class:`~repro.core.workload.Workload`; it
hands us an append-only op log.  This module owns the boundary: the
:class:`TraceEvent` record (point lookup, range scan, sorted-stream probe,
timestamp), JSONL parsing for persisted logs, in-memory batching iterators,
and :func:`compile_events`, which turns one batch of events into a Workload
through the SAME ``locate``/``from_keys`` path offline callers use — so a
trace-compiled batch prices identically to a hand-built workload.

Sorted probes deserve a note: a ``sorted`` event is ONE probe window of a
sorted-stream batch (a join leg, a bulk merge).  Consecutive sorted events
in a batch keep their order when compiled, which is exactly what the
Theorem III.1 closed forms need; interleaved point/range traffic compiles
into sibling parts of a mixed workload.

:func:`synthetic_drifting_trace` generates the piecewise-stationary streams
the drift benchmark and the smoke example replay: each segment fixes an op
mix, a hot region, and a range-width scale, so distribution shift happens
at known boundaries (giving the oracle-retune arm its oracle).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.workload import Workload, locate

__all__ = ["TraceEvent", "parse_jsonl", "to_jsonl", "iter_batches",
           "compile_events", "synthetic_drifting_trace"]

POINT = "point"
RANGE = "range"
SORTED = "sorted"
INSERT = "insert"
UPDATE = "update"
DELETE = "delete"

#: Mutating ops — key-shaped like ``point`` (one target key per event).
WRITE_OPS = (INSERT, UPDATE, DELETE)

_OPS = (POINT, RANGE, SORTED) + WRITE_OPS
_KEY_OPS = (POINT,) + WRITE_OPS


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One op-log record.

    ``op`` is ``"point"`` (uses ``key``), ``"range"`` (``lo_key``/``hi_key``
    rank bounds after location), ``"sorted"`` (one probe window of a
    sorted stream, also ``lo_key``/``hi_key``), or a mutating op —
    ``"insert"`` / ``"update"`` / ``"delete"`` — which targets a single
    ``key`` exactly like ``point``.  ``ts`` is an arbitrary monotone
    timestamp — the serving loop batches by arrival order and only
    reports it.
    """

    op: str
    key: Optional[float] = None
    lo_key: Optional[float] = None
    hi_key: Optional[float] = None
    ts: float = 0.0

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown trace op {self.op!r}; "
                             f"expected one of {_OPS}")
        if self.op in _KEY_OPS and self.key is None:
            raise ValueError(f"{self.op} event needs key")
        if self.op not in _KEY_OPS and (self.lo_key is None
                                        or self.hi_key is None):
            raise ValueError(f"{self.op} event needs lo_key and hi_key")


def to_jsonl(events: Iterable[TraceEvent]) -> str:
    """Serialize events to JSONL (one compact object per line)."""
    out = []
    for e in events:
        rec = {"op": e.op, "ts": e.ts}
        if e.op in _KEY_OPS:
            rec["key"] = e.key
        else:
            rec["lo_key"] = e.lo_key
            rec["hi_key"] = e.hi_key
        out.append(json.dumps(rec))
    return "\n".join(out) + ("\n" if out else "")


def parse_jsonl(source) -> Iterator[TraceEvent]:
    """Parse a JSONL op log into :class:`TraceEvent`s.

    ``source`` is a path, an open file, or any iterable of lines; blank
    lines are skipped.  Streaming — never materializes the trace.
    """
    if isinstance(source, (str, bytes)):
        with open(source) as f:
            yield from parse_jsonl(f)
        return
    for line in source:
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        yield TraceEvent(op=rec["op"], key=rec.get("key"),
                         lo_key=rec.get("lo_key"), hi_key=rec.get("hi_key"),
                         ts=float(rec.get("ts", 0.0)))


def iter_batches(events: Iterable[TraceEvent],
                 batch_size: int) -> Iterator[List[TraceEvent]]:
    """Chop an event stream into arrival-order batches (last may be short)."""
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    batch: List[TraceEvent] = []
    for e in events:
        batch.append(e)
        if len(batch) >= batch_size:
            yield batch
            batch = []
    if batch:
        yield batch


def compile_events(events: Sequence[TraceEvent],
                   keys: np.ndarray) -> Workload:
    """Compile one event batch into a Workload against ``keys``.

    Point events locate through the same ``searchsorted`` path as
    ``Workload.from_keys`` (query keys are kept so routing indexes — RMI —
    can profile the batch); range and sorted events locate both bounds.
    Mutating events (insert/update/delete) locate their target key the same
    way and compile into the matching write parts.  Within every compiled
    part the events keep their arrival order (the per-op grouping is a
    stable filter over the batch — regression-tested), and sorted probes in
    particular keep the order the closed forms need.  A single-op batch
    compiles to that part directly; otherwise the parts compose into a
    mixed workload, which ``Workload.mixed``'s flattening lets downstream
    code concatenate freely.
    """
    if not events:
        raise ValueError("cannot compile an empty event batch")
    keys = np.asarray(keys)
    n = int(keys.shape[0])
    point_keys = [e.key for e in events if e.op == POINT]
    range_bounds = [(e.lo_key, e.hi_key) for e in events if e.op == RANGE]
    sorted_bounds = [(e.lo_key, e.hi_key) for e in events if e.op == SORTED]

    parts = []
    if point_keys:
        qk = np.asarray(point_keys)
        parts.append(Workload.point(locate(keys, qk), n=n, query_keys=qk))
    if range_bounds:
        lo, hi = np.asarray(range_bounds).T
        lo_pos = locate(keys, lo)
        hi_pos = np.maximum(locate(keys, hi), lo_pos)
        parts.append(Workload.range_scan(lo_pos, hi_pos, n=n))
    if sorted_bounds:
        lo, hi = np.asarray(sorted_bounds).T
        lo_pos = locate(keys, lo)
        hi_pos = np.maximum(locate(keys, hi), lo_pos)
        parts.append(Workload.sorted_stream(lo_pos, hi_pos, n=n))
    for op, build in ((INSERT, Workload.insert), (UPDATE, Workload.update),
                      (DELETE, Workload.delete)):
        wkeys = [e.key for e in events if e.op == op]
        if wkeys:
            qk = np.asarray(wkeys)
            parts.append(build(locate(keys, qk), n=n, query_keys=qk))
    return parts[0] if len(parts) == 1 else Workload.mixed(*parts)


# ---------------------------------------------------------------------------
# Synthetic piecewise-drifting traces
# ---------------------------------------------------------------------------

DEFAULT_SEGMENT = {
    "events": 2048,          # events in this stationary segment
    # (point, range, sorted[, insert, update, delete]) op probabilities —
    # 3-tuples stay valid (write mass 0), 6-tuples add mutating traffic
    "mix": (1.0, 0.0, 0.0),
    "hot_center": 0.5,       # hot-region center, fraction of the key space
    "hot_width": 0.1,        # hot-region width, fraction of the key space
    "hot_frac": 0.9,         # probability a query lands in the hot region
    "range_width": 64,       # mean range/sorted window width, in ranks
    "sorted_run": 32,        # consecutive probes per sorted sweep
}


def synthetic_drifting_trace(keys: np.ndarray, segments: Sequence[dict],
                             seed: int = 0) -> List[TraceEvent]:
    """Piecewise-stationary op log over ``keys``.

    Each segment dict overrides :data:`DEFAULT_SEGMENT`.  Inside a segment
    the distribution is fixed: ops are drawn from ``mix``, query positions
    from a hot/cold mixture (``hot_frac`` mass uniform on the
    ``hot_center`` ± ``hot_width``/2 slab, the rest uniform everywhere),
    range widths geometric with mean ``range_width``, and sorted ops emit
    ``sorted_run`` consecutive stride-advancing windows (a miniature merge
    sweep).  Drift is whatever differs between consecutive segments.
    """
    keys = np.asarray(keys)
    n = int(keys.shape[0])
    rng = np.random.default_rng(seed)
    events: List[TraceEvent] = []
    ts = 0.0

    def draw_pos(seg) -> int:
        if rng.random() < seg["hot_frac"]:
            lo = max(0.0, seg["hot_center"] - seg["hot_width"] / 2)
            hi = min(1.0, seg["hot_center"] + seg["hot_width"] / 2)
            return int(rng.uniform(lo, hi) * (n - 1))
        return int(rng.integers(0, n))

    def width(seg) -> int:
        return int(1 + rng.geometric(1.0 / max(seg["range_width"], 1)))

    for spec in segments:
        seg = {**DEFAULT_SEGMENT, **spec}
        mix = tuple(seg["mix"]) + (0.0,) * (6 - len(seg["mix"]))
        p_point, p_range, p_sorted = mix[:3]
        write_ps = mix[3:]
        total = sum(mix)
        emitted = 0
        while emitted < seg["events"]:
            ts += 1.0
            u = rng.random() * total
            if u >= p_point + p_range + p_sorted:
                # mutating op: target key drawn from the same hot/cold mix
                u -= p_point + p_range + p_sorted
                op = WRITE_OPS[0 if u < write_ps[0] else
                               1 if u < write_ps[0] + write_ps[1] else 2]
                pos = draw_pos(seg)
                events.append(TraceEvent(op, key=float(keys[pos]), ts=ts))
                emitted += 1
            elif u < p_point:
                pos = draw_pos(seg)
                events.append(TraceEvent(POINT, key=float(keys[pos]), ts=ts))
                emitted += 1
            elif u < p_point + p_range:
                lo = draw_pos(seg)
                hi = min(n - 1, lo + width(seg))
                events.append(TraceEvent(
                    RANGE, lo_key=float(keys[lo]), hi_key=float(keys[hi]),
                    ts=ts))
                emitted += 1
            else:
                # one sorted sweep: windows advance monotonically
                lo = draw_pos(seg)
                run = min(seg["sorted_run"], seg["events"] - emitted)
                w = width(seg)
                for _ in range(run):
                    hi = min(n - 1, lo + w)
                    events.append(TraceEvent(
                        SORTED, lo_key=float(keys[lo]),
                        hi_key=float(keys[hi]), ts=ts))
                    lo = min(n - 1, hi + 1)
                    emitted += 1
    return events
