"""Serving layer — live-trace ingestion, incremental workload sketches, and
drift-triggered rebuild-cost-aware retuning.

Three layers, one direction of data flow:

* :mod:`repro.serving.trace` — the op-log frontend: :class:`TraceEvent`
  (point / range / sorted probe, timestamped), JSONL parsing, batching, and
  compilation of event batches into :class:`~repro.core.workload.Workload`
  parts through the existing ``locate``/``from_keys`` path;
* :mod:`repro.serving.sketch` — :class:`WindowSketch`, the sliding-window
  workload sketch: ring-buffered per-batch profile chunks whose merge is
  associative (so eviction is subtraction-free), exposing ``to_profiles()``
  views that plug straight into ``CostSession.solve_profiles`` — no trace
  replay, ever;
* :mod:`repro.serving.session` — :class:`ServingSession`, the loop that
  consumes the stream, watches sketch divergence (TV distance with
  hysteresis), retunes from the live sketch via
  ``TuningSession.tune_from_profiles``, and switches configurations only
  when the rebuild-cost-aware extension of Eq. 15/16 says the steady-state
  I/O savings repay the rebuild I/O.
"""
from repro.serving.session import (RetuneDecision, ServingConfig,
                                   ServingSession, ServingStats)
from repro.serving.sketch import WindowSketch, tv_distance
from repro.serving.trace import (TraceEvent, compile_events, iter_batches,
                                 parse_jsonl, synthetic_drifting_trace)

__all__ = [
    "TraceEvent", "parse_jsonl", "iter_batches", "compile_events",
    "synthetic_drifting_trace",
    "WindowSketch", "tv_distance",
    "ServingSession", "ServingConfig", "ServingStats", "RetuneDecision",
]
