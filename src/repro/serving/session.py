"""ServingSession — the drift → retune → (maybe) rebuild loop.

Consumes a live op stream, maintains a :class:`WindowSketch` over it, and
keeps one deployed (knob, buffer-split) configuration honest against the
workload the system is ACTUALLY seeing.  Three rules, in order:

1. **Detect** — after each ingested batch, compare the live window summary
   against the summary the current configuration was tuned on
   (:func:`~repro.serving.sketch.tv_distance`).  Hysteresis keeps the
   detector quiet around the threshold: after any retune evaluation the
   trigger disarms, re-arming only once divergence falls back below
   ``threshold - hysteresis`` (or keeps worsening by another hysteresis
   step — sustained deepening drift must not be maskable by one refused
   evaluation), and a cooldown bounds evaluation frequency outright.

2. **Retune** — on a trigger, re-run the joint (knob x buffer-split)
   search on the live sketch via ``TuningSession.tune_from_profiles``.
   This is the load-bearing structural property of the serving loop: the
   sketch IS the workload — no trace replay, no ``grid_profiles`` pass,
   just one batched ``solve_profiles`` over the (knob x split) table
   (asserted in ``tests/test_serving.py``).

3. **Decide** — the rebuild-cost-aware extension of Eq. 15/16.  The paper
   trades index footprint against buffer pages at a fixed instant; serving
   adds the time axis: switching configurations costs real I/O — a key-file
   scan to rebuild (``num_pages(n)`` reads), writing the new index
   (``ceil(size/page)`` writes), and re-warming the buffer priced through
   the same cache model (the new steady state holds ``min(capacity, N)``
   pages, each a cold miss).  Switch only when

       (io_cur - io_new) * horizon_queries  >  rebuild_io,

   i.e. when predicted steady-state savings over the configured horizon
   repay the modeled rebuild.  ``io_cur`` is the CURRENT configuration
   priced on the LIVE sketch — read off the same solved table, zero extra
   model calls.  Disabling the gate (``rebuild_gate=False``) yields the
   retune-every-drift-event baseline the drift benchmark compares against.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.workload import Workload
from repro.serving.sketch import DEFAULT_PAGE_BINS, WindowSketch, tv_distance
from repro.serving.trace import TraceEvent, compile_events, iter_batches
from repro.tuning.session import (IndexBuilder, TuneResult, TuningSession,
                                  _feasibility_split)

__all__ = ["ServingConfig", "ServingStats", "RetuneDecision", "BatchReport",
           "ServingSession"]


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Knobs of the serving loop itself (not of the index)."""

    batch_size: int = 512          # events per ingested batch
    window_chunks: int = 8         # sliding-window length, in batches
    page_bins: int = DEFAULT_PAGE_BINS
    # None = per-call default (REPRO_ENGINE_EXECUTOR, else auto-TPU);
    # "device" keeps batch occupancy profiling on the accelerator.
    profile_executor: Optional[str] = None
    drift_threshold: float = 0.15  # TV distance that triggers an evaluation
    hysteresis: float = 0.05       # re-arm band below the threshold
    cooldown_batches: int = 2      # min batches between evaluations
    horizon_queries: float = 1e6   # steady-state horizon of the switch rule
    rebuild_gate: bool = True      # False = retune-every-drift baseline


@dataclasses.dataclass
class ServingStats:
    """Counters the drift benchmark reads off."""

    batches: int = 0
    events: int = 0
    drift_events: int = 0          # triggers (armed + above threshold)
    retune_evaluations: int = 0    # solve-table evaluations run
    rebuilds: int = 0              # evaluations that switched the config


@dataclasses.dataclass(frozen=True)
class RetuneDecision:
    """One evaluated drift event: the Eq. 15/16-extension verdict."""

    ts: float
    tv: float
    io_current: float              # current config priced on the live sketch
    io_candidate: float            # retuned best on the live sketch
    rebuild_io: float              # modeled rebuild cost, in page I/Os
    predicted_savings: float       # (io_cur - io_new) * horizon
    switched: bool
    from_knob: object
    to_knob: object
    result: TuneResult


@dataclasses.dataclass(frozen=True)
class BatchReport:
    """Per-batch outcome of :meth:`ServingSession.ingest`."""

    ts: float
    n_queries: int
    tv: float
    drifted: bool
    decision: Optional[RetuneDecision]


class ServingSession:
    """Drift-aware serving of ONE index family on one key file.

    Construction fixes the candidate grid (budget-feasible knob points of
    ``builder``); :meth:`start` warms the sketch and deploys the initial
    configuration; :meth:`observe` / :meth:`ingest` then run the
    detect → retune → decide loop described in the module docstring.
    """

    def __init__(self, tuning: TuningSession, builder: IndexBuilder,
                 keys: np.ndarray, *,
                 overrides: Optional[Dict[str, object]] = None,
                 config: Optional[ServingConfig] = None,
                 size_model=None):
        self.tuning = tuning
        self.builder = builder
        self.keys = np.asarray(keys)
        self.config = config if config is not None else ServingConfig()
        self.space = builder.knob_space(overrides)
        size_model = size_model if size_model is not None \
            else builder.size_model()
        feasible, _skipped = _feasibility_split(
            self.space.points(), self.space, size_model, tuning.system)
        if not feasible:
            raise ValueError("memory budget too small for any candidate "
                             "index")
        self.candidates = [builder.candidate(pt, size)
                           for pt, size in feasible]
        self._size_of = {self.space.key(pt): size for pt, size in feasible}
        self.sketch = WindowSketch(
            tuning.cost, self.candidates,
            window_chunks=self.config.window_chunks,
            page_bins=self.config.page_bins,
            profile_executor=self.config.profile_executor)
        self.current: Optional[TuneResult] = None
        self.stats = ServingStats()
        self.decisions: List[RetuneDecision] = []
        self._baseline = None
        self._armed = False
        self._last_eval_tv = 0.0
        self._cooldown = 0

    # ----------------------------------------------------------------- start
    def start(self, warmup_events: Sequence[TraceEvent]) -> TuneResult:
        """Warm the sketch on an initial event prefix and deploy the tune.

        Even the initial tune runs from the sketch (``tune_from_profiles``),
        so the whole lifecycle shares one code path and the structural
        no-reprofile guarantee holds from the first event onward.
        """
        for batch in iter_batches(warmup_events, self.config.batch_size):
            self.sketch.update(compile_events(batch, self.keys))
        result = self._retune()
        self._deploy(result)
        return result

    # ---------------------------------------------------------------- ingest
    def observe(self, events: Sequence[TraceEvent]) -> List[BatchReport]:
        """Batch an event stream through :meth:`ingest`."""
        return [self.ingest(compile_events(batch, self.keys),
                            ts=batch[-1].ts)
                for batch in iter_batches(events, self.config.batch_size)]

    def ingest(self, workload: Workload, ts: float = 0.0) -> BatchReport:
        """One loop iteration: sketch update, drift check, maybe a retune."""
        if self.current is None:
            raise RuntimeError("ServingSession.start() must run before "
                               "ingest()")
        cfg = self.config
        self.sketch.update(workload)
        self.stats.batches += 1
        self.stats.events += workload.n_queries
        if self._cooldown > 0:
            self._cooldown -= 1
        tv = tv_distance(self.sketch.summary(), self._baseline)
        if not self._armed and tv < cfg.drift_threshold - cfg.hysteresis:
            self._armed = True
        drifted = tv > cfg.drift_threshold and (
            self._armed or tv > self._last_eval_tv + cfg.hysteresis)
        decision = None
        if drifted and self._cooldown == 0:
            self.stats.drift_events += 1
            decision = self._evaluate(tv, ts)
        return BatchReport(ts=ts, n_queries=workload.n_queries, tv=tv,
                           drifted=drifted, decision=decision)

    # -------------------------------------------------------------- decision
    def _retune(self) -> TuneResult:
        return self.tuning.tune_from_profiles(
            self.builder, self.sketch.to_profiles(), knob_space=self.space)

    def _deploy(self, result: TuneResult) -> None:
        self.current = result
        self._baseline = self.sketch.summary()
        self._armed = False
        self._last_eval_tv = 0.0
        self._cooldown = self.config.cooldown_batches

    def _evaluate(self, tv: float, ts: float) -> RetuneDecision:
        cfg = self.config
        result = self._retune()
        self.stats.retune_evaluations += 1
        io_new = float(result.est_io)
        io_cur = self._current_io(result)
        rebuild = self.rebuild_io(result)
        savings = (io_cur - io_new) * cfg.horizon_queries
        if cfg.rebuild_gate:
            switched = (result.best_knob != self.current.best_knob
                        and savings > rebuild)
        else:
            switched = True
        decision = RetuneDecision(
            ts=ts, tv=tv, io_current=io_cur, io_candidate=io_new,
            rebuild_io=rebuild, predicted_savings=savings,
            switched=switched, from_knob=self.current.best_knob,
            to_knob=result.best_knob, result=result)
        self.decisions.append(decision)
        self._armed = False
        self._last_eval_tv = tv
        self._cooldown = cfg.cooldown_batches
        if switched:
            self.stats.rebuilds += 1
            self._deploy(result)
        return decision

    def _current_io(self, result: TuneResult) -> float:
        """Price the DEPLOYED (knob, split) on the live sketch.

        Read off the freshly solved (knob x split) table — same capacities,
        zero extra model calls.  A deployed knob that fell out of the table
        (cannot happen with a fixed candidate grid, but be safe) prices as
        +inf, which always favors switching.
        """
        entries = result.table.get(self.current.best_knob)
        if not entries:
            return math.inf
        cap = self.current.capacity_pages
        return min(entries, key=lambda e: abs(e.capacity_pages - cap)).io

    def rebuild_io(self, result: TuneResult) -> float:
        """Modeled page I/Os to deploy ``result``'s best configuration.

        Key-file scan reads + index write I/O + cold-cache refill: the new
        steady state keeps ``min(capacity, distinct_pages)`` pages resident
        (``distinct_pages`` from the sketch solve — the live workload's
        touched-page footprint), and every one of them re-enters the buffer
        as a miss the old configuration would not have paid.
        """
        geom = self.tuning.system.geom
        scan_reads = geom.num_pages(int(self.keys.shape[0]))
        size_b = float(self._size_of.get(result.best_knob, 0.0))
        write_ios = math.ceil(size_b / geom.page_bytes)
        est = result.estimates[result.best_knob]
        refill = min(float(result.capacity_pages), est.distinct_pages)
        return float(scan_reads + write_ios + refill)
