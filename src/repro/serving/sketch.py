"""Incremental workload sketches — sliding-window profiles without replay.

The serving loop must answer "what would each candidate configuration cost
on the CURRENT workload?" continuously, but a ``grid_profiles`` pass over
the whole trace is O(trace) and grows without bound.  The observation that
makes sketching exact rather than approximate: everything a
:class:`~repro.core.session.GridProfiles` row holds is a SUM over queries —
Eq. 12/13 expected-reference histograms, request mass R, DAC access mass,
sorted-window coverage — so per-batch partial sums are a lossless
representation, and merging them is pure array addition.

:class:`WindowSketch` therefore keeps a ring buffer of per-batch
:class:`SketchChunk`s (``deque(maxlen=W)``): ``update(batch_workload)``
profiles ONE batch (O(batch x K), the only model call), appending evicts
the expired chunk, and ``to_profiles()`` re-merges the ≤ W live chunks —
O(W x K x P), independent of how much trace has ever flowed through.
Eviction is subtraction-free by construction: expired events were only ever
inside their own chunk, so dropping the chunk drops them exactly (no
decremental histogram surgery, no cancellation error).

The merge is a monoid (:class:`_Accum`): commutative array sums plus one
genuinely sequential statistic — the cross-chunk junction term of the
pressure-pinned sorted-scan correction.  A probe window whose lo page
equals the previous window's hi page is a guaranteed hit under any policy
(see ``page_ref.sorted_workload_stats``); when the two windows fall in
different chunks, neither chunk sees the junction.  Each accumulation
therefore carries its first-lo/last-hi boundary pages and the merge adds
``[right.first_lo == left.last_hi]`` — associative by construction, which
``tests/test_serving.py`` property-checks.

Drift detection rides along: each chunk also carries candidate-independent
page-popularity, range-width, and op-mix histograms; :func:`tv_distance`
between normalized window summaries is what :class:`ServingSession`
thresholds.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import reduce
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.session import (CostSession, GridCandidate, GridProfiles,
                                SortedScanPart, WriteStreamPart)
from repro.core.workload import (DELETE, INSERT, MIXED, POINT, RANGE, SORTED,
                                 UPDATE, Workload)

__all__ = ["SketchChunk", "WindowSketch", "tv_distance",
           "shard_page_masses", "WIDTH_BINS", "DEFAULT_PAGE_BINS"]

WIDTH_BINS = 24           # log2 range/sorted window-width histogram
DEFAULT_PAGE_BINS = 32    # coarse page-popularity histogram

_OP_INDEX = {POINT: 0, RANGE: 1, SORTED: 2, INSERT: 3, UPDATE: 4, DELETE: 5}
_N_OPS = len(_OP_INDEX)


# ---------------------------------------------------------------------------
# Chunks and their merge monoid
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SketchChunk:
    """Lossless profile summary of ONE ingested batch.

    Per-candidate arrays are float64 partial sums of the batch's
    ``GridProfiles`` row (``dac_mass`` is ``E[DAC] * n_queries``, so it adds
    across batches); the sorted-stream state is shared across candidates
    (windows are position-defined, so only the Thm III.1 capacity premise
    ``sorted_min_caps`` varies by knob).  ``first_lo_page``/``last_hi_page``
    are the junction-boundary metadata described in the module docstring.
    """

    n_queries: int
    counts: np.ndarray                      # (K, P) float64
    totals: np.ndarray                      # (K,)
    dac_mass: np.ndarray                    # (K,)
    sorted_refs: float = 0.0
    sorted_pinned: float = 0.0
    sorted_coverage: Optional[np.ndarray] = None   # (P,) float64
    sorted_min_caps: Optional[np.ndarray] = None   # (K,) int64
    write_counts: Optional[np.ndarray] = None      # (K, P) float64
    write_refs: Optional[np.ndarray] = None        # (K,) float64
    first_lo_page: Optional[int] = None
    last_hi_page: Optional[int] = None
    page_pop: Optional[np.ndarray] = None   # (page_bins,) drift summary
    width_hist: Optional[np.ndarray] = None  # (WIDTH_BINS,)
    op_mix: Optional[np.ndarray] = None     # (_N_OPS,)


@dataclasses.dataclass
class _Accum:
    """The merge monoid over chunks: array sums + the junction statistic."""

    n_queries: int
    counts: np.ndarray
    totals: np.ndarray
    dac_mass: np.ndarray
    sorted_refs: float
    sorted_pinned: float
    sorted_coverage: Optional[np.ndarray]
    sorted_min_caps: Optional[np.ndarray]
    first_lo_page: Optional[int]
    last_hi_page: Optional[int]
    write_counts: Optional[np.ndarray] = None
    write_refs: Optional[np.ndarray] = None

    @classmethod
    def lift(cls, c: SketchChunk) -> "_Accum":
        return cls(c.n_queries, c.counts, c.totals, c.dac_mass,
                   c.sorted_refs, c.sorted_pinned, c.sorted_coverage,
                   c.sorted_min_caps, c.first_lo_page, c.last_hi_page,
                   c.write_counts, c.write_refs)


def _opt_add(a, b):
    if a is None:
        return b
    if b is None:
        return a
    return a + b


def _opt_max(a, b):
    if a is None:
        return b
    if b is None:
        return a
    return np.maximum(a, b)


def merge_accums(left: _Accum, right: _Accum) -> _Accum:
    """Associative merge of two window accumulations (left precedes right).

    Everything adds except the capacity premise (elementwise max) and the
    boundary metadata: the junction term bridges left's last sorted window
    to right's first, and the merged accumulation keeps left's first /
    right's last boundary — exactly the fold a flat concatenation would
    produce, which is what makes the merge associative.
    """
    junction = 0.0
    if left.last_hi_page is not None and right.first_lo_page is not None:
        junction = 1.0 if right.first_lo_page == left.last_hi_page else 0.0
    return _Accum(
        n_queries=left.n_queries + right.n_queries,
        counts=left.counts + right.counts,
        totals=left.totals + right.totals,
        dac_mass=left.dac_mass + right.dac_mass,
        sorted_refs=left.sorted_refs + right.sorted_refs,
        sorted_pinned=left.sorted_pinned + right.sorted_pinned + junction,
        sorted_coverage=_opt_add(left.sorted_coverage, right.sorted_coverage),
        sorted_min_caps=_opt_max(left.sorted_min_caps, right.sorted_min_caps),
        first_lo_page=(left.first_lo_page if left.first_lo_page is not None
                       else right.first_lo_page),
        last_hi_page=(right.last_hi_page if right.last_hi_page is not None
                      else left.last_hi_page),
        write_counts=_opt_add(left.write_counts, right.write_counts),
        write_refs=_opt_add(left.write_refs, right.write_refs),
    )


# ---------------------------------------------------------------------------
# Drift summaries
# ---------------------------------------------------------------------------

def _iter_parts(workload: Workload):
    return workload.parts if workload.kind == MIXED else (workload,)


def _drift_summary(workload: Workload, num_pages: int, c_ipp: int,
                   page_bins: int):
    page_pop = np.zeros(page_bins, np.float64)
    width_hist = np.zeros(WIDTH_BINS, np.float64)
    op_mix = np.zeros(_N_OPS, np.float64)
    for p in _iter_parts(workload):
        if p.positions is None or p.n_queries == 0:
            continue
        pages = np.asarray(p.positions, np.int64) // c_ipp
        bins = np.minimum(pages * page_bins // max(num_pages, 1),
                          page_bins - 1)
        page_pop += np.bincount(bins, minlength=page_bins)
        op_mix[_OP_INDEX[p.kind]] += p.n_queries
        if p.hi_positions is not None:
            widths = (np.asarray(p.hi_positions, np.int64)
                      - np.asarray(p.positions, np.int64) + 1)
            wb = np.minimum(np.log2(np.maximum(widths, 1)).astype(np.int64),
                            WIDTH_BINS - 1)
            width_hist += np.bincount(wb, minlength=WIDTH_BINS)
    return page_pop, width_hist, op_mix


def _normalize(h: np.ndarray) -> np.ndarray:
    s = float(h.sum())
    return h / s if s > 0 else h


def shard_page_masses(summary: Dict[str, np.ndarray],
                      boundary_pages: Sequence[int],
                      num_pages: int) -> Tuple[float, ...]:
    """Per-shard query-mass fractions read off a sketch summary.

    The sharding layer's view of a serving sketch: the ``page_pop``
    histogram bins the GLOBAL page space, and shard boundaries are page
    positions (``ShardedSystem.boundary_pages``), so each bin's mass is
    attributed to the shard owning the bin's first page — no routing pass,
    no replay.  Resolution is ``page_bins``-coarse, which is exactly the
    hot-shard detector's need: it names the shard soaking up traffic, not
    exact counts.  Returns ``len(boundary_pages) + 1`` fractions summing
    to 1 (all zeros for an empty summary).
    """
    pop = np.asarray(summary["page_pop"], np.float64)
    page_bins = pop.shape[0]
    cuts = np.asarray(boundary_pages, np.int64)
    # first global page of each bin (inverse of the binning in
    # _drift_summary: page -> page * page_bins // num_pages)
    start = (np.arange(page_bins, dtype=np.int64) * max(num_pages, 1)
             + page_bins - 1) // page_bins
    shard = np.searchsorted(cuts, start, side="left")
    masses = np.zeros(cuts.shape[0] + 1, np.float64)
    np.add.at(masses, shard, pop)
    total = float(masses.sum())
    if total > 0:
        masses /= total
    return tuple(float(m) for m in masses)


def tv_distance(a: Dict[str, np.ndarray], b: Dict[str, np.ndarray]) -> float:
    """Worst-component total-variation distance between window summaries.

    Each summary component (page popularity, width histogram, op mix) is
    normalized and compared by TV = 0.5 Σ|p - q|; the max over components
    makes the detector sensitive to drift along ANY axis (a pure hot-set
    move shows up even when the op mix is unchanged).  Components empty on
    both sides contribute 0.
    """
    d = 0.0
    for k in a:
        pa, pb = _normalize(a[k]), _normalize(b[k])
        if pa.sum() == 0 and pb.sum() == 0:
            continue
        d = max(d, 0.5 * float(np.abs(pa - pb).sum()))
    return d


# ---------------------------------------------------------------------------
# The sketch
# ---------------------------------------------------------------------------

class WindowSketch:
    """Sliding-window workload sketch over a FIXED candidate grid.

    Bound to one :class:`CostSession` and one candidate list (the feasible
    knob points of the family being served).  ``update`` ingests one batch
    workload — the single ``grid_profiles`` call per batch is the only
    model work, O(batch x K) — and ``to_profiles`` re-merges the live
    window into a :class:`GridProfiles` for
    ``TuningSession.tune_from_profiles`` / ``CostSession.solve_profiles``.
    """

    def __init__(self, cost: CostSession,
                 candidates: Sequence[GridCandidate], *,
                 window_chunks: int = 8,
                 page_bins: int = DEFAULT_PAGE_BINS,
                 profile_executor: Optional[str] = None):
        if window_chunks < 1:
            raise ValueError("window_chunks must be >= 1")
        self.cost = cost
        self.system = cost.system
        self.profile_executor = profile_executor
        self.candidates = list(candidates)
        self.sizes = np.asarray([c.size_bytes for c in self.candidates],
                                np.float64)
        self.window_chunks = int(window_chunks)
        self.page_bins = int(page_bins)
        self.chunks: collections.deque = collections.deque(
            maxlen=self.window_chunks)
        self.knobs: Optional[Tuple[object, ...]] = None
        self.updates = 0
        self.events_ingested = 0

    # ---------------------------------------------------------------- update
    def update(self, workload: Workload) -> SketchChunk:
        """Ingest one batch: profile it, append its chunk, evict the oldest.

        O(batch x K) — profiles exactly this batch; nothing already
        ingested is touched, and eviction is the deque dropping the expired
        chunk (subtraction-free).
        """
        profs = self.cost.grid_profiles(self.candidates, workload,
                                        executor=self.profile_executor)
        if self.knobs is None:
            self.knobs = profs.knobs
        elif profs.knobs != self.knobs:
            raise ValueError(
                "candidate grid changed mid-sketch: batch profiled "
                f"{profs.knobs} but the window holds {self.knobs}")
        chunk = self._chunk_from(profs, workload)
        self.chunks.append(chunk)
        self.updates += 1
        self.events_ingested += chunk.n_queries
        return chunk

    def _chunk_from(self, profs: GridProfiles,
                    workload: Workload) -> SketchChunk:
        geom = self.system.geom
        num_pages = int(profs.counts.shape[1])
        page_pop, width_hist, op_mix = _drift_summary(
            workload, num_pages, geom.c_ipp, self.page_bins)
        chunk = SketchChunk(
            n_queries=int(profs.n_queries),
            counts=np.asarray(profs.counts, np.float64),
            totals=np.asarray(profs.totals, np.float64),
            dac_mass=np.asarray(profs.dacs, np.float64) * profs.n_queries,
            page_pop=page_pop, width_hist=width_hist, op_mix=op_mix)
        if profs.wparts:
            # write streams are partial sums like everything else: keep the
            # per-candidate (K, P) expected-write histograms and masses
            zero_w = np.zeros(num_pages, np.float64)
            chunk.write_counts = np.stack(
                [np.asarray(wp.counts, np.float64) if wp is not None
                 else zero_w for wp in profs.wparts])
            chunk.write_refs = np.asarray(
                [wp.total_refs if wp is not None else 0.0
                 for wp in profs.wparts], np.float64)
        spart = next((sp for sp in profs.sparts if sp is not None), None)
        if spart is not None:
            chunk.sorted_refs = float(spart.total_refs)
            chunk.sorted_pinned = float(spart.pinned_retouches)
            chunk.sorted_coverage = np.asarray(spart.coverage, np.float64)
            chunk.sorted_min_caps = np.asarray(
                [sp.min_capacity if sp is not None else 1
                 for sp in profs.sparts], np.int64)
            for p in _iter_parts(workload):
                if p.kind == SORTED and p.n_queries:
                    chunk.first_lo_page = int(p.positions[0]) // geom.c_ipp
                    chunk.last_hi_page = int(p.hi_positions[-1]) // geom.c_ipp
                    break
        return chunk

    # ----------------------------------------------------------------- views
    def __len__(self) -> int:
        return len(self.chunks)

    @property
    def full(self) -> bool:
        return len(self.chunks) == self.window_chunks

    def merged(self) -> _Accum:
        if not self.chunks:
            raise ValueError("empty sketch: ingest at least one batch first")
        return reduce(merge_accums, map(_Accum.lift, self.chunks))

    def to_profiles(self) -> GridProfiles:
        """The live window as a :class:`GridProfiles` — NO replay.

        Re-merges the ≤ W live chunks (array sums) and hands the result to
        ``GridProfiles.from_accumulated``; the output prices identically to
        a one-shot ``grid_profiles`` over the concatenation of the window's
        batches (property-tested), at O(W x K x P) cost independent of
        trace length.
        """
        acc = self.merged()
        sparts: List[Optional[SortedScanPart]]
        if acc.sorted_coverage is not None and acc.sorted_refs > 0:
            coverage = jnp.asarray(acc.sorted_coverage, jnp.float32)
            distinct = float(np.sum(acc.sorted_coverage > 0))
            sparts = [SortedScanPart(
                total_refs=acc.sorted_refs, distinct_pages=distinct,
                min_capacity=int(acc.sorted_min_caps[i]), coverage=coverage,
                pinned_retouches=acc.sorted_pinned)
                for i in range(len(self.candidates))]
        else:
            sparts = [None] * len(self.candidates)
        wparts: Tuple[Optional[WriteStreamPart], ...] = ()
        if acc.write_counts is not None and float(acc.write_refs.sum()) > 0:
            wparts = tuple(
                WriteStreamPart(jnp.asarray(acc.write_counts[i], jnp.float32),
                                float(acc.write_refs[i]))
                for i in range(len(self.candidates)))
        return GridProfiles.from_accumulated(
            self.system, self.knobs, acc.counts, acc.totals, acc.dac_mass,
            self.sizes, sparts, acc.n_queries, wparts=wparts)

    def summary(self) -> Dict[str, np.ndarray]:
        """Candidate-independent window summary for drift detection."""
        page_pop = np.zeros(self.page_bins, np.float64)
        width_hist = np.zeros(WIDTH_BINS, np.float64)
        op_mix = np.zeros(_N_OPS, np.float64)
        for c in self.chunks:
            page_pop += c.page_pop
            width_hist += c.width_hist
            op_mix += c.op_mix
        return {"page_pop": page_pop, "width": width_hist, "op_mix": op_mix}
