"""Simulated execution machine: buffered disk + timing model.

The paper measures wall-clock on a real NVMe SSD; this container has no disk
under test, so execution benchmarks run against a deterministic simulated
machine whose *hidden* ground-truth constants play the role of the hardware.
Physical I/O counts are exact (they come from real replay through the eviction
policy); time is physical-miss latency + CPU terms with the magnitudes of the
paper's fitted Table III parameters.  The join cost model (Eq. 17) is then
*calibrated against this machine* exactly the way the paper calibrates against
its server — the tuning/join experiments compare strategies, not absolute
seconds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import replay as replay_mod

__all__ = ["MachineParams", "BufferedDisk", "simulate_point_queries"]


@dataclasses.dataclass(frozen=True)
class MachineParams:
    """Hidden ground-truth constants (seconds) — Table III magnitudes."""

    cpu_per_key: float = 1.64e-6          # traversal + last-mile + buffer mgmt
    cpu_per_page_scan: float = 1.72e-6    # range scan + filtering per page
    range_op_setup: float = 4.42e-6       # per coalesced range-probe op
    point_op_setup: float = 0.30e-6       # per point-probe op
    miss_latency_point: float = 11.9e-6   # physical page miss, random read
    miss_latency_range: float = 4.66e-6   # physical page miss, sequential read
    sort_per_key: float = 0.12e-6         # outer-relation sort amortized / key


class BufferedDisk:
    """Data pages behind a self-managed page buffer (FIFO/LRU/LFU)."""

    def __init__(self, num_pages: int, capacity: int, policy: str = "lru"):
        self.num_pages = int(num_pages)
        self.capacity = int(max(1, capacity))
        self.policy = policy
        self.buffer = replay_mod.make_buffer(policy, self.capacity)
        self.physical_reads = 0
        self.logical_reads = 0

    def fetch_window(self, page_lo: int, page_hi: int) -> int:
        """Fetch pages [lo, hi]; returns physical misses for this request."""
        misses = 0
        access = self.buffer.access
        for page in range(page_lo, page_hi + 1):
            if not access(page):
                misses += 1
        count = page_hi - page_lo + 1
        self.logical_reads += count
        self.physical_reads += misses
        return misses


def simulate_point_queries(
    page_lo: np.ndarray,
    page_hi: np.ndarray,
    capacity: int,
    policy: str,
    machine: MachineParams = MachineParams(),
):
    """Execute a point workload; returns (total_seconds, qps, total_misses)."""
    misses = replay_mod.replay_windows(page_lo, page_hi, capacity, policy)
    total_misses = int(misses.sum())
    n = len(page_lo)
    seconds = (
        n * (machine.cpu_per_key + machine.point_op_setup)
        + total_misses * machine.miss_latency_point
    )
    return seconds, n / max(seconds, 1e-12), total_misses
