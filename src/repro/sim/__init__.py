"""Simulated buffered-disk machine (stands in for the paper's NVMe server)."""
from repro.sim import machine

__all__ = ["machine"]
