"""Merge scheduling: WHEN to flush the delta, CAM-guided and baselines.

Every scheduler sees the same :class:`DecisionContext` — the per-event
price vector the session obtained from ONE ``PricingEngine.price`` call
(defer at the shrunken capacity, merged at the restored capacity, and the
merge burst itself) plus the delta state.  The CAM scheduler is the only
one that READS the prices; the baselines decide from counters, which is
precisely the comparison the benchmark runs.

The CAM rule is Eq. 15 with a time axis.  Eq. 15 picks the configuration
minimizing expected I/O per op at a fixed capacity; a merge decision is a
choice between two capacity TRAJECTORIES over the coming horizon:

    defer:  H * io(C_now)            (keep paying the shrunken cache)
    merge:  burst_io + H * io(C_0)   (pay the flush, then the full cache)

so merge wins when ``(io_defer - io_merged) * H > burst_io`` — deferral's
extra probe misses over the horizon outweigh the merge's own I/O.  H
counts expected READS (only probes pay the shrunken cache; staged writes
are free until merged).  Both sides of the inequality come out of the one
priced table; the decision itself is arithmetic on three floats (zero
model calls, structurally asserted in tests).

First-order is the RIGHT order here, not an approximation shortcut: under
continued write inflow both trajectories refill at the same rate, so the
capacity gap between them — d stolen pages — is invariant along the
horizon and the priced per-query gap holds to first order.  Curvature of
io(C) (convex: each stolen page hurts more than the last) makes deferral
slightly worse than charged, so the rule errs toward deferring, never
toward flushing early.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

__all__ = ["DecisionContext", "MergeDecision", "CamMergeScheduler",
           "EveryKScheduler", "OnFullScheduler"]


class DecisionContext(NamedTuple):
    """What the session hands a scheduler each decision event."""

    batch_index: int
    io_defer: float           # per-query I/O at C(d) — current delta fill
    io_merged: float          # per-query I/O at C(0) — delta flushed
    merge_io: float           # the merge burst's total physical I/O
    horizon_queries: float    # expected READS over the decision horizon
    delta_entries: int
    delta_full: bool
    batches_since_merge: int


class MergeDecision(NamedTuple):
    merge: bool
    reason: str
    benefit: float = 0.0      # (io_defer - io_merged) * horizon_queries
    cost: float = 0.0         # merge_io charged against the benefit


@dataclasses.dataclass
class CamMergeScheduler:
    """Merge when deferral's priced miss penalty beats the burst's I/O.

    ``safety`` scales the burst cost (>1 defers more, <1 flushes more);
    a full delta always flushes (memory is a hard bound).
    """

    safety: float = 1.0
    name: str = "cam"

    def decide(self, ctx: DecisionContext) -> MergeDecision:
        if ctx.delta_entries == 0:
            return MergeDecision(False, "empty")
        if ctx.delta_full:
            return MergeDecision(True, "full")
        benefit = max(ctx.io_defer - ctx.io_merged, 0.0) * ctx.horizon_queries
        cost = ctx.merge_io * self.safety
        if benefit > cost:
            return MergeDecision(True, "priced", benefit, cost)
        return MergeDecision(False, "priced", benefit, cost)


@dataclasses.dataclass
class EveryKScheduler:
    """Cache-oblivious baseline: merge every ``k`` ingested batches."""

    k: int = 8
    name: str = "every_k"

    def decide(self, ctx: DecisionContext) -> MergeDecision:
        if ctx.delta_entries == 0:
            return MergeDecision(False, "empty")
        if ctx.delta_full:
            return MergeDecision(True, "full")
        if ctx.batches_since_merge >= self.k:
            return MergeDecision(True, "period")
        return MergeDecision(False, "period")


@dataclasses.dataclass
class OnFullScheduler:
    """Cache-oblivious baseline: merge only when the delta is full."""

    name: str = "on_full"

    def decide(self, ctx: DecisionContext) -> MergeDecision:
        if ctx.delta_entries and ctx.delta_full:
            return MergeDecision(True, "full")
        return MergeDecision(False, "full")
