"""Write-path cost modeling: delta staging, merge scheduling, WriteSession.

The write-side counterpart of ``repro.serving``: ingest a live read/write
trace, stage mutations in a memory-resident delta buffer, and decide WHEN
to merge the delta into the base structure by pricing the alternatives
through the CAM engine — deferring a merge shrinks the buffer pool (the
delta steals cache pages, so probe misses rise), merging now pays the
merge's own sorted-burst I/O.  ``scheduler.CamMergeScheduler`` answers the
question with Eq. 15 extended over a decision horizon; ``WriteSession``
drives it end-to-end and accounts both I/O streams.
"""
from repro.write.delta import DeltaBuffer, merge_burst_workload
from repro.write.scheduler import (CamMergeScheduler, DecisionContext,
                                   EveryKScheduler, MergeDecision,
                                   OnFullScheduler)
from repro.write.session import (BatchRecord, WriteConfig, WriteSession,
                                 WriteSessionReport)

__all__ = [
    "DeltaBuffer", "merge_burst_workload",
    "CamMergeScheduler", "EveryKScheduler", "OnFullScheduler",
    "MergeDecision", "DecisionContext",
    "WriteConfig", "WriteSession", "WriteSessionReport", "BatchRecord",
]
