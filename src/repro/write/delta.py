"""Delta-buffer staging: deferred writes and their merge bursts.

A :class:`DeltaBuffer` models the memory-resident staging area updatable
disk indexes put in front of the base structure (ALEX's delta nodes, the
LSM memtable, B^eps-tree node buffers): writes append to it instead of
dirtying base pages, so a staged write costs NO immediate I/O.  The two
costs it defers are exactly what the scheduler weighs:

* **capacity pressure** — the delta lives in the same memory budget as the
  buffer pool, so every staged entry shrinks the page cache
  (``stolen_pages``).  Reads keep probing the base; their miss rate is the
  CAM fixed point at the SHRUNKEN capacity — no new model, just Eq. 7/8 at
  ``C(d)``;
* **the merge burst** — flushing rewrites every base page the staged keys
  touch, in key order.  :func:`merge_burst_workload` compiles the staged
  ranks into a sorted-stream workload (coalesced page runs), so the burst
  prices through the SAME Theorem III.1 sorted-scan model every other
  sorted sweep in the repo uses.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

import numpy as np

from repro.core.workload import WRITE_KINDS, Workload

__all__ = ["DeltaBuffer", "merge_burst_workload"]


@dataclasses.dataclass
class DeltaBuffer:
    """Memory-resident write staging area (entry-counted, rank-tracked).

    Tracks how many mutations are pending and WHERE they land (base-file
    ranks), because both matter: the count fixes the stolen cache pages,
    the rank spread fixes the merge burst's page coverage.
    """

    capacity_entries: int
    entry_bytes: float = 16.0
    entries: int = 0
    staged_total: int = 0                  # lifetime staged events
    merges: int = 0                        # lifetime merges
    _positions: List[np.ndarray] = dataclasses.field(default_factory=list)

    def stage(self, workload: Workload) -> int:
        """Stage every mutating part of ``workload``; returns events staged.

        The buffer intentionally accepts overflow past ``capacity_entries``
        (``full`` turns True) — ENFORCING the bound is the scheduler's job,
        and merge-on-full baselines need to observe the full state rather
        than have it resolved under them.
        """
        staged = 0
        for part in (workload.parts if workload.kind == "mixed"
                     else (workload,)):
            if part.kind in WRITE_KINDS and part.n_queries:
                self._positions.append(
                    np.asarray(part.positions, np.int64).ravel())
                staged += part.n_queries
        self.entries += staged
        self.staged_total += staged
        return staged

    @property
    def bytes_used(self) -> float:
        return self.entries * self.entry_bytes

    @property
    def full(self) -> bool:
        return self.entries >= self.capacity_entries

    def stolen_pages(self, page_bytes: int) -> int:
        """Buffer-pool pages the staged entries displace."""
        return int(math.ceil(self.bytes_used / max(page_bytes, 1)))

    def positions(self) -> np.ndarray:
        """All staged ranks (unsorted, duplicates preserved)."""
        if not self._positions:
            return np.zeros(0, np.int64)
        return np.concatenate(self._positions)

    def clear(self) -> int:
        """Merge completed: empty the buffer; returns entries flushed."""
        flushed = self.entries
        self.entries = 0
        self._positions = []
        self.merges += 1 if flushed else 0
        return flushed


def merge_burst_workload(positions: np.ndarray, n: int,
                         c_ipp: int) -> Workload:
    """Compile staged ranks into the merge's sorted rewrite burst.

    The merge walks the staged keys in sorted order and rewrites each base
    page they touch; staged keys on the same or adjacent pages share one
    sequential run.  Coalescing sorted target pages wherever consecutive
    staged pages are within one page of each other yields one sorted-stream
    window per run — a monotone probe sequence, which is exactly the access
    pattern Theorem III.1's closed forms price (and what lets a big buffer
    make re-touched merge pages free).
    """
    pos = np.sort(np.asarray(positions, np.int64).ravel())
    if pos.shape[0] == 0:
        raise ValueError("empty delta: no merge burst to compile")
    pages = np.unique(pos // max(c_ipp, 1))
    # run breaks: next touched page more than one page away
    breaks = np.nonzero(np.diff(pages) > 1)[0]
    starts = np.concatenate([[0], breaks + 1])
    ends = np.concatenate([breaks, [pages.shape[0] - 1]])
    lo = np.minimum(pages[starts] * c_ipp, n - 1)
    hi = np.minimum(pages[ends] * c_ipp + (c_ipp - 1), n - 1)
    return Workload.sorted_stream(lo, np.maximum(hi, lo), n=n)
