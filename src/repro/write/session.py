"""WriteSession — the write-path serving loop (trace in, I/O ledger out).

Drives the full pipeline over a live read/write op log: batches compile
through the shared trace frontend, reads feed the sliding-window sketch
(incremental profiles, no replay), writes stage into the
:class:`~repro.write.delta.DeltaBuffer`, and at every batch boundary the
session prices the merge question through the engine and lets the
configured scheduler decide.

The pricing discipline is the headline invariant: each decision event
builds ONE three-cell :class:`~repro.engine.table.PriceTable` — the live
read mix at the shrunken capacity ``C(d)``, the same mix at the restored
capacity ``C(0)``, and the merge burst row — and makes ONE
``PricingEngine.price`` call.  Every scheduler (CAM and both baselines)
consumes the same priced context, every arm pays the same accounting, and
``engine.calls`` counts exactly one increment per decision event
(structurally asserted in tests/test_write_path.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.session import CostSession, GridCandidate, GridProfiles, System
from repro.core.workload import MIXED, WRITE_KINDS, Workload
from repro.engine.table import PriceTable, PricingEngine
from repro.serving.sketch import WindowSketch
from repro.serving.trace import TraceEvent, compile_events, iter_batches
from repro.write.delta import DeltaBuffer, merge_burst_workload
from repro.write.scheduler import DecisionContext, MergeDecision

__all__ = ["WriteConfig", "WriteSession", "WriteSessionReport",
           "BatchRecord", "split_reads_writes"]


def split_reads_writes(workload: Workload
                       ) -> Tuple[Optional[Workload], Optional[Workload]]:
    """Split a compiled batch into its read and write halves (either may be
    None).  Non-mixed workloads route whole; mixed parts regroup."""
    parts = workload.parts if workload.kind == MIXED else (workload,)
    reads = [p for p in parts if p.kind not in WRITE_KINDS]
    writes = [p for p in parts if p.kind in WRITE_KINDS]

    def regroup(ps):
        if not ps:
            return None
        return ps[0] if len(ps) == 1 else Workload.mixed(*ps)

    return regroup(reads), regroup(writes)


@dataclasses.dataclass(frozen=True)
class WriteConfig:
    """Knobs of the write-path loop (delta sizing, horizon, batching)."""

    batch_size: int = 256
    window_chunks: int = 8
    delta_capacity_entries: int = 8192
    delta_entry_bytes: float = 16.0
    horizon_batches: float = 4.0
    #: Each merged page is read and written back; 2.0 charges both streams.
    merge_write_factor: float = 2.0
    profile_executor: Optional[str] = None
    price_executor: Optional[str] = None


@dataclasses.dataclass
class BatchRecord:
    """One decision event's ledger row."""

    batch_index: int
    n_reads: int
    n_writes: int
    delta_entries: int
    cap_now: int
    cap_empty: int
    io_defer: float
    io_merged: float
    merge_io: float
    read_io: float
    merged: bool
    reason: str


@dataclasses.dataclass
class WriteSessionReport:
    """End-of-trace accounting for one scheduler arm."""

    scheduler: str
    records: List[BatchRecord]
    read_io: float            # Σ batch reads * per-query I/O at C(d)
    merge_io: float           # Σ merge bursts' physical I/O
    merges: int
    engine_calls: int
    decision_events: int

    @property
    def total_io(self) -> float:
        return self.read_io + self.merge_io

    def summary(self) -> dict:
        return {"scheduler": self.scheduler, "total_io": self.total_io,
                "read_io": self.read_io, "merge_io": self.merge_io,
                "merges": self.merges, "engine_calls": self.engine_calls,
                "decision_events": self.decision_events}


def _stack_profiles(a: GridProfiles, b: GridProfiles) -> GridProfiles:
    """Concatenate profile rows over the SAME page space (read mix row(s) +
    merge burst row) so one table prices them in one launch."""
    wa = a.wparts if a.wparts else (None,) * len(a.knobs)
    wb = b.wparts if b.wparts else (None,) * len(b.knobs)
    wparts = tuple(wa) + tuple(wb)
    return GridProfiles(
        knobs=a.knobs + b.knobs,
        counts=jnp.concatenate([a.counts, b.counts], axis=0),
        totals=np.concatenate([a.totals, b.totals]),
        dacs=np.concatenate([a.dacs, b.dacs]),
        sizes=np.concatenate([a.sizes, b.sizes]),
        caps=np.concatenate([a.caps, b.caps]),
        sparts=tuple(a.sparts) + tuple(b.sparts),
        skipped=tuple(a.skipped) + tuple(b.skipped),
        scale=a.scale,
        n_queries=a.n_queries + b.n_queries,
        wparts=(wparts if any(w is not None for w in wparts) else ()))


class WriteSession:
    """Serve a read/write trace against one live index configuration.

    ``candidate`` is the live structure being served — a uniform-eps
    ``GridCandidate`` or an index-backed one (ALEX/B+-tree adapters), same
    protocol the tuning grid uses.  The scheduler is a strategy object from
    ``repro.write.scheduler``; swapping it is the benchmark's only
    difference between arms.
    """

    def __init__(self, keys: np.ndarray, system: System, scheduler, *,
                 candidate: GridCandidate,
                 config: WriteConfig = WriteConfig()):
        self.keys = np.asarray(keys)
        self.n = int(self.keys.shape[0])
        self.system = system
        self.scheduler = scheduler
        self.config = config
        self.cost = CostSession(system)
        self.engine = PricingEngine(self.cost,
                                    executor=config.price_executor)
        self.candidate = candidate
        self.sketch = WindowSketch(self.cost, [candidate],
                                   window_chunks=config.window_chunks,
                                   profile_executor=config.profile_executor)
        self.delta = DeltaBuffer(
            capacity_entries=config.delta_capacity_entries,
            entry_bytes=config.delta_entry_bytes)
        self.cap_empty = int(system.capacity_for(candidate.size_bytes))
        self.batches_since_merge = 0

    # ------------------------------------------------------------------ parts
    def _capacity_now(self) -> int:
        stolen = self.delta.stolen_pages(self.system.geom.page_bytes)
        return max(self.cap_empty - stolen, 0)

    def _burst_profiles(self) -> Tuple[GridProfiles, int]:
        burst = merge_burst_workload(self.delta.positions(), self.n,
                                     self.system.geom.c_ipp)
        profs = self.cost.grid_profiles(
            [GridCandidate(knob="merge_burst", eps=0,
                           size_bytes=self.candidate.size_bytes)],
            burst, executor=self.config.profile_executor)
        return profs, burst.n_queries

    def _price_event(self) -> Tuple[float, float, float]:
        """ONE engine call: (io_defer, io_merged, merge_io_total)."""
        read_profs = self.sketch.to_profiles()
        cells = [("defer", 0, np.asarray([self._capacity_now()])),
                 ("merged", 0, np.asarray([self.cap_empty]))]
        if self.delta.entries:
            burst_profs, n_windows = self._burst_profiles()
            profs = _stack_profiles(read_profs, burst_profs)
            cells.append(("burst", len(read_profs.knobs),
                          np.asarray([self.cap_empty])))
        else:
            profs, n_windows = read_profs, 0
        sol = self.engine.price(PriceTable.from_cells(profs, cells))
        io_defer, io_merged = float(sol.io[0]), float(sol.io[1])
        merge_io = (float(sol.io[2]) * n_windows
                    * self.config.merge_write_factor
                    if self.delta.entries else float("inf"))
        return io_defer, io_merged, merge_io

    # -------------------------------------------------------------------- run
    def run(self, events: Sequence[TraceEvent]) -> WriteSessionReport:
        records: List[BatchRecord] = []
        read_io_total = 0.0
        merge_io_total = 0.0
        for i, batch in enumerate(iter_batches(events,
                                               self.config.batch_size)):
            wl = compile_events(batch, self.keys)
            reads, writes = split_reads_writes(wl)
            n_reads = reads.n_queries if reads is not None else 0
            n_writes = writes.n_queries if writes is not None else 0
            if reads is not None:
                self.sketch.update(reads)
            if writes is not None:
                self.delta.stage(writes)
            if len(self.sketch) == 0:
                # nothing priceable yet (pure-write prefix): stage and wait
                records.append(BatchRecord(i, n_reads, n_writes,
                                           self.delta.entries,
                                           self._capacity_now(),
                                           self.cap_empty, 0.0, 0.0,
                                           float("inf"), 0.0, False,
                                           "no_reads_yet"))
                continue

            io_defer, io_merged, merge_io = self._price_event()
            batch_read_io = io_defer * n_reads
            read_io_total += batch_read_io
            # ledger the state the DECISION saw (pre-flush)
            cap_now, delta_entries = self._capacity_now(), self.delta.entries

            # only reads pay io_defer, so the horizon counts expected reads;
            # the CURRENT batch's read rate predicts the coming regime far
            # better than a lifetime mean on piecewise-stationary traffic
            # (the lagging mean stalls big post-burst flushes for batches)
            horizon = self.config.horizon_batches * n_reads
            decision: MergeDecision = self.scheduler.decide(DecisionContext(
                batch_index=i, io_defer=io_defer, io_merged=io_merged,
                merge_io=merge_io, horizon_queries=horizon,
                delta_entries=self.delta.entries,
                delta_full=self.delta.full,
                batches_since_merge=self.batches_since_merge))
            merged = bool(decision.merge and self.delta.entries)
            if merged:
                merge_io_total += merge_io
                self.delta.clear()
                self.batches_since_merge = 0
            else:
                self.batches_since_merge += 1
            records.append(BatchRecord(
                i, n_reads, n_writes, delta_entries,
                cap_now, self.cap_empty, io_defer, io_merged,
                merge_io if merge_io != float("inf") else 0.0,
                batch_read_io, merged, decision.reason))
        return WriteSessionReport(
            scheduler=getattr(self.scheduler, "name",
                              type(self.scheduler).__name__),
            records=records, read_io=read_io_total,
            merge_io=merge_io_total, merges=self.delta.merges,
            engine_calls=self.engine.calls,
            decision_events=sum(1 for r in records
                                if r.reason != "no_reads_yet"))
