"""Policy-specific buffer hit-rate models (paper §III-B, §III-C).

All estimators operate on a page-request probability vector ``probs``
(``Pr_req(i)`` in the paper) and a buffer capacity ``C`` in pages.  They are
written as pure ``jnp`` programs so the whole CAM pipeline jits; the
fixed-point solves use a fixed-iteration bisection (monotone objectives) that
lowers to a tight ``fori_loop``.

Models implemented
------------------
* ``hit_rate_lru``  — Che's approximation (Eq. 7/8).
* ``hit_rate_fifo`` — Fricker's fixed point (Eq. 4/5/6); equals RANDOM under IRM.
* ``hit_rate_lfu``  — converged top-C mass (Eq. 9).
* ``hit_rate_compulsory`` — ``(R - N) / R`` for the large-capacity case and for
  sorted workloads under recency eviction (Theorem III.1).
* ``sorted_scan_misses`` / ``sorted_scan_hit_rate`` / the vmapped
  ``sorted_scan_hit_rate_grid`` — the policy-aware sorted-scan family: the
  compulsory closed form where Theorem III.1's premises hold (recency
  eviction, capacity above one probe window), a frequency-aware closed form
  from the window-coverage histogram for LFU-like policies, and the thrash
  regime below the capacity premise.  This is the ONE sorted-stream miss
  model shared by ``CostSession._finish`` and the join planner.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "solve_che_time",
    "hit_rate_lru",
    "solve_fifo_tau",
    "hit_rate_fifo",
    "hit_rate_lfu",
    "hit_rate_compulsory",
    "hit_rate",
    "hit_rate_grid",
    "writeback_fraction",
    "sorted_scan_misses",
    "sorted_scan_hit_rate",
    "sorted_scan_hit_rate_grid",
    "sorted_scan_miss_curve",
    "hit_rate_curve",
    "POLICIES",
    "RECENCY_POLICIES",
]

POLICIES = ("lru", "fifo", "lfu")

#: Policies whose eviction order tracks recency.  For these Theorem III.1's
#: proof step — "no page of the current probe window is evicted before the
#: probe finishes" — holds whenever the buffer fits one window, so the
#: compulsory closed form is exact for sorted streams.  Frequency-based
#: policies (LFU) violate it: stale high-frequency pages pin buffer slots and
#: the advancing scan frontier is evicted (with its frequency reset), so they
#: take the frequency-aware form below instead.
RECENCY_POLICIES = ("lru", "fifo")

_BISECT_ITERS = 64  # float32 bisection converges long before this

#: Largest capacity the exact compare path represents (int32).  Saturating
#: here is lossless for regime dispatch: every distinct-page count is far
#: below it, so any saturated capacity is already in the compulsory regime.
_CAP_MAX = 2**31 - 129


def _exact_caps(values) -> jnp.ndarray:
    """Integer-exact page counts for regime compares.

    float32 represents integers exactly only up to 2^24 (a 64 GiB pool at
    4 KiB pages), so ``capacity >= n_distinct``-style compares on float32
    capacities can flip on the rounded value.  Integer inputs pass through
    as int32 (exact to 2^31 pages); float inputs floor — for an integral
    threshold ``floor(c) >= n`` iff ``c >= n`` and ``floor(c) < n`` iff
    ``c < n`` — so float callers keep their semantics while integer callers
    gain exact compares.  Saturates at ``_CAP_MAX`` to keep the float→int
    conversion defined.
    """
    arr = jnp.asarray(values)
    if jnp.issubdtype(arr.dtype, jnp.integer):
        return jnp.minimum(arr.astype(jnp.int32), jnp.int32(_CAP_MAX))
    return jnp.clip(jnp.floor(arr), -1.0, float(_CAP_MAX)).astype(jnp.int32)


def _bisect(f, lo: jnp.ndarray, hi: jnp.ndarray, iters: int = _BISECT_ITERS):
    """Fixed-iteration bisection for a monotone-increasing scalar objective."""

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        val = f(mid)
        lo = jnp.where(val < 0.0, mid, lo)
        hi = jnp.where(val < 0.0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# LRU — Che's approximation
# ---------------------------------------------------------------------------

def solve_che_time(probs: jnp.ndarray, capacity) -> jnp.ndarray:
    """Characteristic time T_C from the consistency condition (Eq. 8):

        C = sum_i (1 - exp(-p_i * T_C))

    The RHS is monotone increasing in ``T_C`` and saturates at ``N`` (the
    number of pages with nonzero probability), so a solution exists whenever
    ``C < N``; callers handle ``C >= N`` via :func:`hit_rate_compulsory`.
    """
    probs = jnp.asarray(probs, jnp.float64 if probs.dtype == jnp.float64 else jnp.float32)
    capacity = jnp.asarray(capacity, probs.dtype)

    def objective(t):
        return jnp.sum(-jnp.expm1(-probs * t)) - capacity

    # Upper bracket: occupancy of every page is >= 1 - exp(-p_min*T); the
    # solution is below C / p_min-ish.  Grow a safe bracket from the mean.
    pmin = jnp.maximum(jnp.min(jnp.where(probs > 0, probs, jnp.inf)), 1e-30)
    hi = jnp.maximum(4.0 * capacity / pmin, jnp.asarray(1.0, probs.dtype))
    lo = jnp.zeros_like(hi)
    return _bisect(objective, lo, hi)


def hit_rate_lru(probs: jnp.ndarray, capacity, use_kernel: bool = False
                 ) -> jnp.ndarray:
    """Che's approximation for LRU (Eq. 7).

    ``use_kernel=True`` solves the characteristic time with the Pallas
    multi-candidate evaluator (kernels/che_solver.py): K=8 candidates per
    HBM pass, 4x less popularity-array traffic on TPU (interpret-mode on
    CPU, so opt-in here; validated equivalent in tests/test_kernels.py).
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        t_c = kernel_ops.che_solve(probs, capacity, k=8, iters=16)
    else:
        t_c = solve_che_time(probs, capacity)
    return jnp.sum(probs * -jnp.expm1(-probs * t_c))


# ---------------------------------------------------------------------------
# FIFO — Fricker's fixed point (== RANDOM under IRM)
# ---------------------------------------------------------------------------

def solve_fifo_tau(probs: jnp.ndarray, capacity) -> jnp.ndarray:
    """Characteristic time tau_C from the consistency condition (Eq. 5):

        C = sum_i p_i * tau / (1 - p_i + p_i * tau)

    Monotone increasing in ``tau`` with limit ``N``; bisection as for Che.
    """
    probs = jnp.asarray(probs, jnp.float64 if probs.dtype == jnp.float64 else jnp.float32)
    capacity = jnp.asarray(capacity, probs.dtype)

    def objective(tau):
        occ = probs * tau / (1.0 - probs + probs * tau)
        return jnp.sum(occ) - capacity

    pmin = jnp.maximum(jnp.min(jnp.where(probs > 0, probs, jnp.inf)), 1e-30)
    hi = jnp.maximum(4.0 * capacity / pmin, jnp.asarray(1.0, probs.dtype))
    lo = jnp.zeros_like(hi)
    return _bisect(objective, lo, hi)


def hit_rate_fifo(probs: jnp.ndarray, capacity) -> jnp.ndarray:
    """Fricker's FIFO/RANDOM stationary hit rate (Eq. 4 + Eq. 6)."""
    tau = solve_fifo_tau(probs, capacity)
    h_i = probs * tau / (1.0 - probs + probs * tau)
    return jnp.sum(probs * h_i)


# ---------------------------------------------------------------------------
# LFU — converged steady state
# ---------------------------------------------------------------------------

def hit_rate_lfu(probs: jnp.ndarray, capacity) -> jnp.ndarray:
    """Converged LFU keeps the C most popular pages (Eq. 9).

    ``capacity`` may be a traced scalar; we sort once and take a masked
    prefix sum so the function stays jittable.
    """
    probs = jnp.asarray(probs)
    order = jnp.argsort(-probs)
    sorted_p = probs[order]
    ranks = jnp.arange(sorted_p.shape[0])
    # clip before the int cast so huge float capacities stay well-defined
    cap = jnp.clip(jnp.asarray(capacity), 0, sorted_p.shape[0])
    mask = ranks < cap.astype(ranks.dtype)
    return jnp.sum(jnp.where(mask, sorted_p, 0.0))


# ---------------------------------------------------------------------------
# Dirty-page writeback — the second physical-I/O stream of a mutating mix
# ---------------------------------------------------------------------------

def _writeback_terms(policy: str, probs: jnp.ndarray, wprobs: jnp.ndarray,
                     capacity) -> jnp.ndarray:
    """Expected writebacks per reference for ONE (histogram, capacity) cell.

    A write dirties its page in the pool; the dirty bit is flushed (one
    physical write I/O) when the page is EVICTED — so the writeback stream
    is the dirty-eviction rate, computable from the SAME characteristic-time
    fixed point the hit rate already solves (no second bisection):

    * page ``i``'s eviction rate equals its insertion (miss) rate,
      ``q_i * (1 - o_i)`` per reference, with ``q_i`` the combined
      read+write reference probability and ``o_i`` the policy occupancy
      (Che Eq. 7 for LRU, Fricker Eq. 4 for FIFO);
    * the evicted copy is dirty iff its residency started with a write
      (prob ``w_i / q_i``) or a write arrived during the residency window
      ``T`` (prob ``1 - exp(-w_i * T)`` for a read-born copy), giving

          wb = sum_i (1 - o_i) * (w_i + r_i * (1 - exp(-w_i * T))),
          r_i = q_i - w_i.

    Limits sanity-check the form: ``C -> 0`` gives ``wb -> sum_i w_i`` (every
    write flushes straight through), a pinned hot page (``o_i -> 1``) absorbs
    its writes entirely.  Converged LFU never evicts its top-C pages, so its
    writeback is exactly the write mass landing OUTSIDE the retained set —
    the write-mass prefix sum under the combined-popularity order (ties
    break identically to Eq. 9's ``argsort``, which keeps host and device
    executors bit-aligned).
    """
    probs = jnp.asarray(probs)
    wprobs = jnp.asarray(wprobs)
    if policy == "lfu":
        order = jnp.argsort(-probs)
        w_sorted = wprobs[order]
        prefix = jnp.cumsum(w_sorted)
        cap = jnp.clip(jnp.asarray(capacity), 0,
                       probs.shape[0]).astype(jnp.int32)
        kept = jnp.where(cap > 0, prefix[jnp.maximum(cap - 1, 0)], 0.0)
        return jnp.sum(wprobs) - kept
    if policy == "lru":
        t = solve_che_time(probs, capacity)
        occ = -jnp.expm1(-probs * t)
    elif policy == "fifo":
        t = solve_fifo_tau(probs, capacity)
        occ = probs * t / (1.0 - probs + probs * t)
    else:
        raise ValueError(f"unknown policy {policy!r}; "
                         f"expected one of {POLICIES}")
    r = jnp.maximum(probs - wprobs, 0.0)
    dirty = wprobs + r * -jnp.expm1(-wprobs * t)
    return jnp.sum((1.0 - occ) * dirty)


def writeback_fraction(policy: str, probs: jnp.ndarray, wprobs: jnp.ndarray,
                       capacity, n_distinct=None) -> jnp.ndarray:
    """Regime-dispatched :func:`_writeback_terms` for one candidate.

    ``probs`` is the COMBINED read+write reference-probability vector,
    ``wprobs`` its write component.  Above ``N`` distinct pages nothing is
    ever evicted, so steady-state writeback is zero (dirty pages stay
    resident — the amortized semantics the replay oracle mirrors); below one
    page every write flushes through.  Subtracting the result from the hit
    rate prices the mix: ``io = (1 - (h - wb)) * E[DAC]`` counts fetches AND
    flushes per reference.
    """
    probs = jnp.asarray(probs, jnp.float32)
    wprobs = jnp.asarray(wprobs, jnp.float32)
    nd = (jnp.sum(probs > 0) if n_distinct is None
          else jnp.asarray(n_distinct))
    cap_i = _exact_caps(jnp.asarray(capacity))
    wb = _writeback_terms(policy, probs, wprobs,
                          jnp.maximum(jnp.asarray(capacity, jnp.float32),
                                      1.0))
    wb = jnp.where(cap_i >= _exact_caps(nd), 0.0, wb)
    return jnp.where(cap_i < 1, jnp.sum(wprobs), wb)


# ---------------------------------------------------------------------------
# Compulsory-miss closed form (C >= N, and sorted workloads via Thm III.1)
# ---------------------------------------------------------------------------

def hit_rate_compulsory(total_requests, distinct_pages) -> jnp.ndarray:
    """h = (R - N) / R — each distinct page misses exactly once."""
    r = jnp.asarray(total_requests, jnp.float32)
    n = jnp.asarray(distinct_pages, jnp.float32)
    return jnp.where(r > 0, (r - n) / jnp.maximum(r, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Sorted-scan model family (Theorem III.1 + policy-aware extensions)
# ---------------------------------------------------------------------------

@jax.jit
def _sorted_scan_misses_freq(coverage: jnp.ndarray, capacity,
                             pinned_retouches) -> jnp.ndarray:
    """Frequency-aware sorted-scan miss count from the coverage histogram.

    A frequency-based cache breaks the recency premise of Theorem III.1 in a
    specific way: eviction resets a page's frequency, so the advancing scan
    frontier keeps being evicted by stale pages whose counts were accumulated
    earlier, and re-misses on re-entry.  Two hit sources survive this
    pathology, and each yields a closed-form hit lower bound:

    * steady-state retention — the converged cache keeps the ``C`` pages
      with the highest coverage (Eq. 9 applied to the coverage histogram),
      whose references hit once resident: ``miss <= R - topC_mass``;
    * pressure-pinned re-touches — the least fixed point of the worst-case
      eviction-pressure recursion (every non-surviving re-reference assumed
      to re-insert and evict).  For sorted streams this collapses to the
      window-junction count ``pinned = sum(lo[i+1] == hi[i])`` (see
      ``page_ref.sorted_workload_stats``): those references hit under ANY
      eviction state, so ``miss <= R - pinned``.

    The model takes the tighter bound and clamps to ``[N, R]`` (compulsory
    floor, thrash ceiling).  Replay-validated against
    ``repro.core.replay.LFUBuffer`` across PGM / RMI / RadixSpline streams
    at tuning-relevant capacities (q-error < 2), and — via the pinned
    correction — on strongly recency-like narrow-window streams at small
    capacities, where the junction bound is tight (q-error ~ 1.0-1.1 on
    width-2 sliding windows and dense jittered width-1/2 streams that the
    width-1 "solo" statistic under-counted by ~2x).
    """
    cov = jnp.asarray(coverage, jnp.float32)
    prefix = jnp.cumsum(-jnp.sort(-cov))
    return _freq_misses_from_prefix(
        prefix, jnp.sum(cov), jnp.sum(cov > 0).astype(jnp.float32),
        capacity, pinned_retouches)


def _freq_misses_from_prefix(prefix, r, n, capacity, pinned_retouches):
    """Frequency-aware miss count given the descending-coverage prefix sums
    (``prefix[k-1]`` = mass of the k most-covered pages) — the O(P log P)
    sort is hoisted here so a knob grid over one shared stream pays it
    once, not once per candidate."""
    # clip before the int cast so huge float capacities stay well-defined
    cap = jnp.clip(jnp.asarray(capacity), 0, prefix.shape[0]).astype(jnp.int32)
    topc = jnp.where(cap > 0, prefix[jnp.maximum(cap - 1, 0)], 0.0)
    steady = r - topc
    pinned = r - jnp.asarray(pinned_retouches, jnp.float32)
    return jnp.clip(jnp.minimum(steady, pinned), n, r)


def sorted_scan_misses(
    policy: str,
    capacity,
    *,
    total_refs: float,
    distinct_pages: float,
    coverage: Optional[jnp.ndarray] = None,
    pinned_retouches: float = 0.0,
    min_capacity: int = 1,
) -> float:
    """Expected physical misses of a sorted one-pass probe stream.

    The policy-aware dispatch for sorted workloads (the single model behind
    ``CostSession`` Algorithm 1's sorted branch and the join planner's
    point-probe pricing):

    * ``capacity < min_capacity`` — the buffer cannot hold one probe window
      (Theorem III.1's capacity premise fails): every reference except the
      pressure-pinned window-junction re-touches misses,
      ``miss = R - pinned`` (thrash regime — junction re-touches survive
      even a capacity-1 buffer because no insertion separates them from the
      previous reference);
    * recency policies, ``capacity >= N``, or no coverage histogram — the
      compulsory closed form, ``miss = N`` (Theorem III.1: one compulsory
      miss per distinct page);
    * frequency-based policies below ``N`` — the frequency-aware closed form
      of :func:`_sorted_scan_misses_freq` on the window-coverage histogram.
    """
    r = float(total_refs)
    n = float(distinct_pages)
    if r <= 0.0:
        return 0.0
    if capacity is not None and capacity < min_capacity:
        return min(max(r - float(pinned_retouches), n), r)
    if (policy in RECENCY_POLICIES or coverage is None
            or capacity is None or capacity >= n):
        return n
    return float(_sorted_scan_misses_freq(jnp.asarray(coverage), capacity,
                                          pinned_retouches))


def sorted_scan_hit_rate(
    policy: str,
    capacity,
    *,
    total_refs: float,
    distinct_pages: float,
    coverage: Optional[jnp.ndarray] = None,
    pinned_retouches: float = 0.0,
    min_capacity: int = 1,
) -> float:
    """Hit rate of a sorted probe stream: ``(R - miss) / R``.

    Shares :func:`hit_rate_compulsory`'s zero-guards, so boundary estimates
    (R ~ 0, capacity at the thrash edge) agree everywhere — for recency
    policies above the capacity premise this IS ``hit_rate_compulsory``.
    """
    r = float(total_refs)
    if r <= 0.0:
        return 0.0
    miss = sorted_scan_misses(
        policy, capacity, total_refs=r, distinct_pages=distinct_pages,
        coverage=coverage, pinned_retouches=pinned_retouches,
        min_capacity=min_capacity)
    return (r - miss) / max(r, 1.0)


@functools.partial(jax.jit, static_argnames=("policy",))
def sorted_scan_hit_rate_grid(
    policy: str,
    coverage: jnp.ndarray,
    total_refs: jnp.ndarray,
    distinct_pages: jnp.ndarray,
    pinned_retouches: jnp.ndarray,
    capacities: jnp.ndarray,
    min_capacities: jnp.ndarray,
) -> jnp.ndarray:
    """Vmapped :func:`sorted_scan_hit_rate` for K sorted-stream candidates.

    The per-candidate dispatch (thrash / compulsory / frequency-aware)
    becomes branchless ``where`` selects so a whole knob grid solves in one
    pass — this is the sorted counterpart of the banded-matmul point/range
    grid kernels.

    Args:
      coverage:       window-coverage histogram(s): (P,) when every
                      candidate shares ONE stream (the common case — sorted
                      windows are eps-independent, only capacities and
                      ``min_capacities`` vary; the O(P log P) coverage sort
                      then runs once for the whole grid), or (K, P) when
                      index-backed candidates contribute distinct streams.
      total_refs:     (K,) request volumes R.
      distinct_pages: (K,) distinct page counts N.
      pinned_retouches: (K,) pressure-pinned window-junction re-touch
                      counts (see ``page_ref.sorted_workload_stats``).
      capacities:     (K,) buffer capacities in pages.
      min_capacities: (K,) Theorem III.1 capacity premises.

    Returns:
      (K,) hit rates.
    """
    r = jnp.asarray(total_refs, jnp.float32)
    n = jnp.asarray(distinct_pages, jnp.float32)
    # Regime dispatch compares in exact integer arithmetic (float32 rounds
    # page counts above 2^24); float32 stays for the miss-count values.
    cap_i = _exact_caps(capacities)
    n_i = _exact_caps(distinct_pages)
    if policy in RECENCY_POLICIES:
        miss = n
    else:
        cov = jnp.asarray(coverage, jnp.float32)
        pinned = jnp.asarray(pinned_retouches, jnp.float32)
        if cov.ndim == 1:
            prefix = jnp.cumsum(-jnp.sort(-cov))
            freq = jax.vmap(
                lambda rr, nn, cc, ss: _freq_misses_from_prefix(
                    prefix, rr, nn, cc, ss))(r, n, cap_i, pinned)
        else:
            freq = jax.vmap(_sorted_scan_misses_freq)(cov, cap_i, pinned)
        miss = jnp.where(cap_i >= n_i, n, freq)
    thrash = jnp.clip(r - jnp.asarray(pinned_retouches, jnp.float32), n, r)
    miss = jnp.where(cap_i < _exact_caps(min_capacities), thrash, miss)
    return jnp.where(r > 0, (r - miss) / jnp.maximum(r, 1.0), 0.0)


def sorted_scan_miss_curve(
    policy: str,
    capacities,
    *,
    total_refs: float,
    distinct_pages: float,
    coverage: Optional[jnp.ndarray] = None,
    pinned_retouches: float = 0.0,
    min_capacity: int = 1,
) -> jnp.ndarray:
    """Misses of ONE sorted stream as a function of buffer capacity.

    The miss-curve evaluation behind budget splitting: a join tree sharing
    one buffer pool needs every level's miss count at every candidate
    capacity, so this evaluates :func:`sorted_scan_misses` over a whole
    capacity vector in one vmapped solve (the stream statistics are shared,
    the O(P log P) coverage sort runs once — see
    :func:`sorted_scan_hit_rate_grid`, which this wraps with broadcast
    stats).  The curve is non-increasing in capacity: thrash (``miss = R``)
    below the Theorem III.1 premise, then the policy-aware regime, floored
    at the compulsory count N.

    Returns a (K,) miss vector aligned with ``capacities``.
    """
    caps = jnp.asarray(capacities)   # integer dtypes keep exact compares
    caps_f = caps.astype(jnp.float32)
    r = float(total_refs)
    if r <= 0.0:
        return jnp.zeros_like(caps_f)
    if policy not in RECENCY_POLICIES and coverage is not None:
        ones = jnp.ones_like(caps_f)
        h = sorted_scan_hit_rate_grid(
            policy, jnp.asarray(coverage, jnp.float32), r * ones,
            float(distinct_pages) * ones, float(pinned_retouches) * ones,
            caps, float(min_capacity) * ones)
        return (1.0 - h) * r
    # Recency policies (and coverage-less profiles) price through the
    # compulsory closed form; only the thrash edge depends on capacity.
    miss = jnp.full_like(caps_f, float(distinct_pages))
    thrash = min(max(r - float(pinned_retouches), float(distinct_pages)), r)
    return jnp.where(_exact_caps(caps) < int(min_capacity), thrash, miss)


def hit_rate_curve(
    policy: str,
    counts: jnp.ndarray,
    sample_refs: float,
    full_refs: float,
    capacities,
) -> jnp.ndarray:
    """Hit rate of ONE request histogram across a capacity vector.

    The IRM counterpart of :func:`sorted_scan_miss_curve`: K capacities of
    the SAME page-reference histogram solve as one vmapped lockstep
    bisection through :func:`hit_rate_grid` (compulsory closed form where
    ``C >= N``, zero below one page), so a budget-split solve never loops
    Python-side over candidate capacities.

    Returns a (K,) hit-rate vector aligned with ``capacities``.
    """
    caps = jnp.asarray(capacities)   # integer dtypes keep exact compares
    counts = jnp.asarray(counts, jnp.float32)
    ones = jnp.ones(caps.shape, jnp.float32)
    h, _ = hit_rate_grid(
        policy, jnp.broadcast_to(counts, caps.shape + counts.shape),
        float(sample_refs) * ones, float(full_refs) * ones, caps)
    return h


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy",))
def _hit_rate_jit(policy: str, probs, capacity):
    if policy == "lru":
        return hit_rate_lru(probs, capacity)
    if policy == "fifo":
        return hit_rate_fifo(probs, capacity)
    if policy == "lfu":
        return hit_rate_lfu(probs, capacity)
    raise ValueError(f"unknown policy {policy!r}")


def hit_rate(
    policy: str,
    capacity,
    probs: jnp.ndarray,
    *,
    total_requests: Optional[float] = None,
    distinct_pages: Optional[float] = None,
    sorted_workload: bool = False,
) -> jnp.ndarray:
    """Paper §III-B/§III-C dispatcher.

    * sorted workloads → Theorem III.1 closed form (NOTE: only exact for
      recency policies; policy-aware callers should use the
      ``sorted_scan_*`` family, which adds the frequency-aware form),
    * ``C >= N``       → compulsory-miss closed form,
    * otherwise        → the policy-specific IRM estimator.
    """
    probs = jnp.asarray(probs)
    n_distinct = (
        float(distinct_pages)
        if distinct_pages is not None
        else float(jnp.sum(probs > 0))
    )
    if sorted_workload or (capacity is not None and float(capacity) >= n_distinct):
        if total_requests is None:
            raise ValueError("closed-form hit rate needs total_requests (R)")
        return hit_rate_compulsory(total_requests, n_distinct)
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")
    return _hit_rate_jit(policy, probs, capacity)


# ---------------------------------------------------------------------------
# Batched grid solver (CostSession.estimate_grid)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy",))
def hit_rate_grid(
    policy: str,
    counts: jnp.ndarray,
    sample_refs: jnp.ndarray,
    full_refs: jnp.ndarray,
    capacities: jnp.ndarray,
    sorted_coverage: Optional[jnp.ndarray] = None,
    sorted_refs: Optional[jnp.ndarray] = None,
    sorted_distinct: Optional[jnp.ndarray] = None,
    sorted_pinned: Optional[jnp.ndarray] = None,
    sorted_min_caps: Optional[jnp.ndarray] = None,
    sorted_full_refs: Optional[jnp.ndarray] = None,
    write_counts: Optional[jnp.ndarray] = None,
    write_refs: Optional[jnp.ndarray] = None,
    write_full_refs: Optional[jnp.ndarray] = None,
):
    """Hit rates for K (histogram, capacity) candidates in one vmapped solve.

    The per-candidate dispatch of :func:`hit_rate` (compulsory closed form
    when ``C >= N``, zero when ``C < 1``, policy fixed point otherwise)
    becomes branchless ``where`` selects so the whole knob grid solves under
    a single jit — K bisections run lockstep instead of K Python round trips.

    When the ``sorted_*`` arguments are given (mixed workloads containing
    sorted probe streams), each candidate's IRM estimate is composed with the
    policy-aware sorted-scan model (:func:`sorted_scan_hit_rate_grid`) by
    expected-miss addition over a shared buffer — the same composition
    ``CostSession._finish`` applies per candidate.

    Args:
      counts:      (K, P) expected page-reference histograms (IRM parts).
      sample_refs: (K,) sample request mass (normalizer of Pr_req).
      full_refs:   (K,) full-workload request volume R (compulsory branch).
      capacities:  (K,) buffer capacities in pages (may be <= 0).
      sorted_coverage / sorted_refs / sorted_distinct / sorted_pinned /
      sorted_min_caps: per-candidate sorted-stream statistics, shapes as in
        :func:`sorted_scan_hit_rate_grid`.
      sorted_full_refs: (K,) full-workload sorted request volume (CAM-x
        scaling of the sorted part's expected misses).
      write_counts / write_refs / write_full_refs: per-candidate write-stream
        histograms ((K, P)), sample write mass and full write volume.  Write
        references are COMBINED into the request histogram before the solve
        (a write faults its target page like a read), and the dirty-eviction
        writeback stream (:func:`writeback_fraction`) is subtracted from the
        hit rate, so ``(1 - h) * E[DAC]`` prices fetches AND flushes of the
        read/write mix in one number.  The returned ``h`` may be slightly
        negative at tiny capacities (a write can cost fetch + flush > 1 I/O
        per reference) — by construction, not by error.

    Returns:
      (hit_rates (K,), distinct_pages (K,)) — pages with nonzero mass in
      either the IRM histogram or the sorted coverage.
    """
    if policy == "lru":
        fn = hit_rate_lru
    elif policy == "fifo":
        fn = hit_rate_fifo
    elif policy == "lfu":
        fn = hit_rate_lfu
    else:
        raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")
    has_write = write_counts is not None
    if has_write:
        # writes fault their target page exactly like reads: fold the write
        # stream into the request histogram so misses price automatically,
        # then add the dirty-eviction flush stream below.
        counts = counts + write_counts
        sample_refs = sample_refs + jnp.asarray(write_refs,
                                                jnp.asarray(sample_refs).dtype)
        full_refs = full_refs + jnp.asarray(write_full_refs,
                                            jnp.asarray(full_refs).dtype)
    probs = counts / jnp.maximum(sample_refs[:, None], 1e-30)
    n_distinct_i = jnp.sum(counts > 0, axis=1)
    n_distinct = n_distinct_i.astype(jnp.float32)
    cap_f = capacities.astype(jnp.float32)
    # exact integer compares for the regime dispatch (float32 rounds page
    # counts above 2^24); the fixed-point solve itself stays float32 — it
    # only runs below n_distinct, far under the rounding threshold.
    cap_i = _exact_caps(capacities)
    h_policy = jax.vmap(lambda p, c: fn(p, jnp.maximum(c, 1.0)))(probs, cap_f)
    floor = jnp.zeros_like(h_policy)
    if has_write:
        wprobs = write_counts / jnp.maximum(sample_refs[:, None], 1e-30)
        wb = jax.vmap(lambda p, w, c: _writeback_terms(
            policy, p, w, jnp.maximum(c, 1.0)))(probs, wprobs, cap_f)
        h_policy = h_policy - wb
        floor = -jnp.sum(wprobs, axis=1)  # cap < 1: every write flushes
    h_comp = hit_rate_compulsory(full_refs, n_distinct)
    h = jnp.where(cap_i >= n_distinct_i, h_comp, h_policy)
    h = jnp.where(cap_i < 1, floor, h)
    h = jnp.where(jnp.asarray(sample_refs, jnp.float32) > 0, h, 0.0)
    if sorted_coverage is None:
        return h, n_distinct
    h_s = sorted_scan_hit_rate_grid(
        policy, sorted_coverage, sorted_refs, sorted_distinct, sorted_pinned,
        capacities, sorted_min_caps)
    s_full = jnp.asarray(sorted_full_refs, jnp.float32)
    total_full = full_refs + s_full
    miss = (1.0 - h) * full_refs + (1.0 - h_s) * s_full
    h_mix = jnp.where(total_full > 0,
                      1.0 - miss / jnp.maximum(total_full, 1.0), 0.0)
    n_mix = jnp.sum((counts > 0) | (sorted_coverage > 0),
                    axis=1).astype(jnp.float32)
    return h_mix, n_mix
