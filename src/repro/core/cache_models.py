"""Policy-specific buffer hit-rate models (paper §III-B, §III-C).

All estimators operate on a page-request probability vector ``probs``
(``Pr_req(i)`` in the paper) and a buffer capacity ``C`` in pages.  They are
written as pure ``jnp`` programs so the whole CAM pipeline jits; the
fixed-point solves use a fixed-iteration bisection (monotone objectives) that
lowers to a tight ``fori_loop``.

Models implemented
------------------
* ``hit_rate_lru``  — Che's approximation (Eq. 7/8).
* ``hit_rate_fifo`` — Fricker's fixed point (Eq. 4/5/6); equals RANDOM under IRM.
* ``hit_rate_lfu``  — converged top-C mass (Eq. 9).
* ``hit_rate_compulsory`` — ``(R - N) / R`` for the large-capacity case and for
  sorted workloads (Theorem III.1).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "solve_che_time",
    "hit_rate_lru",
    "solve_fifo_tau",
    "hit_rate_fifo",
    "hit_rate_lfu",
    "hit_rate_compulsory",
    "hit_rate",
    "hit_rate_grid",
    "POLICIES",
]

POLICIES = ("lru", "fifo", "lfu")

_BISECT_ITERS = 64  # float32 bisection converges long before this


def _bisect(f, lo: jnp.ndarray, hi: jnp.ndarray, iters: int = _BISECT_ITERS):
    """Fixed-iteration bisection for a monotone-increasing scalar objective."""

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        val = f(mid)
        lo = jnp.where(val < 0.0, mid, lo)
        hi = jnp.where(val < 0.0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# LRU — Che's approximation
# ---------------------------------------------------------------------------

def solve_che_time(probs: jnp.ndarray, capacity) -> jnp.ndarray:
    """Characteristic time T_C from the consistency condition (Eq. 8):

        C = sum_i (1 - exp(-p_i * T_C))

    The RHS is monotone increasing in ``T_C`` and saturates at ``N`` (the
    number of pages with nonzero probability), so a solution exists whenever
    ``C < N``; callers handle ``C >= N`` via :func:`hit_rate_compulsory`.
    """
    probs = jnp.asarray(probs, jnp.float64 if probs.dtype == jnp.float64 else jnp.float32)
    capacity = jnp.asarray(capacity, probs.dtype)

    def objective(t):
        return jnp.sum(-jnp.expm1(-probs * t)) - capacity

    # Upper bracket: occupancy of every page is >= 1 - exp(-p_min*T); the
    # solution is below C / p_min-ish.  Grow a safe bracket from the mean.
    pmin = jnp.maximum(jnp.min(jnp.where(probs > 0, probs, jnp.inf)), 1e-30)
    hi = jnp.maximum(4.0 * capacity / pmin, jnp.asarray(1.0, probs.dtype))
    lo = jnp.zeros_like(hi)
    return _bisect(objective, lo, hi)


def hit_rate_lru(probs: jnp.ndarray, capacity, use_kernel: bool = False
                 ) -> jnp.ndarray:
    """Che's approximation for LRU (Eq. 7).

    ``use_kernel=True`` solves the characteristic time with the Pallas
    multi-candidate evaluator (kernels/che_solver.py): K=8 candidates per
    HBM pass, 4x less popularity-array traffic on TPU (interpret-mode on
    CPU, so opt-in here; validated equivalent in tests/test_kernels.py).
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        t_c = kernel_ops.che_solve(probs, capacity, k=8, iters=16)
    else:
        t_c = solve_che_time(probs, capacity)
    return jnp.sum(probs * -jnp.expm1(-probs * t_c))


# ---------------------------------------------------------------------------
# FIFO — Fricker's fixed point (== RANDOM under IRM)
# ---------------------------------------------------------------------------

def solve_fifo_tau(probs: jnp.ndarray, capacity) -> jnp.ndarray:
    """Characteristic time tau_C from the consistency condition (Eq. 5):

        C = sum_i p_i * tau / (1 - p_i + p_i * tau)

    Monotone increasing in ``tau`` with limit ``N``; bisection as for Che.
    """
    probs = jnp.asarray(probs, jnp.float64 if probs.dtype == jnp.float64 else jnp.float32)
    capacity = jnp.asarray(capacity, probs.dtype)

    def objective(tau):
        occ = probs * tau / (1.0 - probs + probs * tau)
        return jnp.sum(occ) - capacity

    pmin = jnp.maximum(jnp.min(jnp.where(probs > 0, probs, jnp.inf)), 1e-30)
    hi = jnp.maximum(4.0 * capacity / pmin, jnp.asarray(1.0, probs.dtype))
    lo = jnp.zeros_like(hi)
    return _bisect(objective, lo, hi)


def hit_rate_fifo(probs: jnp.ndarray, capacity) -> jnp.ndarray:
    """Fricker's FIFO/RANDOM stationary hit rate (Eq. 4 + Eq. 6)."""
    tau = solve_fifo_tau(probs, capacity)
    h_i = probs * tau / (1.0 - probs + probs * tau)
    return jnp.sum(probs * h_i)


# ---------------------------------------------------------------------------
# LFU — converged steady state
# ---------------------------------------------------------------------------

def hit_rate_lfu(probs: jnp.ndarray, capacity) -> jnp.ndarray:
    """Converged LFU keeps the C most popular pages (Eq. 9).

    ``capacity`` may be a traced scalar; we sort once and take a masked
    prefix sum so the function stays jittable.
    """
    probs = jnp.asarray(probs)
    order = jnp.argsort(-probs)
    sorted_p = probs[order]
    ranks = jnp.arange(sorted_p.shape[0])
    mask = ranks < jnp.asarray(capacity, ranks.dtype)
    return jnp.sum(jnp.where(mask, sorted_p, 0.0))


# ---------------------------------------------------------------------------
# Compulsory-miss closed form (C >= N, and sorted workloads via Thm III.1)
# ---------------------------------------------------------------------------

def hit_rate_compulsory(total_requests, distinct_pages) -> jnp.ndarray:
    """h = (R - N) / R — each distinct page misses exactly once."""
    r = jnp.asarray(total_requests, jnp.float32)
    n = jnp.asarray(distinct_pages, jnp.float32)
    return jnp.where(r > 0, (r - n) / jnp.maximum(r, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy",))
def _hit_rate_jit(policy: str, probs, capacity):
    if policy == "lru":
        return hit_rate_lru(probs, capacity)
    if policy == "fifo":
        return hit_rate_fifo(probs, capacity)
    if policy == "lfu":
        return hit_rate_lfu(probs, capacity)
    raise ValueError(f"unknown policy {policy!r}")


def hit_rate(
    policy: str,
    capacity,
    probs: jnp.ndarray,
    *,
    total_requests: Optional[float] = None,
    distinct_pages: Optional[float] = None,
    sorted_workload: bool = False,
) -> jnp.ndarray:
    """Paper §III-B/§III-C dispatcher.

    * sorted workloads → Theorem III.1 closed form (policy independent),
    * ``C >= N``       → compulsory-miss closed form,
    * otherwise        → the policy-specific IRM estimator.
    """
    probs = jnp.asarray(probs)
    n_distinct = (
        float(distinct_pages)
        if distinct_pages is not None
        else float(jnp.sum(probs > 0))
    )
    if sorted_workload or (capacity is not None and float(capacity) >= n_distinct):
        if total_requests is None:
            raise ValueError("closed-form hit rate needs total_requests (R)")
        return hit_rate_compulsory(total_requests, n_distinct)
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")
    return _hit_rate_jit(policy, probs, capacity)


# ---------------------------------------------------------------------------
# Batched grid solver (CostSession.estimate_grid)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy",))
def hit_rate_grid(
    policy: str,
    counts: jnp.ndarray,
    sample_refs: jnp.ndarray,
    full_refs: jnp.ndarray,
    capacities: jnp.ndarray,
):
    """Hit rates for K (histogram, capacity) candidates in one vmapped solve.

    The per-candidate dispatch of :func:`hit_rate` (compulsory closed form
    when ``C >= N``, zero when ``C < 1``, policy fixed point otherwise)
    becomes branchless ``where`` selects so the whole knob grid solves under
    a single jit — K bisections run lockstep instead of K Python round trips.

    Args:
      counts:      (K, P) expected page-reference histograms.
      sample_refs: (K,) sample request mass (normalizer of Pr_req).
      full_refs:   (K,) full-workload request volume R (compulsory branch).
      capacities:  (K,) buffer capacities in pages (may be <= 0).

    Returns:
      (hit_rates (K,), distinct_pages (K,)).
    """
    if policy == "lru":
        fn = hit_rate_lru
    elif policy == "fifo":
        fn = hit_rate_fifo
    elif policy == "lfu":
        fn = hit_rate_lfu
    else:
        raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")
    probs = counts / jnp.maximum(sample_refs[:, None], 1e-30)
    n_distinct = jnp.sum(counts > 0, axis=1).astype(jnp.float32)
    cap = capacities.astype(jnp.float32)
    h_policy = jax.vmap(lambda p, c: fn(p, jnp.maximum(c, 1.0)))(probs, cap)
    h_comp = hit_rate_compulsory(full_refs, n_distinct)
    h = jnp.where(cap >= n_distinct, h_comp, h_policy)
    return jnp.where(cap < 1.0, 0.0, h), n_distinct
