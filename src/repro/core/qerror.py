"""Q-error metric (paper §VII-A)."""
from __future__ import annotations

import numpy as np

__all__ = ["q_error", "mean_q_error"]

_EPS = 1e-12


def q_error(estimated, actual) -> np.ndarray:
    """max(est/actual, actual/est), elementwise, guarded against zeros.

    A Q-error of 1.0 means a perfect estimate.  Zero-vs-zero compares as 1.0;
    zero-vs-nonzero is clamped by ``_EPS`` (→ a very large Q-error), matching
    the convention in cardinality-estimation literature.
    """
    est = np.maximum(np.asarray(estimated, np.float64), _EPS)
    act = np.maximum(np.asarray(actual, np.float64), _EPS)
    return np.maximum(est / act, act / est)


def mean_q_error(estimated, actual) -> float:
    return float(np.mean(q_error(estimated, actual)))
