"""CAM — the end-to-end cache-aware I/O cost estimator (paper Alg. 1 + §III).

Composition:  Cost_CAM = (1 - h) * E[DAC]          (Eq. 3)

  1. map queries to true ranks (host-side searchsorted, reused across eps),
  2. structural page-reference histogram -> Pr_req      (§IV, jitted),
  3. policy-specific hit-rate model on Pr_req           (§III-B / §III-C),
  4. expected data-access cost from the fetch lemmas    (§III-D),
  5. optionally compose with a device-side model        (§III-A).

Everything after step 1 is pure JAX.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import cache_models, dac, page_ref

__all__ = ["CamGeometry", "CamEstimate", "estimate_point_io", "estimate_range_io",
           "estimate_sorted_io", "sample_workload", "capacity_pages"]


@dataclasses.dataclass(frozen=True)
class CamGeometry:
    """Disk layout of the data file (index-data separation design, §II-B)."""

    c_ipp: int = 256            # items per page
    page_bytes: int = 4096      # page size B
    strategy: str = "all_at_once"

    def num_pages(self, n: int) -> int:
        return -(-n // self.c_ipp)


@dataclasses.dataclass(frozen=True)
class CamEstimate:
    """CAM output + diagnostics."""

    io_per_query: float         # expected physical I/Os per query (Eq. 3)
    hit_rate: float
    dac: float                  # expected logical refs per query
    capacity_pages: int
    total_refs: float           # R
    distinct_pages: float       # N (pages with nonzero mass)
    estimation_seconds: float
    policy: str

    @property
    def miss_rate(self) -> float:
        return 1.0 - self.hit_rate


def capacity_pages(memory_budget_bytes: float, index_bytes: float, page_bytes: int) -> int:
    """C = floor((M - M_idx) / B)  — Alg. 1 line 15."""
    return int(max(0, (memory_budget_bytes - index_bytes) // page_bytes))


def sample_workload(arr: np.ndarray, rate: float, seed: int = 0) -> np.ndarray:
    """CAM-x: estimate from an x% workload sample (keeps order for sorted use)."""
    if rate >= 1.0:
        return arr
    rng = np.random.default_rng(seed)
    k = max(1, int(round(arr.shape[0] * rate)))
    idx = np.sort(rng.choice(arr.shape[0], size=k, replace=False))
    return arr[idx]


def _finish(
    probs_counts: jnp.ndarray,
    sample_refs: float,
    full_refs: float,
    expected_dac: float,
    capacity: int,
    policy: str,
    sorted_workload: bool,
    t_start: float,
    distinct_override: Optional[float] = None,
) -> CamEstimate:
    counts = probs_counts
    n_distinct = (
        float(distinct_override)
        if distinct_override is not None
        else float(jnp.sum(counts > 0))
    )
    if capacity <= 0:
        h = 0.0
    else:
        # Normalize by the SAMPLE mass (probabilities must sum to 1); the
        # full-workload request volume only enters the compulsory branch.
        probs = counts / jnp.maximum(float(sample_refs), 1e-30)
        h = float(
            cache_models.hit_rate(
                policy,
                capacity,
                probs,
                total_requests=full_refs,
                distinct_pages=n_distinct,
                sorted_workload=sorted_workload,
            )
        )
    io = (1.0 - h) * float(expected_dac)
    return CamEstimate(
        io_per_query=io,
        hit_rate=h,
        dac=float(expected_dac),
        capacity_pages=capacity,
        total_refs=float(full_refs),
        distinct_pages=n_distinct,
        estimation_seconds=time.perf_counter() - t_start,
        policy=policy,
    )


def estimate_point_io(
    positions: np.ndarray,
    eps: int,
    n: int,
    geom: CamGeometry,
    memory_budget_bytes: float,
    index_bytes: float,
    policy: str = "lru",
    sample_rate: float = 1.0,
    seed: int = 0,
) -> CamEstimate:
    """Algorithm 1 for point workloads.

    ``positions`` are the true ranks of the query keys (LocateQueries output —
    computed once per (dataset, workload) pair and reused across every
    (eps, M) candidate, which is where CAM's tuning-loop speedup comes from).
    """
    t0 = time.perf_counter()
    pos = sample_workload(np.asarray(positions), sample_rate, seed)
    num_pages = geom.num_pages(n)
    counts, total = page_ref.point_page_refs(
        jnp.asarray(pos, jnp.int32), int(eps), geom.c_ipp, num_pages
    )
    e_dac = float(dac.expected_dac(eps, geom.c_ipp, geom.strategy))
    cap = capacity_pages(memory_budget_bytes, index_bytes, geom.page_bytes)
    # Scale R to the full workload for the compulsory-miss branch only
    # (probabilities are normalized by the sample mass).
    scale = max(1.0, len(positions) / max(len(pos), 1))
    return _finish(counts, float(total), float(total) * scale, e_dac, cap,
                   policy, False, t0)


def estimate_range_io(
    lo_positions: np.ndarray,
    hi_positions: np.ndarray,
    eps: int,
    n: int,
    geom: CamGeometry,
    memory_budget_bytes: float,
    index_bytes: float,
    policy: str = "lru",
    sample_rate: float = 1.0,
    seed: int = 0,
) -> CamEstimate:
    """Algorithm 1 for range workloads (§IV-B)."""
    t0 = time.perf_counter()
    lo = np.asarray(lo_positions)
    hi = np.asarray(hi_positions)
    if sample_rate < 1.0:
        rng = np.random.default_rng(seed)
        k = max(1, int(round(lo.shape[0] * sample_rate)))
        idx = np.sort(rng.choice(lo.shape[0], size=k, replace=False))
        lo, hi = lo[idx], hi[idx]
    num_pages = geom.num_pages(n)
    counts, total = page_ref.range_page_refs(
        jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
        int(eps), geom.c_ipp, num_pages, n,
    )
    e_dac = float(total) / max(lo.shape[0], 1)
    cap = capacity_pages(memory_budget_bytes, index_bytes, geom.page_bytes)
    scale = max(1.0, len(lo_positions) / max(lo.shape[0], 1))
    return _finish(counts, float(total), float(total) * scale, e_dac, cap,
                   policy, False, t0)


def estimate_sorted_io(
    window_lo: np.ndarray,
    window_hi: np.ndarray,
    eps: int,
    n: int,
    geom: CamGeometry,
    memory_budget_bytes: float,
    index_bytes: float,
) -> CamEstimate:
    """Sorted probe streams (joins): Theorem III.1, policy-independent.

    ``window_lo/hi`` are per-query *position* windows in sorted order.  Needs
    only (R, N); requires C >= 1 + ceil(2*eps/C_ipp) to be exact.
    """
    t0 = time.perf_counter()
    num_pages = geom.num_pages(n)
    plo, phi = page_ref.page_intervals(
        jnp.asarray(window_lo, jnp.int32), jnp.asarray(window_hi, jnp.int32),
        geom.c_ipp, num_pages,
    )
    r_total, n_distinct = page_ref.sorted_workload_rn(plo, phi)
    r_total, n_distinct = float(r_total), float(n_distinct)
    e_dac = r_total / max(window_lo.shape[0], 1)
    cap = capacity_pages(memory_budget_bytes, index_bytes, geom.page_bytes)
    min_cap = 1 + int(np.ceil(2 * eps / geom.c_ipp))
    if cap < min_cap:
        # Below the theorem's capacity premise: fall back to the conservative
        # no-reuse bound (every reference that isn't an immediate window
        # overlap misses) — flagged via hit_rate=0 diagnostics.
        h = 0.0
    else:
        h = (r_total - n_distinct) / max(r_total, 1e-30)
    io = (1.0 - h) * e_dac
    return CamEstimate(io, h, e_dac, cap, r_total, n_distinct,
                       time.perf_counter() - t0, "sorted-closed-form")
