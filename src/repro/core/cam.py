"""CAM — the end-to-end cache-aware I/O cost estimator (paper Alg. 1 + §III).

Composition:  Cost_CAM = (1 - h) * E[DAC]          (Eq. 3)

  1. map queries to true ranks (host-side searchsorted, reused across eps),
  2. structural page-reference histogram -> Pr_req      (§IV, jitted),
  3. policy-specific hit-rate model on Pr_req           (§III-B / §III-C),
  4. expected data-access cost from the fetch lemmas    (§III-D),
  5. optionally compose with a device-side model        (§III-A).

Everything after step 1 is pure JAX.

NOTE: the per-shape entry points below (``estimate_point_io`` /
``estimate_range_io`` / ``estimate_sorted_io``) are DEPRECATED shims kept for
golden equivalence; new code should use the index-agnostic
:class:`repro.core.session.CostSession` with a
:class:`repro.core.workload.Workload` — which also adds batched knob-grid
estimation (``estimate_grid``) these one-shot functions cannot express.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import numpy as np

__all__ = ["CamGeometry", "CamEstimate", "estimate_point_io", "estimate_range_io",
           "estimate_sorted_io", "sample_workload", "capacity_pages"]


@dataclasses.dataclass(frozen=True)
class CamGeometry:
    """Disk layout of the data file (index-data separation design, §II-B)."""

    c_ipp: int = 256            # items per page
    page_bytes: int = 4096      # page size B
    strategy: str = "all_at_once"

    def num_pages(self, n: int) -> int:
        return -(-n // self.c_ipp)


@dataclasses.dataclass(frozen=True)
class CamEstimate:
    """CAM output + diagnostics."""

    io_per_query: float         # expected physical I/Os per query (Eq. 3)
    hit_rate: float
    dac: float                  # expected logical refs per query
    capacity_pages: int
    total_refs: float           # R
    distinct_pages: float       # N (pages with nonzero mass)
    estimation_seconds: float
    policy: str
    device_cost: Optional[float] = None   # §III-A composition, if a device set

    @property
    def miss_rate(self) -> float:
        return 1.0 - self.hit_rate


def capacity_pages(memory_budget_bytes: float, index_bytes: float, page_bytes: int) -> int:
    """C = floor((M - M_idx) / B)  — Alg. 1 line 15."""
    return int(max(0, (memory_budget_bytes - index_bytes) // page_bytes))


def _deprecated(old: str) -> None:
    warnings.warn(
        f"cam.{old} is deprecated; use repro.core.session.CostSession with a "
        "repro.core.workload.Workload (estimate / estimate_grid)",
        DeprecationWarning, stacklevel=3)


def sample_workload(arr: np.ndarray, rate: float, seed: int = 0) -> np.ndarray:
    """CAM-x: estimate from an x% workload sample (keeps order for sorted use).

    Deprecated shim over :meth:`repro.core.workload.Workload.sample`.
    """
    arr = np.asarray(arr)
    if rate >= 1.0:
        return arr
    from repro.core.workload import subsample_indices

    return arr[subsample_indices(arr.shape[0], rate, seed)]


def _session(geom: CamGeometry, memory_budget_bytes: float, policy: str):
    from repro.core.session import CostSession, System

    return CostSession(System(geom, memory_budget_bytes, policy))


def estimate_point_io(
    positions: np.ndarray,
    eps: int,
    n: int,
    geom: CamGeometry,
    memory_budget_bytes: float,
    index_bytes: float,
    policy: str = "lru",
    sample_rate: float = 1.0,
    seed: int = 0,
) -> CamEstimate:
    """Algorithm 1 for point workloads (deprecated shim).

    ``positions`` are the true ranks of the query keys (LocateQueries output —
    computed once per (dataset, workload) pair and reused across every
    (eps, M) candidate, which is where CAM's tuning-loop speedup comes from).
    """
    _deprecated("estimate_point_io")
    from repro.core.session import UniformEpsModel
    from repro.core.workload import Workload

    return _session(geom, memory_budget_bytes, policy).estimate(
        UniformEpsModel(int(eps), int(n), float(index_bytes)),
        Workload.point(positions, n=int(n)),
        sample_rate=sample_rate, seed=seed)


def estimate_range_io(
    lo_positions: np.ndarray,
    hi_positions: np.ndarray,
    eps: int,
    n: int,
    geom: CamGeometry,
    memory_budget_bytes: float,
    index_bytes: float,
    policy: str = "lru",
    sample_rate: float = 1.0,
    seed: int = 0,
) -> CamEstimate:
    """Algorithm 1 for range workloads (§IV-B) (deprecated shim)."""
    _deprecated("estimate_range_io")
    from repro.core.session import UniformEpsModel
    from repro.core.workload import Workload

    return _session(geom, memory_budget_bytes, policy).estimate(
        UniformEpsModel(int(eps), int(n), float(index_bytes)),
        Workload.range_scan(lo_positions, hi_positions, n=int(n)),
        sample_rate=sample_rate, seed=seed)


def estimate_sorted_io(
    window_lo: np.ndarray,
    window_hi: np.ndarray,
    eps: int,
    n: int,
    geom: CamGeometry,
    memory_budget_bytes: float,
    index_bytes: float,
) -> CamEstimate:
    """Sorted probe streams (joins): Theorem III.1 closed form under LRU.

    ``window_lo/hi`` are per-query *position* windows in sorted order.
    Requires C >= 1 + ceil(2*eps/C_ipp) to be exact.  (Deprecated shim —
    pinned to LRU; for policy-aware sorted estimates (LFU's frequency
    pathology, thrash regime) use ``CostSession`` with a sorted
    ``Workload``, which dispatches through ``cache_models.sorted_scan_*``.)
    """
    _deprecated("estimate_sorted_io")
    from repro.core.session import UniformEpsModel
    from repro.core.workload import Workload

    return _session(geom, memory_budget_bytes, "lru").estimate(
        UniformEpsModel(int(eps), int(n), float(index_bytes)),
        Workload.sorted_stream(window_lo, window_hi, n=int(n)))
