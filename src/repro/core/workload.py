"""Workload — the query-side noun of the CostSession API.

A :class:`Workload` owns everything CAM needs to know about the queries and
nothing about any particular index: the query keys, their *true positions*
(ranks in the sorted key file — located once via ``searchsorted`` and cached,
so every (knob, budget) candidate reuses them), and the query shape
(point / range / sorted probe stream / mixed).

``sample()`` is the single implementation of CAM-x workload sampling that
previously existed as three divergent copies (``cam.sample_workload`` plus
inline variants in ``cam.estimate_range_io`` and ``rmi_tuner``).  Sampling
keeps positional order (required by the sorted closed form) and remembers the
pre-sample query count so compulsory-miss scaling stays exact.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["Workload", "locate", "subsample_indices"]

POINT = "point"
RANGE = "range"
SORTED = "sorted"
MIXED = "mixed"
INSERT = "insert"
UPDATE = "update"
DELETE = "delete"

#: Mutating kinds — point-shaped (one target rank per event): ``positions``
#: carry the located rank of the written key, ``query_keys`` the raw key.
WRITE_KINDS = (INSERT, UPDATE, DELETE)

_KINDS = (POINT, RANGE, SORTED, MIXED) + WRITE_KINDS


def locate(keys: np.ndarray, query_keys: np.ndarray) -> np.ndarray:
    """True ranks of ``query_keys`` in the sorted key file (LocateQueries).

    Computed ONCE per (dataset, workload) pair; every estimation call reuses
    the cached result — this is where CAM's tuning-loop speedup starts.
    """
    keys = np.asarray(keys)
    pos = np.searchsorted(keys, np.asarray(query_keys), side="left")
    return np.minimum(pos, keys.shape[0] - 1).astype(np.int64)


def subsample_indices(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Order-preserving CAM-x sample indices (sorted choice w/o replacement)."""
    rng = np.random.default_rng(seed)
    k = max(1, int(round(n * rate)))
    return np.sort(rng.choice(n, size=k, replace=False))


@dataclasses.dataclass(frozen=True)
class Workload:
    """Index-agnostic query description.

    Fields
    ------
    kind:          "point" | "range" | "sorted" | "mixed".
    positions:     point → true ranks; range → lower-bound ranks;
                   sorted → per-probe window-lo positions.
    hi_positions:  range → upper-bound ranks; sorted → window-hi positions.
    query_keys:    original query keys (needed by routing indexes, e.g. RMI).
    n:             size of the indexed key file (defines the page count).
    parts:         sub-workloads of a mixed workload.
    base_queries:  pre-sampling |Q| (compulsory-miss scaling of CAM-x).
    """

    kind: str
    positions: Optional[np.ndarray] = None
    hi_positions: Optional[np.ndarray] = None
    query_keys: Optional[np.ndarray] = None
    n: Optional[int] = None
    parts: Tuple["Workload", ...] = ()
    base_queries: Optional[int] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown workload kind {self.kind!r}; "
                             f"expected one of {_KINDS}")

    # ------------------------------------------------------------------ build
    @classmethod
    def point(cls, positions: np.ndarray, *, n: Optional[int] = None,
              query_keys: Optional[np.ndarray] = None) -> "Workload":
        """Point lookups from pre-located true ranks."""
        return cls(POINT, positions=np.asarray(positions, np.int64),
                   query_keys=None if query_keys is None else np.asarray(query_keys),
                   n=n)

    @classmethod
    def from_keys(cls, keys: np.ndarray, query_keys: np.ndarray) -> "Workload":
        """Point lookups from raw query keys — locates once and caches."""
        keys = np.asarray(keys)
        return cls.point(locate(keys, query_keys), n=int(keys.shape[0]),
                         query_keys=np.asarray(query_keys))

    @classmethod
    def range_scan(cls, lo_positions: np.ndarray, hi_positions: np.ndarray,
                   *, n: Optional[int] = None) -> "Workload":
        """Range scans [lo, hi] given rank bounds."""
        return cls(RANGE, positions=np.asarray(lo_positions, np.int64),
                   hi_positions=np.asarray(hi_positions, np.int64), n=n)

    @classmethod
    def sorted_stream(cls, window_lo: np.ndarray, window_hi: np.ndarray,
                      *, n: Optional[int] = None) -> "Workload":
        """Sorted probe stream (joins): per-probe position windows, in order."""
        return cls(SORTED, positions=np.asarray(window_lo, np.int64),
                   hi_positions=np.asarray(window_hi, np.int64), n=n)

    @classmethod
    def insert(cls, positions: np.ndarray, *, n: Optional[int] = None,
               query_keys: Optional[np.ndarray] = None) -> "Workload":
        """Inserts at pre-located target ranks (where the new key lands)."""
        return cls(INSERT, positions=np.asarray(positions, np.int64),
                   query_keys=None if query_keys is None
                   else np.asarray(query_keys), n=n)

    @classmethod
    def update(cls, positions: np.ndarray, *, n: Optional[int] = None,
               query_keys: Optional[np.ndarray] = None) -> "Workload":
        """In-place value updates at pre-located true ranks."""
        return cls(UPDATE, positions=np.asarray(positions, np.int64),
                   query_keys=None if query_keys is None
                   else np.asarray(query_keys), n=n)

    @classmethod
    def delete(cls, positions: np.ndarray, *, n: Optional[int] = None,
               query_keys: Optional[np.ndarray] = None) -> "Workload":
        """Deletes (tombstone writes) at pre-located true ranks."""
        return cls(DELETE, positions=np.asarray(positions, np.int64),
                   query_keys=None if query_keys is None
                   else np.asarray(query_keys), n=n)

    @classmethod
    def mixed(cls, *parts: "Workload") -> "Workload":
        """Composite workload; page-reference histograms add across parts.

        Nested mixed parts are flattened (depth-first, order preserved), so
        trace-compiled batches — themselves mixed — compose without manual
        flattening: ``mixed(mixed(a, b), c).parts == (a, b, c)``.
        """
        if not parts:
            raise ValueError("mixed workload needs at least one part")
        flat: list = []
        for p in parts:
            flat.extend(p.parts if p.kind == MIXED else (p,))
        ns = {p.n for p in flat if p.n is not None}
        if len(ns) > 1:
            raise ValueError(f"mixed parts disagree on key-file size: {ns}")
        return cls(MIXED, parts=tuple(flat), n=ns.pop() if ns else None)

    @classmethod
    def concat(cls, *workloads: "Workload") -> "Workload":
        """Incremental construction: append workloads into one composite.

        Mixed inputs are flattened, then same-kind runs concatenate into a
        single part per kind (encounter order; array concatenation preserves
        each input's internal probe order, which the sorted closed form
        needs).  Returns the single merged part when only one kind appears —
        so a stream of trace-batch workloads folds into a compact profile
        input instead of an ever-growing parts tuple.
        """
        flat: list = []
        for w in workloads:
            flat.extend(w.parts if w.kind == MIXED else (w,))
        if not flat:
            raise ValueError("concat needs at least one workload")
        by_kind: dict = {}
        for p in flat:
            by_kind.setdefault(p.kind, []).append(p)

        def _cat(arrays):
            got = [a for a in arrays if a is not None]
            if not got:
                return None
            if len(got) != len(arrays):      # keys known only for some parts
                return None
            return np.concatenate(got)

        merged = []
        for kind, group in by_kind.items():
            if len(group) == 1:
                merged.append(group[0])
                continue
            ns = {p.n for p in group if p.n is not None}
            if len(ns) > 1:
                raise ValueError(f"concat parts disagree on key-file size: {ns}")
            base = sum(p.base_queries if p.base_queries is not None
                       else p.n_queries for p in group)
            merged.append(cls(
                kind,
                positions=_cat([p.positions for p in group]),
                hi_positions=_cat([p.hi_positions for p in group]),
                query_keys=_cat([p.query_keys for p in group]),
                n=ns.pop() if ns else None,
                base_queries=base,
            ))
        return merged[0] if len(merged) == 1 else cls.mixed(*merged)

    # ---------------------------------------------------------------- split
    def split_at(self, cuts) -> Tuple["Workload", ...]:
        """Split into ``len(cuts) + 1`` segment workloads at rank boundaries.

        ``cuts`` are strictly increasing global ranks in ``(0, n)``; segment
        ``s`` owns ranks ``[cuts[s-1], cuts[s])`` (with the implicit edges 0
        and n).  Every point query lands in exactly ONE segment; range and
        sorted windows crossing a cut are split into per-segment pieces
        (clipped to the segment, emitted in original probe order — the
        sorted closed forms need it) via the same repeat + prefix-scan
        offset idiom as ``join.hybrid.partition_probes``.  Segments stay in
        GLOBAL coordinates (same ``n``), so ``Workload.concat`` of the
        pieces reproduces the original exactly when no window crosses a cut
        and preserves per-kind position multisets and total covered rank
        mass in general.  This is the shared routing primitive of
        ``ShardingSession`` (key-space shard boundaries) and any consumer
        that previously masked key ranges ad hoc.
        """
        cuts = np.asarray(cuts, np.int64)
        if cuts.ndim != 1:
            raise ValueError("cuts must be a 1-D array of ranks")
        if cuts.size == 0:
            return (self,)
        if np.any(np.diff(cuts) <= 0) or cuts[0] <= 0 or (
                self.n is not None and cuts[-1] >= self.n):
            raise ValueError(
                "cuts must be strictly increasing ranks inside (0, n); got "
                f"{cuts.tolist()} for n={self.n}")
        n_segs = int(cuts.size) + 1
        if self.kind == MIXED:
            per_part = [p.split_at(cuts) for p in self.parts]
            segs = []
            for s in range(n_segs):
                live = [pp[s] for pp in per_part if pp[s].n_queries > 0]
                if not live:
                    segs.append(Workload.point(np.zeros(0, np.int64),
                                               n=self.n))
                elif len(live) == 1:
                    segs.append(live[0])
                else:
                    segs.append(Workload.mixed(*live))
            return tuple(segs)
        if self.positions is None or self.n_queries == 0:
            return tuple(dataclasses.replace(self) for _ in range(n_segs))
        if self.kind in (POINT,) + WRITE_KINDS:
            # Writes are point-shaped: each event targets exactly one rank,
            # so segment routing is the same searchsorted bucket — the kind
            # tag rides along losslessly (ShardingSession must not silently
            # downgrade mutating traffic to reads).
            seg_of = np.searchsorted(cuts, self.positions, side="right")
            out = []
            for s in range(n_segs):
                m = seg_of == s
                out.append(Workload(
                    self.kind, positions=self.positions[m],
                    query_keys=(None if self.query_keys is None
                                else self.query_keys[m]),
                    n=self.n))
            return tuple(out)
        # range / sorted: a window may span several segments.  Pieces are
        # generated probe-major (then segment-minor), so each segment's
        # subsequence keeps the original probe order.
        lo = np.asarray(self.positions, np.int64)
        hi = np.asarray(self.hi_positions, np.int64)
        first = np.searchsorted(cuts, lo, side="right")
        last = np.searchsorted(cuts, hi, side="right")
        counts = last - first + 1
        probe = np.repeat(np.arange(lo.shape[0]), counts)
        # within-probe piece index: arange minus each probe's start offset
        # (exclusive prefix sum of counts, repeated) — the two-pass idiom.
        offs = (np.arange(probe.shape[0])
                - np.repeat(np.cumsum(counts) - counts, counts))
        seg = first[probe] + offs
        top = (int(self.n) if self.n is not None
               else int(hi.max()) + 1)
        edges_lo = np.concatenate([np.zeros(1, np.int64), cuts])
        edges_hi = np.concatenate([cuts, np.asarray([top], np.int64)])
        plo = np.maximum(lo[probe], edges_lo[seg])
        phi = np.minimum(hi[probe], edges_hi[seg] - 1)
        out = []
        for s in range(n_segs):
            m = seg == s
            out.append(Workload(self.kind, positions=plo[m],
                                hi_positions=phi[m], n=self.n))
        return tuple(out)

    # ------------------------------------------------------------- properties
    @property
    def n_queries(self) -> int:
        if self.kind == MIXED:
            return sum(p.n_queries for p in self.parts)
        return 0 if self.positions is None else int(self.positions.shape[0])

    @property
    def scale(self) -> float:
        """Full-workload / sample request-volume ratio (compulsory branch)."""
        base = self.base_queries if self.base_queries is not None else self.n_queries
        return max(1.0, base / max(self.n_queries, 1))

    # --------------------------------------------------------------- sampling
    def sample(self, rate: float, seed: int = 0) -> "Workload":
        """CAM-x: estimate from an x% sample (order preserved)."""
        if rate >= 1.0:
            return self
        if self.kind == MIXED:
            return dataclasses.replace(
                self, parts=tuple(p.sample(rate, seed) for p in self.parts))
        idx = subsample_indices(self.n_queries, rate, seed)
        take = lambda a: None if a is None else a[idx]  # noqa: E731
        return dataclasses.replace(
            self,
            positions=take(self.positions),
            hi_positions=take(self.hi_positions),
            query_keys=take(self.query_keys),
            base_queries=self.base_queries if self.base_queries is not None
            else self.n_queries,
        )
