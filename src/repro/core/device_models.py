"""Device-side I/O cost abstractions CAM composes with (paper §III-A).

CAM's output is an *effective physical I/O count/size*; these models translate
it into device time:

* DAM    — unit cost per block transfer (Aggarwal & Vitter).
* Affine — cost(x) = 1 + alpha * x for an I/O of size x (setup + transfer).
* PDAM   — affine divided by device parallelism P.
* PIO    — parametric read/write asymmetry + concurrency (Papon & Athanassoulis).

All take page-run lengths (contiguous missed-page runs coalesce into one
device I/O under all-at-once fetching) so sequentiality is modeled.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["DAM", "Affine", "PDAM", "PIO", "runs_from_missed_pages"]


def runs_from_missed_pages(missed_pages: np.ndarray) -> np.ndarray:
    """Lengths of maximal contiguous runs in a sorted array of page ids."""
    pages = np.asarray(missed_pages)
    if pages.size == 0:
        return np.zeros(0, np.int64)
    breaks = np.flatnonzero(np.diff(pages) != 1)
    starts = np.concatenate([[0], breaks + 1])
    ends = np.concatenate([breaks, [pages.size - 1]])
    return (ends - starts + 1).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class DAM:
    """Unit cost per transferred page."""

    def cost(self, run_lengths: Sequence[int]) -> float:
        return float(np.sum(run_lengths))


@dataclasses.dataclass(frozen=True)
class Affine:
    """cost(run of x pages) = 1 + alpha * x (normalized setup + transfer)."""

    alpha: float = 0.25

    def cost(self, run_lengths: Sequence[int]) -> float:
        runs = np.asarray(run_lengths, np.float64)
        return float(np.sum(1.0 + self.alpha * runs))


@dataclasses.dataclass(frozen=True)
class PDAM:
    """Affine with device-level parallelism P (P runs proceed concurrently)."""

    alpha: float = 0.25
    parallelism: int = 8

    def cost(self, run_lengths: Sequence[int]) -> float:
        return Affine(self.alpha).cost(run_lengths) / max(self.parallelism, 1)


@dataclasses.dataclass(frozen=True)
class PIO:
    """Parametric I/O: per-op latency + size/bandwidth with read concurrency."""

    read_setup: float = 1.0
    read_bandwidth_pages: float = 16.0  # pages per time unit
    read_concurrency: int = 8

    def cost(self, run_lengths: Sequence[int]) -> float:
        runs = np.asarray(run_lengths, np.float64)
        per_op = self.read_setup + runs / self.read_bandwidth_pages
        return float(np.sum(per_op)) / max(self.read_concurrency, 1)
