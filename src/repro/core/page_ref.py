"""Structural page-reference estimators (paper §IV).

Given query *true positions* (ranks) and the index geometry (error bound
``eps``, items-per-page ``C_ipp``), these estimators derive the expected
page-reference histogram ``C_p`` — and from it the request distribution
``Pr_req(p)`` — WITHOUT replaying the workload.

TPU-native adaptation: the paper's per-query C++ loops become vectorized
gather (LUT), masked windowed adds, and one ``segment_sum`` scatter; the whole
estimator jits.

* Point queries  — Eq. 12/13 via the (d, s) lookup table (O(eps + C_ipp) entries).
* Range queries  — Eq. 14 via a difference array + prefix sum.
* Sorted (join)  — Theorem III.1 needs only (R, N); computed from interval
  unions with a cummax, no histogram required.
* RMI            — per-leaf mixture: grouped by distinct leaf error bound.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "point_lut",
    "point_page_refs",
    "point_page_refs_grid",
    "point_page_refs_mixed_eps",
    "point_page_refs_mixed_eps_grid",
    "mixed_eps_class_codes",
    "mixed_eps_class_eps",
    "range_page_refs",
    "range_page_refs_grid",
    "page_intervals",
    "sorted_workload_rn",
    "sorted_workload_stats",
    "point_access_prob_exact",
]


def lut_radius(eps: int, c_ipp: int) -> int:
    """Max |page distance| d reachable from the true position's page."""
    return int(np.ceil(2 * eps / c_ipp))


@functools.partial(jax.jit, static_argnames=("eps", "c_ipp"))
def point_lut(eps: int, c_ipp: int) -> jnp.ndarray:
    """LUT[d + D, s] = Pr(page q+d accessed | in-page offset s) per Eq. 12.

    With the true position r = q*C_ipp + s and the error e ~ U{-eps..eps},
    page p = q + d is touched iff the window [r+e-eps, r+e+eps] intersects
    [p*C_ipp, (p+1)*C_ipp - 1].  Substituting p*C_ipp - r = d*C_ipp - s gives

        L(d,s) = max(-eps, d*C_ipp - s - eps)
        U(d,s) = min(+eps, d*C_ipp - s + C_ipp - 1 + eps)
        Pr     = max(0, U - L + 1) / (2*eps + 1)
    """
    d_radius = lut_radius(eps, c_ipp)
    d = jnp.arange(-d_radius, d_radius + 1)[:, None]      # (2D+1, 1)
    s = jnp.arange(c_ipp)[None, :]                        # (1, C_ipp)
    lo = jnp.maximum(-eps, d * c_ipp - s - eps)
    hi = jnp.minimum(eps, d * c_ipp - s + c_ipp - 1 + eps)
    width = jnp.maximum(0, hi - lo + 1)
    return width.astype(jnp.float32) / jnp.float32(2 * eps + 1)


def point_access_prob_exact(r: int, page: int, eps: int, c_ipp: int) -> float:
    """Brute-force enumeration of Eq. 12 (test oracle, O(eps))."""
    hits = 0
    for e in range(-eps, eps + 1):
        w_lo, w_hi = r + e - eps, r + e + eps
        p_lo, p_hi = page * c_ipp, (page + 1) * c_ipp - 1
        if w_lo <= p_hi and p_lo <= w_hi:
            hits += 1
    return hits / (2 * eps + 1)


@functools.partial(jax.jit, static_argnames=("eps", "c_ipp", "num_pages"))
def point_page_refs(
    positions: jnp.ndarray, eps: int, c_ipp: int, num_pages: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expected page-reference histogram for a point workload (Eq. 13).

    Args:
      positions: (Q,) int32 true ranks of the query keys.
      eps, c_ipp, num_pages: index geometry (static for jit).

    Returns:
      counts: (num_pages,) float32 expected reference counts ``C_p``.
      total:  scalar — total expected logical references R (window mass that
              falls on valid pages; boundary-clipped windows drop the
              out-of-range share, matching the clamped last-mile search).
    """
    lut = point_lut(eps, c_ipp)                            # (2D+1, C_ipp)
    d_radius = lut_radius(eps, c_ipp)
    positions = positions.astype(jnp.int32)
    q = positions // c_ipp
    s = positions % c_ipp
    contribs = lut[:, s].T                                 # (Q, 2D+1)
    targets = q[:, None] + jnp.arange(-d_radius, d_radius + 1)[None, :]
    valid = (targets >= 0) & (targets < num_pages)
    contribs = jnp.where(valid, contribs, 0.0)
    flat_t = jnp.where(valid, targets, 0).reshape(-1)
    counts = jax.ops.segment_sum(
        contribs.reshape(-1), flat_t, num_segments=num_pages
    )
    return counts, jnp.sum(contribs)


def _point_lut_traced(eps: jnp.ndarray, d_radius: int, c_ipp: int) -> jnp.ndarray:
    """Eq. 12 LUT with a *traced* eps and a static padded radius.

    Entries with |d| beyond the candidate's own radius get width 0 from the
    max(0, ·) clamp, so padding to the grid-wide max radius is exact — this is
    what lets a whole eps grid share one compiled kernel.
    """
    d = jnp.arange(-d_radius, d_radius + 1)[:, None]
    s = jnp.arange(c_ipp)[None, :]
    eps = eps.astype(jnp.int32)
    lo = jnp.maximum(-eps, d * c_ipp - s - eps)
    hi = jnp.minimum(eps, d * c_ipp - s + c_ipp - 1 + eps)
    width = jnp.maximum(0, hi - lo + 1)
    return width.astype(jnp.float32) / (2.0 * eps.astype(jnp.float32) + 1.0)


@functools.partial(jax.jit, static_argnames=("d_radius", "c_ipp", "num_pages"))
def point_page_refs_grid(
    positions: jnp.ndarray,
    eps_grid: jnp.ndarray,
    d_radius: int,
    c_ipp: int,
    num_pages: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 13 histograms for a WHOLE eps grid in one compiled pass.

    Since every query at true position (q, s) contributes ``LUT[d, s]`` to
    page ``q + d``, the workload enters only through its (page, offset)
    occupancy histogram — computed ONCE and shared by every candidate.  Each
    candidate's page histogram is then a banded contraction

        counts_k[q + d] += sum_s pos_hist[q, s] * LUT_k[d, s]

    i.e. one (K*(2D+1), C_ipp) x (C_ipp, P) matmul plus 2D+1 shifted adds —
    no per-query scatter, no per-eps recompiles, work independent of |Q|
    beyond the single bincount.  This replaces K jit specializations of
    :func:`point_page_refs` in the legacy tuning loop.

    Args:
      positions: (Q,) true ranks, shared page-ref state for the grid.
      eps_grid:  (K,) int32 candidate error bounds.
      d_radius:  static padded radius — ``lut_radius(max(eps_grid), c_ipp)``.

    Returns:
      counts: (K, num_pages) expected reference histograms (boundary-clipped,
              matching :func:`point_page_refs`).
      totals: (K,) total expected logical references per candidate.
    """
    k = eps_grid.shape[0]
    width = 2 * d_radius + 1
    pos_hist = jax.ops.segment_sum(
        jnp.ones(positions.shape[0], jnp.float32),
        positions.astype(jnp.int32),
        num_segments=num_pages * c_ipp,
    ).reshape(num_pages, c_ipp)                            # shared state
    lut = _point_lut_traced(
        eps_grid.astype(jnp.int32)[:, None, None], d_radius, c_ipp
    )                                                      # (K, 2D+1, C_ipp)
    band = (lut.reshape(k * width, c_ipp) @ pos_hist.T).reshape(
        k, width, num_pages)
    out = jnp.zeros((k, num_pages + 2 * d_radius), jnp.float32)
    for j in range(width):                                 # shifted adds
        out = out.at[:, j:j + num_pages].add(band[:, j, :])
    counts = out[:, d_radius:d_radius + num_pages]         # clip to valid pages
    return counts, jnp.sum(counts, axis=1)


@functools.partial(jax.jit, static_argnames=("c_ipp", "num_pages", "n"))
def range_page_refs_grid(
    lo_pos: jnp.ndarray,
    hi_pos: jnp.ndarray,
    eps_grid: jnp.ndarray,
    c_ipp: int,
    num_pages: int,
    n: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 14 histograms for an eps grid in one compiled pass (cf. point)."""
    lo_pos = lo_pos.astype(jnp.int32)
    hi_pos = hi_pos.astype(jnp.int32)

    def one(eps):
        eps = eps.astype(jnp.int32)
        start = jnp.maximum(0, lo_pos - 2 * eps) // c_ipp
        end = jnp.minimum(n - 1, hi_pos + 2 * eps) // c_ipp
        ones = jnp.ones_like(start, jnp.float32)
        diff = jax.ops.segment_sum(ones, start, num_segments=num_pages + 1)
        diff = diff - jax.ops.segment_sum(ones, end + 1, num_segments=num_pages + 1)
        counts = jnp.cumsum(diff)[:num_pages]
        return counts, jnp.sum((end - start + 1).astype(jnp.float32))

    return jax.lax.map(one, eps_grid.astype(jnp.int32))


def point_page_refs_mixed_eps(
    positions: np.ndarray,
    eps_per_query: np.ndarray,
    c_ipp: int,
    num_pages: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RMI variant (§V-C): per-query leaf error bounds.

    Queries are grouped by distinct eps (leaf error bounds repeat heavily),
    and each group reuses the fixed-eps jitted estimator — so cost is
    O(#distinct_eps) compiles worst case, with LUTs of size O(eps + C_ipp).
    """
    positions = np.asarray(positions)
    eps_per_query = np.asarray(eps_per_query)
    counts = jnp.zeros((num_pages,), jnp.float32)
    total = jnp.zeros((), jnp.float32)
    for eps in np.unique(eps_per_query):
        sel = positions[eps_per_query == eps]
        c, t = point_page_refs(jnp.asarray(sel), int(max(eps, 1)), c_ipp, num_pages)
        counts = counts + c
        total = total + t
    return counts, total


#: Reusable host buffers for the mixed-eps grid kernel, keyed by
#: (dtype, tag) and grown geometrically.  The kernel is bandwidth-bound and
#: called in a warm tuning loop; fresh mmap-backed temporaries would pay
#: page-fault zeroing on every call.  Bounded by the largest grid profiled
#: (a few tens of MB); single-threaded use, like the session-level caches.
_SCRATCH: dict = {}

#: Max banded entries materialized at once (bounds each scratch buffer).
_SCRATCH_ENTRIES = 2_000_000


def _scratch(dtype, n: int, tag: str = "") -> np.ndarray:
    key = (np.dtype(dtype), tag)
    buf = _SCRATCH.get(key)
    if buf is None or buf.size < n:
        buf = np.empty(int(n * 1.25) + 16, dtype)
        _SCRATCH[key] = buf
    return buf[:n]


@functools.lru_cache(maxsize=256)
def _point_lut_np(eps: int, c_ipp: int) -> np.ndarray:
    """Eq. 12 LUT transposed to (C_ipp, 2D+1), float64, host-side.

    The mixed-eps grid kernel gathers whole LUT rows per reference, so the
    slot axis leads; float64 is deliberate — ``np.bincount`` casts weights
    to float64 internally, so a narrower gather would just add a copy.
    """
    d_radius = lut_radius(eps, c_ipp)
    s = np.arange(c_ipp)[:, None]
    d = np.arange(-d_radius, d_radius + 1)[None, :] * c_ipp
    lo = np.maximum(-eps, d - s - eps)
    hi = np.minimum(eps, d - s + c_ipp - 1 + eps)
    return np.maximum(0, hi - lo + 1) / float(2 * eps + 1)


def mixed_eps_class_codes(
    flat_eps: np.ndarray,
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Eps-class codes shared by the host and device mixed-eps kernels.

    Class codes without a sort over K*Q elements: pow2-quantized bounds
    (the adapters' contract) map to their exponent — popcount(e - 1) —
    while arbitrary bounds (third-party callers) fall back to unique-rank
    codes.  Returns ``(codes, classes)``: ``codes[i]`` is the class code of
    ``flat_eps[i]``; ``classes`` is ``None`` for pow2 inputs (decode with
    :func:`mixed_eps_class_eps`) or the sorted unique eps values otherwise.
    Both kernels MUST group through this one helper so their per-class LUT
    layouts stay aligned (pinned by the host-vs-device oracle suite).
    """
    flat_eps = np.asarray(flat_eps, np.int64)
    if np.bitwise_and(flat_eps, flat_eps - 1).any():
        classes, codes = np.unique(flat_eps, return_inverse=True)
        if len(classes) <= 256:             # byte compares in the class loop
            codes = codes.astype(np.uint8)
        return codes, classes
    if hasattr(np, "bitwise_count"):
        return np.bitwise_count(flat_eps - 1), None
    codes = np.rint(np.log2(flat_eps.astype(np.float64))).astype(np.uint8)
    return codes, None


def mixed_eps_class_eps(code: int, classes: Optional[np.ndarray]) -> int:
    """Decode a :func:`mixed_eps_class_codes` code back to its eps value."""
    return int(classes[code]) if classes is not None else 1 << int(code)


def point_page_refs_mixed_eps_grid(
    positions: np.ndarray,
    eps_rows: np.ndarray,
    c_ipp: int,
    num_pages: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Mixed-eps histograms for a WHOLE candidate grid in one grouped pass.

    The batched counterpart of :func:`point_page_refs_mixed_eps` for RMI
    branch grids (§V-C): ``eps_rows[k, i]`` is candidate k's error bound for
    the i-th query (its routed leaf's quantized bound), over the SHARED
    ``positions``.  References are grouped by quantized eps ACROSS the whole
    grid with ONE stable argsort — leaf bounds are pow2-quantized, so the
    union has ~log2(max_eps) classes — and each class does one banded
    LUT-row gather plus one ``np.bincount`` into a padded (K, P + 2D)
    histogram (out-of-range window mass lands in the pad and is sliced off,
    reproducing :func:`point_page_refs`'s boundary clipping without a mask).

    This kernel is deliberately host-side: its cost is one weighted scatter
    of ~R_total banded contributions, and on the CPU backends that run the
    tuning loops XLA lowers ``segment_sum`` to a serial scatter (~10x slower
    per entry than ``np.bincount``), which is exactly the bottleneck of the
    per-branch path this replaces — K x #distinct-eps jitted scatters plus
    as many dispatch round trips.  The downstream hit-rate solve stays one
    vmapped jit; the histograms it consumes are device-uploaded once.

    Returns (counts (K, num_pages) float32, totals (K,) float64).
    """
    positions = np.asarray(positions, np.int64)
    eps_rows = np.maximum(np.asarray(eps_rows, np.int64), 1)
    k, q_n = eps_rows.shape
    if positions.shape[0] != q_n:
        raise ValueError(f"eps_rows has {q_n} columns for "
                         f"{positions.shape[0]} positions")
    page = positions // c_ipp
    slot = positions - page * c_ipp
    max_radius = lut_radius(int(eps_rows.max()), c_ipp)
    pad = num_pages + 2 * max_radius
    counts = np.zeros(k * pad, np.float64)

    codes, classes = mixed_eps_class_codes(eps_rows.ravel())
    # Shared flat arrays: row*pad + page in one precomputed vector, so each
    # class needs exactly two gathers before its banded bincount.  All big
    # temporaries live in the module scratch pool — the kernel is memory-
    # bound, and re-faulting ~25 MB of fresh mmap pages per warm call would
    # cost as much as the arithmetic it feeds.
    prebase = _scratch(np.int64, k * q_n).reshape(k, q_n)
    np.add(np.arange(k, dtype=np.int64)[:, None] * pad, page[None, :],
           out=prebase)
    prebase = prebase.reshape(-1)
    slot_tiled = _scratch(np.int32, k * q_n).reshape(k, q_n)
    np.copyto(slot_tiled, slot.astype(np.int32)[None, :])
    slot_tiled = slot_tiled.reshape(-1)
    for code in np.flatnonzero(np.bincount(codes)):
        eps = mixed_eps_class_eps(code, classes)
        class_idx = np.flatnonzero(codes == code)
        radius = lut_radius(eps, c_ipp)
        width = 2 * radius + 1
        lut = _point_lut_np(eps, c_ipp)
        offs = np.arange(width)[None, :]
        # Wide-window classes (tiny branch factors) chunk so the scratch
        # pool stays bounded (~30 MB) whatever the grid.
        chunk = max(1, _SCRATCH_ENTRIES // width)
        for a in range(0, class_idx.shape[0], chunk):
            idx = class_idx[a:a + chunk]
            t = idx.shape[0]
            w = _scratch(np.float64, t * width, "w").reshape(t, width)
            np.take(lut, slot_tiled[idx], axis=0, out=w)   # (T, 2D+1) rows
            base = _scratch(np.int64, t, "base")
            np.take(prebase, idx, out=base)
            base += max_radius - radius
            flat = _scratch(np.int64, t * width, "flat").reshape(t, width)
            np.add(base[:, None], offs, out=flat)
            counts += np.bincount(flat.reshape(-1), weights=w.reshape(-1),
                                  minlength=k * pad)
    valid = counts.reshape(k, pad)[:, max_radius:max_radius + num_pages]
    return valid.astype(np.float32), valid.sum(axis=1)


@functools.partial(jax.jit, static_argnames=("eps", "c_ipp", "num_pages", "n"))
def range_page_refs(
    lo_pos: jnp.ndarray,
    hi_pos: jnp.ndarray,
    eps: int,
    c_ipp: int,
    num_pages: int,
    n: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Range-workload histogram via Eq. 14 + difference array.

    S(Q) = floor(max(0, r(lo) - 2eps) / C_ipp)
    E(Q) = floor(min(n-1, r(hi) + 2eps) / C_ipp)

    Returns (counts, total_refs R); E[DAC] = R / |Q|.
    """
    start = jnp.maximum(0, lo_pos.astype(jnp.int32) - 2 * eps) // c_ipp
    end = jnp.minimum(n - 1, hi_pos.astype(jnp.int32) + 2 * eps) // c_ipp
    ones = jnp.ones_like(start, jnp.float32)
    diff = jax.ops.segment_sum(ones, start, num_segments=num_pages + 1)
    diff = diff - jax.ops.segment_sum(ones, end + 1, num_segments=num_pages + 1)
    counts = jnp.cumsum(diff)[:num_pages]
    total = jnp.sum((end - start + 1).astype(jnp.float32))
    return counts, total


@functools.partial(jax.jit, static_argnames=("c_ipp", "num_pages"))
def page_intervals(
    window_lo: jnp.ndarray, window_hi: jnp.ndarray, c_ipp: int, num_pages: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Map position windows to inclusive page intervals (PAGEINTERVALS in Alg. 2)."""
    lo = jnp.clip(window_lo, 0, None) // c_ipp
    hi = jnp.clip(window_hi, None, num_pages * c_ipp - 1) // c_ipp
    return lo.astype(jnp.int32), jnp.clip(hi, lo, num_pages - 1).astype(jnp.int32)


@jax.jit
def sorted_workload_rn(
    page_lo: jnp.ndarray, page_hi: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(R, N) for a sorted probe stream (Theorem III.1 inputs).

    R = sum of window widths; N = |union of intervals|.  For intervals sorted
    by ``page_lo`` the union size is a running-cummax sweep — O(|Q|), no
    histogram materialization.
    """
    widths = (page_hi - page_lo + 1).astype(jnp.float32)
    r_total = jnp.sum(widths)
    prev_hi = jnp.concatenate(
        [jnp.array([-1], page_hi.dtype), jax.lax.cummax(page_hi)[:-1]]
    )
    new_lo = jnp.maximum(page_lo, prev_hi + 1)
    n_distinct = jnp.sum(jnp.maximum(0, page_hi - new_lo + 1).astype(jnp.float32))
    return r_total, n_distinct


def sorted_workload_stats(
    page_lo: jnp.ndarray, page_hi: jnp.ndarray, num_pages: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(R, N, coverage, pinned_retouches) for a sorted probe stream.

    Deliberately NOT jitted: the join planner calls it with
    outer-relation-sized arrays whose shapes vary call to call, and a
    per-shape retrace would cost more than the handful of eager ops here
    (one scatter, one scan, two reductions).

    Extends :func:`sorted_workload_rn` with the two statistics the
    frequency-aware sorted-scan model (``cache_models.sorted_scan_*``)
    needs beyond Theorem III.1's (R, N):

    * ``coverage`` — the window-coverage histogram ``coverage[p] = number of
      probe windows covering page p`` (difference array + prefix sum, same
      shape as the Eq. 13/14 histograms, so it can also join a mixed
      workload's request distribution);
    * ``pinned_retouches`` — references that survive eviction pressure under
      ANY policy state: a reference to the page the immediately preceding
      reference touched cannot be separated from it by an insertion, so no
      eviction can occur in between.  For a sorted stream the worst-case
      residency recursion (every other re-reference assumed to re-insert)
      collapses — the proven-resident set between insertions is always the
      single most recent page — so its least fixed point is exactly the
      window-junction count ``sum(lo[i+1] == hi[i])``.  This subsumes the
      width-1 repeat ("solo") count and is the pressure correction used by
      ``cache_models.sorted_scan_misses``.
    """
    lo = jnp.asarray(page_lo, jnp.int32)
    hi = jnp.asarray(page_hi, jnp.int32)
    ones = jnp.ones(lo.shape[0], jnp.float32)
    diff = jax.ops.segment_sum(ones, lo, num_segments=num_pages + 1)
    diff = diff - jax.ops.segment_sum(ones, hi + 1, num_segments=num_pages + 1)
    coverage = jnp.cumsum(diff)[:num_pages]
    r_total = jnp.sum((hi - lo + 1).astype(jnp.float32))
    n_distinct = jnp.sum(coverage > 0).astype(jnp.float32)
    pinned = jnp.sum((lo[1:] == hi[:-1]).astype(jnp.float32))
    return r_total, n_distinct, coverage, pinned
