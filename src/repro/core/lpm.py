"""LPM — the Logical Page Model baseline (paper §I, Fig. 1).

LPM predicts physical I/O directly from logical page counts, i.e. it assumes
every logical page reference reaches the device (no buffer).  It is the weak
baseline CAM is compared against: up to 2.6x Q-error on point workloads and
~22x on skewed ones (Tables IV/V), because it ignores cache absorption.
"""
from __future__ import annotations

import numpy as np

__all__ = ["lpm_estimate_from_windows", "lpm_estimate_analytic"]


def lpm_estimate_from_windows(page_lo: np.ndarray, page_hi: np.ndarray) -> float:
    """Mean logical pages per query, counted from actual last-mile windows."""
    widths = np.asarray(page_hi, np.int64) - np.asarray(page_lo, np.int64) + 1
    return float(widths.mean()) if widths.size else 0.0


def lpm_estimate_analytic(eps: int, c_ipp: int, strategy: str = "all_at_once") -> float:
    """Closed-form logical page count (== E[DAC], Lemmas III.2/III.3)."""
    from repro.core import dac

    return float(dac.expected_dac(eps, c_ipp, strategy))
