"""Ground-truth trace replay under real eviction policies (the paper's
Replay-x baseline, §VII-A).

Replay is inherently sequential (every LRU/LFU update depends on the previous
one), so it stays a host-side numpy/python simulator — its cost is exactly the
paper's motivation for CAM.  It is the *oracle* every estimator is validated
against, and also the engine behind the simulated buffered disk used by the
join executors.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Iterable, List, Sequence, Tuple

import numpy as np

__all__ = [
    "Buffer",
    "LRUBuffer",
    "FIFOBuffer",
    "LFUBuffer",
    "CLOCKBuffer",
    "make_buffer",
    "replay_refs",
    "replay_windows",
    "replay_write_refs",
]


class Buffer:
    """Page buffer interface: ``access(page) -> hit?``.

    ``last_evicted`` holds the page evicted by the most recent ``access``
    (None when the access hit or fit without eviction) — the hook the
    write-replay oracle uses to count dirty-page writebacks.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("buffer capacity must be >= 1 page")
        self.capacity = int(capacity)
        self.last_evicted = None

    def access(self, page: int) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def __contains__(self, page: int) -> bool:  # pragma: no cover - interface
        raise NotImplementedError


class LRUBuffer(Buffer):
    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._od: "OrderedDict[int, None]" = OrderedDict()

    def access(self, page: int) -> bool:
        od = self._od
        self.last_evicted = None
        if page in od:
            od.move_to_end(page)
            return True
        if len(od) >= self.capacity:
            self.last_evicted, _ = od.popitem(last=False)
        od[page] = None
        return False

    def __contains__(self, page: int) -> bool:
        return page in self._od


class FIFOBuffer(Buffer):
    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._queue: deque = deque()
        self._resident: set = set()

    def access(self, page: int) -> bool:
        self.last_evicted = None
        if page in self._resident:
            return True
        if len(self._resident) >= self.capacity:
            victim = self._queue.popleft()
            self._resident.discard(victim)
            self.last_evicted = victim
        self._queue.append(page)
        self._resident.add(page)
        return False

    def __contains__(self, page: int) -> bool:
        return page in self._resident


class LFUBuffer(Buffer):
    """O(1) LFU (freq buckets + min-freq pointer); LRU tie-break in-bucket."""

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._freq: dict = {}
        self._buckets: dict = {}  # freq -> OrderedDict of pages
        self._minfreq = 0

    def access(self, page: int) -> bool:
        freq = self._freq
        buckets = self._buckets
        self.last_evicted = None
        if page in freq:
            f = freq[page]
            del buckets[f][page]
            if not buckets[f]:
                del buckets[f]
                if self._minfreq == f:
                    self._minfreq = f + 1
            freq[page] = f + 1
            buckets.setdefault(f + 1, OrderedDict())[page] = None
            return True
        if len(freq) >= self.capacity:
            victims = buckets[self._minfreq]
            victim, _ = victims.popitem(last=False)
            if not victims:
                del buckets[self._minfreq]
            del freq[victim]
            self.last_evicted = victim
        freq[page] = 1
        buckets.setdefault(1, OrderedDict())[page] = None
        self._minfreq = 1
        return False

    def __contains__(self, page: int) -> bool:
        return page in self._freq


class CLOCKBuffer(Buffer):
    """Second-chance / CLOCK: circular scan over frames with reference bits.

    Beyond the paper's three policies — demonstrates policy pluggability.
    Under IRM its hit rate lies between FIFO and LRU (it approximates LRU
    with FIFO-cost bookkeeping), which CAM brackets with those estimators.
    """

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._frames: list = []
        self._refbit: dict = {}
        self._slot: dict = {}
        self._hand = 0

    def access(self, page: int) -> bool:
        self.last_evicted = None
        if page in self._refbit:
            self._refbit[page] = 1
            return True
        if len(self._frames) < self.capacity:
            self._slot[page] = len(self._frames)
            self._frames.append(page)
            self._refbit[page] = 1
            return False
        while True:                      # advance the hand, clearing ref bits
            victim = self._frames[self._hand]
            if self._refbit[victim]:
                self._refbit[victim] = 0
                self._hand = (self._hand + 1) % self.capacity
            else:
                del self._refbit[victim]
                del self._slot[victim]
                self.last_evicted = victim
                self._frames[self._hand] = page
                self._slot[page] = self._hand
                self._refbit[page] = 1
                self._hand = (self._hand + 1) % self.capacity
                return False

    def __contains__(self, page: int) -> bool:
        return page in self._refbit


_POLICY_CLASSES = {"lru": LRUBuffer, "fifo": FIFOBuffer, "lfu": LFUBuffer,
                   "clock": CLOCKBuffer}


def make_buffer(policy: str, capacity: int) -> Buffer:
    try:
        return _POLICY_CLASSES[policy](capacity)
    except KeyError:
        raise ValueError(f"unknown policy {policy!r}") from None


def replay_refs(
    refs: Sequence[int], capacity: int, policy: str = "lru"
) -> Tuple[int, int]:
    """Replay a flat page-reference trace. Returns (hits, misses)."""
    buf = make_buffer(policy, capacity)
    access = buf.access
    hits = 0
    for page in refs:
        if access(int(page)):
            hits += 1
    return hits, len(refs) - hits


def replay_write_refs(
    refs: Sequence[int],
    is_write: Sequence[bool],
    capacity: int,
    policy: str = "lru",
) -> Tuple[int, int]:
    """Replay a mixed read/write page trace. Returns (fetches, writebacks).

    Write refs pull the page through the same buffer (a write miss fetches
    the page first) and mark it dirty; evicting a dirty page costs one
    writeback.  Dirty pages still resident at end of trace are NOT flushed —
    the estimator models the amortized steady state, where writeback
    happens at eviction time and a page pinned in an infinite cache is
    never written back.
    """
    buf = make_buffer(policy, capacity)
    access = buf.access
    dirty: set = set()
    fetches = 0
    writebacks = 0
    for page, w in zip(refs, is_write):
        page = int(page)
        if not access(page):
            fetches += 1
        victim = buf.last_evicted
        if victim is not None and victim in dirty:
            dirty.discard(victim)
            writebacks += 1
        if w:
            dirty.add(page)
    return fetches, writebacks


def replay_windows(
    page_lo: np.ndarray,
    page_hi: np.ndarray,
    capacity: int,
    policy: str = "lru",
) -> np.ndarray:
    """Replay per-query page windows [lo_i, hi_i] (all-at-once fetching).

    Returns per-query physical miss counts — the ground-truth ``IO(Q)`` of
    Eq. 1.  Logical refs per query are ``hi - lo + 1``.
    """
    buf = make_buffer(policy, capacity)
    access = buf.access
    lo = np.asarray(page_lo, np.int64)
    hi = np.asarray(page_hi, np.int64)
    misses = np.zeros(lo.shape[0], np.int32)
    for i in range(lo.shape[0]):
        m = 0
        for page in range(lo[i], hi[i] + 1):
            if not access(page):
                m += 1
        misses[i] = m
    return misses
