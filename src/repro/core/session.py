"""CostSession — the index-agnostic estimation surface of CAM.

The paper's claim that CAM "is not tied to a particular learned index design"
is realized here as three nouns plus a session object:

* :class:`~repro.core.workload.Workload` — queries, cached true positions,
  shapes (point / range / sorted / mixed), CAM-x sampling;
* :class:`IndexModel` — anything exposing ``size_bytes`` + knob metadata +
  a ``page_ref_profile(workload, geom)`` returning the Eq. 12/13/14
  histograms (adapters for PGM, RMI and RadixSpline live in
  ``repro.index.adapters``);
* :class:`System` — page geometry, memory budget, cache policy, optional
  device-side cost model.

``CostSession.estimate`` reproduces Algorithm 1 for a single configuration;
``CostSession.estimate_grid`` evaluates an entire knob grid (eps grid x
per-candidate buffer capacities) in ONE jitted pass over shared page-ref
state — K lockstep bisections instead of K Python loop iterations with K
per-eps recompiles, which is the tuning-loop speedup the paper's §V needs.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import (Dict, NamedTuple, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

import jax.numpy as jnp
import numpy as np

from repro.core import cache_models, dac, page_ref
from repro.core.cam import CamEstimate, CamGeometry, capacity_pages
from repro.core.workload import (INSERT, MIXED, POINT, RANGE, SORTED,
                                 WRITE_KINDS, Workload)

__all__ = [
    "System",
    "SortedScanPart",
    "WriteStreamPart",
    "PageRefProfile",
    "IndexModel",
    "UniformEpsModel",
    "GridCandidate",
    "GridResult",
    "GridProfiles",
    "SkippedCandidate",
    "PlanCost",
    "CostSession",
    "UnsupportedWorkloadError",
    "uniform_eps_profile",
    "sorted_stream_profile",
]


class UnsupportedWorkloadError(ValueError):
    """A workload (or one of its parts) an estimation path cannot price.

    Carries the offending ``kind`` (and, for composite workloads, the
    ``part`` kind that triggered it) so callers — notably
    ``CostSession.estimate_grid``, which records per-candidate skip reasons —
    can report *what* was unsupported instead of a bare message.
    """

    def __init__(self, kind: str, part: Optional[str] = None,
                 detail: str = ""):
        self.kind = kind
        self.part = part
        msg = f"unsupported workload kind {kind!r}"
        if part is not None:
            msg += f" (offending part: {part!r})"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


# ---------------------------------------------------------------------------
# System: where the index runs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class System:
    """Disk geometry + memory budget + cache policy (+ device model)."""

    geom: CamGeometry = CamGeometry()
    memory_budget_bytes: float = 8 << 20
    policy: str = "lru"
    device: Optional[object] = None   # repro.core.device_models instance

    def __post_init__(self):
        # Validate eagerly: the compulsory-miss branch never consults the
        # policy, so a typo could otherwise survive a whole tuning run.
        if self.policy not in cache_models.POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; expected one "
                             f"of {cache_models.POLICIES}")

    def capacity_for(self, index_bytes: float) -> int:
        """Buffer capacity left once the index is resident (Alg. 1 l. 15)."""
        return capacity_pages(self.memory_budget_bytes, index_bytes,
                              self.geom.page_bytes)

    def with_budget_fraction(self, fraction: float, *,
                             pool_bytes: Optional[float] = None,
                             resident_bytes: float = 0.0) -> "System":
        """A view of this System owning ``fraction`` of a shared buffer pool.

        ``pool_bytes`` is the pool being split (defaults to the full memory
        budget); ``resident_bytes`` is memory this view's consumer keeps
        resident on top of its slice (its index), added back so that
        ``view.capacity_for(resident_bytes)`` returns exactly the slice:
        ``floor(fraction * pool / page_bytes)`` pages.  Join trees use this
        to hand each level a System whose budget is its share of the ONE
        pool left after all inner indexes are resident — geometry, policy
        and device model stay shared.
        """
        if not 0.0 <= fraction <= 1.0:
            raise ValueError(f"budget fraction must be in [0, 1], "
                             f"got {fraction}")
        pool = self.memory_budget_bytes if pool_bytes is None else pool_bytes
        return dataclasses.replace(
            self, memory_budget_bytes=resident_bytes + fraction * pool)

    def layout(self):
        """The :class:`repro.index.disk_layout.PageLayout` this geometry
        implies — the bridge every execution-side consumer (joins, the
        simulated machine, benchmarks) uses instead of re-deriving page
        counts from raw constants."""
        from repro.index.disk_layout import PageLayout

        return PageLayout(c_ipp=self.geom.c_ipp,
                          page_bytes=self.geom.page_bytes)


# ---------------------------------------------------------------------------
# Plan-level cost summaries (shared by CostSession consumers and JoinSession)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Model-predicted cost of one executable plan / strategy.

    The join planner emits one per candidate strategy; anything that ranks
    alternatives by predicted cost (plan selection, knob grids with attached
    execution strategies) compares these.  ``seconds`` is the Eq. 17-style
    fitted-time prediction, ``physical_ios`` the CAM cache-aware miss count
    it was derived from, and ``logical_refs`` the request mass R.
    """

    strategy: str
    seconds: float
    physical_ios: float
    logical_refs: float

    def __lt__(self, other: "PlanCost") -> bool:
        return self.seconds < other.seconds

    @classmethod
    def compose(cls, strategy: str,
                parts: Sequence["PlanCost"]) -> "PlanCost":
        """Sum component costs into one plan cost (join trees: levels run
        in sequence against disjoint buffer slices, so seconds, physical
        I/Os and request mass all add)."""
        return cls(strategy,
                   sum(p.seconds for p in parts),
                   sum(p.physical_ios for p in parts),
                   sum(p.logical_refs for p in parts))


# ---------------------------------------------------------------------------
# Page-reference profiles and the IndexModel protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SortedScanPart:
    """Sorted-stream statistics feeding the ``cache_models.sorted_scan``
    family: Theorem III.1's (R, N) plus the window-coverage histogram and
    pressure-pinned re-touch count the frequency-aware closed form needs
    (see ``page_ref.sorted_workload_stats``)."""

    total_refs: float
    distinct_pages: float
    min_capacity: int = 1                 # Thm III.1 capacity premise
    coverage: Optional[jnp.ndarray] = None
    pinned_retouches: float = 0.0


@dataclasses.dataclass
class WriteStreamPart:
    """Write-reference statistics of a mutating workload part.

    ``counts`` is the expected WRITE-reference histogram (the pages a write
    dirties — the eps-0 target window scaled by the structure's write
    amplification), ``total_refs`` its sample mass.  The cache solve folds
    these into the combined request histogram (a write faults its page like
    a read) and prices the dirty-eviction writeback stream on top — see
    ``cache_models.hit_rate_grid``'s ``write_*`` arguments.
    """

    counts: jnp.ndarray
    total_refs: float


def _merge_write_parts(parts: Sequence[WriteStreamPart]) -> WriteStreamPart:
    """Merge write sub-streams: histograms and reference mass add."""
    if len(parts) == 1:
        return parts[0]
    counts = parts[0].counts
    for p in parts[1:]:
        counts = counts + p.counts
    return WriteStreamPart(counts=counts,
                           total_refs=sum(p.total_refs for p in parts))


@dataclasses.dataclass
class PageRefProfile:
    """Structural page-reference summary an index reports for a workload.

    ``counts`` is the Eq. 13/14 expected-reference histogram of the
    random-access (IRM) part.  Sorted probe streams carry their statistics in
    ``sorted_part`` instead (pure sorted streams set ``sorted_stream`` and
    leave ``counts`` as None; mixed workloads may have both).  Profiles built
    without a ``sorted_part`` but with the legacy ``sorted_stream`` fields
    still price through the recency closed form.
    """

    counts: Optional[jnp.ndarray]
    total_refs: float                     # sample request mass R (IRM part)
    expected_dac: float                   # E[DAC] per query (all parts)
    sorted_stream: bool = False
    distinct_pages: Optional[float] = None
    min_capacity: int = 1                 # Thm III.1 capacity premise
    sorted_part: Optional[SortedScanPart] = None
    write_part: Optional[WriteStreamPart] = None


@runtime_checkable
class IndexModel(Protocol):
    """What CAM needs from a learned index — nothing design-specific."""

    family: str

    @property
    def size_bytes(self) -> float: ...    # in-memory footprint M_idx

    def knobs(self) -> Dict[str, object]: ...

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile: ...


def sorted_part_for(workload: Workload, eps: int, geom: CamGeometry,
                    num_pages: int) -> SortedScanPart:
    """Sorted-stream statistics of one SORTED workload (shared helper).

    The Theorem III.1 capacity premise comes from ``eps`` for uniformly
    error-bounded designs; with ``eps=0`` (no uniform bound, e.g. RMI) it is
    read off the widest observed probe window instead.
    """
    plo, phi = page_ref.page_intervals(
        jnp.asarray(workload.positions, jnp.int32),
        jnp.asarray(workload.hi_positions, jnp.int32),
        geom.c_ipp, num_pages)
    r_total, n_distinct, coverage, pinned = page_ref.sorted_workload_stats(
        plo, phi, num_pages)
    if eps > 0:
        min_cap = 1 + int(np.ceil(2 * eps / geom.c_ipp))
    elif workload.n_queries:
        min_cap = int(jnp.max(phi - plo + 1))
    else:
        min_cap = 1
    return SortedScanPart(
        total_refs=float(r_total), distinct_pages=float(n_distinct),
        min_capacity=min_cap, coverage=coverage,
        pinned_retouches=float(pinned))


def sorted_stream_profile(workload: Workload, geom: CamGeometry,
                          num_pages: int, eps: int = 0) -> PageRefProfile:
    """Pure sorted-stream profile (any index family — windows are explicit
    positions, so no design-specific error bound enters beyond ``eps``'s
    role in the capacity premise)."""
    sp = sorted_part_for(workload, eps, geom, num_pages)
    return PageRefProfile(
        counts=None, total_refs=sp.total_refs,
        expected_dac=sp.total_refs / max(workload.n_queries, 1),
        sorted_stream=True, distinct_pages=sp.distinct_pages,
        min_capacity=sp.min_capacity, sorted_part=sp)


def _compulsory_coverage(sp: SortedScanPart, num_pages: int) -> jnp.ndarray:
    """Coverage surrogate for a legacy sorted part without a histogram.

    Piling the whole mass on one page makes the frequency-aware form's
    steady bound collapse to 0, so its ``[N, R]`` clamp returns exactly N —
    i.e. the compulsory closed form that coverage-less parts price through
    on the single-candidate path (``sorted_scan_misses`` with
    ``coverage=None``) — for every capacity above the premise.
    """
    return jnp.zeros((num_pages,), jnp.float32).at[0].set(
        jnp.float32(sp.total_refs))


def _resolve_profile_executor(executor: Optional[str]) -> str:
    """Profiling-side executor dispatch, mirroring ``PricingEngine._resolve``:
    an explicit argument wins, then the ``REPRO_ENGINE_EXECUTOR`` environment
    variable, then auto — ``device`` on a TPU backend, ``host`` elsewhere.
    ``host`` is the golden ``np.bincount`` mixed-eps kernel; ``device`` the
    banded one-hot matmul kernel (``kernels/profile_grid.py``), whose
    histograms are born in HBM and chain into the fused pricing launch.
    """
    if executor is None:
        executor = os.environ.get("REPRO_ENGINE_EXECUTOR") or None
    if executor is None:
        import jax
        executor = "device" if jax.default_backend() == "tpu" else "host"
    if executor not in ("host", "device"):
        raise ValueError(f"unknown profile executor {executor!r}; expected "
                         "'host' or 'device'")
    return executor


def _exact_cap_array(values) -> jnp.ndarray:
    """int32 page-count vector, saturating at 2^31-129 pages (≈8 TiB pools
    at 4 KiB pages).  float32 rounds integers above 2^24, which can flip the
    ``cap >= n_distinct`` compulsory-branch compare in ``hit_rate_grid``;
    int32 keeps the compare exact, and any saturated capacity is already
    deep in the compulsory regime so the clamp is lossless.
    """
    arr = np.floor(np.asarray(values, np.float64))
    return jnp.asarray(np.clip(arr, -1, 2**31 - 129).astype(np.int32))


def _pad_row(row: jnp.ndarray, width: int) -> jnp.ndarray:
    """Zero-pad a (P,) histogram row out to ``width`` pages."""
    row = jnp.asarray(row, jnp.float32)
    pad = width - int(row.shape[0])
    return row if pad <= 0 else jnp.pad(row, (0, pad))


def _stack_or_share(coverages: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """One (P,) row when every candidate references the SAME stream object
    (uniform-eps grids: sorted windows are eps-independent), else a stacked
    (K, P) matrix — lets the grid solve sort the shared histogram once."""
    first = coverages[0]
    if all(c is first for c in coverages):
        return jnp.asarray(first, jnp.float32)
    return jnp.stack([jnp.asarray(c, jnp.float32) for c in coverages])


def _merge_sorted_parts(parts: Sequence[SortedScanPart]) -> SortedScanPart:
    """Merge sorted sub-streams: coverage and R add, N is the union's size,
    the capacity premise is the widest part's."""
    if len(parts) == 1:
        return parts[0]
    coverage = parts[0].coverage
    for p in parts[1:]:
        coverage = coverage + p.coverage
    return SortedScanPart(
        total_refs=sum(p.total_refs for p in parts),
        distinct_pages=float(jnp.sum(coverage > 0)),
        min_capacity=max(p.min_capacity for p in parts),
        coverage=coverage,
        pinned_retouches=sum(p.pinned_retouches for p in parts))


def uniform_eps_profile(workload: Workload, eps: int, geom: CamGeometry,
                        n: Optional[int] = None,
                        write_amp: float = 1.0) -> PageRefProfile:
    """Shared profile for any uniformly error-bounded design (PGM, RadixSpline).

    Dispatches on the workload shape; mixed workloads sum part histograms,
    with sorted parts accumulated separately into ``sorted_part`` (they are
    priced by the policy-aware sorted-scan model, not the IRM fixed point)
    and mutating parts into ``write_part``.  A write locates its target
    through the same eps-window search a point lookup pays (read
    references), then dirties the target page itself — ``write_amp`` scales
    the INSERT dirty mass (structure-dependent shifting: gapped arrays /
    node splits touch more than one page per insert; updates and deletes
    stay in place).
    """
    n = int(n if n is not None else workload.n)
    num_pages = geom.num_pages(n)
    if workload.kind == POINT:
        counts, total = page_ref.point_page_refs(
            jnp.asarray(workload.positions, jnp.int32), int(eps),
            geom.c_ipp, num_pages)
        e_dac = float(dac.expected_dac(eps, geom.c_ipp, geom.strategy))
        return PageRefProfile(counts, float(total), e_dac)
    if workload.kind in WRITE_KINDS:
        counts, total = page_ref.point_page_refs(
            jnp.asarray(workload.positions, jnp.int32), int(eps),
            geom.c_ipp, num_pages)
        wcounts, wtotal = page_ref.point_page_refs(
            jnp.asarray(workload.positions, jnp.int32), 0,
            geom.c_ipp, num_pages)
        amp = float(write_amp) if workload.kind == INSERT else 1.0
        e_dac = float(dac.expected_dac(eps, geom.c_ipp, geom.strategy)) + amp
        wp = WriteStreamPart(counts=wcounts * jnp.float32(amp),
                             total_refs=float(wtotal) * amp)
        return PageRefProfile(counts, float(total), e_dac, write_part=wp)
    if workload.kind == RANGE:
        counts, total = page_ref.range_page_refs(
            jnp.asarray(workload.positions, jnp.int32),
            jnp.asarray(workload.hi_positions, jnp.int32),
            int(eps), geom.c_ipp, num_pages, n)
        e_dac = float(total) / max(workload.n_queries, 1)
        return PageRefProfile(counts, float(total), e_dac)
    if workload.kind == SORTED:
        return sorted_stream_profile(workload, geom, num_pages, eps=eps)
    if workload.kind == MIXED:
        counts = jnp.zeros((num_pages,), jnp.float32)
        total = 0.0
        dac_mass = 0.0
        sorted_parts = []
        write_parts = []
        for part in workload.parts:
            prof = uniform_eps_profile(part, eps, geom, n,
                                       write_amp=write_amp)
            dac_mass += prof.expected_dac * part.n_queries
            if prof.sorted_part is not None:
                sorted_parts.append(prof.sorted_part)
            if prof.write_part is not None:
                write_parts.append(prof.write_part)
            if not prof.sorted_stream:
                counts = counts + prof.counts
                total += prof.total_refs
        e_dac = dac_mass / max(workload.n_queries, 1)
        wp = _merge_write_parts(write_parts) if write_parts else None
        if not sorted_parts:
            return PageRefProfile(counts, total, e_dac, write_part=wp)
        sp = _merge_sorted_parts(sorted_parts)
        if total <= 0.0 and wp is None:
            # every part is sorted: still a pure sorted stream
            return PageRefProfile(
                counts=None, total_refs=sp.total_refs, expected_dac=e_dac,
                sorted_stream=True, distinct_pages=sp.distinct_pages,
                min_capacity=sp.min_capacity, sorted_part=sp)
        return PageRefProfile(counts, total, e_dac, sorted_part=sp,
                              write_part=wp)
    raise UnsupportedWorkloadError(workload.kind)


@dataclasses.dataclass(frozen=True)
class UniformEpsModel:
    """Un-built stand-in for any error-bounded index: knob metadata only.

    Lets tuners price an (eps, size) candidate — size typically from a fitted
    power law — without constructing the index (paper §V-B).
    """

    eps: int
    n: int
    size_bytes: float
    family: str = "uniform-eps"

    def knobs(self) -> Dict[str, object]:
        return {"eps": {"value": self.eps, "kind": "error_bound",
                        "tunable": True}}

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile:
        return uniform_eps_profile(workload, self.eps, geom, self.n)


# ---------------------------------------------------------------------------
# Grid candidates / results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GridCandidate:
    """One knob configuration in an ``estimate_grid`` sweep.

    Either ``eps`` (uniform error bound — enables the fully batched kernel,
    no index build needed) or ``index`` (a built :class:`IndexModel`, e.g. an
    RMI whose per-leaf mixture has no uniform eps) must be set.
    """

    knob: object
    size_bytes: float
    eps: Optional[int] = None
    index: Optional[IndexModel] = None

    def __post_init__(self):
        if self.eps is None and self.index is None:
            raise ValueError("GridCandidate needs eps or index")


class SkippedCandidate(NamedTuple):
    """A grid candidate dropped from a sweep, with the reason why —
    budget-infeasible, or a profile the candidate's index cannot produce."""

    knob: object
    reason: str


@dataclasses.dataclass
class GridProfiles:
    """Per-candidate structural profiles from ONE batched profiling pass.

    This is the workload-dependent half of ``estimate_grid``, split out so
    capacity-dependent consumers (the tuner's joint knob x buffer-split
    search) can price the SAME profiles at many capacities without
    re-profiling: everything here is independent of the buffer capacity, and
    :meth:`CostSession.solve_profiles` turns (row, capacity) pairs into hit
    rates with a single batched cache-model solve.

    ``caps`` are the full-budget capacities (``System.capacity_for`` of each
    candidate's footprint) — the maximal buffer split each knob can take.
    """

    knobs: Tuple[object, ...]
    counts: jnp.ndarray                     # (K, P) IRM histograms
    totals: np.ndarray                      # (K,) sample IRM request mass
    dacs: np.ndarray                        # (K,) E[DAC] per query
    sizes: np.ndarray                       # (K,) index footprints (bytes)
    caps: np.ndarray                        # (K,) full-budget capacities
    sparts: Tuple[Optional[SortedScanPart], ...]
    skipped: Tuple[SkippedCandidate, ...]
    scale: float                            # full/sample request-volume ratio
    n_queries: int
    #: Per-candidate write streams ((), the read-only default, means none).
    wparts: Tuple[Optional[WriteStreamPart], ...] = ()

    def sorted_refs(self, i: int) -> float:
        sp = self.sparts[i]
        return sp.total_refs if sp is not None else 0.0

    def wpart(self, i: int) -> Optional[WriteStreamPart]:
        return self.wparts[i] if self.wparts else None

    def write_refs(self, i: int) -> float:
        wp = self.wpart(i)
        return wp.total_refs if wp is not None else 0.0

    @classmethod
    def from_accumulated(cls, system, knobs, counts, totals, dac_mass,
                         sizes, sparts, n_queries,
                         skipped: Sequence["SkippedCandidate"] = (),
                         wparts: Sequence[Optional[WriteStreamPart]] = ()
                         ) -> "GridProfiles":
        """Assemble profiles from incrementally accumulated sums.

        The serving-sketch entry point: everything a profile row holds is a
        per-query-mass SUM over the workload (histogram counts, request
        mass R, DAC access mass, sorted coverage), so a sliding-window
        sketch can maintain those sums per chunk and re-derive the exact
        profile of the whole window without replaying it — ``dac_mass`` is
        the accumulated ``E[DAC] * n_queries`` mass and is normalized back
        to a per-query expectation here.  ``scale`` is 1.0 by construction:
        the sketch sees every event, sampling (CAM-x) happens upstream of
        ingestion if at all.
        """
        sizes_arr = np.asarray(sizes, np.float64)
        nq = max(int(n_queries), 1)
        return cls(
            knobs=tuple(knobs),
            counts=jnp.asarray(counts, jnp.float32),
            totals=np.asarray(totals, np.float64),
            dacs=np.asarray(dac_mass, np.float64) / nq,
            sizes=sizes_arr,
            caps=np.asarray([system.capacity_for(s) for s in sizes_arr],
                            np.int64),
            sparts=tuple(sparts),
            skipped=tuple(skipped),
            scale=1.0,
            n_queries=int(n_queries),
            wparts=tuple(wparts))


@dataclasses.dataclass
class GridResult:
    """All candidate estimates + argmin, from one batched pass."""

    estimates: Dict[object, CamEstimate]
    best_knob: object
    seconds: float
    skipped: Tuple[SkippedCandidate, ...] = ()

    @property
    def best(self) -> CamEstimate:
        return self.estimates[self.best_knob]

    @property
    def est_io(self) -> float:
        return self.best.io_per_query


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------

class CostSession:
    """Reusable estimation context bound to one :class:`System`.

    Holds the sampled-workload cache so repeated ``estimate``/``estimate_grid``
    calls over the same workload (the tuning loop) never re-sample or
    re-locate queries.
    """

    _SAMPLE_CACHE_MAX = 16

    def __init__(self, system: System):
        self.system = system
        self._sample_cache: Dict[tuple, tuple] = {}
        self._engine = None

    @property
    def engine(self):
        """The session's :class:`~repro.engine.table.PricingEngine` —
        lazily built (the engine layer imports this module)."""
        if self._engine is None:
            from repro.engine import PricingEngine
            self._engine = PricingEngine(self)
        return self._engine

    # ------------------------------------------------------------------ single
    def estimate(self, index: IndexModel, workload: Workload,
                 sample_rate: float = 1.0, seed: int = 0) -> CamEstimate:
        """Algorithm 1 for one (index, workload) pair."""
        t0 = time.perf_counter()
        wl = self._sampled(workload, sample_rate, seed)
        prof = index.page_ref_profile(wl, self.system.geom)
        cap = self.system.capacity_for(index.size_bytes)
        return self._finish(prof, wl, cap, t0)

    # ------------------------------------------------------------------- grid
    def estimate_grid(self, candidates: Sequence[GridCandidate],
                      workload: Workload, sample_rate: float = 1.0,
                      seed: int = 0, batch_mixed_eps: bool = True,
                      executor: Optional[str] = None) -> GridResult:
        """Estimate a whole knob grid in one jitted/vmapped pass.

        Page-ref state (positions, scatter targets) is shared across
        candidates; histograms for uniform-eps candidates come from the
        batched grid kernel, index-backed candidates exposing
        ``point_ref_eps`` (RMI) batch through the grouped mixed-eps kernel
        (``batch_mixed_eps=False`` falls back to per-candidate mixture
        histograms — the legacy per-branch path kept for golden equivalence
        and benchmarking); ALL hit-rate fixed points then solve in a single
        vmapped bisection.  Sorted workloads batch through the vmapped
        sorted-scan solve (one shared coverage profile — see
        ``_sorted_grid``), and mixed workloads may contain sorted parts,
        composed with the IRM solve inside ``cache_models.hit_rate_grid``.
        Candidates that are budget-infeasible or cannot profile the
        workload are recorded in ``GridResult.skipped`` with their reasons.
        """
        t0 = time.perf_counter()
        wl = self._sampled(workload, sample_rate, seed)
        feasible, skipped = self._feasible(candidates)
        if wl.kind == SORTED:
            return self._sorted_grid(feasible, skipped, wl, t0)
        prof = self._profile_batch(feasible, wl, skipped, batch_mixed_eps,
                                   executor)
        from repro.engine import PriceTable
        sol = self.engine.price(PriceTable.max_capacity(
            prof, self.system.memory_budget_bytes))
        h, n_distinct = sol.hit_rates, sol.distinct

        elapsed = time.perf_counter() - t0
        per = elapsed / max(len(prof.knobs), 1)
        estimates: Dict[object, CamEstimate] = {}
        for i, knob in enumerate(prof.knobs):
            io = (1.0 - float(h[i])) * float(prof.dacs[i])
            estimates[knob] = CamEstimate(
                io_per_query=io, hit_rate=float(h[i]),
                dac=float(prof.dacs[i]), capacity_pages=int(prof.caps[i]),
                total_refs=(float(prof.totals[i]) + prof.sorted_refs(i)
                            + prof.write_refs(i)) * prof.scale,
                distinct_pages=float(n_distinct[i]),
                estimation_seconds=per, policy=self.system.policy,
                device_cost=self._device_cost(io))
        best = min(estimates, key=lambda k: estimates[k].io_per_query)
        return GridResult(estimates, best, elapsed, tuple(prof.skipped))

    def grid_profiles(self, candidates: Sequence[GridCandidate],
                      workload: Workload, sample_rate: float = 1.0,
                      seed: int = 0, batch_mixed_eps: bool = True,
                      executor: Optional[str] = None) -> GridProfiles:
        """Capacity-independent profiles of a knob grid (one batched pass).

        The profiling half of :meth:`estimate_grid`: feasibility filtering,
        the uniform-eps banded-matmul kernels, the grouped mixed-eps kernel
        for batchable index-backed candidates, per-candidate profiles for
        the rest.  Pair with :meth:`solve_profiles` to price the SAME
        profiles at arbitrary (row, capacity) combinations — the engine
        behind the tuner's joint (knob x buffer-split) search.

        ``executor`` picks the mixed-eps kernel: ``"host"`` (the golden
        ``np.bincount`` path), ``"device"`` (the banded one-hot matmul
        kernel of ``kernels/profile_grid.py`` — histograms stay in HBM and
        chain into the fused pricing launch), or ``None`` for the engine's
        dispatch rule (``REPRO_ENGINE_EXECUTOR``, then auto-TPU).
        """
        wl = self._sampled(workload, sample_rate, seed)
        feasible, skipped = self._feasible(candidates)
        return self._profile_batch(feasible, wl, skipped, batch_mixed_eps,
                                   executor)

    def grid_profiles_grouped(self, groups, sample_rate: float = 1.0,
                              seed: int = 0, batch_mixed_eps: bool = True,
                              executor: Optional[str] = None
                              ) -> GridProfiles:
        """Profiles of MANY (key, candidates, workload) groups — ONE pass.

        The batched-over-shards generalization of :meth:`grid_profiles`:
        each group is profiled against its OWN workload (a shard's routed
        sub-workload over its local page range), and the per-group rows are
        concatenated into a single :class:`GridProfiles` whose knob keys
        are ``(group_key, knob)`` pairs.  Histograms (and sorted coverage)
        are zero-padded to the widest group's page span — zero columns are
        invisible to ``hit_rate_grid`` (no mass, no distinct pages) — so
        one :meth:`solve_profiles` call can then price ANY (group, knob,
        capacity) combination of the whole fleet in a single
        ``cache_models.hit_rate_grid`` solve.  This is what lets a sharded
        search run with zero per-shard model calls: S shards x B boundary
        candidates collapse into one profiling pass and one solve.
        """
        parts = []
        for key, cands, wl in groups:
            wls = self._sampled(wl, sample_rate, seed)
            feasible, skipped = self._feasible(cands)
            parts.append((key, self._profile_batch(feasible, wls, skipped,
                                                   batch_mixed_eps,
                                                   executor)))
        if not parts:
            raise ValueError("grid_profiles_grouped needs at least one group")
        scales = {p.scale for _, p in parts}
        if len(scales) > 1:
            raise ValueError(f"groups disagree on sample scale: {scales}")
        width = max(int(p.counts.shape[1]) for _, p in parts)

        def pad(arr: jnp.ndarray) -> jnp.ndarray:
            w = int(arr.shape[-1])
            if w == width:
                return arr
            padding = [(0, 0)] * (arr.ndim - 1) + [(0, width - w)]
            return jnp.pad(arr, padding)

        sparts = []
        for _, p in parts:
            for sp in p.sparts:
                if sp is not None and sp.coverage is not None:
                    sp = dataclasses.replace(sp, coverage=pad(sp.coverage))
                sparts.append(sp)
        wparts = []
        for _, p in parts:
            for wp in (p.wparts if p.wparts else (None,) * len(p.knobs)):
                if wp is not None:
                    wp = dataclasses.replace(wp, counts=pad(wp.counts))
                wparts.append(wp)
        return GridProfiles(
            knobs=tuple((key, kn) for key, p in parts for kn in p.knobs),
            counts=jnp.concatenate([pad(p.counts) for _, p in parts]),
            totals=np.concatenate([p.totals for _, p in parts]),
            dacs=np.concatenate([p.dacs for _, p in parts]),
            sizes=np.concatenate([p.sizes for _, p in parts]),
            caps=np.concatenate([p.caps for _, p in parts]),
            sparts=tuple(sparts),
            skipped=tuple(SkippedCandidate((key, s.knob), s.reason)
                          for key, p in parts for s in p.skipped),
            scale=float(scales.pop()),
            n_queries=sum(p.n_queries for _, p in parts),
            wparts=(tuple(wparts) if any(wp is not None for wp in wparts)
                    else ()))

    def solve_profiles(self, profiles: GridProfiles, capacities,
                       rows: Optional[np.ndarray] = None,
                       policy: Optional[str] = None,
                       policies=None):
        """Hit rates of profile rows at given capacities — ONE batched solve.

        ``rows[i]`` names the profile row that ``capacities[i]`` applies to
        (default: row i), so a (knob x split) table — every knob priced at
        every candidate buffer split — solves in a single
        ``cache_models.hit_rate_grid`` call, the many-histogram
        generalization of the ``hit_rate_curve`` capacity-curve evaluator.
        Mixed workloads' sorted parts compose inside the same call through
        ``sorted_scan_hit_rate_grid`` (which ``sorted_scan_miss_curve``
        wraps), preserving the per-candidate composition semantics of
        ``_finish``.  Returns ``(hit_rates, distinct_pages)`` float64
        arrays aligned with ``capacities``.

        ``policy`` overrides the system's eviction policy for every cell;
        ``policies`` gives a PER-CELL policy column (names, or ids into
        ``cache_models.POLICIES`` with -1 = the session policy — the
        multi-policy ``PriceTable.pols`` contract): cells group by policy
        and solve as one ``hit_rate_grid`` dispatch per distinct policy
        (<= 3), scattered back in cell order.
        """
        idx = (np.arange(len(profiles.knobs), dtype=np.int64)
               if rows is None else np.asarray(rows, np.int64))
        if policies is not None:
            base = policy if policy is not None else self.system.policy
            names = [base if p == -1 or p is None
                     else (p if isinstance(p, str)
                           else cache_models.POLICIES[int(p)])
                     for p in np.asarray(policies).tolist()]
            caps_in = np.asarray(capacities)
            h_out = np.empty(len(names), np.float64)
            nd_out = np.empty(len(names), np.float64)
            for pol in sorted(set(names)):
                m = np.asarray([nm == pol for nm in names])
                h_out[m], nd_out[m] = self.solve_profiles(
                    profiles, caps_in[m], rows=idx[m], policy=pol)
            return h_out, nd_out
        policy = policy if policy is not None else self.system.policy
        counts = (profiles.counts if rows is None
                  else profiles.counts[jnp.asarray(idx)])
        sample_refs = jnp.asarray(profiles.totals[idx], jnp.float32)
        full_refs = sample_refs * profiles.scale
        caps_arr = _exact_cap_array(capacities)
        num_pages = int(profiles.counts.shape[1])
        wkw = {}
        wps = [profiles.wpart(i) for i in idx]
        if any(wp is not None for wp in wps):
            # Mutating mix: fold write streams into the solve (combined
            # request histogram + dirty-eviction writeback, see
            # hit_rate_grid).  _stack_or_share keeps the common
            # shared-stream case (write windows are knob-independent for
            # uniform grids) a single (P,) row.
            zero_w = jnp.zeros((num_pages,), jnp.float32)
            w_refs = jnp.asarray([wp.total_refs if wp is not None else 0.0
                                  for wp in wps], jnp.float32)
            wkw = dict(
                write_counts=_stack_or_share(
                    [wp.counts if wp is not None else zero_w for wp in wps]),
                write_refs=w_refs,
                write_full_refs=w_refs * profiles.scale)
        sparts = [profiles.sparts[i] for i in idx]
        surrogate = {}
        if any(sp is not None for sp in sparts):
            # Mixed workload with sorted sub-streams: compose the IRM solve
            # with the policy-aware sorted-scan model inside hit_rate_grid.
            zero = SortedScanPart(0.0, 0.0, 1,
                                  jnp.zeros((num_pages,), jnp.float32), 0.0)
            sps = [sp if sp is not None else zero for sp in sparts]
            # coverage-less legacy parts: remember the true N per row, price
            # through the compulsory-equivalent surrogate histogram
            for i, sp in enumerate(sps):
                if sp.coverage is None:
                    surrogate[i] = sp.distinct_pages
                    sps[i] = dataclasses.replace(
                        sp, coverage=_compulsory_coverage(sp, num_pages))
            s_refs = jnp.asarray([sp.total_refs for sp in sps], jnp.float32)
            h, n_distinct = cache_models.hit_rate_grid(
                policy, counts, sample_refs, full_refs, caps_arr,
                sorted_coverage=_stack_or_share(
                    [sp.coverage for sp in sps]),
                sorted_refs=s_refs,
                sorted_distinct=_exact_cap_array(
                    [sp.distinct_pages for sp in sps]),
                sorted_pinned=jnp.asarray(
                    [sp.pinned_retouches for sp in sps], jnp.float32),
                sorted_min_caps=_exact_cap_array(
                    [sp.min_capacity for sp in sps]),
                sorted_full_refs=s_refs * profiles.scale, **wkw)
        else:
            h, n_distinct = cache_models.hit_rate_grid(
                policy, counts, sample_refs, full_refs, caps_arr, **wkw)
        h = np.asarray(h, np.float64)
        n_distinct = np.asarray(n_distinct, np.float64)
        for i, true_n in surrogate.items():
            # report the same footprint _finish's coverage-less fallback
            # does (IRM distinct + the part's N), not the surrogate's page
            n_distinct[i] = float(jnp.sum(counts[i] > 0)) + true_n
        return h, n_distinct

    def _feasible(self, candidates: Sequence[GridCandidate]):
        """Budget-feasibility filter (Alg. 1 l. 15) with typed skip reasons."""
        feasible, skipped = [], []
        for c in candidates:
            if self.system.capacity_for(c.size_bytes) >= 1:
                feasible.append(c)
            else:
                skipped.append(SkippedCandidate(
                    c.knob,
                    f"memory budget {self.system.memory_budget_bytes:.0f} B "
                    f"leaves no buffer page after a {c.size_bytes:.0f} B "
                    f"index"))
        if not feasible:
            raise ValueError("memory budget too small for any candidate index")
        return feasible, skipped

    def _profile_batch(self, feasible, wl: Workload, skipped,
                       batch_mixed_eps: bool,
                       executor: Optional[str] = None) -> GridProfiles:
        """Assemble per-candidate (histogram, R, E[DAC], sorted part) rows."""
        geom = self.system.geom
        uniform = [c for c in feasible if c.index is None]
        backed = [c for c in feasible if c.index is not None]

        rows, totals, dacs, knobs, sparts, sizes = [], [], [], [], [], []
        wparts = []
        if uniform:
            counts_u, totals_u, dacs_u, spart_u, wpart_u = self._uniform_grid(
                uniform, wl)
            rows.extend(counts_u)
            totals.extend(totals_u)
            dacs.extend(dacs_u)
            knobs.extend(c.knob for c in uniform)
            sizes.extend(c.size_bytes for c in uniform)
            # Sorted windows are eps-independent; only the Thm III.1 capacity
            # premise varies across uniform-eps candidates (eps <= 0 keeps
            # the shared profile's widest-observed-window premise, matching
            # sorted_part_for's single-candidate dispatch).
            sparts.extend(
                None if spart_u is None
                else spart_u if c.eps <= 0
                else dataclasses.replace(
                    spart_u,
                    min_capacity=1 + int(np.ceil(2 * c.eps / geom.c_ipp)))
                for c in uniform)
            # Write target windows are eps-independent too: ONE shared
            # stream object per grid (solve_profiles' _stack_or_share then
            # keeps a single (P,) row for the whole grid).
            wparts.extend(wpart_u for _ in uniform)
        mixed_rows = self._mixed_eps_rows(backed, wl, skipped,
                                          batch_mixed_eps, executor)
        for c in backed:
            if id(c) in mixed_rows:
                entry = mixed_rows[id(c)]
                if entry is None:       # point_ref_eps raised: skip recorded
                    continue
                counts_c, total_c, dac_c = entry
                rows.append(counts_c)
                totals.append(total_c)
                dacs.append(dac_c)
                sparts.append(None)
                wparts.append(None)
                knobs.append(c.knob)
                sizes.append(c.size_bytes)
                continue
            try:
                prof = c.index.page_ref_profile(wl, geom)
            except UnsupportedWorkloadError as e:
                skipped.append(SkippedCandidate(c.knob, str(e)))
                continue
            if prof.counts is None:
                # A mixed workload whose parts are ALL sorted profiles as a
                # pure sorted stream (counts=None, total_refs=R_sorted):
                # the IRM part is empty, everything lives in sorted_part
                # (synthesized from the legacy fields if a third-party
                # profile carries only those).
                sp = prof.sorted_part or SortedScanPart(
                    prof.total_refs, float(prof.distinct_pages),
                    prof.min_capacity)
                if sp.coverage is not None:
                    width = sp.coverage.shape[0]
                elif wl.n is not None:
                    width = geom.num_pages(int(wl.n))
                else:
                    raise ValueError("Workload.n (key-file size) required "
                                     "for grid estimation")
                rows.append(jnp.zeros((width,), jnp.float32))
                totals.append(0.0)
                sparts.append(sp)
            else:
                rows.append(prof.counts)
                totals.append(prof.total_refs)
                sparts.append(prof.sorted_part)
            wparts.append(prof.write_part)
            dacs.append(prof.expected_dac)
            knobs.append(c.knob)
            sizes.append(c.size_bytes)
        if not knobs:
            raise UnsupportedWorkloadError(
                wl.kind,
                detail="no grid candidate could profile this workload ("
                       + "; ".join(s.reason for s in skipped) + ")")

        sizes_arr = np.asarray(sizes, np.float64)
        widths = [int(jnp.asarray(r).shape[0]) for r in rows]
        if len(set(widths)) > 1:
            # Index-backed candidates may live in per-knob SLOT spaces
            # (gapped/fill-factor layouts: more slack = more pages), so
            # histogram rows can differ in width.  Zero-pad to the widest:
            # absent pages carry no reference mass, so probabilities,
            # n_distinct and the fixed points are unchanged.
            width = max(widths)
            rows = [_pad_row(r, width) for r in rows]
            sparts = [sp if sp is None or sp.coverage is None
                      else dataclasses.replace(
                          sp, coverage=_pad_row(sp.coverage, width))
                      for sp in sparts]
            wparts = [wp if wp is None
                      else WriteStreamPart(_pad_row(wp.counts, width),
                                           wp.total_refs)
                      for wp in wparts]
        return GridProfiles(
            knobs=tuple(knobs),
            counts=jnp.stack([jnp.asarray(r, jnp.float32) for r in rows]),
            totals=np.asarray(totals, np.float64),
            dacs=np.asarray(dacs, np.float64),
            sizes=sizes_arr,
            caps=np.asarray([self.system.capacity_for(s)
                             for s in sizes_arr], np.int64),
            sparts=tuple(sparts),
            skipped=tuple(skipped),
            scale=float(wl.scale),
            n_queries=int(wl.n_queries),
            wparts=(tuple(wparts) if any(wp is not None for wp in wparts)
                    else ()))

    def _mixed_eps_rows(self, backed, wl: Workload, skipped,
                        batch_mixed_eps: bool,
                        executor: Optional[str] = None):
        """Batched §V-C mixture histograms (the ROADMAP mixed-eps kernel).

        Index-backed candidates exposing ``point_ref_eps`` (RMI adapters)
        hand over per-query quantized leaf error bounds; the whole branch
        grid then profiles in ONE grouped banded pass — references grouped
        by LUT radius ACROSS candidates — instead of per-branch mixture
        histograms with K x #distinct-eps jit round trips.  The pass runs
        on the resolved profile executor: ``host`` is the golden
        ``page_ref.point_page_refs_mixed_eps_grid`` bincount kernel,
        ``device`` the banded one-hot matmul kernel
        (``kernels.profile_grid``) whose histogram rows stay device
        arrays from birth.

        Returns ``{id(candidate): (counts_row, total, e_dac) | None}`` —
        ``None`` marks a candidate whose routing raised (skip recorded).
        """
        if (not batch_mixed_eps or wl.kind != POINT
                or wl.query_keys is None):
            return {}
        batchable = [c for c in backed if hasattr(c.index, "point_ref_eps")]
        if not batchable:
            return {}
        geom = self.system.geom
        out, ok, eps_rows, ok_dacs = {}, [], [], []
        for c in batchable:
            try:
                eps_q, e_dac = c.index.point_ref_eps(wl, geom)
            except UnsupportedWorkloadError as e:
                skipped.append(SkippedCandidate(c.knob, str(e)))
                out[id(c)] = None
                continue
            ok.append(c)
            eps_rows.append(np.asarray(eps_q, np.int64))
            ok_dacs.append(float(e_dac))
        if ok:
            num_pages = geom.num_pages(int(ok[0].index.n))
            if _resolve_profile_executor(executor) == "device":
                from repro.kernels import profile_grid as _device_profile
                counts_b, totals_b = \
                    _device_profile.point_page_refs_mixed_eps_grid(
                        wl.positions, np.stack(eps_rows), geom.c_ipp,
                        num_pages)
            else:
                counts_b, totals_b = page_ref.point_page_refs_mixed_eps_grid(
                    wl.positions, np.stack(eps_rows), geom.c_ipp, num_pages)
            for i, c in enumerate(ok):
                out[id(c)] = (counts_b[i], float(totals_b[i]), ok_dacs[i])
        return out

    def _sorted_grid(self, feasible, skipped, wl: Workload,
                     t0: float) -> GridResult:
        """Batched sorted-stream grid (the vmapped counterpart of the
        point/range banded-matmul kernels).

        The probe windows of a sorted stream do not depend on eps, so ONE
        shared (R, N, coverage, pinned) profile serves every uniform-eps
        candidate — only the capacity and the Theorem III.1 premise vary —
        and all candidates solve through one call of
        ``cache_models.sorted_scan_hit_rate_grid``.
        """
        geom = self.system.geom
        shared = None
        entries = []          # (candidate, SortedScanPart, capacity)
        for c in feasible:
            if c.index is not None:
                try:
                    prof = c.index.page_ref_profile(wl, geom)
                except UnsupportedWorkloadError as e:
                    skipped.append(SkippedCandidate(c.knob, str(e)))
                    continue
                sp = prof.sorted_part
                if sp is None:
                    sp = SortedScanPart(prof.total_refs,
                                        float(prof.distinct_pages),
                                        prof.min_capacity)
            else:
                if shared is None:
                    if wl.n is None:
                        raise ValueError("Workload.n (key-file size) required "
                                         "for grid estimation")
                    shared = sorted_part_for(wl, 0, geom,
                                             geom.num_pages(int(wl.n)))
                # eps <= 0 keeps the shared profile's widest-observed-window
                # premise, matching sorted_part_for's dispatch.
                sp = (shared if c.eps <= 0 else dataclasses.replace(
                    shared,
                    min_capacity=1 + int(np.ceil(2 * c.eps / geom.c_ipp))))
            entries.append((c, sp, self.system.capacity_for(c.size_bytes)))
        if not entries:
            raise UnsupportedWorkloadError(
                wl.kind,
                detail="no grid candidate could profile this workload ("
                       + "; ".join(s.reason for s in skipped) + ")")

        batched = [e for e in entries if e[1].coverage is not None]
        if batched:
            h_arr = np.asarray(cache_models.sorted_scan_hit_rate_grid(
                self.system.policy,
                _stack_or_share([sp.coverage for _, sp, _ in batched]),
                jnp.asarray([sp.total_refs for _, sp, _ in batched],
                            jnp.float32),
                _exact_cap_array([sp.distinct_pages for _, sp, _ in batched]),
                jnp.asarray([sp.pinned_retouches for _, sp, _ in batched],
                            jnp.float32),
                _exact_cap_array([cap for _, _, cap in batched]),
                _exact_cap_array([sp.min_capacity for _, sp, _ in batched])),
                np.float64)
        hit_rates = {}
        k = 0
        for c, sp, cap in entries:
            if sp.coverage is not None:
                hit_rates[c.knob] = float(h_arr[k])
                k += 1
            else:   # profile without a coverage histogram: recency form
                hit_rates[c.knob] = cache_models.sorted_scan_hit_rate(
                    self.system.policy, cap, total_refs=sp.total_refs,
                    distinct_pages=sp.distinct_pages,
                    min_capacity=sp.min_capacity)

        elapsed = time.perf_counter() - t0
        per = elapsed / max(len(entries), 1)
        estimates: Dict[object, CamEstimate] = {}
        for c, sp, cap in entries:
            h = hit_rates[c.knob]
            e_dac = sp.total_refs / max(wl.n_queries, 1)
            io = (1.0 - h) * e_dac
            estimates[c.knob] = CamEstimate(
                io_per_query=io, hit_rate=h, dac=e_dac, capacity_pages=cap,
                total_refs=sp.total_refs, distinct_pages=sp.distinct_pages,
                estimation_seconds=per,
                policy=self._sorted_label(cap, sp),
                device_cost=self._device_cost(io))
        best = min(estimates, key=lambda kn: estimates[kn].io_per_query)
        return GridResult(estimates, best, elapsed, tuple(skipped))

    def _sorted_label(self, cap: int, sp: SortedScanPart) -> str:
        """Which sorted-scan form priced this estimate (CamEstimate.policy)."""
        freq_aware = (self.system.policy not in cache_models.RECENCY_POLICIES
                      and sp.coverage is not None
                      and sp.min_capacity <= cap < sp.distinct_pages)
        return (f"sorted-{self.system.policy}" if freq_aware
                else "sorted-closed-form")

    # -------------------------------------------------------------- internals
    def _uniform_grid(self, cands: Sequence[GridCandidate], wl: Workload):
        """(counts rows, totals, dacs, sorted part) for uniform-eps
        candidates, batched.

        Point/range parts accumulate into the shared banded-matmul
        histograms; sorted parts accumulate into ONE merged
        :class:`SortedScanPart` (their windows are eps-independent) whose
        capacity premise the caller re-derives per candidate.
        """
        geom = self.system.geom
        if wl.n is None:
            raise ValueError("Workload.n (key-file size) required for "
                             "grid estimation")
        num_pages = geom.num_pages(int(wl.n))
        eps_arr = jnp.asarray([c.eps for c in cands], jnp.int32)
        eps_f = np.asarray([c.eps for c in cands], np.float64)
        dac_per_query = np.asarray(
            dac.expected_dac(eps_f, geom.c_ipp, geom.strategy), np.float64)
        sorted_parts = []
        write_parts = []

        def grid_counts(w: Workload):
            if w.kind == POINT:
                d_radius = page_ref.lut_radius(max(c.eps for c in cands),
                                               geom.c_ipp)
                counts, totals = page_ref.point_page_refs_grid(
                    jnp.asarray(w.positions, jnp.int32), eps_arr, d_radius,
                    geom.c_ipp, num_pages)
                dac_mass = dac_per_query * w.n_queries
                return counts, np.asarray(totals, np.float64), dac_mass
            if w.kind in WRITE_KINDS:
                # locate references vary with eps (same banded kernel as
                # point); the dirtied target window is eps-independent, so
                # ONE shared write stream serves the whole grid (amp = 1:
                # un-built uniform-eps candidates have no gap structure).
                d_radius = page_ref.lut_radius(max(c.eps for c in cands),
                                               geom.c_ipp)
                counts, totals = page_ref.point_page_refs_grid(
                    jnp.asarray(w.positions, jnp.int32), eps_arr, d_radius,
                    geom.c_ipp, num_pages)
                wcounts, wtotal = page_ref.point_page_refs(
                    jnp.asarray(w.positions, jnp.int32), 0,
                    geom.c_ipp, num_pages)
                write_parts.append(WriteStreamPart(wcounts, float(wtotal)))
                dac_mass = (dac_per_query + 1.0) * w.n_queries
                return counts, np.asarray(totals, np.float64), dac_mass
            if w.kind == RANGE:
                counts, totals = page_ref.range_page_refs_grid(
                    jnp.asarray(w.positions, jnp.int32),
                    jnp.asarray(w.hi_positions, jnp.int32),
                    eps_arr, geom.c_ipp, num_pages, int(wl.n))
                totals = np.asarray(totals, np.float64)
                return counts, totals, totals.copy()
            if w.kind == SORTED:
                sp = sorted_part_for(w, 0, geom, num_pages)
                sorted_parts.append(sp)
                return (jnp.zeros((len(cands), num_pages), jnp.float32),
                        np.zeros(len(cands)),
                        np.full(len(cands), sp.total_refs))
            if w.kind == MIXED:
                counts = jnp.zeros((len(cands), num_pages), jnp.float32)
                totals = np.zeros(len(cands))
                dac_mass = np.zeros(len(cands))
                for part in w.parts:
                    c, t, d = grid_counts(part)
                    counts, totals, dac_mass = counts + c, totals + t, dac_mass + d
                return counts, totals, dac_mass
            raise UnsupportedWorkloadError(
                wl.kind, part=w.kind if w is not wl else None)

        counts, totals, dac_mass = grid_counts(wl)
        dacs = dac_mass / max(wl.n_queries, 1)
        spart = (_merge_sorted_parts(sorted_parts) if sorted_parts else None)
        wpart = (_merge_write_parts(write_parts) if write_parts else None)
        return list(counts), list(totals), list(dacs), spart, wpart

    def _finish(self, prof: PageRefProfile, wl: Workload, cap: int,
                t0: float) -> CamEstimate:
        """Compose a profile with the cache model — Eq. 3 (legacy-identical).

        Sorted streams (pure, or the sorted sub-part of a mixed workload)
        dispatch by ``system.policy`` through the shared
        ``cache_models.sorted_scan`` family: the Theorem III.1 compulsory
        closed form under recency eviction, the frequency-aware form under
        LFU-like policies, the thrash regime below the capacity premise.
        """
        if prof.sorted_stream:
            sp = prof.sorted_part or SortedScanPart(
                prof.total_refs, float(prof.distinct_pages),
                prof.min_capacity)
            h = cache_models.sorted_scan_hit_rate(
                self.system.policy, cap, total_refs=sp.total_refs,
                distinct_pages=sp.distinct_pages, coverage=sp.coverage,
                pinned_retouches=sp.pinned_retouches,
                min_capacity=sp.min_capacity)
            io = (1.0 - h) * prof.expected_dac
            return CamEstimate(io, h, prof.expected_dac, cap,
                               sp.total_refs, sp.distinct_pages,
                               time.perf_counter() - t0,
                               self._sorted_label(cap, sp),
                               device_cost=self._device_cost(io))
        wp = prof.write_part
        counts = prof.counts
        sample_refs = prof.total_refs
        if wp is not None:
            # combined read+write request histogram — same pre-combine the
            # batched solve (hit_rate_grid's write_* path) applies
            counts = counts + wp.counts
            sample_refs = sample_refs + wp.total_refs
        full_refs = sample_refs * wl.scale
        n_distinct = (float(prof.distinct_pages)
                      if prof.distinct_pages is not None
                      else float(jnp.sum(counts > 0)))
        if cap <= 0 or sample_refs <= 0:
            h = (0.0 if wp is None or sample_refs <= 0
                 else -wp.total_refs / sample_refs)
        else:
            probs = counts / jnp.maximum(float(sample_refs), 1e-30)
            h = float(cache_models.hit_rate(
                self.system.policy, cap, probs, total_requests=full_refs,
                distinct_pages=n_distinct))
            if wp is not None:
                h -= float(cache_models.writeback_fraction(
                    self.system.policy, probs,
                    wp.counts / jnp.maximum(float(sample_refs), 1e-30),
                    cap, n_distinct))
        sp = prof.sorted_part
        if sp is not None:
            # Mixed workload with sorted sub-streams: expected misses add
            # over the shared buffer (each part priced by its own model).
            h_s = cache_models.sorted_scan_hit_rate(
                self.system.policy, cap, total_refs=sp.total_refs,
                distinct_pages=sp.distinct_pages, coverage=sp.coverage,
                pinned_retouches=sp.pinned_retouches,
                min_capacity=sp.min_capacity)
            s_full = sp.total_refs * wl.scale
            total_full = full_refs + s_full
            miss = (1.0 - h) * full_refs + (1.0 - h_s) * s_full
            h = (1.0 - miss / max(total_full, 1.0)
                 if total_full > 0 else 0.0)
            full_refs = total_full
            n_distinct = (float(jnp.sum((prof.counts > 0)
                                        | (sp.coverage > 0)))
                          if sp.coverage is not None
                          # coverage-less legacy part: no union available,
                          # report the parts' sum
                          else n_distinct + sp.distinct_pages)
        io = (1.0 - h) * float(prof.expected_dac)
        return CamEstimate(
            io_per_query=io, hit_rate=h, dac=float(prof.expected_dac),
            capacity_pages=cap, total_refs=float(full_refs),
            distinct_pages=n_distinct,
            estimation_seconds=time.perf_counter() - t0,
            policy=self.system.policy, device_cost=self._device_cost(io))

    def _device_cost(self, io_per_query: float) -> Optional[float]:
        """Compose with the device model (§III-A): one run per query."""
        if self.system.device is None:
            return None
        return float(self.system.device.cost(np.asarray([io_per_query])))

    def _sampled(self, workload: Workload, rate: float, seed: int) -> Workload:
        if rate >= 1.0:
            return workload
        # Keyed by identity (the workload object is the unit of reuse in a
        # tuning loop); the strong reference in the value keeps the id valid
        # for the entry's lifetime.  FIFO-bounded so a long-lived session
        # over many workloads cannot pin arbitrary amounts of array memory.
        key = (id(workload), rate, seed)
        hit = self._sample_cache.get(key)
        if hit is not None:
            return hit[1]
        sampled = workload.sample(rate, seed)
        while len(self._sample_cache) >= self._SAMPLE_CACHE_MAX:
            self._sample_cache.pop(next(iter(self._sample_cache)))
        self._sample_cache[key] = (workload, sampled)
        return sampled
