"""CostSession — the index-agnostic estimation surface of CAM.

The paper's claim that CAM "is not tied to a particular learned index design"
is realized here as three nouns plus a session object:

* :class:`~repro.core.workload.Workload` — queries, cached true positions,
  shapes (point / range / sorted / mixed), CAM-x sampling;
* :class:`IndexModel` — anything exposing ``size_bytes`` + knob metadata +
  a ``page_ref_profile(workload, geom)`` returning the Eq. 12/13/14
  histograms (adapters for PGM, RMI and RadixSpline live in
  ``repro.index.adapters``);
* :class:`System` — page geometry, memory budget, cache policy, optional
  device-side cost model.

``CostSession.estimate`` reproduces Algorithm 1 for a single configuration;
``CostSession.estimate_grid`` evaluates an entire knob grid (eps grid x
per-candidate buffer capacities) in ONE jitted pass over shared page-ref
state — K lockstep bisections instead of K Python loop iterations with K
per-eps recompiles, which is the tuning-loop speedup the paper's §V needs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Protocol, Sequence, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core import cache_models, dac, page_ref
from repro.core.cam import CamEstimate, CamGeometry, capacity_pages
from repro.core.workload import MIXED, POINT, RANGE, SORTED, Workload

__all__ = [
    "System",
    "PageRefProfile",
    "IndexModel",
    "UniformEpsModel",
    "GridCandidate",
    "GridResult",
    "PlanCost",
    "CostSession",
    "uniform_eps_profile",
]


# ---------------------------------------------------------------------------
# System: where the index runs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class System:
    """Disk geometry + memory budget + cache policy (+ device model)."""

    geom: CamGeometry = CamGeometry()
    memory_budget_bytes: float = 8 << 20
    policy: str = "lru"
    device: Optional[object] = None   # repro.core.device_models instance

    def __post_init__(self):
        # Validate eagerly: the compulsory-miss branch never consults the
        # policy, so a typo could otherwise survive a whole tuning run.
        if self.policy not in cache_models.POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; expected one "
                             f"of {cache_models.POLICIES}")

    def capacity_for(self, index_bytes: float) -> int:
        """Buffer capacity left once the index is resident (Alg. 1 l. 15)."""
        return capacity_pages(self.memory_budget_bytes, index_bytes,
                              self.geom.page_bytes)

    def layout(self):
        """The :class:`repro.index.disk_layout.PageLayout` this geometry
        implies — the bridge every execution-side consumer (joins, the
        simulated machine, benchmarks) uses instead of re-deriving page
        counts from raw constants."""
        from repro.index.disk_layout import PageLayout

        return PageLayout(c_ipp=self.geom.c_ipp,
                          page_bytes=self.geom.page_bytes)


# ---------------------------------------------------------------------------
# Plan-level cost summaries (shared by CostSession consumers and JoinSession)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Model-predicted cost of one executable plan / strategy.

    The join planner emits one per candidate strategy; anything that ranks
    alternatives by predicted cost (plan selection, knob grids with attached
    execution strategies) compares these.  ``seconds`` is the Eq. 17-style
    fitted-time prediction, ``physical_ios`` the CAM cache-aware miss count
    it was derived from, and ``logical_refs`` the request mass R.
    """

    strategy: str
    seconds: float
    physical_ios: float
    logical_refs: float

    def __lt__(self, other: "PlanCost") -> bool:
        return self.seconds < other.seconds


# ---------------------------------------------------------------------------
# Page-reference profiles and the IndexModel protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PageRefProfile:
    """Structural page-reference summary an index reports for a workload.

    ``counts`` is the Eq. 13/14 expected-reference histogram; sorted probe
    streams need only (R, N) for the Theorem III.1 closed form and leave
    ``counts`` as None.
    """

    counts: Optional[jnp.ndarray]
    total_refs: float                     # sample request mass R
    expected_dac: float                   # E[DAC] per query
    sorted_stream: bool = False
    distinct_pages: Optional[float] = None
    min_capacity: int = 1                 # Thm III.1 capacity premise


@runtime_checkable
class IndexModel(Protocol):
    """What CAM needs from a learned index — nothing design-specific."""

    family: str

    @property
    def size_bytes(self) -> float: ...    # in-memory footprint M_idx

    def knobs(self) -> Dict[str, object]: ...

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile: ...


def uniform_eps_profile(workload: Workload, eps: int, geom: CamGeometry,
                        n: Optional[int] = None) -> PageRefProfile:
    """Shared profile for any uniformly error-bounded design (PGM, RadixSpline).

    Dispatches on the workload shape; mixed workloads sum part histograms.
    """
    n = int(n if n is not None else workload.n)
    num_pages = geom.num_pages(n)
    if workload.kind == POINT:
        counts, total = page_ref.point_page_refs(
            jnp.asarray(workload.positions, jnp.int32), int(eps),
            geom.c_ipp, num_pages)
        e_dac = float(dac.expected_dac(eps, geom.c_ipp, geom.strategy))
        return PageRefProfile(counts, float(total), e_dac)
    if workload.kind == RANGE:
        counts, total = page_ref.range_page_refs(
            jnp.asarray(workload.positions, jnp.int32),
            jnp.asarray(workload.hi_positions, jnp.int32),
            int(eps), geom.c_ipp, num_pages, n)
        e_dac = float(total) / max(workload.n_queries, 1)
        return PageRefProfile(counts, float(total), e_dac)
    if workload.kind == SORTED:
        plo, phi = page_ref.page_intervals(
            jnp.asarray(workload.positions, jnp.int32),
            jnp.asarray(workload.hi_positions, jnp.int32),
            geom.c_ipp, num_pages)
        r_total, n_distinct = page_ref.sorted_workload_rn(plo, phi)
        r_total, n_distinct = float(r_total), float(n_distinct)
        return PageRefProfile(
            counts=None, total_refs=r_total,
            expected_dac=r_total / max(workload.n_queries, 1),
            sorted_stream=True, distinct_pages=n_distinct,
            min_capacity=1 + int(np.ceil(2 * eps / geom.c_ipp)))
    if workload.kind == MIXED:
        counts = jnp.zeros((num_pages,), jnp.float32)
        total = 0.0
        dac_mass = 0.0
        for part in workload.parts:
            prof = uniform_eps_profile(part, eps, geom, n)
            if prof.sorted_stream:
                raise ValueError("sorted parts cannot join a mixed histogram")
            counts = counts + prof.counts
            total += prof.total_refs
            dac_mass += prof.expected_dac * part.n_queries
        return PageRefProfile(counts, total,
                              dac_mass / max(workload.n_queries, 1))
    raise ValueError(f"unsupported workload kind {workload.kind!r}")


@dataclasses.dataclass(frozen=True)
class UniformEpsModel:
    """Un-built stand-in for any error-bounded index: knob metadata only.

    Lets tuners price an (eps, size) candidate — size typically from a fitted
    power law — without constructing the index (paper §V-B).
    """

    eps: int
    n: int
    size_bytes: float
    family: str = "uniform-eps"

    def knobs(self) -> Dict[str, object]:
        return {"eps": {"value": self.eps, "kind": "error_bound",
                        "tunable": True}}

    def page_ref_profile(self, workload: Workload,
                         geom: CamGeometry) -> PageRefProfile:
        return uniform_eps_profile(workload, self.eps, geom, self.n)


# ---------------------------------------------------------------------------
# Grid candidates / results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GridCandidate:
    """One knob configuration in an ``estimate_grid`` sweep.

    Either ``eps`` (uniform error bound — enables the fully batched kernel,
    no index build needed) or ``index`` (a built :class:`IndexModel`, e.g. an
    RMI whose per-leaf mixture has no uniform eps) must be set.
    """

    knob: object
    size_bytes: float
    eps: Optional[int] = None
    index: Optional[IndexModel] = None

    def __post_init__(self):
        if self.eps is None and self.index is None:
            raise ValueError("GridCandidate needs eps or index")


@dataclasses.dataclass
class GridResult:
    """All candidate estimates + argmin, from one batched pass."""

    estimates: Dict[object, CamEstimate]
    best_knob: object
    seconds: float
    skipped: tuple = ()                   # knobs infeasible under the budget

    @property
    def best(self) -> CamEstimate:
        return self.estimates[self.best_knob]

    @property
    def est_io(self) -> float:
        return self.best.io_per_query


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------

class CostSession:
    """Reusable estimation context bound to one :class:`System`.

    Holds the sampled-workload cache so repeated ``estimate``/``estimate_grid``
    calls over the same workload (the tuning loop) never re-sample or
    re-locate queries.
    """

    _SAMPLE_CACHE_MAX = 16

    def __init__(self, system: System):
        self.system = system
        self._sample_cache: Dict[tuple, tuple] = {}

    # ------------------------------------------------------------------ single
    def estimate(self, index: IndexModel, workload: Workload,
                 sample_rate: float = 1.0, seed: int = 0) -> CamEstimate:
        """Algorithm 1 for one (index, workload) pair."""
        t0 = time.perf_counter()
        wl = self._sampled(workload, sample_rate, seed)
        prof = index.page_ref_profile(wl, self.system.geom)
        cap = self.system.capacity_for(index.size_bytes)
        return self._finish(prof, wl, cap, t0)

    # ------------------------------------------------------------------- grid
    def estimate_grid(self, candidates: Sequence[GridCandidate],
                      workload: Workload, sample_rate: float = 1.0,
                      seed: int = 0) -> GridResult:
        """Estimate a whole knob grid in one jitted/vmapped pass.

        Page-ref state (positions, scatter targets) is shared across
        candidates; histograms for uniform-eps candidates come from the
        batched grid kernel, built indexes (RMI) contribute their mixture
        profiles; ALL hit-rate fixed points then solve in a single vmapped
        bisection.
        """
        t0 = time.perf_counter()
        wl = self._sampled(workload, sample_rate, seed)
        geom = self.system.geom
        feasible, skipped = [], []
        for c in candidates:
            (feasible if self.system.capacity_for(c.size_bytes) >= 1
             else skipped).append(c)
        if not feasible:
            raise ValueError("memory budget too small for any candidate index")

        if wl.kind == SORTED:
            # Theorem III.1 is already closed-form per candidate — no solver
            # to batch; evaluate directly (fresh clock per candidate so
            # estimation_seconds stays per-call, like the non-sorted path).
            estimates = {}
            for c in feasible:
                c_t0 = time.perf_counter()
                prof = (c.index.page_ref_profile(wl, geom)
                        if c.index is not None
                        else uniform_eps_profile(wl, c.eps, geom))
                estimates[c.knob] = self._finish(
                    prof, wl, self.system.capacity_for(c.size_bytes), c_t0)
            best = min(estimates, key=lambda k: estimates[k].io_per_query)
            return GridResult(estimates, best, time.perf_counter() - t0,
                              tuple(c.knob for c in skipped))

        uniform = [c for c in feasible if c.index is None]
        backed = [c for c in feasible if c.index is not None]

        rows, totals, dacs, caps, knobs = [], [], [], [], []
        if uniform:
            counts_u, totals_u, dacs_u = self._uniform_grid(uniform, wl)
            rows.extend(counts_u)
            totals.extend(totals_u)
            dacs.extend(dacs_u)
            caps.extend(self.system.capacity_for(c.size_bytes) for c in uniform)
            knobs.extend(c.knob for c in uniform)
        for c in backed:
            prof = c.index.page_ref_profile(wl, geom)
            rows.append(prof.counts)
            totals.append(prof.total_refs)
            dacs.append(prof.expected_dac)
            caps.append(self.system.capacity_for(c.size_bytes))
            knobs.append(c.knob)

        counts = jnp.stack([jnp.asarray(r, jnp.float32) for r in rows])
        sample_refs = jnp.asarray(totals, jnp.float32)
        full_refs = sample_refs * wl.scale
        h, n_distinct = cache_models.hit_rate_grid(
            self.system.policy, counts, sample_refs, full_refs,
            jnp.asarray(caps, jnp.float32))
        h = np.asarray(h, np.float64)
        n_distinct = np.asarray(n_distinct, np.float64)

        elapsed = time.perf_counter() - t0
        per = elapsed / max(len(knobs), 1)
        estimates: Dict[object, CamEstimate] = {}
        for i, knob in enumerate(knobs):
            io = (1.0 - float(h[i])) * float(dacs[i])
            estimates[knob] = CamEstimate(
                io_per_query=io, hit_rate=float(h[i]), dac=float(dacs[i]),
                capacity_pages=int(caps[i]),
                total_refs=float(totals[i]) * wl.scale,
                distinct_pages=float(n_distinct[i]),
                estimation_seconds=per, policy=self.system.policy,
                device_cost=self._device_cost(io))
        best = min(estimates, key=lambda k: estimates[k].io_per_query)
        return GridResult(estimates, best, elapsed,
                          tuple(c.knob for c in skipped))

    # -------------------------------------------------------------- internals
    def _uniform_grid(self, cands: Sequence[GridCandidate], wl: Workload):
        """(counts rows, totals, dacs) for uniform-eps candidates, batched."""
        geom = self.system.geom
        if wl.n is None:
            raise ValueError("Workload.n (key-file size) required for "
                             "grid estimation")
        num_pages = geom.num_pages(int(wl.n))
        eps_arr = jnp.asarray([c.eps for c in cands], jnp.int32)
        eps_f = np.asarray([c.eps for c in cands], np.float64)
        dac_per_query = np.asarray(
            dac.expected_dac(eps_f, geom.c_ipp, geom.strategy), np.float64)

        def grid_counts(w: Workload):
            if w.kind == POINT:
                d_radius = page_ref.lut_radius(max(c.eps for c in cands),
                                               geom.c_ipp)
                counts, totals = page_ref.point_page_refs_grid(
                    jnp.asarray(w.positions, jnp.int32), eps_arr, d_radius,
                    geom.c_ipp, num_pages)
                dac_mass = dac_per_query * w.n_queries
                return counts, np.asarray(totals, np.float64), dac_mass
            if w.kind == RANGE:
                counts, totals = page_ref.range_page_refs_grid(
                    jnp.asarray(w.positions, jnp.int32),
                    jnp.asarray(w.hi_positions, jnp.int32),
                    eps_arr, geom.c_ipp, num_pages, int(wl.n))
                totals = np.asarray(totals, np.float64)
                return counts, totals, totals.copy()
            if w.kind == MIXED:
                counts = jnp.zeros((len(cands), num_pages), jnp.float32)
                totals = np.zeros(len(cands))
                dac_mass = np.zeros(len(cands))
                for part in w.parts:
                    c, t, d = grid_counts(part)
                    counts, totals, dac_mass = counts + c, totals + t, dac_mass + d
                return counts, totals, dac_mass
            raise ValueError(f"grid estimation unsupported for {w.kind!r}")

        counts, totals, dac_mass = grid_counts(wl)
        dacs = dac_mass / max(wl.n_queries, 1)
        return list(counts), list(totals), list(dacs)

    def _finish(self, prof: PageRefProfile, wl: Workload, cap: int,
                t0: float) -> CamEstimate:
        """Compose a profile with the cache model — Eq. 3 (legacy-identical)."""
        if prof.sorted_stream:
            r, nd = prof.total_refs, float(prof.distinct_pages)
            h = 0.0 if cap < prof.min_capacity else (r - nd) / max(r, 1e-30)
            io = (1.0 - h) * prof.expected_dac
            return CamEstimate(io, h, prof.expected_dac, cap, r, nd,
                               time.perf_counter() - t0, "sorted-closed-form",
                               device_cost=self._device_cost(io))
        full_refs = prof.total_refs * wl.scale
        n_distinct = (float(prof.distinct_pages)
                      if prof.distinct_pages is not None
                      else float(jnp.sum(prof.counts > 0)))
        if cap <= 0:
            h = 0.0
        else:
            probs = prof.counts / jnp.maximum(float(prof.total_refs), 1e-30)
            h = float(cache_models.hit_rate(
                self.system.policy, cap, probs, total_requests=full_refs,
                distinct_pages=n_distinct))
        io = (1.0 - h) * float(prof.expected_dac)
        return CamEstimate(
            io_per_query=io, hit_rate=h, dac=float(prof.expected_dac),
            capacity_pages=cap, total_refs=float(full_refs),
            distinct_pages=n_distinct,
            estimation_seconds=time.perf_counter() - t0,
            policy=self.system.policy, device_cost=self._device_cost(io))

    def _device_cost(self, io_per_query: float) -> Optional[float]:
        """Compose with the device model (§III-A): one run per query."""
        if self.system.device is None:
            return None
        return float(self.system.device.cost(np.asarray([io_per_query])))

    def _sampled(self, workload: Workload, rate: float, seed: int) -> Workload:
        if rate >= 1.0:
            return workload
        # Keyed by identity (the workload object is the unit of reuse in a
        # tuning loop); the strong reference in the value keeps the id valid
        # for the entry's lifetime.  FIFO-bounded so a long-lived session
        # over many workloads cannot pin arbitrary amounts of array memory.
        key = (id(workload), rate, seed)
        hit = self._sample_cache.get(key)
        if hit is not None:
            return hit[1]
        sampled = workload.sample(rate, seed)
        while len(self._sample_cache) >= self._SAMPLE_CACHE_MAX:
            self._sample_cache.pop(next(iter(self._sample_cache)))
        self._sample_cache[key] = (workload, sampled)
        return sampled
