"""CAM core: the paper's contribution as a composable JAX module."""
from repro.core import (cache_models, cam, dac, device_models, lpm, page_ref,
                        qerror, replay, session, workload)

__all__ = [
    "cache_models",
    "cam",
    "dac",
    "device_models",
    "lpm",
    "page_ref",
    "qerror",
    "replay",
    "session",
    "workload",
]
