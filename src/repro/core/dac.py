"""Expected data-access cost E[DAC] (paper §III-D, Lemmas III.2 / III.3).

The closed forms assume the predicted position lands at a uniformly
distributed in-page offset.  ``*_exact`` variants evaluate the finite sums in
the lemma proofs directly (used by property tests to certify the closed
forms), and the RMI variant computes the workload-weighted leaf mixture of
§V-C.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "expected_dac_all_at_once",
    "expected_dac_one_by_one",
    "expected_dac",
    "expected_dac_all_at_once_exact",
    "expected_dac_one_by_one_exact",
    "expected_dac_rmi",
]

STRATEGIES = ("all_at_once", "one_by_one")


def expected_dac_all_at_once(eps, c_ipp):
    """Lemma III.2:  E[DAC] = 1 + 2*eps / C_ipp   (S2 fetching)."""
    return 1.0 + 2.0 * jnp.asarray(eps, jnp.float32) / jnp.asarray(c_ipp, jnp.float32)


def expected_dac_one_by_one(eps, c_ipp):
    """Lemma III.3:  E[DAC] = 1 + eps / C_ipp   (S1 fetching)."""
    return 1.0 + jnp.asarray(eps, jnp.float32) / jnp.asarray(c_ipp, jnp.float32)


def expected_dac(eps, c_ipp, strategy: str = "all_at_once"):
    if strategy == "all_at_once":
        return expected_dac_all_at_once(eps, c_ipp)
    if strategy == "one_by_one":
        return expected_dac_one_by_one(eps, c_ipp)
    raise ValueError(f"unknown strategy {strategy!r}; expected one of {STRATEGIES}")


# ---------------------------------------------------------------------------
# Exact finite sums from the lemma proofs (test oracles)
# ---------------------------------------------------------------------------

def expected_dac_all_at_once_exact(eps: int, c_ipp: int) -> float:
    """Direct evaluation of the sum in the proof of Lemma III.2."""
    s = np.arange(c_ipp)
    total = 1.0 + np.ceil((eps - s) / c_ipp).clip(min=0)
    total += np.ceil((eps - (c_ipp - 1 - s)) / c_ipp).clip(min=0)
    return float(total.mean())


def expected_dac_one_by_one_exact(eps: int, c_ipp: int) -> float:
    """Direct evaluation of the double sum in the proof of Lemma III.3."""
    x = np.arange(2 * eps + 1)[:, None]
    k = np.arange(c_ipp)[None, :]
    extra = (k + x) // c_ipp
    return float(1.0 + extra.mean())


# ---------------------------------------------------------------------------
# RMI mixture (§V-C): E[DAC] = sum_j w_j (1 + lambda * eps_j / C_ipp)
# ---------------------------------------------------------------------------

def expected_dac_rmi(leaf_eps, leaf_weights, c_ipp, strategy: str = "all_at_once"):
    """Workload-weighted mixture over leaf-local error bounds.

    ``leaf_eps[j]`` is the empirical max error of leaf j, ``leaf_weights[j]``
    the probability a query routes to leaf j (estimated from the workload).
    """
    lam = 2.0 if strategy == "all_at_once" else 1.0
    leaf_eps = jnp.asarray(leaf_eps, jnp.float32)
    w = jnp.asarray(leaf_weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)
    per_leaf = 1.0 + lam * leaf_eps / jnp.asarray(c_ipp, jnp.float32)
    return jnp.sum(w * per_leaf)
