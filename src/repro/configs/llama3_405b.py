"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783; unverified].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

Training-memory posture on v5e (16 GB HBM): optimizer moments are int8 with
per-tensor scales (8-bit Adam) and gradients accumulate in bf16 — at 405B
params over a 256-chip pod the fp32-moment footprint alone (12.7 GB/chip)
would not leave room for activations.  See EXPERIMENTS.md §Dry-run.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=5e5,
    opt_moment_dtype="int8",
)
