"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048, 4 codebooks.
The EnCodec frontend is a stub: inputs are the 4 parallel token streams
(B, S, 4); per-codebook embeddings are summed, and 4 output heads predict the
next frame's codebook tokens.  MLP is GeLU (standard transformer decoder).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    mlp_type="gelu",
    attention_bias=False,
)
