"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Every 6 Mamba2 layers, ONE shared attention+MLP block (weights reused across
all 9 invocations) is applied — the Zamba2 weight-sharing trick.  Hybrid =>
sub-quadratic; runs long_500k with (SSM state + small shared-attn KV).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state_dim=64,
    ssm_head_dim=64,
    expand=2,
    shared_attn_every=6,
    subquadratic=True,
)
