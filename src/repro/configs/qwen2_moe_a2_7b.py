"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=151936.
Routed experts are padded 60 -> 64 for even EP over the 16-way model axis
(4 experts per device); the 4 shared experts always fire.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    num_experts=60,
    experts_per_tok=4,
    num_shared_experts=4,
    moe_d_ff=1408,
)
