"""qwen2-vl-7b — M-RoPE VLM backbone [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The dynamic-
resolution vision tower is a stub: ``input_specs`` provides precomputed patch
embeddings (B, num_vision_tokens, patch_dim) that a linear merger projects
into the first ``num_vision_tokens`` sequence slots; M-RoPE (t/h/w sections
16/24/24 over the half-dim) comes in as 3-channel position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    mrope=True,
    mrope_sections=(16, 24, 24),
    num_vision_tokens=256,
    vision_patch_dim=1176,
    rope_theta=1e6,
)
