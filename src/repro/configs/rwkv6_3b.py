"""rwkv6-3b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=2560 d_ff=8960 vocab=65536.  40 heads of dim 64; matrix-valued
state (H, 64, 64) per layer.  Sub-quadratic: runs the long_500k decode shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    num_layers=32,
    d_model=2560,
    num_heads=40,         # d_model / ssm_head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    ssm_head_dim=64,
    subquadratic=True,
)
