"""Model/shape configuration system.

Every assigned architecture is a frozen ``ModelConfig``; input shapes are
``ShapeSpec``s.  ``reduced()`` gives the CPU-smoke-test version of any config
(same family/wiring, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | rwkv | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # MLP
    mlp_type: str = "swiglu"    # swiglu | gelu
    attention_bias: bool = False

    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25

    # SSM / RWKV / hybrid
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    shared_attn_every: int = 0  # zamba2: shared attn+mlp block period
    chunk_size: int = 128       # chunked linear-attention/SSD block length

    # Modality frontends (stubs; see DESIGN.md)
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    num_vision_tokens: int = 0
    vision_patch_dim: int = 0
    num_codebooks: int = 0      # musicgen: EnCodec token streams

    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    subquadratic: bool = False  # supports long_500k decode
    dtype: str = "bfloat16"

    # training-side knobs (overridable per recipe)
    opt_moment_dtype: str = "float32"   # float32 | bfloat16 | int8

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs)."""
        d, f, l, v = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd = self.head_dim
        emb = v * d * (1 + self.num_codebooks if self.num_codebooks else 1)
        out_heads = max(1, self.num_codebooks or 1) * v * d
        if self.family == "rwkv":
            per_layer = d * d * 4 + d * self.d_ff * 2 + d * 512  # tm + cm + loras
            return emb + out_heads + l * per_layer
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        mlp_mult = 3 if self.mlp_type == "swiglu" else 2
        if self.is_moe:
            fe = self.moe_d_ff or f
            mlp = self.num_experts * mlp_mult * d * fe \
                + self.num_shared_experts * mlp_mult * d * fe + d * self.num_experts
        else:
            mlp = mlp_mult * d * f
        if self.family == "hybrid":
            d_in = d * self.expand
            ssm = l * (d * (2 * d_in + 2 * self.ssm_state_dim * 0 + 2) + d_in * d
                       + d_in * 2 * self.ssm_state_dim)
            n_shared = max(1, l // max(self.shared_attn_every, 1))
            return emb + out_heads + ssm + (attn + mlp)  # one shared block
        return emb + out_heads + l * (attn + mlp)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        hd = self.head_dim
        fe = self.moe_d_ff or self.d_ff
        mlp_mult = 3 if self.mlp_type == "swiglu" else 2
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        mlp = (self.experts_per_tok + self.num_shared_experts) * mlp_mult * d * fe \
            + d * self.num_experts
        return 2 * self.vocab_size * d + l * (attn + mlp)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}
