"""The paper's own experimental configuration (§VII-A), as data.

Benchmarks import these constants so the mapping from paper setup to our
scaled runs is explicit and greppable; `scale_factor` converts between them.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["PaperSetup", "PAPER", "SCALED_DEFAULT"]


@dataclasses.dataclass(frozen=True)
class PaperSetup:
    datasets: Tuple[str, ...] = ("books", "fb", "osm", "wiki")
    keys_per_dataset: int = 200_000_000          # 200M uint64 keys
    point_queries: int = 1_000_000
    buffer_bytes: int = 128 * 2**20              # 128 MiB LRU default
    page_bytes: int = 4096
    eps_configurations: int = 9                  # averaged in Tables IV/V
    tuning_budgets_mb: Tuple[int, ...] = (64, 96, 128, 160)
    join_outer: int = 1_000_000
    join_inner: int = 200_000_000
    join_buffer_bytes: int = 16 * 2**20
    workloads: Tuple[str, ...] = ("w1", "w2", "w3", "w4", "w5", "w6")
    default_workload: str = "w4"
    # Table III fitted cost parameters (seconds)
    lambda_point: float = 1.19e-6
    lambda_range: float = 4.66e-7
    alpha: float = 1.64e-6
    beta: float = 1.72e-6
    eta: float = 4.42e-6
    delta: float = 5.00e-3

    def scale_factor(self, our_keys: int) -> float:
        return self.keys_per_dataset / our_keys


PAPER = PaperSetup()

# Our CPU-container defaults (benchmarks/common.py): 100x smaller keys,
# buffer scaled to keep buffer/data ratio in the paper's regime.
SCALED_DEFAULT = dataclasses.replace(
    PAPER,
    keys_per_dataset=2_000_000,
    point_queries=200_000,
    buffer_bytes=8 * 2**20,
    tuning_budgets_mb=(1, 2, 3, 4),
    join_outer=30_000,
    join_inner=4_000_000,
    join_buffer_bytes=2 * 2**20,
)
