"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import dataclasses

from repro.configs import (
    base,
    command_r_35b,
    llama3_405b,
    musicgen_medium,
    phi35_moe,
    qwen2_moe_a2_7b,
    qwen2_vl_7b,
    rwkv6_3b,
    starcoder2_3b,
    yi_34b,
    zamba2_2_7b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec

ARCHS = {
    "qwen2-vl-7b": qwen2_vl_7b.CONFIG,
    "yi-34b": yi_34b.CONFIG,
    "llama3-405b": llama3_405b.CONFIG,
    "command-r-35b": command_r_35b.CONFIG,
    "starcoder2-3b": starcoder2_3b.CONFIG,
    "rwkv6-3b": rwkv6_3b.CONFIG,
    "musicgen-medium": musicgen_medium.CONFIG,
    "zamba2-2.7b": zamba2_2_7b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi35_moe.CONFIG,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; one of {sorted(ARCHS)}") from None


def reduced(cfg: ModelConfig, seq_hint: int = 128) -> ModelConfig:
    """Family-preserving tiny config for CPU smoke tests."""
    kw = dict(
        num_layers=4 if cfg.family == "hybrid" else 2,
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        chunk_size=32,
    )
    if cfg.is_moe:
        kw.update(num_experts=4, experts_per_tok=2, moe_d_ff=64,
                  num_shared_experts=min(cfg.num_shared_experts, 1))
    if cfg.family in ("rwkv", "hybrid"):
        kw.update(ssm_head_dim=16, head_dim=16, num_heads=8, num_kv_heads=8,
                  ssm_state_dim=min(cfg.ssm_state_dim, 16) or 0)
    if cfg.family == "hybrid":
        kw.update(shared_attn_every=2)
    if cfg.family == "vlm":
        kw.update(num_vision_tokens=8, vision_patch_dim=48, mrope_sections=(4, 6, 6))
    return dataclasses.replace(cfg, **kw)


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "get_config", "reduced", "base"]
