"""Parameter templates: shapes + logical sharding roles per architecture family.

Every family builds a nested dict of ParamDef; from it we derive
 * ShapeDtypeStructs (dry-run inputs, no allocation),
 * PartitionSpecs / NamedShardings (via ShardingCtx),
 * initialized arrays (smoke tests, real training).

Layer-stacked leaves carry a leading L (or [G, K] for the Zamba2 hybrid) dim
so forward passes can lax.scan over depth.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamDef

__all__ = ["param_defs", "init_params", "param_shapes", "padded_experts"]


def padded_experts(num_experts: int) -> int:
    """Pad routed-expert count to a multiple of 16 for even EP sharding
    (mesh-independent so checkpoints stay portable)."""
    if num_experts >= 16 and num_experts % 16 != 0:
        return ((num_experts + 15) // 16) * 16
    return num_experts


def _attn_defs(cfg: ModelConfig, lead=()) -> Dict[str, ParamDef]:
    d = cfg.d_model
    qd = cfg.num_heads * cfg.head_dim
    kd = cfg.num_kv_heads * cfg.head_dim
    lead_dims = (None,) * len(lead)
    defs = {
        "ln1": ParamDef(lead + (d,), lead_dims + (None,), init="ones"),
        "wq": ParamDef(lead + (d, qd), lead_dims + ("fsdp", "tp")),
        "wk": ParamDef(lead + (d, kd), lead_dims + ("fsdp", "tp")),
        "wv": ParamDef(lead + (d, kd), lead_dims + ("fsdp", "tp")),
        "wo": ParamDef(lead + (qd, d), lead_dims + ("tp", "fsdp")),
    }
    if cfg.attention_bias:
        defs.update({
            "bq": ParamDef(lead + (qd,), lead_dims + ("tp",), init="zeros"),
            "bk": ParamDef(lead + (kd,), lead_dims + ("tp",), init="zeros"),
            "bv": ParamDef(lead + (kd,), lead_dims + ("tp",), init="zeros"),
            "bo": ParamDef(lead + (d,), lead_dims + (None,), init="zeros"),
        })
    return defs


def _mlp_defs(cfg: ModelConfig, lead=()) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    lead_dims = (None,) * len(lead)
    defs: Dict[str, ParamDef] = {
        "ln2": ParamDef(lead + (d,), lead_dims + (None,), init="ones"),
    }
    if cfg.is_moe:
        e = cfg.num_experts
        ep = padded_experts(e)
        fe = cfg.moe_d_ff or f
        defs["router"] = ParamDef(lead + (d, e), lead_dims + ("fsdp", None))
        defs["moe_gate"] = ParamDef(lead + (ep, d, fe), lead_dims + ("ep", "fsdp", None))
        defs["moe_up"] = ParamDef(lead + (ep, d, fe), lead_dims + ("ep", "fsdp", None))
        defs["moe_down"] = ParamDef(lead + (ep, fe, d), lead_dims + ("ep", None, "fsdp"))
        if cfg.num_shared_experts:
            fs = cfg.num_shared_experts * fe
            defs["sh_gate"] = ParamDef(lead + (d, fs), lead_dims + ("fsdp", "tp"))
            defs["sh_up"] = ParamDef(lead + (d, fs), lead_dims + ("fsdp", "tp"))
            defs["sh_down"] = ParamDef(lead + (fs, d), lead_dims + ("tp", "fsdp"))
    elif cfg.mlp_type == "swiglu":
        defs["w_gate"] = ParamDef(lead + (d, f), lead_dims + ("fsdp", "tp"))
        defs["w_up"] = ParamDef(lead + (d, f), lead_dims + ("fsdp", "tp"))
        defs["w_down"] = ParamDef(lead + (f, d), lead_dims + ("tp", "fsdp"))
    else:  # gelu
        defs["w_up"] = ParamDef(lead + (d, f), lead_dims + ("fsdp", "tp"))
        defs["w_down"] = ParamDef(lead + (f, d), lead_dims + ("tp", "fsdp"))
        if cfg.attention_bias:
            defs["b_up"] = ParamDef(lead + (f,), lead_dims + ("tp",), init="zeros")
            defs["b_down"] = ParamDef(lead + (d,), lead_dims + (None,), init="zeros")
    return defs


def _rwkv_block_defs(cfg: ModelConfig, lead=()) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    h, hd = cfg.num_heads, cfg.ssm_head_dim
    lead_dims = (None,) * len(lead)
    lora = 32
    return {
        "ln1": ParamDef(lead + (d,), lead_dims + (None,), init="ones"),
        "ln2": ParamDef(lead + (d,), lead_dims + (None,), init="ones"),
        "tm_mix": ParamDef(lead + (5, d), lead_dims + (None, None), init="zeros"),
        "tm_lora_a": ParamDef(lead + (d, 5 * lora), lead_dims + ("fsdp", None)),
        "tm_lora_b": ParamDef(lead + (5, lora, d), lead_dims + (None, None, "fsdp"),
                              init="zeros"),
        "w0": ParamDef(lead + (d,), lead_dims + (None,), init="zeros"),
        "decay_lora_a": ParamDef(lead + (d, 64), lead_dims + ("fsdp", None)),
        "decay_lora_b": ParamDef(lead + (64, d), lead_dims + (None, "fsdp"),
                                 init="zeros"),
        "bonus_u": ParamDef(lead + (h, hd), lead_dims + (None, None), init="zeros"),
        "wr": ParamDef(lead + (d, d), lead_dims + ("fsdp", "tp")),
        "wk": ParamDef(lead + (d, d), lead_dims + ("fsdp", "tp")),
        "wv": ParamDef(lead + (d, d), lead_dims + ("fsdp", "tp")),
        "wg": ParamDef(lead + (d, d), lead_dims + ("fsdp", "tp")),
        "w_att_out": ParamDef(lead + (d, d), lead_dims + ("tp", "fsdp")),
        "ln_x": ParamDef(lead + (d,), lead_dims + (None,), init="ones"),
        "cm_mix": ParamDef(lead + (2, d), lead_dims + (None, None), init="zeros"),
        "cm_k": ParamDef(lead + (d, f), lead_dims + ("fsdp", "tp")),
        "cm_v": ParamDef(lead + (f, d), lead_dims + ("tp", "fsdp")),
        "cm_r": ParamDef(lead + (d, d), lead_dims + ("fsdp", "tp")),
    }


def _mamba2_block_defs(cfg: ModelConfig, lead=()) -> Dict[str, ParamDef]:
    d = cfg.d_model
    din = cfg.expand * d
    n = cfg.ssm_state_dim
    h = din // cfg.ssm_head_dim
    w = cfg.conv_width
    lead_dims = (None,) * len(lead)
    return {
        "ln": ParamDef(lead + (d,), lead_dims + (None,), init="ones"),
        "wz": ParamDef(lead + (d, din), lead_dims + ("fsdp", "tp")),
        "wx": ParamDef(lead + (d, din), lead_dims + ("fsdp", "tp")),
        "wB": ParamDef(lead + (d, n), lead_dims + ("fsdp", None)),
        "wC": ParamDef(lead + (d, n), lead_dims + ("fsdp", None)),
        "wdt": ParamDef(lead + (d, h), lead_dims + ("fsdp", None)),
        "conv_w": ParamDef(lead + (w, din), lead_dims + (None, "tp")),
        "conv_b": ParamDef(lead + (din,), lead_dims + ("tp",), init="zeros"),
        "a_log": ParamDef(lead + (h,), lead_dims + (None,), init="zeros"),
        "d_skip": ParamDef(lead + (h,), lead_dims + (None,), init="ones"),
        "dt_bias": ParamDef(lead + (h,), lead_dims + (None,), init="zeros"),
        "gn": ParamDef(lead + (din,), lead_dims + ("tp",), init="ones"),
        "wo": ParamDef(lead + (din, d), lead_dims + ("tp", "fsdp")),
    }


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    l = cfg.num_layers
    defs: Dict[str, Any] = {"final_ln": ParamDef((d,), (None,), init="ones")}

    if cfg.family == "audio":
        c = cfg.num_codebooks
        defs["codebook_embed"] = ParamDef((c, v, d), (None, "tp", "fsdp"), scale=0.02)
        defs["lm_head"] = ParamDef((c, d, v), (None, "fsdp", "tp"))
    else:
        defs["embed"] = ParamDef((v, d), ("tp", "fsdp"), scale=0.02)
        defs["lm_head"] = ParamDef((d, v), ("fsdp", "tp"))
    if cfg.family == "vlm":
        defs["vision_proj"] = ParamDef((cfg.vision_patch_dim, d), (None, "fsdp"))

    if cfg.family == "rwkv":
        defs["blocks"] = _rwkv_block_defs(cfg, lead=(l,))
    elif cfg.family == "hybrid":
        g = l // cfg.shared_attn_every
        k = cfg.shared_attn_every
        defs["mamba"] = _mamba2_block_defs(cfg, lead=(g, k))
        defs["shared"] = {**_attn_defs(cfg), **_mlp_defs(cfg)}
    else:  # dense / moe / vlm / audio transformer
        defs["blocks"] = {**_attn_defs(cfg, lead=(l,)), **_mlp_defs(cfg, lead=(l,))}
    return defs


def param_shapes(cfg: ModelConfig, dtype=jnp.float32):
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, dtype),
        param_defs(cfg),
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    defs = param_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))

    def one(pd: ParamDef, k):
        if pd.init == "zeros":
            return jnp.zeros(pd.shape, dtype)
        if pd.init == "ones":
            return jnp.ones(pd.shape, dtype)
        scale = pd.scale if pd.scale > 0 else 1.0 / np.sqrt(max(pd.fan_in(), 1))
        return (jax.random.normal(k, pd.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [one(pd, k) for pd, k in zip(leaves, keys)])
