"""Mixture-of-Experts block: top-k routing, capacity-based scatter dispatch,
optional always-on shared experts (Qwen2-MoE style).

Dispatch is scatter/gather based (sort-free GShard variant): tokens are
scattered into a (E, capacity, D) buffer, experts run as one batched einsum,
and results gather back weighted by the router gates.  With experts sharded
over the ``model`` axis (EP), GSPMD lowers the scatter/gather into
all-to-all-style collectives.  Overflowing tokens are dropped (classic
capacity-factor semantics); the load-balancing auxiliary loss keeps the
router from abusing that.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["moe_block"]


def moe_block(
    x: jnp.ndarray,              # (B, S, D)
    router_w: jnp.ndarray,       # (D, E_logical)
    gate_w: jnp.ndarray,         # (E_pad, D, F)
    up_w: jnp.ndarray,           # (E_pad, D, F)
    down_w: jnp.ndarray,         # (E_pad, F, D)
    top_k: int,
    capacity_factor: float,
    ctx=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux load-balance loss scalar)."""
    b, s, d = x.shape
    e_logical = router_w.shape[-1]
    e_pad = gate_w.shape[0]
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf, router_w,
                        preferred_element_type=jnp.float32)   # (T, E_logical)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)                  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch): E * sum_e f_e * p_e.
    me = probs.mean(0)                                        # (E,)
    ce = jnp.zeros(e_logical).at[idx.reshape(-1)].add(1.0) / (t * top_k)
    aux = e_logical * jnp.sum(me * ce)

    capacity = int(max(1, -(-t * top_k * capacity_factor // e_pad)))

    flat_e = idx.reshape(-1)                                  # (T*k,) in [0, E)
    onehot = jax.nn.one_hot(flat_e, e_pad, dtype=jnp.int32)   # (T*k, E_pad)
    pos = jnp.cumsum(onehot, axis=0) - onehot                 # slot per token
    pos_flat = jnp.sum(pos * onehot, axis=-1)                 # (T*k,)
    keep = pos_flat < capacity
    pos_c = jnp.minimum(pos_flat, capacity - 1)

    xk = jnp.repeat(xf, top_k, axis=0)                        # (T*k, D)
    contrib = jnp.where(keep[:, None], xk, 0).astype(x.dtype)
    dispatch = jnp.zeros((e_pad, capacity, d), x.dtype)
    dispatch = dispatch.at[flat_e, pos_c].add(contrib)
    if ctx is not None:
        dispatch = ctx.constrain(dispatch, "heads", None, None)  # experts -> EP

    g = jnp.einsum("ecd,edf->ecf", dispatch, gate_w,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", dispatch, up_w,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    expert_out = jnp.einsum("ecf,efd->ecd", h, down_w,
                            preferred_element_type=jnp.float32).astype(x.dtype)

    gathered = expert_out[flat_e, pos_c]                      # (T*k, D)
    weighted = gathered * (gates.reshape(-1, 1) * keep[:, None]).astype(x.dtype)
    out = weighted.reshape(t, top_k, d).sum(axis=1)
    return out.reshape(b, s, d), aux.astype(jnp.float32)
