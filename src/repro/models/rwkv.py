"""RWKV6 (Finch): token shift, data-dependent decay via LoRA, matrix-valued
state — implemented in *chunked parallel* form so prefill/training cost shows
up as dense einsums (TPU-native), with a lax.scan only across chunks.

Recurrence (per head, head_dim hd):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: hd x hd)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Chunked evaluation keeps all decay ratios in log space; every exponent is
<= 0 because decays lie in (0, 1), so the chunk math is overflow-free.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

__all__ = ["rwkv_block", "rwkv_block_decode", "rwkv_logits", "rwkv_loss",
           "rwkv_decode", "init_rwkv_state"]


def _token_shift(x, prev):
    """x_{t-1} with ``prev`` filling slot -1 of the previous chunk/step."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(x, sx, tm_mix, lora_a, lora_b):
    """RWKV6 data-dependent interpolation for the 5 mix channels."""
    base = x + sx * tm_mix[0]
    ddd = jnp.tanh(jnp.einsum("btd,dr->btr", base, lora_a,
                              preferred_element_type=jnp.float32))
    ddd = ddd.reshape(*ddd.shape[:2], 5, -1)                # (B,T,5,rank)
    deltas = jnp.einsum("btfr,frd->btfd", ddd, lora_b,
                        preferred_element_type=jnp.float32).astype(x.dtype)
    return [x + sx * (tm_mix[f] + deltas[:, :, f]) for f in range(5)]


def _wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunked WKV: r,k,v,logw (B,T,H,hd); u (H,hd); state (B,H,hd,hd)."""
    b, t, h, hd = r.shape
    n_chunks = t // chunk
    f32 = jnp.float32
    rs = r.reshape(b, n_chunks, chunk, h, hd).astype(f32)
    ks = k.reshape(b, n_chunks, chunk, h, hd).astype(f32)
    vs = v.reshape(b, n_chunks, chunk, h, hd).astype(f32)
    lw = logw.reshape(b, n_chunks, chunk, h, hd).astype(f32)
    # move chunk axis first for scan
    rs, ks, vs, lw = (jnp.moveaxis(a, 1, 0) for a in (rs, ks, vs, lw))

    tri_lower = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)   # s < t

    def step(state, xs):
        rc, kc, vc, lwc = xs                                # (B,c,H,hd)
        cum = jnp.cumsum(lwc, axis=1)                       # inclusive
        cum_prev = cum - lwc                                # exclusive (t-1)
        # --- inter-chunk: contribution of the carried state
        r_dec = rc * jnp.exp(cum_prev)                      # (B,c,H,hd)
        o = jnp.einsum("bthd,bhde->bthe", r_dec, state)
        # --- intra-chunk pairs s < t (log-space decay ratios <= 0)
        att = jnp.einsum("bthd,bshd->bhts", r_dec, kc * jnp.exp(-cum))
        att = jnp.where(tri_lower[None, None], att, 0.0)
        # --- diagonal (bonus u)
        diag = jnp.einsum("bthd,bthd->bth", rc, kc * u)
        o = o + jnp.einsum("bhts,bshe->bthe", att, vc)
        o = o + diag[..., None] * vc
        # --- state update
        decay_all = jnp.exp(cum[:, -1])                     # (B,H,hd)
        kw = kc * jnp.exp(cum[:, -1:] - cum)                # (B,c,H,hd)
        state = state * decay_all[..., None]                # decay the k-dim
        state = state + jnp.einsum("bshd,bshe->bhde", kw, vc)
        return state, o

    state, o = jax.lax.scan(step, state.astype(f32), (rs, ks, vs, lw))
    o = jnp.moveaxis(o, 0, 1).reshape(b, t, h, hd)
    return o.astype(r.dtype), state


def rwkv_block(h, blk, cfg: ModelConfig, ctx,
               tm_prev=None, cm_prev=None, att_state=None):
    """Full-sequence RWKV block. Returns (h, (tm_last, cm_last, att_state))."""
    b, t, d = h.shape
    hh = cfg.num_heads
    hd = cfg.ssm_head_dim
    if tm_prev is None:
        tm_prev = jnp.zeros((b, d), h.dtype)
        cm_prev = jnp.zeros((b, d), h.dtype)
        att_state = jnp.zeros((b, hh, hd, hd), jnp.float32)

    # ---- time mix ----
    x = layers.rms_norm(h, blk["ln1"], cfg.norm_eps)
    sx = _token_shift(x, tm_prev) - x
    mr, mk, mv, mw, mg = _ddlerp(x, sx, blk["tm_mix"], blk["tm_lora_a"],
                                 blk["tm_lora_b"])
    r = jnp.einsum("btd,de->bte", mr, blk["wr"]).reshape(b, t, hh, hd)
    k = jnp.einsum("btd,de->bte", mk, blk["wk"]).reshape(b, t, hh, hd)
    v = jnp.einsum("btd,de->bte", mv, blk["wv"]).reshape(b, t, hh, hd)
    g = jnp.einsum("btd,de->bte", mg, blk["wg"])
    logw = -jnp.exp(
        blk["w0"]
        + jnp.einsum("btd,dr->btr", jnp.tanh(
            jnp.einsum("btd,dr->btr", mw, blk["decay_lora_a"])), blk["decay_lora_b"])
    ).reshape(b, t, hh, hd).astype(jnp.float32)
    u = blk["bonus_u"].astype(jnp.float32)
    o, att_state = _wkv_chunked(r, k, v, logw, u, att_state, min(cfg.chunk_size, t))
    # per-head normalization (GroupNorm stand-in) + gate
    o = o.reshape(b, t, d)
    o = layers.rms_norm(o, blk["ln_x"], cfg.norm_eps) * jax.nn.silu(g.astype(o.dtype))
    h = h + jnp.einsum("btd,de->bte", o, blk["w_att_out"]).astype(h.dtype)
    tm_last = x[:, -1]

    # ---- channel mix ----
    x2 = layers.rms_norm(h, blk["ln2"], cfg.norm_eps)
    sx2 = _token_shift(x2, cm_prev) - x2
    xk = x2 + sx2 * blk["cm_mix"][0]
    xr = x2 + sx2 * blk["cm_mix"][1]
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, blk["cm_k"],
                                           preferred_element_type=jnp.float32)))
    kv = jnp.einsum("btf,fd->btd", kk.astype(h.dtype), blk["cm_v"],
                    preferred_element_type=jnp.float32)
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, blk["cm_r"],
                                    preferred_element_type=jnp.float32))
    h = h + (out * kv).astype(h.dtype)
    cm_last = x2[:, -1]
    return h, (tm_last, cm_last, att_state)


def rwkv_block_decode(h, blk, cfg, ctx, tm_prev, cm_prev, att_state):
    """Single-token step using the O(1) recurrence directly."""
    b, d = h.shape[0], h.shape[-1]
    hh, hd = cfg.num_heads, cfg.ssm_head_dim
    x = layers.rms_norm(h, blk["ln1"], cfg.norm_eps)         # (B,1,D)
    sx = tm_prev[:, None] - x
    mr, mk, mv, mw, mg = _ddlerp(x, sx, blk["tm_mix"], blk["tm_lora_a"],
                                 blk["tm_lora_b"])
    r = jnp.einsum("btd,de->bte", mr, blk["wr"]).reshape(b, hh, hd)
    k = jnp.einsum("btd,de->bte", mk, blk["wk"]).reshape(b, hh, hd)
    v = jnp.einsum("btd,de->bte", mv, blk["wv"]).reshape(b, hh, hd)
    g = jnp.einsum("btd,de->bte", mg, blk["wg"])[:, 0]
    w = jnp.exp(-jnp.exp(
        blk["w0"] + jnp.einsum("btd,dr->btr", jnp.tanh(
            jnp.einsum("btd,dr->btr", mw, blk["decay_lora_a"])),
            blk["decay_lora_b"])
    )).reshape(b, hh, hd).astype(jnp.float32)
    u = blk["bonus_u"].astype(jnp.float32)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    o = jnp.einsum("bhd,bhde->bhe", rf, att_state) \
        + jnp.einsum("bhd,bhd,bhe->bhe", rf, kf * u, vf)
    att_state = att_state * w[..., None] + jnp.einsum("bhd,bhe->bhde", kf, vf)
    o = o.reshape(b, 1, d).astype(h.dtype)
    o = layers.rms_norm(o, blk["ln_x"], cfg.norm_eps) * jax.nn.silu(g[:, None])
    h = h + jnp.einsum("btd,de->bte", o, blk["w_att_out"]).astype(h.dtype)
    tm_last = x[:, 0]

    x2 = layers.rms_norm(h, blk["ln2"], cfg.norm_eps)
    sx2 = cm_prev[:, None] - x2
    xk = x2 + sx2 * blk["cm_mix"][0]
    xr = x2 + sx2 * blk["cm_mix"][1]
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, blk["cm_k"],
                                           preferred_element_type=jnp.float32)))
    kv = jnp.einsum("btf,fd->btd", kk.astype(h.dtype), blk["cm_v"],
                    preferred_element_type=jnp.float32)
    gate = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, blk["cm_r"],
                                     preferred_element_type=jnp.float32))
    h = h + (gate * kv).astype(h.dtype)
    return h, (tm_last, x2[:, 0], att_state)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def _embed(params, cfg, tokens, ctx):
    h = layers.take_embedding(params["embed"], tokens, ctx)
    h = h.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else h.dtype)
    return ctx.constrain(h, "batch", "seq", "act_embed")


def rwkv_logits(params, cfg: ModelConfig, batch, ctx, remat: str = "none"):
    h = _embed(params, cfg, batch["tokens"], ctx)

    def body(hh, blk):
        hh, _ = rwkv_block(hh, blk, cfg, ctx)
        return hh, None

    from repro.models.transformer import scan_blocks

    (h), _ = scan_blocks(lambda c, b_: body(c, b_), h, params["blocks"], remat)
    h = layers.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return ctx.constrain(logits, "batch", "seq", "heads")


def rwkv_loss(params, cfg, batch, ctx):
    tokens = batch["tokens"]
    logits = rwkv_logits(params, cfg, dict(batch, tokens=tokens[:, :-1]), ctx,
                         remat=ctx.recipe.remat).astype(jnp.float32)
    targets = tokens[:, 1:]
    return layers.softmax_xent(logits, targets, ctx)


def init_rwkv_state(cfg: ModelConfig, batch_size: int, dtype=jnp.bfloat16):
    l, d = cfg.num_layers, cfg.d_model
    h, hd = cfg.num_heads, cfg.ssm_head_dim
    return {
        "att": jax.ShapeDtypeStruct((l, batch_size, h, hd, hd), jnp.float32),
        "tm": jax.ShapeDtypeStruct((l, batch_size, d), dtype),
        "cm": jax.ShapeDtypeStruct((l, batch_size, d), dtype),
    }


def rwkv_decode(params, cfg: ModelConfig, batch, state, ctx):
    """One decode step with O(1) state; no KV cache — long_500k runs here."""
    h = _embed(params, cfg, batch["tokens"], ctx)            # (B,1,D)

    def body(hh, xs):
        blk, tm, cm, att = xs
        hh, (tm2, cm2, att2) = rwkv_block_decode(hh, blk, cfg, ctx, tm, cm, att)
        return hh, (tm2, cm2, att2)

    h, (tm, cm, att) = jax.lax.scan(
        body, h, (params["blocks"], state["tm"], state["cm"], state["att"]))
    h = layers.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits[:, -1], {"att": att, "tm": tm, "cm": cm}
