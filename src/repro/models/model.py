"""Unified model API: family dispatch for loss / prefill / decode, plus input
and cache ShapeDtypeStruct builders used by the dry-run and launchers."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import ShardingCtx
from repro.models import mamba2, params as params_mod, rwkv, transformer

__all__ = ["loss_fn", "prefill_fn", "decode_fn", "input_specs", "cache_specs",
           "input_dims", "cache_dims"]


# ---------------------------------------------------------------------------
# Forward dispatch
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch, ctx: ShardingCtx):
    if cfg.family == "rwkv":
        return rwkv.rwkv_loss(params, cfg, batch, ctx)
    if cfg.family == "hybrid":
        return mamba2.hybrid_loss(params, cfg, batch, ctx)
    return transformer.transformer_loss(params, cfg, batch, ctx)


def prefill_fn(params, cfg: ModelConfig, batch, ctx: ShardingCtx):
    if cfg.family == "rwkv":
        return rwkv_prefill(params, cfg, batch, ctx)
    if cfg.family == "hybrid":
        return hybrid_prefill(params, cfg, batch, ctx)
    return transformer.transformer_prefill(params, cfg, batch, ctx)


def decode_fn(params, cfg: ModelConfig, batch, cache, ctx: ShardingCtx):
    if cfg.family == "rwkv":
        return rwkv.rwkv_decode(params, cfg, batch, cache, ctx)
    if cfg.family == "hybrid":
        return mamba2.hybrid_decode(params, cfg, batch, cache, ctx)
    return transformer.transformer_decode(params, cfg, batch, cache, ctx)


# ---------------------------------------------------------------------------
# Prefill variants for the recurrent families (last logits + running state)
# ---------------------------------------------------------------------------

def rwkv_prefill(params, cfg, batch, ctx):
    h = rwkv._embed(params, cfg, batch["tokens"], ctx)

    def body(hh, blk):
        hh, (tm, cm, att) = rwkv.rwkv_block(hh, blk, cfg, ctx)
        return hh, (tm, cm, att)

    h, (tm, cm, att) = jax.lax.scan(body, h, params["blocks"])
    from repro.models import layers

    h = layers.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"att": att, "tm": tm, "cm": cm}


def hybrid_prefill(params, cfg, batch, ctx):
    from repro.models import attention as attn_mod
    from repro.models import layers
    from repro.models.transformer import _mlp, _project_qkv, _apply_rope

    tokens = batch["tokens"]
    b, t = tokens.shape
    h = layers.take_embedding(params["embed"], tokens)
    h = h.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else h.dtype)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    shared = params["shared"]

    def group(hh, gblk):
        def inner(hc, blk):
            hc, (conv, ssm) = mamba2.mamba2_block(hc, blk, cfg, ctx)
            return hc, (conv, ssm)

        hh, (conv, ssm) = jax.lax.scan(inner, hh, gblk)
        x = layers.rms_norm(hh, shared["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(x, shared, cfg, ctx)
        q, k = _apply_rope(q, k, cfg, positions, None)
        out = attn_mod.attention(q, k, v, impl=ctx.recipe.attn_impl,
                                 block_kv=ctx.recipe.block_kv)
        out = jnp.einsum("bsq,qd->bsd", out.reshape(b, t, -1), shared["wo"],
                         preferred_element_type=jnp.float32)
        hh = hh + out.astype(hh.dtype)
        x2 = layers.rms_norm(hh, shared["ln2"], cfg.norm_eps)
        y, _ = _mlp(x2, shared, cfg, ctx)
        hh = hh + y.astype(hh.dtype)
        return hh, (conv, ssm, k, v)

    h, (conv, ssm, kc, vc) = jax.lax.scan(group, h, params["mamba"])
    h = layers.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"conv": conv, "ssm": ssm, "k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Input / cache specs (ShapeDtypeStructs + logical dims) per (cfg, shape)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    tok_dtype = jnp.int32
    if shape.kind == "train":
        toks = (b, s + 1, cfg.num_codebooks) if cfg.family == "audio" else (b, s + 1)
        batch = {"tokens": jax.ShapeDtypeStruct(toks, tok_dtype)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_vision_tokens, cfg.vision_patch_dim), jnp.float32)
            batch["positions_3d"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        return batch
    if shape.kind == "prefill":
        toks = (b, s, cfg.num_codebooks) if cfg.family == "audio" else (b, s)
        batch = {"tokens": jax.ShapeDtypeStruct(toks, tok_dtype)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_vision_tokens, cfg.vision_patch_dim), jnp.float32)
            batch["positions_3d"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        return batch
    # decode: one new token against a seq_len-deep context
    toks = (b, 1, cfg.num_codebooks) if cfg.family == "audio" else (b, 1)
    return {"tokens": jax.ShapeDtypeStruct(toks, tok_dtype),
            "lengths": jax.ShapeDtypeStruct((b,), jnp.int32)}


def input_dims(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Tuple]:
    """Logical sharding roles matching input_specs."""
    dims: Dict[str, Tuple] = {}
    if cfg.family == "audio":
        dims["tokens"] = ("batch", None, None)
    else:
        dims["tokens"] = ("batch", None)
    if shape.kind != "decode" and cfg.family == "vlm":
        dims["vision_embeds"] = ("batch", None, None)
        dims["positions_3d"] = (None, "batch", None)
    if shape.kind == "decode":
        dims["lengths"] = ("batch",)
    return dims


def cache_specs(cfg: ModelConfig, shape: ShapeSpec,
                kv_dtype=jnp.bfloat16) -> Optional[Dict[str, Any]]:
    if shape.kind != "decode":
        return None
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "rwkv":
        return rwkv.init_rwkv_state(cfg, b)
    if cfg.family == "hybrid":
        return mamba2.init_hybrid_state(cfg, b, s)
    return transformer.init_kv_cache(cfg, b, s, kv_dtype)


def cache_dims(cfg: ModelConfig) -> Dict[str, Tuple]:
    if cfg.family == "rwkv":
        return {"att": (None, "kv_batch", "heads", None, None),
                "tm": (None, "kv_batch", None),
                "cm": (None, "kv_batch", None)}
    if cfg.family == "hybrid":
        return {"conv": (None, None, "kv_batch", None, "heads"),
                "ssm": (None, None, "kv_batch", "heads", None, None),
                "k": (None, "kv_batch", "kv_seq", None, None),
                "v": (None, "kv_batch", "kv_seq", None, None)}
    return {"k": (None, "kv_batch", "kv_seq", None, None),
            "v": (None, "kv_batch", "kv_seq", None, None),
            "k_scale": (None, "kv_batch", "kv_seq", None),
            "v_scale": (None, "kv_batch", "kv_seq", None)}
