"""Attention implementations.

* ``blockwise_attention`` — flash-style streaming softmax over KV blocks,
  expressed in lax.scan so XLA never materializes the (S x S) score matrix.
  This is the default for training and 32k prefill; it is the same tiling the
  Pallas TPU kernel (kernels/flash_attention.py) uses, which replaces it on
  real hardware via ``impl="pallas"``.
* ``dense_attention``  — einsum attention with explicit causal mask (oracle
  for tests; acceptable for short sequences).
* ``decode_attention`` — single-step GQA over a static KV cache with length
  masking (one einsum pair; flash-decode split-K arrives via the cache's
  kv_seq sharding, which turns the softmax reductions into cross-device
  collectives handled by GSPMD).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["dense_attention", "blockwise_attention", "decode_attention"]

_NEG_INF = -1e30


def _group_heads(q, num_kv_heads):
    b, s, h, d = q.shape
    g = h // num_kv_heads
    return q.reshape(b, s, num_kv_heads, g, d)


def dense_attention(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q: (B,Sq,H,D), k/v: (B,Skv,Hk,D).  Test oracle / short sequences."""
    b, sq, h, d = q.shape
    hk = k.shape[2]
    qg = _group_heads(q, hk)                                   # (B,Sq,Hk,G,D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                        block_kv: int = 1024):
    """Streaming-softmax attention, scanning KV blocks with an (m, l, acc)
    carry — O(Sq * block_kv) live memory instead of O(Sq * Skv)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    hk = k.shape[2]
    g = h // hk
    block_kv = min(block_kv, skv)
    n_blocks = -(-skv // block_kv)
    pad = n_blocks * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blocks, block_kv, hk, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, block_kv, hk, d).transpose(1, 0, 2, 3, 4)

    qg = _group_heads(q, hk).astype(jnp.float32)               # (B,Sq,Hk,G,D)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(sq) + q_offset

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kblk, vblk, start = blk
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kblk.astype(jnp.float32),
                            preferred_element_type=jnp.float32) * scale
        kpos = start + jnp.arange(block_kv)
        valid = kpos < skv
        if causal:
            mask = (qpos[:, None] >= kpos[None, :]) & valid[None, :]
        else:
            mask = jnp.broadcast_to(valid[None, :], (sq, block_kv))
        scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
        m_blk = jnp.max(scores, axis=-1)                       # (B,Hk,G,Sq)
        m_new = jnp.maximum(m_prev, m_blk)
        correction = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_prev * correction + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p, vblk.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc = acc * correction[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hk, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hk, g, sq, d), jnp.float32)
    starts = jnp.arange(n_blocks) * block_kv
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)               # (B,Hk,G,Sq,D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths):
    """One-token GQA decode against a static cache.

    q: (B,1,H,D); caches: (B,Smax,Hk,D); lengths: (B,) valid prefix lengths.
    """
    b, _, h, d = q.shape
    hk = k_cache.shape[2]
    qg = _group_heads(q, hk)[:, 0]                             # (B,Hk,G,D)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    kpos = jnp.arange(k_cache.shape[1])
    mask = kpos[None, :] < lengths[:, None]                    # (B,Smax)
    scores = jnp.where(mask[:, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attention(q, k, v, *, impl: str = "blockwise", causal: bool = True,
              q_offset: int = 0, block_kv: int = 1024):
    if impl == "dense" or q.shape[1] <= 256:
        return dense_attention(q, k, v, causal=causal, q_offset=q_offset)
    if impl == "pallas":  # TPU fast path; falls back off-TPU
        try:
            from repro.kernels import ops as kernel_ops

            return kernel_ops.flash_attention(q, k, v, causal=causal)
        except Exception:
            pass
    return blockwise_attention(q, k, v, causal=causal, q_offset=q_offset,
                               block_kv=block_kv)
