"""Mamba2 (SSD) blocks + the Zamba2 hybrid (Mamba2 backbone with a SHARED
attention+MLP block applied every ``shared_attn_every`` layers).

The selective state space runs in chunked form: scalar-per-head decays in log
space, intra-chunk pairs as dense (C x C) einsums, inter-chunk state carried
by a lax.scan — same structure as the RWKV6 chunked WKV, with state
(B, H, head_dim, N).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers

__all__ = ["mamba2_block", "mamba2_block_decode", "hybrid_loss",
           "hybrid_logits", "hybrid_decode", "init_hybrid_state"]


def _causal_conv(x, conv_w, conv_b, prev=None):
    """Depthwise causal conv1d: x (B,T,C), conv_w (W,C).

    ``prev`` (B,W-1,C) carries state across steps for decode.
    Returns (out (B,T,C), new_prev)."""
    w = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)                 # (B, T+W-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * conv_w[i] for i in range(w))
    new_prev = xp[:, -(w - 1):] if w > 1 else prev
    return out + conv_b, new_prev


def _ssd_chunked(xh, b_in, c_in, dt, loga, state, chunk: int):
    """Chunked selective scan.

    xh: (B,T,H,P) inputs per head; b_in/c_in: (B,T,N); dt: (B,T,H);
    loga: (B,T,H) log decays (<= 0); state: (B,H,P,N).
    Returns (y (B,T,H,P), state_out)."""
    bsz, t, h, p = xh.shape
    n = b_in.shape[-1]
    nc = t // chunk
    f32 = jnp.float32
    xs = jnp.moveaxis(xh.reshape(bsz, nc, chunk, h, p), 1, 0).astype(f32)
    bs = jnp.moveaxis(b_in.reshape(bsz, nc, chunk, n), 1, 0).astype(f32)
    cs = jnp.moveaxis(c_in.reshape(bsz, nc, chunk, n), 1, 0).astype(f32)
    dts = jnp.moveaxis(dt.reshape(bsz, nc, chunk, h), 1, 0).astype(f32)
    las = jnp.moveaxis(loga.reshape(bsz, nc, chunk, h), 1, 0).astype(f32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))          # s <= t

    def step(carry, xs_):
        st = carry                                          # (B,H,P,N)
        xc, bc, cc, dtc, lac = xs_
        cum = jnp.cumsum(lac, axis=1)                       # (B,c,H) inclusive
        # inter-chunk: y_state[t] = exp(cum_t) * C_t . state
        y = jnp.einsum("bsn,bhpn->bshp", cc, st) * jnp.exp(cum)[..., None]
        # intra-chunk: pairs s <= t
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,t,s,H)
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("btn,bsn->bts", cc, bc)             # (B,t,s)
        att = cb[..., None] * decay * dtc[:, None]          # (B,t,s,H)
        y = y + jnp.einsum("btsh,bshp->bthp", att, xc)
        # state update
        w_end = jnp.exp(cum[:, -1][:, None] - cum)          # (B,c,H)
        st = st * jnp.exp(cum[:, -1])[..., None, None]
        st = st + jnp.einsum("bsh,bshp,bsn->bhpn", w_end * dtc, xc, bc)
        return st, y

    state, y = jax.lax.scan(step, state.astype(f32), (xs, bs, cs, dts, las))
    y = jnp.moveaxis(y, 0, 1).reshape(bsz, t, h, p)
    return y.astype(xh.dtype), state


def mamba2_block(h, blk, cfg: ModelConfig, ctx, conv_prev=None, ssm_prev=None):
    """Full-sequence Mamba2 block. Returns (h, (conv_state, ssm_state))."""
    bsz, t, d = h.shape
    din = cfg.expand * d
    nheads = din // cfg.ssm_head_dim
    p = cfg.ssm_head_dim
    n = cfg.ssm_state_dim

    x = layers.rms_norm(h, blk["ln"], cfg.norm_eps)
    z = jnp.einsum("btd,de->bte", x, blk["wz"], preferred_element_type=jnp.float32)
    xin = jnp.einsum("btd,de->bte", x, blk["wx"],
                     preferred_element_type=jnp.float32).astype(h.dtype)
    xin, conv_state = _causal_conv(xin, blk["conv_w"], blk["conv_b"], conv_prev)
    xin = jax.nn.silu(xin)
    xin = ctx.constrain(xin, "batch", None, "heads")
    b_in = jnp.einsum("btd,dn->btn", x, blk["wB"], preferred_element_type=jnp.float32)
    c_in = jnp.einsum("btd,dn->btn", x, blk["wC"], preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, blk["wdt"],
                   preferred_element_type=jnp.float32) + blk["dt_bias"])
    loga = -jnp.exp(blk["a_log"]) * dt                      # (B,T,H), <= 0

    if ssm_prev is None:
        ssm_prev = jnp.zeros((bsz, nheads, p, n), jnp.float32)
    xh = xin.reshape(bsz, t, nheads, p)
    y, ssm_state = _ssd_chunked(xh, b_in, c_in, dt, loga, ssm_prev,
                                min(cfg.chunk_size, t))
    y = y + blk["d_skip"][None, None, :, None] * xh
    y = y.reshape(bsz, t, din)
    y = layers.rms_norm(y * jax.nn.silu(z).astype(y.dtype), blk["gn"], cfg.norm_eps)
    h = h + jnp.einsum("bte,ed->btd", y, blk["wo"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
    return h, (conv_state, ssm_state)


def mamba2_block_decode(h, blk, cfg, ctx, conv_prev, ssm_prev):
    """Single-token Mamba2 step (O(1) state update)."""
    bsz, _, d = h.shape
    din = cfg.expand * d
    nheads = din // cfg.ssm_head_dim
    p = cfg.ssm_head_dim

    x = layers.rms_norm(h, blk["ln"], cfg.norm_eps)
    z = jnp.einsum("btd,de->bte", x, blk["wz"], preferred_element_type=jnp.float32)
    xin = jnp.einsum("btd,de->bte", x, blk["wx"],
                     preferred_element_type=jnp.float32).astype(h.dtype)
    xin, conv_state = _causal_conv(xin, blk["conv_w"], blk["conv_b"], conv_prev)
    xin = jax.nn.silu(xin)
    b_in = jnp.einsum("btd,dn->btn", x, blk["wB"], preferred_element_type=jnp.float32)
    c_in = jnp.einsum("btd,dn->btn", x, blk["wC"], preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, blk["wdt"],
                   preferred_element_type=jnp.float32) + blk["dt_bias"])
    a = jnp.exp(-jnp.exp(blk["a_log"]) * dt)[:, 0]          # (B,H)

    xh = xin.reshape(bsz, nheads, p).astype(jnp.float32)
    ssm_state = ssm_prev * a[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt[:, 0], xh, b_in[:, 0].astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", c_in[:, 0].astype(jnp.float32), ssm_state)
    y = y + blk["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, din).astype(h.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z).astype(y.dtype), blk["gn"], cfg.norm_eps)
    h = h + jnp.einsum("bte,ed->btd", y, blk["wo"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
    return h, (conv_state, ssm_state)


# ---------------------------------------------------------------------------
# Zamba2 hybrid model
# ---------------------------------------------------------------------------

def _shared_attn_block(h, shared, cfg, ctx, positions, impl):
    from repro.models.transformer import make_block_fn

    block = make_block_fn(cfg, ctx, positions, impl=impl)
    (h, _), _ = block((h, jnp.zeros((), jnp.float32)), shared)
    return h


def hybrid_logits(params, cfg: ModelConfig, batch, ctx, remat: str = "none"):
    tokens = batch["tokens"]
    b, t = tokens.shape
    h = layers.take_embedding(params["embed"], tokens, ctx)
    h = h.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else h.dtype)
    h = ctx.constrain(h, "batch", "seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    shared = params["shared"]
    impl = ctx.recipe.attn_impl

    def group(hh, gblk):
        def inner(hc, blk):
            hc, _ = mamba2_block(hc, blk, cfg, ctx)
            return hc, None

        hh, _ = jax.lax.scan(inner, hh, gblk)
        hh = _shared_attn_block(hh, shared, cfg, ctx, positions, impl)
        return hh, None

    grp = jax.checkpoint(group) if remat != "none" else group
    h, _ = jax.lax.scan(grp, h, params["mamba"])
    h = layers.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return ctx.constrain(logits, "batch", "seq", "heads")


def hybrid_loss(params, cfg, batch, ctx):
    tokens = batch["tokens"]
    logits = hybrid_logits(params, cfg, dict(batch, tokens=tokens[:, :-1]), ctx,
                           remat=ctx.recipe.remat).astype(jnp.float32)
    targets = tokens[:, 1:]
    return layers.softmax_xent(logits, targets, ctx)


def init_hybrid_state(cfg: ModelConfig, batch_size: int, max_seq: int,
                      dtype=jnp.bfloat16):
    g = cfg.num_layers // cfg.shared_attn_every
    k = cfg.shared_attn_every
    din = cfg.expand * cfg.d_model
    nheads = din // cfg.ssm_head_dim
    return {
        "conv": jax.ShapeDtypeStruct(
            (g, k, batch_size, cfg.conv_width - 1, din), dtype),
        "ssm": jax.ShapeDtypeStruct(
            (g, k, batch_size, nheads, cfg.ssm_head_dim, cfg.ssm_state_dim),
            jnp.float32),
        # shared attention block's KV cache, one per group invocation
        "k": jax.ShapeDtypeStruct(
            (g, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jax.ShapeDtypeStruct(
            (g, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def hybrid_decode(params, cfg: ModelConfig, batch, state, ctx):
    """One decode step: Mamba states are O(1); the shared attention block
    keeps one KV cache per group invocation."""
    lengths = batch["lengths"]
    tokens = batch["tokens"]                                 # (B,1)
    b = tokens.shape[0]
    h = layers.take_embedding(params["embed"], tokens, ctx)
    h = h.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else h.dtype)
    pos = lengths[:, None].astype(jnp.int32)
    shared = params["shared"]
    bidx = jnp.arange(b)

    def group(hh, xs):
        gblk, conv_g, ssm_g, k_g, v_g = xs

        def inner(carry, xs_inner):
            hc = carry
            blk, cp, sp = xs_inner
            hc, (cp2, sp2) = mamba2_block_decode(hc, blk, cfg, ctx, cp, sp)
            return hc, (cp2, sp2)

        hh, (conv2, ssm2) = jax.lax.scan(inner, hh, (gblk, conv_g, ssm_g))
        # shared attention with cache
        x = layers.rms_norm(hh, shared["ln1"], cfg.norm_eps)
        from repro.models.transformer import _mlp, _project_qkv

        q, k, v = _project_qkv(x, shared, cfg, ctx)
        q = layers.rope(q, pos, cfg.rope_theta)
        k = layers.rope(k, pos, cfg.rope_theta)
        k_g = k_g.at[bidx, lengths].set(k[:, 0])
        v_g = v_g.at[bidx, lengths].set(v[:, 0])
        out = attn_mod.decode_attention(q, k_g, v_g, lengths + 1)
        out = jnp.einsum("bsq,qd->bsd", out.reshape(b, 1, -1), shared["wo"],
                         preferred_element_type=jnp.float32)
        hh = hh + out.astype(hh.dtype)
        x2 = layers.rms_norm(hh, shared["ln2"], cfg.norm_eps)
        y, _ = _mlp(x2, shared, cfg, ctx)
        hh = hh + y.astype(hh.dtype)
        return hh, (conv2, ssm2, k_g, v_g)

    h, (conv, ssm, kc, vc) = jax.lax.scan(
        group, h, (params["mamba"], state["conv"], state["ssm"],
                   state["k"], state["v"]))
    h = layers.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits[:, -1], {"conv": conv, "ssm": ssm, "k": kc, "v": vc}
