"""Shared neural layers: RMSNorm, MLPs, RoPE / M-RoPE, embeddings.

Pure-functional JAX; weights come in as dict leaves, sharding via the
ShardingCtx activation constraints.  Matmuls accumulate in f32
(``preferred_element_type``) regardless of the bf16 compute dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "swiglu", "gelu_mlp", "rope", "mrope", "take_embedding"]


def rms_norm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down, ctx=None):
    g = jnp.einsum("...d,df->...f", x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("...d,df->...f", x, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    if ctx is not None:
        h = ctx.constrain(h, "batch", None, "heads")
    return jnp.einsum("...f,fd->...d", h, w_down,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def gelu_mlp(x, w_up, w_down, b_up=None, b_down=None, ctx=None):
    u = jnp.einsum("...d,df->...f", x, w_up, preferred_element_type=jnp.float32)
    if b_up is not None:
        u = u + b_up
    h = jax.nn.gelu(u).astype(x.dtype)
    if ctx is not None:
        h = ctx.constrain(h, "batch", None, "heads")
    out = jnp.einsum("...f,fd->...d", h, w_down, preferred_element_type=jnp.float32)
    if b_down is not None:
        out = out + b_down
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _apply_rot(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope(x, positions, theta: float = 1e6):
    """x: (B, S, H, D); positions: (B, S) int32 absolute positions."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def mrope(x, positions_3d, sections, theta: float = 1e6):
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions_3d: (3, B, S) — (temporal, height, width) ids.
    ``sections`` split the D/2 frequency slots among the three id channels.
    """
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                       # (D/2,)
    assert sum(sections) == d // 2, (sections, d)
    # per-frequency channel selector: first sections[0] freqs use temporal ids...
    channel = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )                                                   # (D/2,)
    pos = positions_3d.astype(jnp.float32)[channel]     # (D/2, B, S)
    ang = jnp.moveaxis(pos, 0, -1) * freqs              # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def take_embedding(table, tokens, ctx=None):
    """Token embedding lookup; table (V, D) possibly vocab-sharded.

    Under a mesh, a gather over the sharded vocab dim makes GSPMD replicate
    the whole table ("involuntary full rematerialization"); the one-hot
    matmul keeps the contraction sharded over V (a partial-sum all-reduce of
    the small (B,S,D) output instead of an all-gather of the huge table).
    """
    if ctx is None or ctx.mesh is None:
        return jnp.take(table, tokens, axis=0)
    onehot = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
    onehot = ctx.constrain(onehot, "batch", *([None] * (tokens.ndim - 1)), "heads")
    out = jnp.einsum("...v,vd->...d", onehot, table,
                     preferred_element_type=jnp.float32).astype(table.dtype)
    return ctx.constrain(out, "batch", *([None] * (out.ndim - 2)), None)


def softmax_xent(logits, targets, ctx=None):
    """Mean next-token CE over possibly vocab-sharded logits.

    Under a mesh, gathering the target logit (take_along_axis) over the
    sharded vocab dim would force resharding; the one-hot contraction stays
    elementwise-sharded instead.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    if ctx is None or ctx.mesh is None:
        ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    else:
        onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.bfloat16)
        dims = ("batch",) + (None,) * (targets.ndim - 1) + ("heads",)
        onehot = ctx.constrain(onehot, *dims)
        ll = jnp.einsum("...v,...v->...", logits, onehot,
                        preferred_element_type=jnp.float32)
    return jnp.mean(lse - ll)
