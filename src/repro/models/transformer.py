"""Decoder-only transformer forward passes (dense / MoE / VLM / audio).

One block function serves train, prefill, and decode; depth is always a
lax.scan over layer-stacked params (compile time stays flat in num_layers),
with remat policies:

  * "none"   — store everything (inference / tiny models)
  * "block"  — checkpoint each layer (classic)
  * "nested" — two-level sqrt(L) grouping: outer scan saves only group
               boundaries, inner layers recompute (126-layer models at 32k
               would otherwise need tens of GB of residual checkpoints).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers, moe
from repro.models.params import padded_experts

__all__ = ["embed_inputs", "transformer_logits", "transformer_loss",
           "transformer_prefill", "transformer_decode", "scan_blocks",
           "init_kv_cache"]


# ---------------------------------------------------------------------------
# Depth scan with remat
# ---------------------------------------------------------------------------

def _nested_factors(num_layers: int) -> Tuple[int, int]:
    """Largest divisor of L that is <= sqrt(L) (outer group count)."""
    g = max(d for d in range(1, int(math.isqrt(num_layers)) + 1)
            if num_layers % d == 0)
    return g, num_layers // g


def scan_blocks(body, carry, stacked, remat: str = "block"):
    """Scan ``body(carry, blk)->(carry, ys)`` over layer-stacked params."""
    if remat == "none":
        return jax.lax.scan(body, carry, stacked)
    if remat == "block":
        return jax.lax.scan(jax.checkpoint(body), carry, stacked)
    if remat == "nested":
        num_layers = jax.tree.leaves(stacked)[0].shape[0]
        g, k = _nested_factors(num_layers)
        regrouped = jax.tree.map(
            lambda x: x.reshape((g, k) + x.shape[1:]), stacked)

        # Two-level remat: the outer checkpoint drops everything inside a
        # group (stores only G group-boundary carries); the inner checkpoint
        # makes the group's recompute-backward store only K layer-boundary
        # carries instead of every internal activation of K layers at once.
        inner_body = jax.checkpoint(body)

        def outer(c, gblk):
            return jax.lax.scan(inner_body, c, gblk)

        carry, ys = jax.lax.scan(jax.checkpoint(outer), carry, regrouped)
        ys = jax.tree.map(
            lambda y: y.reshape((num_layers,) + y.shape[2:]) if y is not None else y,
            ys)
        return carry, ys
    raise ValueError(f"unknown remat mode {remat!r}")


# ---------------------------------------------------------------------------
# Input embedding (token / VLM-merge / audio codebooks)
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, batch: Dict[str, Any], ctx):
    if cfg.family == "audio":
        toks = batch["tokens"]                              # (B, S, C)
        embeds = [layers.take_embedding(params["codebook_embed"][c],
                                        toks[..., c], ctx)
                  for c in range(cfg.num_codebooks)]
        h = sum(embeds)
    else:
        h = layers.take_embedding(params["embed"], batch["tokens"], ctx)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = jnp.einsum("bnp,pd->bnd", batch["vision_embeds"],
                         params["vision_proj"],
                         preferred_element_type=jnp.float32).astype(h.dtype)
        # frontend stub: vision tokens occupy the first num_vision_tokens slots
        nv = vis.shape[1]
        h = jnp.concatenate([vis, h[:, nv:]], axis=1)
    h = h.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else h.dtype)
    return ctx.constrain(h, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# One transformer block (attention + MLP/MoE)
# ---------------------------------------------------------------------------

def _project_qkv(x, blk, cfg, ctx):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, blk["wq"], preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dq->bsq", x, blk["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dq->bsq", x, blk["wv"], preferred_element_type=jnp.float32)
    if cfg.attention_bias and "bq" in blk:
        q, k, v = q + blk["bq"], k + blk["bk"], v + blk["bv"]
    q = q.astype(x.dtype).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.astype(x.dtype).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.astype(x.dtype).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    q = ctx.constrain(q, "batch", None, "heads", None)
    k = ctx.constrain(k, "batch", None, "heads", None)
    return q, k, v


def _apply_rope(q, k, cfg, positions, pos3d):
    if cfg.mrope and pos3d is not None:
        q = layers.mrope(q, pos3d, cfg.mrope_sections, cfg.rope_theta)
        k = layers.mrope(k, pos3d, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
    return q, k


def _mlp(x, blk, cfg, ctx):
    """Dense MLP or MoE sublayer. Returns (out, aux)."""
    if cfg.is_moe:
        out, aux = moe.moe_block(
            x, blk["router"], blk["moe_gate"], blk["moe_up"], blk["moe_down"],
            cfg.experts_per_tok, cfg.capacity_factor, ctx)
        if cfg.num_shared_experts:
            out = out + layers.swiglu(x, blk["sh_gate"], blk["sh_up"],
                                      blk["sh_down"], ctx)
        return out, aux
    if cfg.mlp_type == "swiglu":
        return layers.swiglu(x, blk["w_gate"], blk["w_up"], blk["w_down"], ctx), 0.0
    return layers.gelu_mlp(x, blk["w_up"], blk["w_down"],
                           blk.get("b_up"), blk.get("b_down"), ctx), 0.0


def make_block_fn(cfg: ModelConfig, ctx, positions, pos3d=None,
                  impl: Optional[str] = None, return_kv: bool = False):
    impl = impl or ctx.recipe.attn_impl

    def block(carry, blk):
        h, aux = carry
        x = layers.rms_norm(h, blk["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(x, blk, cfg, ctx)
        q, k = _apply_rope(q, k, cfg, positions, pos3d)
        out = attn_mod.attention(q, k, v, impl=impl, causal=True,
                                 block_kv=ctx.recipe.block_kv)
        out = jnp.einsum("bsq,qd->bsd",
                         out.reshape(out.shape[0], out.shape[1], -1),
                         blk["wo"], preferred_element_type=jnp.float32)
        if cfg.attention_bias and "bo" in blk:
            out = out + blk["bo"]
        h = h + out.astype(h.dtype)
        x2 = layers.rms_norm(h, blk["ln2"], cfg.norm_eps)
        y, aux_l = _mlp(x2, blk, cfg, ctx)
        h = ctx.constrain(h + y.astype(h.dtype), "batch", "seq", "act_embed")
        ys = (k, v) if return_kv else None
        return (h, aux + aux_l), ys

    return block


# ---------------------------------------------------------------------------
# Full forwards
# ---------------------------------------------------------------------------

def _positions(batch, cfg):
    tokens = batch["tokens"]
    b, s = tokens.shape[:2]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def _head_logits(params, cfg, h, ctx):
    if cfg.family == "audio":
        logits = jnp.einsum("bsd,cdv->bscv", h, params["lm_head"],
                            preferred_element_type=jnp.float32)
        logits = ctx.constrain(logits, "batch", "seq", None, "heads")
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                            preferred_element_type=jnp.float32)
        logits = ctx.constrain(logits, "batch", "seq", "heads")
    return logits


def transformer_logits(params, cfg: ModelConfig, batch, ctx,
                       remat: str = "none", return_kv: bool = False):
    h = embed_inputs(params, cfg, batch, ctx)
    pos = _positions(batch, cfg)
    block = make_block_fn(cfg, ctx, pos, batch.get("positions_3d"),
                          return_kv=return_kv)
    (h, aux), kv = scan_blocks(block, (h, jnp.zeros((), jnp.float32)),
                               params["blocks"], remat=remat)
    h = layers.rms_norm(h, params["final_ln"], cfg.norm_eps)
    return _head_logits(params, cfg, h, ctx), aux, kv


def transformer_loss(params, cfg: ModelConfig, batch, ctx) -> jnp.ndarray:
    """Next-token CE (mean over tokens); MoE adds the aux balance loss."""
    tokens = batch["tokens"]
    batch_in = dict(batch, tokens=tokens[:, :-1])
    targets = tokens[:, 1:]            # (B, S) — or (B, S, C) for audio
    logits, aux, _ = transformer_logits(params, cfg, batch_in, ctx,
                                        remat=ctx.recipe.remat)
    ce = layers.softmax_xent(logits, targets, ctx)
    return ce + 0.01 * aux


def transformer_prefill(params, cfg: ModelConfig, batch, ctx):
    """Prefill: last-token logits + per-layer KV caches."""
    logits, _, kv = transformer_logits(params, cfg, batch, ctx,
                                       remat="none", return_kv=True)
    k_cache, v_cache = kv                                   # (L, B, S, Hk, Dh)
    k_cache = ctx.constrain(k_cache, None, "batch", "kv_seq", None, None)
    v_cache = ctx.constrain(v_cache, None, "batch", "kv_seq", None, None)
    return logits[:, -1], {"k": k_cache, "v": v_cache}


def init_kv_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    shape = (cfg.num_layers, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim)
    cache = {"k": jax.ShapeDtypeStruct(shape, dtype),
             "v": jax.ShapeDtypeStruct(shape, dtype)}
    if dtype == jnp.int8:
        # per-(token, head) absmax scales: ~6% overhead at head_dim 64,
        # halving decode's dominant HBM term (cache streaming)
        sshape = shape[:-1]
        cache["k_scale"] = jax.ShapeDtypeStruct(sshape, jnp.float32)
        cache["v_scale"] = jax.ShapeDtypeStruct(sshape, jnp.float32)
    return cache


def _quantize_kv(x):
    """x: (B, Hk, D) -> (int8 values, (B, Hk) scales)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def transformer_decode(params, cfg: ModelConfig, batch, cache, ctx):
    """One decode step: batch has tokens (B,1) and lengths (B,)."""
    lengths = batch["lengths"]
    h = embed_inputs(params, cfg, batch, ctx)               # (B,1,D)
    pos = lengths[:, None].astype(jnp.int32)                # (B,1)
    b = h.shape[0]
    bidx = jnp.arange(b)
    quantized = "k_scale" in cache

    def block(carry, xs):
        hh, _ = carry
        if quantized:
            blk, k_l, v_l, ks_l, vs_l = xs
        else:
            blk, k_l, v_l = xs
        x = layers.rms_norm(hh, blk["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(x, blk, cfg, ctx)
        if cfg.mrope:
            pos3 = jnp.broadcast_to(pos[None], (3,) + pos.shape)
            q, k = _apply_rope(q, k, cfg, pos, pos3)
        else:
            q, k = _apply_rope(q, k, cfg, pos, None)
        if quantized:
            kq, ksc = _quantize_kv(k[:, 0])
            vq, vsc = _quantize_kv(v[:, 0])
            k_l = k_l.at[bidx, lengths].set(kq)
            v_l = v_l.at[bidx, lengths].set(vq)
            ks_l = ks_l.at[bidx, lengths].set(ksc)
            vs_l = vs_l.at[bidx, lengths].set(vsc)
            # dequant fuses into the attention dots: HBM reads stay int8
            k_use = k_l.astype(jnp.bfloat16) * ks_l[..., None].astype(jnp.bfloat16)
            v_use = v_l.astype(jnp.bfloat16) * vs_l[..., None].astype(jnp.bfloat16)
        else:
            k_l = k_l.at[bidx, lengths].set(k[:, 0])
            v_l = v_l.at[bidx, lengths].set(v[:, 0])
            k_use, v_use = k_l, v_l
        out = attn_mod.decode_attention(q, k_use, v_use, lengths + 1)
        out = jnp.einsum("bsq,qd->bsd", out.reshape(b, 1, -1), blk["wo"],
                         preferred_element_type=jnp.float32)
        if cfg.attention_bias and "bo" in blk:
            out = out + blk["bo"]
        hh = hh + out.astype(hh.dtype)
        x2 = layers.rms_norm(hh, blk["ln2"], cfg.norm_eps)
        y, _ = _mlp(x2, blk, cfg, ctx)
        ys = ((k_l, v_l, ks_l, vs_l) if quantized else (k_l, v_l))
        return (hh + y.astype(hh.dtype), 0.0), ys

    if quantized:
        xs = (params["blocks"], cache["k"], cache["v"],
              cache["k_scale"], cache["v_scale"])
        (h, _), (k_new, v_new, ks_new, vs_new) = jax.lax.scan(block, (h, 0.0), xs)
        new_cache = {"k": k_new, "v": v_new,
                     "k_scale": ks_new, "v_scale": vs_new}
    else:
        (h, _), (k_new, v_new) = jax.lax.scan(
            block, (h, 0.0), (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": k_new, "v": v_new}
    h = layers.rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = _head_logits(params, cfg, h, ctx)
    return logits[:, -1], new_cache
