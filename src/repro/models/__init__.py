"""Model zoo: GQA transformers (dense/MoE/VLM/audio), RWKV6, Mamba2/Zamba2."""
from repro.models import attention, layers, mamba2, model, moe, params, rwkv, transformer

__all__ = ["attention", "layers", "mamba2", "model", "moe", "params", "rwkv",
           "transformer"]
