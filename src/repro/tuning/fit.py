"""Model fitting utilities (no scipy): power-law index-size fit and OLS.

The PGM tuner (§V-B) fits M_idx(eps) = a * eps^(-b) + c from a handful of
sampled constructions: log-log regression initializes (a, b), then a short
Adam refinement (jax.grad on the squared loss) polishes all three parameters —
the hand-rolled stand-in for nonlinear least squares.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PowerLawFit", "fit_power_law", "ols"]


@dataclasses.dataclass(frozen=True)
class PowerLawFit:
    a: float
    b: float
    c: float

    def __call__(self, eps) -> np.ndarray:
        return self.a * np.asarray(eps, np.float64) ** (-self.b) + self.c


def fit_power_law(
    eps_samples: Sequence[float],
    size_samples: Sequence[float],
    steps: int = 2000,
    lr: float = 0.05,
) -> PowerLawFit:
    """Fit size(eps) = a * eps^-b + c in log-space with Adam refinement."""
    x = np.asarray(eps_samples, np.float64)
    y = np.asarray(size_samples, np.float64)
    # Init: assume c ~ 0.5 * min(y); log-log regression for a, b.
    c0 = 0.5 * float(y.min())
    ly = np.log(np.maximum(y - c0, 1e-9))
    lx = np.log(x)
    b0 = -float(np.polyfit(lx, ly, 1)[0])
    a0 = float(np.exp(np.polyfit(lx, ly, 1)[1]))

    scale = float(y.mean())
    xj = jnp.asarray(x)
    yj = jnp.asarray(y / scale)

    def loss(params):
        log_a, b, c = params
        pred = jnp.exp(log_a) * xj ** (-b) + c
        return jnp.mean((pred - yj) ** 2)

    params = jnp.asarray([np.log(max(a0 / scale, 1e-9)), max(b0, 0.05), c0 / scale])
    grad = jax.jit(jax.grad(loss))
    m = jnp.zeros(3)
    v = jnp.zeros(3)
    for t in range(1, steps + 1):
        g = grad(params)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mhat = m / (1 - 0.9**t)
        vhat = v / (1 - 0.999**t)
        params = params - lr * mhat / (jnp.sqrt(vhat) + 1e-8)
    log_a, b, c = np.asarray(params, np.float64)
    return PowerLawFit(a=float(np.exp(log_a)) * scale, b=float(b), c=float(c) * scale)


def ols(features: np.ndarray, targets: np.ndarray) -> np.ndarray:
    """Least-squares coefficients (design matrix -> coef vector)."""
    coef, *_ = np.linalg.lstsq(np.asarray(features, np.float64),
                               np.asarray(targets, np.float64), rcond=None)
    return coef
