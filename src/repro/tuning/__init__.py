"""Memory-budgeted index tuning via CAM (paper §V) + cache-oblivious baselines."""
from repro.tuning import fit, pgm_tuner, rmi_tuner, rs_tuner

__all__ = ["fit", "pgm_tuner", "rmi_tuner", "rs_tuner"]
