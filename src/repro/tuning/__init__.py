"""Memory-budgeted index tuning via CAM (paper §V).

``repro.tuning.session`` is the ONE tuning surface: ``TuningSession`` over
declarative ``KnobSpace``s, lazy ``SizeModel``s, and pluggable ``Tuner``
strategies (CAM joint knob x buffer-split search, multicriteria-PGM and
CDFShop cache-oblivious baselines).  The per-family modules
(``pgm_tuner`` / ``rmi_tuner`` / ``rs_tuner``) are deprecated shims.
"""
from repro.tuning import fit, pgm_tuner, rmi_tuner, rs_tuner, session

__all__ = ["fit", "pgm_tuner", "rmi_tuner", "rs_tuner", "session"]
