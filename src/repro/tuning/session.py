"""TuningSession — ONE knob-tuning surface over declarative knob spaces (§V).

The tuning counterpart of the CostSession/JoinSession/JoinTreeSession
three-noun design.  Where the legacy tuners were three divergent function
bags (``pgm_tuner`` / ``rmi_tuner`` / ``rs_tuner``, now deprecated shims over
this module), everything here speaks four small abstractions:

* :class:`KnobSpace` — a declarative grid over an index family's tunable
  knobs, derived from the ``IndexModel.knobs()`` metadata the adapters
  publish (eps grids, branch grids, RadixSpline's ``radix_bits``, and
  cartesian products thereof);
* :class:`SizeModel` — footprint prediction WITHOUT construction: lazy
  power-law fits for the uniformly error-bounded families (the §V-B
  fitting trick, via ``tuning/fit.py``), the exact analytic formula for RMI
  (root + per-leaf parameters are fixed-size).  Budget-infeasible knob
  points are therefore skipped *before any index is built* and recorded in
  ``TuneResult.skipped`` with typed reasons;
* :class:`IndexBuilder` — a family bound to a key file: its knob space, its
  size model, candidate construction for the feasible points (RMI builds
  only here), and the deterministic in-memory profile score the
  cache-oblivious baselines optimize;
* :class:`Tuner` — a pluggable strategy: :class:`CamTuner` (the paper's
  cache-aware joint search), :class:`MulticriteriaTuner` (multicriteria-PGM:
  reserve a fixed buffer fraction, profile the candidates that fit the
  rest), :class:`CDFShopTuner` (CPU-optimal, I/O-oblivious).  All return a
  uniform :class:`TuneResult`.

The CAM search is *joint* over (knob, buffer-split fraction), the Eq. 15/16
trade-off solved on precomputed tables: ONE ``CostSession.grid_profiles``
pass produces every knob's capacity-independent profile (uniform-eps
candidates through the banded-matmul kernels, RMI branch grids through the
batched mixed-eps kernel), then ONE ``CostSession.solve_profiles`` call — the
many-histogram generalization of the PR-4 ``hit_rate_curve`` /
``sorted_scan_miss_curve`` capacity-curve evaluators — prices the whole
(knob x split) table in a single vmapped pass.  Picking the argmin is pure
array lookups: ZERO per-split model calls, structurally asserted in
``tests/test_tuning_session.py``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import (Callable, Dict, NamedTuple, Optional, Protocol, Sequence,
                    Tuple, Union, runtime_checkable)

import numpy as np

from repro.core.cam import CamEstimate
from repro.core.session import (CostSession, GridCandidate, SkippedCandidate,
                                System)
from repro.core.workload import Workload
from repro.engine import PriceTable
from repro.index import pgm as pgm_mod
from repro.index import radixspline as rs_mod
from repro.index import rmi as rmi_mod
from repro.index.adapters import (ALEXAdapter, BTreeAdapter, PGMAdapter,
                                  RMIAdapter, RadixSplineAdapter)
from repro.tuning import fit

__all__ = [
    "Knob",
    "KnobSpace",
    "SizeModel",
    "PowerLawSizeModel",
    "RadixSplineSizeModel",
    "AnalyticSizeModel",
    "TableSizeModel",
    "IndexBuilder",
    "PGMBuilder",
    "RMIBuilder",
    "RadixSplineBuilder",
    "ALEXBuilder",
    "BTreeBuilder",
    "builder_for",
    "SplitTable",
    "SplitEstimate",
    "TuneResult",
    "Tuner",
    "CamTuner",
    "MulticriteriaTuner",
    "CDFShopTuner",
    "TuningSession",
    "DEFAULT_SPLITS",
]

#: Candidate buffer fractions of the shared budget enumerated by the joint
#: (knob x split) search, in addition to each knob's maximal feasible split
#: (all memory the index does not claim).  The maximum split is listed first
#: per knob, so objective ties resolve toward the larger buffer — exactly
#: what the legacy tuners (which always took the maximum) chose.
DEFAULT_SPLITS = (0.25, 0.5, 0.75)


# ---------------------------------------------------------------------------
# Declarative knob spaces
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable axis: a name and the grid of values to sweep."""

    name: str
    values: Tuple[object, ...]
    kind: str = "knob"


@dataclasses.dataclass(frozen=True)
class KnobSpace:
    """Cartesian grid over an index family's tunable knobs.

    Derived from ``IndexModel.knobs()``-style metadata: every ``tunable``
    entry carrying a ``grid`` becomes an axis (RadixSpline's
    (eps x radix_bits) plane, PGM's eps line, RMI's branch line).
    ``overrides`` replaces an axis' grid — a scalar override pins the axis
    to a single value.
    """

    knobs: Tuple[Knob, ...]

    @classmethod
    def from_metadata(cls, metadata: Dict[str, dict],
                      overrides: Optional[Dict[str, object]] = None
                      ) -> "KnobSpace":
        overrides = dict(overrides or {})
        axes = []
        for name, meta in metadata.items():
            if name in overrides:
                grid = overrides.pop(name)
                if np.isscalar(grid):
                    grid = (grid,)
                axes.append(Knob(name, tuple(grid),
                                 meta.get("kind", "knob")))
            elif meta.get("tunable") and "grid" in meta:
                axes.append(Knob(name, tuple(meta["grid"]),
                                 meta.get("kind", "knob")))
        if overrides:
            raise ValueError(f"overrides name unknown knobs: "
                             f"{sorted(overrides)}; metadata has "
                             f"{sorted(metadata)}")
        if not axes:
            raise ValueError("knob space has no tunable axes")
        return cls(tuple(axes))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(k.name for k in self.knobs)

    def points(self) -> Tuple[Dict[str, object], ...]:
        """Cartesian product, first axis outermost (stable tuning order)."""
        names = self.names
        return tuple(dict(zip(names, combo)) for combo in
                     itertools.product(*(k.values for k in self.knobs)))

    def key(self, point: Dict[str, object]):
        """Estimate-dict key for a point: the bare value for 1-D spaces
        (legacy ``estimates[eps]`` compatibility), a tuple otherwise."""
        if len(self.knobs) == 1:
            return point[self.knobs[0].name]
        return tuple(point[n] for n in self.names)


# ---------------------------------------------------------------------------
# Size models: footprint prediction without construction
# ---------------------------------------------------------------------------

@runtime_checkable
class SizeModel(Protocol):
    """Predicts an index footprint in bytes from knob values.

    ``model(eps=64)`` / ``model(branch=1024)`` /
    ``model(eps=64, radix_bits=12)`` — called once per knob point during
    feasibility filtering, BEFORE any candidate index exists.
    """

    def __call__(self, **knobs) -> float: ...


@dataclasses.dataclass
class PowerLawSizeModel:
    """Lazy ``a * eps^-b + c`` fit from a few sampled builds (§V-B).

    The multicriteria-PGM fitting trick: construction happens only for
    ``sample_eps`` (and only on first use), after which the dense eps grid
    prices through the closed form.
    """

    build_size: Callable[[int], float]
    sample_eps: Tuple[int, ...] = (16, 64, 256, 1024)
    _fit: Optional[fit.PowerLawFit] = dataclasses.field(default=None,
                                                        repr=False)
    fit_seconds: float = 0.0

    @property
    def fitted(self) -> fit.PowerLawFit:
        if self._fit is None:
            t0 = time.perf_counter()
            sizes = [float(self.build_size(e)) for e in self.sample_eps]
            self._fit = fit.fit_power_law(list(self.sample_eps), sizes)
            self.fit_seconds = time.perf_counter() - t0
        return self._fit

    def __call__(self, eps: int, **_ignored) -> float:
        return float(self.fitted(eps))


@dataclasses.dataclass
class RadixSplineSizeModel:
    """2-D RadixSpline footprint: fitted spline knots + analytic radix table.

    The knot count shrinks as a power law of the corridor eps (fitted from
    sampled builds at ``ref_radix_bits``, table bytes subtracted), while the
    radix table is exactly ``4 * (2^bits + 1)`` bytes — so the whole
    (eps x radix_bits) plane prices from ONE sampled 1-D fit.
    """

    keys: np.ndarray
    sample_eps: Tuple[int, ...] = (16, 64, 256, 1024)
    ref_radix_bits: int = 12
    _spline_fit: Optional[PowerLawSizeModel] = dataclasses.field(
        default=None, repr=False)

    @staticmethod
    def table_bytes(radix_bits: int) -> float:
        return 4.0 * (2 ** int(radix_bits) + 1)

    def __call__(self, eps: int, radix_bits: Optional[int] = None,
                 **_ignored) -> float:
        if self._spline_fit is None:
            ref_table = self.table_bytes(self.ref_radix_bits)
            self._spline_fit = PowerLawSizeModel(
                lambda e: rs_mod.build_radixspline(
                    self.keys, e, self.ref_radix_bits).size_bytes - ref_table,
                self.sample_eps)
        bits = self.ref_radix_bits if radix_bits is None else radix_bits
        return float(self._spline_fit(eps)) + self.table_bytes(bits)


@dataclasses.dataclass(frozen=True)
class AnalyticSizeModel:
    """Exact closed-form footprint (RMI: fixed-size root + per-leaf params).

    No sampling, no builds — which is what lets the tuner drop
    budget-infeasible branch factors before paying an O(n) construction
    (the ``cam_tune_rmi`` eager-build bug this PR fixes).
    """

    fn: Callable[..., float]

    def __call__(self, **knobs) -> float:
        return float(self.fn(**knobs))


@dataclasses.dataclass(frozen=True)
class TableSizeModel:
    """Exact per-point sizes from already-built indexes (benchmark replays
    that must agree with replay capacities bit-for-bit)."""

    sizes: Dict[object, float]
    names: Tuple[str, ...] = ("eps",)

    def __call__(self, **knobs) -> float:
        key = (knobs[self.names[0]] if len(self.names) == 1
               else tuple(knobs[n] for n in self.names))
        return float(self.sizes[key])


# ---------------------------------------------------------------------------
# Index builders: a family bound to a key file
# ---------------------------------------------------------------------------

@runtime_checkable
class IndexBuilder(Protocol):
    """What ``TuningSession`` needs from an index family."""

    family: str
    keys: np.ndarray

    def knob_space(self, overrides: Optional[Dict[str, object]] = None
                   ) -> KnobSpace: ...

    def size_model(self) -> SizeModel: ...

    def candidate(self, point: Dict[str, object],
                  size_bytes: float) -> GridCandidate: ...

    def build(self, point: Dict[str, object]): ...

    def profile_score(self, point: Dict[str, object],
                      probe_keys: np.ndarray) -> float: ...


@dataclasses.dataclass
class PGMBuilder:
    """PGM family: uniform eps knob, power-law size model, no builds in the
    CAM grid (candidates are ``GridCandidate(eps=...)``)."""

    keys: np.ndarray
    sample_eps: Tuple[int, ...] = (16, 64, 256, 1024)
    family: str = "pgm"
    built: Dict[object, PGMAdapter] = dataclasses.field(default_factory=dict)
    _size_model: Optional[PowerLawSizeModel] = dataclasses.field(
        default=None, repr=False)

    def knob_space(self, overrides=None) -> KnobSpace:
        return KnobSpace.from_metadata(PGMAdapter.knob_metadata(), overrides)

    def size_model(self) -> PowerLawSizeModel:
        if self._size_model is None:
            self._size_model = PowerLawSizeModel(
                lambda e: pgm_mod.build_pgm(self.keys, e).size_bytes,
                self.sample_eps)
        return self._size_model

    def candidate(self, point, size_bytes) -> GridCandidate:
        return GridCandidate(knob=point["eps"], eps=int(point["eps"]),
                             size_bytes=float(size_bytes))

    def build(self, point) -> PGMAdapter:
        key = point["eps"]
        if key not in self.built:
            self.built[key] = PGMAdapter.build(self.keys, int(point["eps"]))
        return self.built[key]

    def profile_score(self, point, probe_keys) -> float:
        """The multicriteria optimizer's deterministic in-memory lookup
        cost: traversal levels + log2 last-mile steps (the profiling pass
        itself — a real build + predict — is charged to tuning time)."""
        idx = self.build(point).index
        idx.predict(probe_keys)                       # the profiling pass
        return 1.5 * len(idx.levels) + float(
            np.log2(2 * point["eps"] + 1))


@dataclasses.dataclass
class RMIBuilder:
    """RMI family: branch-factor knob, EXACT analytic size model (so
    budget-infeasible branches are never constructed), candidates built
    lazily for the feasible points only and profiled through the batched
    mixed-eps kernel."""

    keys: np.ndarray
    family: str = "rmi"
    built: Dict[object, RMIAdapter] = dataclasses.field(default_factory=dict)

    def knob_space(self, overrides=None) -> KnobSpace:
        return KnobSpace.from_metadata(RMIAdapter.knob_metadata(), overrides)

    def size_model(self) -> AnalyticSizeModel:
        return AnalyticSizeModel(
            lambda branch: rmi_mod.rmi_size_bytes(int(branch)))

    def candidate(self, point, size_bytes) -> GridCandidate:
        adapter = self.build(point)
        return GridCandidate(knob=point["branch"],
                             size_bytes=float(adapter.size_bytes),
                             index=adapter)

    def build(self, point) -> RMIAdapter:
        key = point["branch"]
        if key not in self.built:
            self.built[key] = RMIAdapter.build(self.keys,
                                               int(point["branch"]))
        return self.built[key]

    def profile_score(self, point, probe_keys) -> float:
        """CDFShop's deterministic CPU score: model evals + log2 last-mile
        steps over the mean leaf error (profiling pass included)."""
        idx = self.build(point).index
        idx.window(probe_keys)                        # the profiling pass
        return 2.0 + float(np.log2(2.0 * idx.leaf_eps.mean() + 1.0))


@dataclasses.dataclass
class RadixSplineBuilder:
    """RadixSpline family: the 2-D (corridor eps x radix_bits) knob plane.

    The spline profile depends only on eps (the radix table accelerates
    in-memory knot search, not disk windows), so every (eps, radix_bits)
    point shares the banded uniform-eps kernels — radix_bits enters purely
    through the footprint, which is exactly the Eq. 15/16 trade-off: wider
    tables steal buffer pages.
    """

    keys: np.ndarray
    sample_eps: Tuple[int, ...] = (16, 64, 256, 1024)
    ref_radix_bits: int = 12
    family: str = "radixspline"
    built: Dict[object, RadixSplineAdapter] = dataclasses.field(
        default_factory=dict)
    _size_model: Optional[RadixSplineSizeModel] = dataclasses.field(
        default=None, repr=False)

    def knob_space(self, overrides=None) -> KnobSpace:
        return KnobSpace.from_metadata(RadixSplineAdapter.knob_metadata(),
                                       overrides)

    def size_model(self) -> RadixSplineSizeModel:
        if self._size_model is None:
            self._size_model = RadixSplineSizeModel(
                self.keys, self.sample_eps, self.ref_radix_bits)
        return self._size_model

    def candidate(self, point, size_bytes) -> GridCandidate:
        return GridCandidate(knob=(point["eps"], point["radix_bits"]),
                             eps=int(point["eps"]),
                             size_bytes=float(size_bytes))

    def build(self, point) -> RadixSplineAdapter:
        key = (point["eps"], point["radix_bits"])
        if key not in self.built:
            self.built[key] = RadixSplineAdapter.build(
                self.keys, int(point["eps"]), int(point["radix_bits"]))
        return self.built[key]

    def profile_score(self, point, probe_keys) -> float:
        idx = self.build(point).index
        idx.predict(probe_keys)                       # the profiling pass
        narrowed = max(0.0, float(np.log2(max(len(idx.knots_key), 2)))
                       - point["radix_bits"])
        return 1.0 + narrowed + float(np.log2(2 * point["eps"] + 1))


@dataclasses.dataclass
class ALEXBuilder:
    """ALEX family: gap-density knob, exact analytic size model.

    Candidates are index-backed (the slot-space remap differs per knob, so
    the shared uniform-eps grid over one ``n`` cannot represent them), but
    "building" is O(1) — the adapter is a layout model, not a structure —
    so the whole gap grid still prices in one grouped profile pass, write
    streams included.
    """

    keys: np.ndarray
    eps: int = 64
    family: str = "alex"
    built: Dict[object, ALEXAdapter] = dataclasses.field(default_factory=dict)

    def knob_space(self, overrides=None) -> KnobSpace:
        return KnobSpace.from_metadata(ALEXAdapter.knob_metadata(), overrides)

    def size_model(self) -> AnalyticSizeModel:
        n = int(np.asarray(self.keys).shape[0])
        return AnalyticSizeModel(
            lambda gap_density: ALEXAdapter(n, float(gap_density),
                                            self.eps).size_bytes)

    def candidate(self, point, size_bytes) -> GridCandidate:
        adapter = self.build(point)
        return GridCandidate(knob=point["gap_density"],
                             size_bytes=float(size_bytes), index=adapter)

    def build(self, point) -> ALEXAdapter:
        key = point["gap_density"]
        if key not in self.built:
            self.built[key] = ALEXAdapter.build(
                self.keys, float(point["gap_density"]), self.eps)
        return self.built[key]

    def profile_score(self, point, probe_keys) -> float:
        """Deterministic in-memory score: root model eval + exponential
        search over the eps corridor (gap slack does not change CPU cost —
        which is precisely why cache-oblivious tuners cannot rank it)."""
        self.build(point).window(probe_keys)          # the profiling pass
        return 1.0 + float(np.log2(2 * self.eps + 1))


@dataclasses.dataclass
class BTreeBuilder:
    """B+-tree family: leaf fill-factor knob, exact analytic size model."""

    keys: np.ndarray
    family: str = "btree"
    built: Dict[object, BTreeAdapter] = dataclasses.field(
        default_factory=dict)

    def knob_space(self, overrides=None) -> KnobSpace:
        return KnobSpace.from_metadata(BTreeAdapter.knob_metadata(),
                                       overrides)

    def size_model(self) -> AnalyticSizeModel:
        n = int(np.asarray(self.keys).shape[0])
        return AnalyticSizeModel(
            lambda fill_factor: BTreeAdapter(n,
                                             float(fill_factor)).size_bytes)

    def candidate(self, point, size_bytes) -> GridCandidate:
        adapter = self.build(point)
        return GridCandidate(knob=point["fill_factor"],
                             size_bytes=float(size_bytes), index=adapter)

    def build(self, point) -> BTreeAdapter:
        key = point["fill_factor"]
        if key not in self.built:
            self.built[key] = BTreeAdapter.build(self.keys,
                                                 float(point["fill_factor"]))
        return self.built[key]

    def profile_score(self, point, probe_keys) -> float:
        """Resident inner-node descent: log_fanout(n) comparisons levels."""
        adapter = self.build(point)
        adapter.window(probe_keys)                    # the profiling pass
        return float(np.log(max(adapter.n, 2)) / np.log(256.0)) + 1.0


_BUILDERS = {"pgm": PGMBuilder, "rmi": RMIBuilder,
             "radixspline": RadixSplineBuilder, "alex": ALEXBuilder,
             "btree": BTreeBuilder}


def builder_for(family: str, keys: np.ndarray, **kwargs) -> IndexBuilder:
    """Builder registry: ``builder_for("pgm", keys)`` etc."""
    if family not in _BUILDERS:
        raise ValueError(f"unknown index family {family!r}; expected one of "
                         f"{sorted(_BUILDERS)}")
    return _BUILDERS[family](keys, **kwargs)


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

#: The joint (knob x split) solve table IS the engine's canonical table IR
#: (PR 8 moved it there verbatim); the alias keeps the tuning-era name that
#: sharding and the test suite grew up with.
SplitTable = PriceTable


class SplitEstimate(NamedTuple):
    """One (knob, buffer split) cell of the joint search table."""

    split: float              # buffer fraction of the shared budget
    capacity_pages: int
    io: float                 # (1 - h) * E[DAC] per query
    hit_rate: float
    dac: float
    size_bytes: float
    seconds: float            # device-model objective (== io under DAM)


@dataclasses.dataclass
class TuneResult:
    """Uniform result of every tuner strategy.

    ``best`` is the chosen knob point (name -> value), ``split`` the chosen
    buffer fraction, ``estimates`` each knob's CamEstimate at its own best
    split (CAM tuners; baselines estimate nothing and leave it empty), and
    ``table`` the full joint (knob x split) table the argmin ran over.
    ``skipped`` carries typed reasons — budget-infeasible points recorded
    from the SIZE MODEL, before any build.  ``batched_solves`` counts the
    cache-model solve passes: the joint search does exactly one, however
    many splits are enumerated.
    """

    family: str
    tuner: str
    objective: str
    best: Dict[str, object]
    best_knob: object
    split: float
    capacity_pages: int
    est_io: float
    objective_value: float
    estimates: Dict[object, CamEstimate]
    table: Dict[object, Tuple[SplitEstimate, ...]]
    skipped: Tuple[SkippedCandidate, ...]
    tuning_seconds: float
    batched_solves: int = 0
    size_model: Optional[SizeModel] = None


# ---------------------------------------------------------------------------
# Tuner strategies
# ---------------------------------------------------------------------------

@runtime_checkable
class Tuner(Protocol):
    name: str

    def tune(self, session: "TuningSession", builder: IndexBuilder,
             workload: Workload, space: KnobSpace, objective,
             sample_rate: float, seed: int,
             size_model: Optional[SizeModel]) -> TuneResult: ...


def _feasibility_split(points, space, size_model, system):
    """Size-model feasibility BEFORE any construction (typed skips)."""
    feasible, skipped = [], []
    for pt in points:
        size = float(size_model(**pt))
        if system.capacity_for(size) >= 1:
            feasible.append((pt, size))
        else:
            skipped.append(SkippedCandidate(
                space.key(pt),
                f"predicted {size:.0f} B footprint leaves no buffer page "
                f"under the {system.memory_budget_bytes:.0f} B budget"))
    return feasible, skipped


@dataclasses.dataclass
@dataclasses.dataclass
class CamTuner:
    """The paper's tuner: cache-aware joint (knob x buffer split) search.

    One ``grid_profiles`` pass (capacity-independent), one
    ``solve_profiles`` pass over the whole (knob x split) table, then pure
    array argmin — zero per-split model calls.

    ``policies`` makes the EVICTION POLICY a knob: the assembled table is
    crossed with the given ``cache_models.POLICIES`` names
    (``PriceTable.cross_policies``), so the single engine call prices
    every (knob x split x policy) cell — side by side in ONE fused launch
    on the device executor — and the winning point carries a ``"policy"``
    entry.  ``None`` (default) prices under the session's configured
    policy, exactly as before.

    Objectives:

    * ``"io"``      — expected physical I/Os per query, Eq. 15/16;
    * ``"seconds"`` — device-model-aware: each miss event issues one device
      op whose run length is the query's data-access span, so
      ``seconds = miss_rate * device.cost([E[DAC]])`` (§III-A composition;
      under the unit-cost DAM, or with no ``System.device``, this equals
      ``"io"``).  A seek-heavy device weighs the op term against the
      transfer term differently than raw page counts do, and can therefore
      pick a different knob than ``"io"``;
    * a callable ``f(point, SplitEstimate) -> float`` — custom metric,
      evaluated over the precomputed table (still no model calls); e.g. a
      memory-frugality penalty that prefers sub-maximal splits.
    """

    policies: Optional[Tuple[str, ...]] = None
    name: str = "cam"

    def tune(self, session, builder, workload, space, objective,
             sample_rate, seed, size_model) -> TuneResult:
        t0 = time.perf_counter()
        system = session.system
        cost = session.cost
        size_model = size_model if size_model is not None \
            else builder.size_model()
        feasible, skipped = _feasibility_split(
            space.points(), space, size_model, system)
        if not feasible:
            raise ValueError("memory budget too small for any candidate "
                             "index")
        # Construction happens here and only here — for the feasible points
        # of index-backed families (RMI); uniform-eps families build nothing.
        cands = [builder.candidate(pt, size) for pt, size in feasible]
        profiles = cost.grid_profiles(cands, workload, sample_rate, seed)
        skipped.extend(profiles.skipped)
        points = {space.key(pt): pt for pt, _size in feasible}
        return self.tune_profiles(
            session, builder, space, profiles, points=points,
            objective=objective, size_model=size_model,
            skipped=skipped, t0=t0)

    def tune_profiles(self, session, builder, space, profiles, *,
                      points: Optional[Dict[object, Dict[str, object]]] = None,
                      objective="io", size_model=None,
                      skipped: Sequence[SkippedCandidate] = (),
                      t0: Optional[float] = None) -> TuneResult:
        """Joint (knob x split) search on PRECOMPUTED profiles.

        The solve-and-argmin half of :meth:`tune`, callable with any
        capacity-independent :class:`GridProfiles` — in particular one
        assembled incrementally from serving sketches
        (``GridProfiles.from_accumulated``).  Runs NO profiling pass: the
        only model call is the single batched ``solve_profiles`` over the
        (knob x split) table, which is what lets the serving loop retune
        from sketches without replaying or re-profiling the trace
        (structurally asserted in ``tests/test_serving.py``).

        ``points`` maps each profile knob key to its knob-space point; when
        omitted it is reconstructed from ``space.points()``.
        """
        t0 = time.perf_counter() if t0 is None else t0
        system = session.system
        cost = session.cost
        if points is None:
            by_key = {}
            for pt in space.points():
                by_key.setdefault(space.key(pt), pt)
            points = {kn: by_key[kn] for kn in profiles.knobs
                      if kn in by_key}
        table = self.assemble_table(
            profiles, points, splits=session.splits,
            budget_bytes=system.memory_budget_bytes,
            page_bytes=system.geom.page_bytes)
        if self.policies:
            # policy-as-a-knob: cross every (knob x split) cell with the
            # candidate eviction policies — still ONE engine call below
            table = table.cross_policies(self.policies)
        # ----- ONE engine call prices the whole table ---------------------
        sol = cost.engine.price(
            table, objective=objective if objective == "seconds" else "io")
        return self.finish_from_solution(
            session, builder, space, profiles, table, sol.hit_rates,
            sol.distinct, objective=objective, size_model=size_model,
            skipped=skipped, t0=t0)

    @staticmethod
    def assemble_table(profiles, points, *, splits, budget_bytes,
                       page_bytes, index_in_split: bool = False,
                       include_max_split: bool = True) -> SplitTable:
        """The joint (knob x split) table — delegates to
        :meth:`repro.engine.PriceTable.from_profiles`, where the assembly
        semantics (max-split-first tie ordering, ``index_in_split`` fleet
        capacities) now live."""
        return PriceTable.from_profiles(
            profiles, points, splits=splits, budget_bytes=budget_bytes,
            page_bytes=page_bytes, index_in_split=index_in_split,
            include_max_split=include_max_split)

    def finish_from_solution(self, session, builder, space, profiles,
                             table: SplitTable, h, n_distinct, *,
                             objective="io", size_model=None,
                             skipped: Sequence[SkippedCandidate] = (),
                             t0: Optional[float] = None,
                             batched_solves: int = 1) -> TuneResult:
        """Argmin + result assembly over an ALREADY-SOLVED table.

        ``h``/``n_distinct`` are :meth:`CostSession.solve_profiles` outputs
        aligned with ``table``'s cells; everything here is array lookups —
        no model calls — so a caller that solved MANY concatenated tables
        at once (the sharded fleet search) can finish each table's slice
        separately without re-solving.
        """
        t0 = time.perf_counter() if t0 is None else t0
        system = session.system
        cost = session.cost
        skipped = list(skipped)
        spans, points_of = table.spans, table.points_of
        rows_arr, caps_arr, fracs = table.rows, table.caps, table.fracs
        h = np.asarray(h, np.float64)
        n_distinct = np.asarray(n_distinct, np.float64)
        dacs = profiles.dacs[rows_arr]
        sizes = profiles.sizes[rows_arr]
        io = (1.0 - h) * dacs
        device = system.device
        if device is None:
            seconds = io
        else:
            run_cost = np.asarray([float(device.cost([d]))
                                   for d in profiles.dacs])
            seconds = (1.0 - h) * run_cost[rows_arr]

        entries = {
            knob: tuple(SplitEstimate(float(fracs[j]), int(caps_arr[j]),
                                      float(io[j]), float(h[j]),
                                      float(dacs[j]), float(sizes[j]),
                                      float(seconds[j]))
                        for j in range(a, b))
            for knob, (a, b) in spans.items()}

        if objective == "io":
            obj = io
            obj_name = "io"
        elif objective == "seconds":
            obj = seconds
            obj_name = "seconds"
        elif callable(objective):
            obj = np.asarray([
                objective(points_of[knob], e)
                for knob, (a, b) in spans.items()
                for e in entries[knob]])
            obj_name = getattr(objective, "__name__", "custom")
        else:
            raise ValueError(f"unknown objective {objective!r}; expected "
                             "'io', 'seconds', or a callable")

        # ----- argmin + per-knob estimates: array lookups only ------------
        per_cand = (time.perf_counter() - t0) / max(len(spans), 1)
        estimates: Dict[object, CamEstimate] = {}
        best_knob, best_j, best_val = None, -1, np.inf
        for knob, (a, b) in spans.items():
            j = a + int(np.argmin(obj[a:b]))
            if obj[j] < best_val:
                best_knob, best_j, best_val = knob, j, float(obj[j])
            # the span's first cell names the knob's profile row (every
            # cell of a span shares one row) — valid for plain AND
            # policy-crossed tables, whose (policy, knob) keys are not
            # profile knob keys
            i = int(rows_arr[a])
            estimates[knob] = CamEstimate(
                io_per_query=float(io[j]), hit_rate=float(h[j]),
                dac=float(dacs[j]), capacity_pages=int(caps_arr[j]),
                total_refs=(float(profiles.totals[i])
                            + profiles.sorted_refs(i)) * profiles.scale,
                distinct_pages=float(n_distinct[j]),
                estimation_seconds=per_cand,
                policy=points_of[knob].get("policy", system.policy),
                device_cost=cost._device_cost(float(io[j])))
        if best_knob is None:
            raise ValueError("no knob point survived profiling")
        return TuneResult(
            family=builder.family, tuner=self.name, objective=obj_name,
            best=dict(points_of[best_knob]), best_knob=best_knob,
            split=float(fracs[best_j]), capacity_pages=int(caps_arr[best_j]),
            est_io=float(io[best_j]), objective_value=float(obj[best_j]),
            estimates=estimates, table=entries, skipped=tuple(skipped),
            tuning_seconds=time.perf_counter() - t0,
            batched_solves=batched_solves, size_model=size_model)


@dataclasses.dataclass
class _ProfilingBaseline:
    """Shared body of the cache-oblivious baselines: reserve a fixed buffer
    fraction, build-and-profile the candidates whose PREDICTED size fits
    the remaining index-space budget, score them with the family's
    deterministic in-memory cost.  Buffer interaction is invisible to the
    score by construction — that is the point of the baseline."""

    buffer_fraction: float = 0.5
    profile_lookups: int = 20_000
    max_profiled: Optional[int] = None
    name: str = "baseline"

    def tune(self, session, builder, workload, space, objective,
             sample_rate, seed, size_model) -> TuneResult:
        t0 = time.perf_counter()
        system = session.system
        size_model = size_model if size_model is not None \
            else builder.size_model()
        index_budget = (1.0 - self.buffer_fraction) \
            * system.memory_budget_bytes
        points = space.points()
        feasible, skipped = [], []
        for pt in points:
            size = float(size_model(**pt))
            if size <= index_budget:
                feasible.append(pt)
            else:
                skipped.append(SkippedCandidate(
                    space.key(pt),
                    f"predicted {size:.0f} B footprint exceeds the "
                    f"{index_budget:.0f} B reserved index space"))
        if not feasible:
            # Legacy fallbacks when nothing fits the reserved index space:
            # multicriteria takes the COARSEST candidate (smallest predicted
            # footprint, max eps — grid-order independent), CDFShop its
            # grid's first entry.
            if self.name == "multicriteria":
                feasible = [min(points,
                                key=lambda pt: float(size_model(**pt)))]
            else:
                feasible = [points[0]]
        if self.max_profiled is not None:
            feasible = feasible[:self.max_profiled]
        rng = np.random.default_rng(0)
        probe = builder.keys[rng.integers(0, len(builder.keys),
                                          size=self.profile_lookups)]
        best_pt, best_score = None, np.inf
        for pt in feasible:
            score = builder.profile_score(pt, probe)
            if score < best_score:
                best_pt, best_score = pt, score
        best_knob = space.key(best_pt)
        size = float(size_model(**best_pt))
        cap = system.capacity_for(size)
        return TuneResult(
            family=builder.family, tuner=self.name, objective="cpu_profile",
            best=dict(best_pt), best_knob=best_knob,
            split=self.buffer_fraction, capacity_pages=cap,
            est_io=float("nan"), objective_value=float(best_score),
            estimates={}, table={}, skipped=tuple(skipped),
            tuning_seconds=time.perf_counter() - t0, batched_solves=0,
            size_model=size_model)


@dataclasses.dataclass
class MulticriteriaTuner(_ProfilingBaseline):
    """Multicriteria-PGM baseline (time-minimization-given-space mode):
    profiles the first ``max_profiled`` feasible candidates, picks the
    fastest in-memory one; falls back to the coarsest point when nothing
    fits the reserved index space."""

    max_profiled: Optional[int] = 10
    name: str = "multicriteria"


@dataclasses.dataclass
class CDFShopTuner(_ProfilingBaseline):
    """CDFShop-style baseline: CPU-optimal configuration, I/O-oblivious;
    profiles every candidate within the reserved index space (legacy
    behavior built even the infeasible ones first — the size-model path
    skips those builds, selection unchanged)."""

    name: str = "cdfshop"


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------

class TuningSession:
    """Knob tuning bound to ONE :class:`System` (the three-noun pattern).

    ``tune(builder, workload)`` runs a :class:`Tuner` strategy (CAM by
    default) over the builder's declarative knob space under the system's
    shared index+buffer memory budget.  ``budget=`` tunes under a different
    budget without rebinding (a replaced System view); ``splits`` overrides
    the candidate buffer fractions of the joint search.
    """

    def __init__(self, system: System,
                 splits: Sequence[float] = DEFAULT_SPLITS):
        self.system = system
        self.cost = CostSession(system)
        self.splits = tuple(splits)

    def tune(self, builder: IndexBuilder, workload: Workload,
             budget: Optional[float] = None, *,
             objective: Union[str, Callable] = "io",
             tuner: Optional[Tuner] = None,
             overrides: Optional[Dict[str, object]] = None,
             knob_space: Optional[KnobSpace] = None,
             size_model: Optional[SizeModel] = None,
             policies: Optional[Sequence[str]] = None,
             sample_rate: float = 1.0, seed: int = 0) -> TuneResult:
        session = self
        if budget is not None:
            session = TuningSession(
                dataclasses.replace(self.system,
                                    memory_budget_bytes=float(budget)),
                self.splits)
        space = knob_space if knob_space is not None \
            else builder.knob_space(overrides)
        if policies is not None and tuner is not None:
            raise ValueError("policies= configures the CAM tuner; pass "
                             "CamTuner(policies=...) explicitly instead of "
                             "combining it with tuner=")
        strategy = tuner if tuner is not None \
            else CamTuner(policies=tuple(policies) if policies else None)
        return strategy.tune(session, builder, workload, space, objective,
                             sample_rate, seed, size_model)

    def tune_from_profiles(self, builder: IndexBuilder, profiles,
                           budget: Optional[float] = None, *,
                           objective: Union[str, Callable] = "io",
                           overrides: Optional[Dict[str, object]] = None,
                           knob_space: Optional[KnobSpace] = None,
                           size_model: Optional[SizeModel] = None,
                           policies: Optional[Sequence[str]] = None,
                           ) -> TuneResult:
        """Joint (knob x split) retune on PRECOMPUTED profiles.

        The serving loop's retune path: ``profiles`` is a capacity-
        independent :class:`GridProfiles` — typically assembled
        incrementally by a workload sketch (``WindowSketch.to_profiles``)
        rather than by a ``grid_profiles`` pass — and this method runs only
        the solve-and-argmin half of :meth:`tune`.  No trace replay, no
        re-profiling: exactly one batched ``solve_profiles`` call.
        """
        session = self
        if budget is not None:
            session = TuningSession(
                dataclasses.replace(self.system,
                                    memory_budget_bytes=float(budget)),
                self.splits)
        space = knob_space if knob_space is not None \
            else builder.knob_space(overrides)
        tuner = CamTuner(policies=tuple(policies) if policies else None)
        return tuner.tune_profiles(
            session, builder, space, profiles,
            objective=objective, size_model=size_model)
