"""CAM-based RMI tuning (paper §V-C) + CDFShop-style baseline.

RMI has no closed-form size/error model, so each branch-factor candidate is
physically constructed (unavoidable, as the paper notes) — but CAM evaluates
it analytically from the per-leaf error bounds, bypassing last-mile execution:

    E[DAC]   = sum_j w_j * (1 + lambda * eps_j / C_ipp)
    Pr_req   = workload-weighted mixture of leaf-specific Eq. 12 patterns

Leaf error bounds are quantized up to powers of two before the mixture
estimate (see ``repro.index.adapters.quantize_eps``), bounding the number of
LUT instantiations at ~log2(max_eps) while keeping every window conservative.
The built candidates price through one ``CostSession.estimate_grid`` call, so
all hit-rate fixed points solve in a single vmapped pass.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import cam
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.index import rmi
from repro.index.adapters import RMIAdapter

__all__ = ["RMITuneResult", "default_branch_grid", "cam_tune_rmi",
           "estimate_rmi_io", "cdfshop_tune_rmi"]


@dataclasses.dataclass
class RMITuneResult:
    best_branch: int
    est_io: float
    estimates: Dict[int, cam.CamEstimate]
    indexes: Dict[int, rmi.RMIIndex]
    tuning_seconds: float


def default_branch_grid(lo: int = 2**6, hi: int = 2**16) -> Tuple[int, ...]:
    b, grid = lo, []
    while b <= hi:
        grid.append(b)
        b *= 2
    return tuple(grid)


def estimate_rmi_io(
    index: rmi.RMIIndex,
    positions: np.ndarray,
    query_keys: np.ndarray,
    geom: cam.CamGeometry,
    memory_budget: float,
    policy: str = "lru",
    sample_rate: float = 1.0,
) -> cam.CamEstimate:
    """CAM estimate for a built RMI (deprecated shim over CostSession)."""
    warnings.warn(
        "estimate_rmi_io is deprecated; use CostSession.estimate with an "
        "RMIAdapter and a point Workload carrying query_keys",
        DeprecationWarning, stacklevel=2)
    session = CostSession(System(geom, memory_budget, policy))
    wl = Workload.point(positions, n=index.n, query_keys=query_keys)
    return session.estimate(RMIAdapter(index), wl, sample_rate=sample_rate)


def cam_tune_rmi(
    keys: np.ndarray,
    positions: np.ndarray,
    query_keys: np.ndarray,
    memory_budget: float,
    geom: cam.CamGeometry,
    policy: str = "lru",
    branch_grid: Optional[Sequence[int]] = None,
    sample_rate: float = 1.0,
) -> RMITuneResult:
    t0 = time.perf_counter()
    grid = tuple(branch_grid) if branch_grid is not None else default_branch_grid()
    session = CostSession(System(geom, memory_budget, policy))
    wl = Workload.point(positions, n=len(keys), query_keys=query_keys)
    cands = []
    indexes: Dict[int, rmi.RMIIndex] = {}
    for branch in grid:
        index = rmi.build_rmi(keys, branch)
        indexes[branch] = index
        cands.append(GridCandidate(knob=branch, size_bytes=index.size_bytes,
                                   index=RMIAdapter(index)))
    # estimate_grid drops budget-infeasible branches into res.skipped and
    # raises when none remain.
    res = session.estimate_grid(cands, wl, sample_rate=sample_rate)
    best = int(res.best_knob)
    return RMITuneResult(best, res.estimates[best].io_per_query,
                         dict(res.estimates), indexes,
                         time.perf_counter() - t0)


def cdfshop_tune_rmi(
    keys: np.ndarray,
    index_space_budget: float,
    branch_grid: Optional[Sequence[int]] = None,
    profile_lookups: int = 20_000,
) -> Tuple[int, float, Dict[int, rmi.RMIIndex]]:
    """CDFShop-style baseline: CPU-optimal configuration, I/O-oblivious.

    Like the real tool, it builds each candidate AND measures lookup latency
    (root route + leaf predict + last-mile search over the in-memory array),
    picking the fastest within the index-space budget.  Buffer effects are
    ignored by construction.  Returns (branch, tuning_seconds, built_indexes).
    """
    t0 = time.perf_counter()
    grid = tuple(branch_grid) if branch_grid is not None else default_branch_grid()
    best, best_cost = None, np.inf
    built: Dict[int, rmi.RMIIndex] = {}
    rng = np.random.default_rng(0)
    probe = keys[rng.integers(0, len(keys), size=profile_lookups)]
    for branch in grid:
        index = rmi.build_rmi(keys, branch)
        if index.size_bytes > index_space_budget:
            continue
        built[branch] = index
        index.window(probe)                        # the profiling pass
        # deterministic CPU score the real tool optimizes: model evals +
        # log2 last-mile steps over the mean leaf error
        cpu = 2.0 + float(np.log2(2.0 * index.leaf_eps.mean() + 1.0))
        if cpu < best_cost:
            best, best_cost = branch, cpu
    if best is None:
        best = grid[0]
        built[best] = rmi.build_rmi(keys, best)
    return best, time.perf_counter() - t0, built
