"""DEPRECATED shims: CAM-based RMI tuning (paper §V-C) + CDFShop baseline.

Every entry point delegates to :class:`repro.tuning.session.TuningSession`
with an :class:`~repro.tuning.session.RMIBuilder`.  Two behavioral upgrades
ride along (selection unchanged on golden seeds):

* RMI's size model is EXACT and analytic (``rmi.rmi_size_bytes``), so
  budget-infeasible branch factors are skipped *before construction* — the
  legacy path built every candidate eagerly and let ``estimate_grid`` drop
  the infeasible ones afterwards;
* feasible branch grids profile through the batched mixed-eps kernel
  (one grouped pass for the whole grid) instead of per-branch mixture
  histograms.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import cam
from repro.core.session import CostSession, System
from repro.core.workload import Workload
from repro.index import rmi
from repro.index.adapters import RMIAdapter

__all__ = ["RMITuneResult", "default_branch_grid", "cam_tune_rmi",
           "estimate_rmi_io", "cdfshop_tune_rmi"]


def _deprecated(name: str) -> None:
    warnings.warn(
        f"repro.tuning.rmi_tuner.{name} is deprecated; use "
        "repro.tuning.session.TuningSession with an RMIBuilder",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class RMITuneResult:
    best_branch: int
    est_io: float
    estimates: Dict[int, cam.CamEstimate]
    indexes: Dict[int, rmi.RMIIndex]
    tuning_seconds: float


def default_branch_grid(lo: int = 2**6, hi: int = 2**16) -> Tuple[int, ...]:
    """Doubling branch grid (delegates to the one implementation behind the
    adapters' knob metadata, ``repro.index.adapters.pow2_grid``)."""
    from repro.index.adapters import pow2_grid

    return pow2_grid(lo, hi)


def estimate_rmi_io(
    index: rmi.RMIIndex,
    positions: np.ndarray,
    query_keys: np.ndarray,
    geom: cam.CamGeometry,
    memory_budget: float,
    policy: str = "lru",
    sample_rate: float = 1.0,
) -> cam.CamEstimate:
    """CAM estimate for a built RMI (deprecated shim over CostSession)."""
    warnings.warn(
        "estimate_rmi_io is deprecated; use CostSession.estimate with an "
        "RMIAdapter and a point Workload carrying query_keys",
        DeprecationWarning, stacklevel=2)
    session = CostSession(System(geom, memory_budget, policy))
    wl = Workload.point(positions, n=index.n, query_keys=query_keys)
    return session.estimate(RMIAdapter(index), wl, sample_rate=sample_rate)


def cam_tune_rmi(
    keys: np.ndarray,
    positions: np.ndarray,
    query_keys: np.ndarray,
    memory_budget: float,
    geom: cam.CamGeometry,
    policy: str = "lru",
    branch_grid: Optional[Sequence[int]] = None,
    sample_rate: float = 1.0,
) -> RMITuneResult:
    """Branch-factor tuning (deprecated shim over ``TuningSession.tune``).

    ``RMITuneResult.indexes`` now contains only the candidates that were
    actually constructed — i.e. the budget-FEASIBLE branches; the legacy
    path built the infeasible ones too, for nothing.
    """
    _deprecated("cam_tune_rmi")
    from repro.tuning.session import RMIBuilder, TuningSession

    t0 = time.perf_counter()
    builder = RMIBuilder(keys)
    grid = tuple(int(b) for b in branch_grid) if branch_grid is not None \
        else default_branch_grid()
    res = TuningSession(System(geom, memory_budget, policy)).tune(
        builder, Workload.point(positions, n=len(keys),
                                query_keys=query_keys),
        overrides={"branch": grid}, sample_rate=sample_rate)
    indexes = {b: adapter.index for b, adapter in builder.built.items()}
    return RMITuneResult(int(res.best_knob), res.est_io, res.estimates,
                         indexes, time.perf_counter() - t0)


def cdfshop_tune_rmi(
    keys: np.ndarray,
    index_space_budget: float,
    branch_grid: Optional[Sequence[int]] = None,
    profile_lookups: int = 20_000,
) -> Tuple[int, float, Dict[int, rmi.RMIIndex]]:
    """CDFShop-style baseline (deprecated shim over
    ``TuningSession.tune(tuner=CDFShopTuner(...))``).

    Returns (branch, tuning_seconds, built_indexes).  The legacy tool built
    every candidate before checking its size; the size-model path skips the
    infeasible builds with the selection unchanged.
    """
    _deprecated("cdfshop_tune_rmi")
    from repro.tuning.session import CDFShopTuner, RMIBuilder, TuningSession

    t0 = time.perf_counter()
    builder = RMIBuilder(keys)
    grid = tuple(int(b) for b in branch_grid) if branch_grid is not None \
        else default_branch_grid()
    session = TuningSession(System(cam.CamGeometry(),
                                   2.0 * index_space_budget, "lru"))
    res = session.tune(
        builder, Workload.point(np.zeros(1, np.int64), n=len(keys)),
        tuner=CDFShopTuner(profile_lookups=profile_lookups),
        overrides={"branch": grid})
    built = {b: adapter.index for b, adapter in builder.built.items()}
    return int(res.best_knob), time.perf_counter() - t0, built
