"""CAM-based RMI tuning (paper §V-C) + CDFShop-style baseline.

RMI has no closed-form size/error model, so each branch-factor candidate is
physically constructed (unavoidable, as the paper notes) — but CAM evaluates
it analytically from the per-leaf error bounds, bypassing last-mile execution:

    E[DAC]   = sum_j w_j * (1 + lambda * eps_j / C_ipp)
    Pr_req   = workload-weighted mixture of leaf-specific Eq. 12 patterns

Leaf error bounds are quantized up to powers of two before the mixture
estimate, bounding the number of LUT instantiations at ~log2(max_eps) while
keeping every window conservative (a TPU/XLA-friendly adaptation: few big
vectorized passes instead of thousands of per-leaf loops).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import cam, cache_models, dac, page_ref
from repro.index import rmi
from repro.tuning import fit as fit_mod

__all__ = ["RMITuneResult", "default_branch_grid", "cam_tune_rmi", "cdfshop_tune_rmi"]


@dataclasses.dataclass
class RMITuneResult:
    best_branch: int
    est_io: float
    estimates: Dict[int, cam.CamEstimate]
    indexes: Dict[int, rmi.RMIIndex]
    tuning_seconds: float


def default_branch_grid(lo: int = 2**6, hi: int = 2**16) -> Tuple[int, ...]:
    b, grid = lo, []
    while b <= hi:
        grid.append(b)
        b *= 2
    return tuple(grid)


def _quantize_eps(eps: np.ndarray) -> np.ndarray:
    """Round leaf error bounds up to powers of two (conservative windows)."""
    eps = np.maximum(np.asarray(eps, np.int64), 1)
    return (2 ** np.ceil(np.log2(eps))).astype(np.int64)


def estimate_rmi_io(
    index: rmi.RMIIndex,
    positions: np.ndarray,
    query_keys: np.ndarray,
    geom: cam.CamGeometry,
    memory_budget: float,
    policy: str = "lru",
    sample_rate: float = 1.0,
) -> cam.CamEstimate:
    """CAM estimate for a built RMI (workload-weighted leaf mixture)."""
    t0 = time.perf_counter()
    pos = np.asarray(positions)
    qk = np.asarray(query_keys)
    if sample_rate < 1.0:
        rng = np.random.default_rng(0)
        k = max(1, int(round(pos.shape[0] * sample_rate)))
        sel = np.sort(rng.choice(pos.shape[0], size=k, replace=False))
        pos, qk = pos[sel], qk[sel]
    leaf = index.route(qk)
    eps_q = _quantize_eps(index.leaf_eps[leaf])
    num_pages = geom.num_pages(index.n)
    counts, total = page_ref.point_page_refs_mixed_eps(pos, eps_q, geom.c_ipp, num_pages)

    weights = np.bincount(leaf, minlength=index.branch).astype(np.float64)
    weights /= max(weights.sum(), 1.0)
    e_dac = float(dac.expected_dac_rmi(index.leaf_eps, weights, geom.c_ipp, geom.strategy))

    capv = cam.capacity_pages(memory_budget, index.size_bytes, geom.page_bytes)
    sample_refs = float(total)
    total_f = sample_refs * max(1.0, len(positions) / max(len(pos), 1))
    n_distinct = float((np.asarray(counts) > 0).sum())
    if capv <= 0:
        h = 0.0
    else:
        import jax.numpy as jnp

        probs = jnp.asarray(counts) / max(sample_refs, 1e-30)
        h = float(cache_models.hit_rate(policy, capv, probs,
                                        total_requests=total_f,
                                        distinct_pages=n_distinct))
    io = (1.0 - h) * e_dac
    return cam.CamEstimate(io, h, e_dac, capv, total_f, n_distinct,
                           time.perf_counter() - t0, policy)


def cam_tune_rmi(
    keys: np.ndarray,
    positions: np.ndarray,
    query_keys: np.ndarray,
    memory_budget: float,
    geom: cam.CamGeometry,
    policy: str = "lru",
    branch_grid: Optional[Sequence[int]] = None,
    sample_rate: float = 1.0,
) -> RMITuneResult:
    t0 = time.perf_counter()
    grid = tuple(branch_grid) if branch_grid is not None else default_branch_grid()
    estimates: Dict[int, cam.CamEstimate] = {}
    indexes: Dict[int, rmi.RMIIndex] = {}
    for branch in grid:
        index = rmi.build_rmi(keys, branch)
        if index.size_bytes >= memory_budget - geom.page_bytes:
            continue
        indexes[branch] = index
        estimates[branch] = estimate_rmi_io(
            index, positions, query_keys, geom, memory_budget,
            policy=policy, sample_rate=sample_rate,
        )
    if not estimates:
        raise ValueError("memory budget too small for any RMI candidate")
    best = min(estimates, key=lambda b: estimates[b].io_per_query)
    return RMITuneResult(best, estimates[best].io_per_query, estimates, indexes,
                         time.perf_counter() - t0)


def cdfshop_tune_rmi(
    keys: np.ndarray,
    index_space_budget: float,
    branch_grid: Optional[Sequence[int]] = None,
    profile_lookups: int = 20_000,
) -> Tuple[int, float, Dict[int, rmi.RMIIndex]]:
    """CDFShop-style baseline: CPU-optimal configuration, I/O-oblivious.

    Like the real tool, it builds each candidate AND measures lookup latency
    (root route + leaf predict + last-mile search over the in-memory array),
    picking the fastest within the index-space budget.  Buffer effects are
    ignored by construction.  Returns (branch, tuning_seconds, built_indexes).
    """
    t0 = time.perf_counter()
    grid = tuple(branch_grid) if branch_grid is not None else default_branch_grid()
    best, best_cost = None, np.inf
    built: Dict[int, rmi.RMIIndex] = {}
    rng = np.random.default_rng(0)
    probe = keys[rng.integers(0, len(keys), size=profile_lookups)]
    for branch in grid:
        index = rmi.build_rmi(keys, branch)
        if index.size_bytes > index_space_budget:
            continue
        built[branch] = index
        index.window(probe)                        # the profiling pass
        # deterministic CPU score the real tool optimizes: model evals +
        # log2 last-mile steps over the mean leaf error
        cpu = 2.0 + float(np.log2(2.0 * index.leaf_eps.mean() + 1.0))
        if cpu < best_cost:
            best, best_cost = branch, cpu
    if best is None:
        best = grid[0]
        built[best] = rmi.build_rmi(keys, best)
    return best, time.perf_counter() - t0, built
