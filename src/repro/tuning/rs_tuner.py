"""CAM-based RadixSpline tuning — the third index family under one API.

RadixSpline's greedy spline corridor is uniformly error-bounded exactly like
PGM (|interp(k) - rank(k)| <= eps), so the corridor eps is a tunable knob and
the WHOLE uniform-eps machinery applies unchanged: fit a power-law size model
from a few sampled builds, then price the dense eps grid in one
``CostSession.estimate_grid`` pass.  The seed repo shipped RadixSpline with
no estimation or tuning path at all; this module closes that gap and is the
concrete payoff of the index-agnostic redesign.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import cam
from repro.core.session import System
from repro.core.workload import Workload
from repro.index import radixspline
from repro.tuning import fit
from repro.tuning.pgm_tuner import cam_tune_uniform_eps, default_eps_grid

__all__ = ["RSTuneResult", "profile_radixspline_size_model",
           "cam_tune_radixspline"]


@dataclasses.dataclass
class RSTuneResult:
    best_eps: int
    est_io: float
    estimates: Dict[int, cam.CamEstimate]
    size_model: fit.PowerLawFit
    tuning_seconds: float


def profile_radixspline_size_model(
    keys: np.ndarray, sample_eps: Sequence[int] = (16, 64, 256, 1024),
    radix_bits: int = 16,
) -> Tuple[fit.PowerLawFit, float]:
    """Build a few RadixSplines, fit M_idx(eps) = a*eps^-b + c.

    The knot count shrinks roughly as a power of the corridor width, so the
    same fitting trick as PGM's applies; the radix table contributes the
    constant term c.
    """
    t0 = time.perf_counter()
    sizes = [radixspline.build_radixspline(keys, e, radix_bits).size_bytes
             for e in sample_eps]
    model = fit.fit_power_law(list(sample_eps), sizes)
    return model, time.perf_counter() - t0


def cam_tune_radixspline(
    keys: np.ndarray,
    positions: np.ndarray,
    memory_budget: float,
    geom: cam.CamGeometry,
    policy: str = "lru",
    eps_grid: Optional[Sequence[int]] = None,
    sample_eps: Sequence[int] = (16, 64, 256, 1024),
    sample_rate: float = 1.0,
    radix_bits: int = 16,
) -> RSTuneResult:
    """Pick the corridor eps* minimizing Eq. 15/16 under the memory budget."""
    t0 = time.perf_counter()
    size_model, _ = profile_radixspline_size_model(keys, sample_eps, radix_bits)
    grid = tuple(eps_grid) if eps_grid is not None else default_eps_grid()
    best_eps, estimates, _ = cam_tune_uniform_eps(
        Workload.point(positions, n=len(keys)), size_model,
        System(geom, memory_budget, policy), grid, sample_rate)
    return RSTuneResult(
        best_eps=best_eps,
        est_io=estimates[best_eps].io_per_query,
        estimates=estimates,
        size_model=size_model,
        tuning_seconds=time.perf_counter() - t0,
    )
