"""DEPRECATED shims: CAM-based RadixSpline tuning.

Delegates to :class:`repro.tuning.session.TuningSession` with a
:class:`~repro.tuning.session.RadixSplineBuilder`.  The legacy entry point
pinned ``radix_bits`` and tuned the corridor eps alone; the session tunes
the full 2-D (eps x radix_bits) plane — ``cam_tune_radixspline`` keeps the
pinned-bits behavior for golden equivalence.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import cam
from repro.core.session import System
from repro.core.workload import Workload
from repro.tuning import fit

__all__ = ["RSTuneResult", "profile_radixspline_size_model",
           "cam_tune_radixspline"]


def _deprecated(name: str) -> None:
    warnings.warn(
        f"repro.tuning.rs_tuner.{name} is deprecated; use "
        "repro.tuning.session.TuningSession with a RadixSplineBuilder "
        "(which also tunes radix_bits jointly)",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class RSTuneResult:
    best_eps: int
    est_io: float
    estimates: Dict[int, cam.CamEstimate]
    size_model: object          # callable knobs -> bytes
    tuning_seconds: float


def profile_radixspline_size_model(
    keys: np.ndarray, sample_eps: Sequence[int] = (16, 64, 256, 1024),
    radix_bits: int = 16,
) -> Tuple[fit.PowerLawFit, float]:
    """Fit M_idx(eps) at fixed ``radix_bits`` (deprecated shim over the 2-D
    :class:`repro.tuning.session.RadixSplineSizeModel`)."""
    _deprecated("profile_radixspline_size_model")
    from repro.index import radixspline
    t0 = time.perf_counter()
    sizes = [radixspline.build_radixspline(keys, e, radix_bits).size_bytes
             for e in sample_eps]
    model = fit.fit_power_law(list(sample_eps), sizes)
    return model, time.perf_counter() - t0


def cam_tune_radixspline(
    keys: np.ndarray,
    positions: np.ndarray,
    memory_budget: float,
    geom: cam.CamGeometry,
    policy: str = "lru",
    eps_grid: Optional[Sequence[int]] = None,
    sample_eps: Sequence[int] = (16, 64, 256, 1024),
    sample_rate: float = 1.0,
    radix_bits: int = 16,
) -> RSTuneResult:
    """Corridor-eps tuning at pinned ``radix_bits`` (deprecated shim)."""
    _deprecated("cam_tune_radixspline")
    from repro.tuning.session import RadixSplineBuilder, TuningSession
    from repro.tuning.pgm_tuner import default_eps_grid

    t0 = time.perf_counter()
    builder = RadixSplineBuilder(keys, tuple(sample_eps),
                                 ref_radix_bits=radix_bits)
    grid = tuple(int(e) for e in eps_grid) if eps_grid is not None \
        else default_eps_grid()
    res = TuningSession(System(geom, memory_budget, policy)).tune(
        builder, Workload.point(positions, n=len(keys)),
        overrides={"eps": grid, "radix_bits": radix_bits},
        sample_rate=sample_rate)
    # the pinned 2-D space keys estimates by (eps, radix_bits); re-key to
    # the legacy eps-only shape
    estimates = {knob[0]: est for knob, est in res.estimates.items()}
    return RSTuneResult(
        best_eps=int(res.best["eps"]),
        est_io=res.est_io,
        estimates=estimates,
        size_model=res.size_model,
        tuning_seconds=time.perf_counter() - t0,
    )
