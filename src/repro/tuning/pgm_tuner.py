"""CAM-based PGM tuning under a memory budget (paper §V-B).

Given total memory M split between index and buffer, pick

    eps* = argmin_eps (1 - h(M - M_idx(eps))) * E[DAC(eps)]        (Eq. 15/16)

M_idx(eps) follows the fitted dataset-specific power law a*eps^-b + c from a
few sampled constructions (the multicriteria-PGM fitting trick), so the dense
eps grid costs one CAM estimate per candidate — no index builds in the loop.
The whole grid now prices through ``CostSession.estimate_grid``: one jitted
pass over shared page-ref state instead of a per-candidate Python loop.

The baseline ``multicriteria_pgm_tune`` reproduces the cache-oblivious tuner:
it receives a fixed index-space budget (a reserved fraction of M) and picks
the most accurate (smallest-eps) index that fits, ignoring the buffer interaction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import cam
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.index import pgm
from repro.tuning import fit

__all__ = ["PGMTuneResult", "default_eps_grid", "profile_pgm_size_model",
           "cam_tune_pgm", "cam_tune_uniform_eps", "multicriteria_pgm_tune"]


@dataclasses.dataclass
class PGMTuneResult:
    best_eps: int
    est_io: float
    estimates: Dict[int, cam.CamEstimate]
    size_model: fit.PowerLawFit
    tuning_seconds: float


def default_eps_grid(lo: int = 4, hi: int = 4096) -> Tuple[int, ...]:
    """Dense sqrt(2)-spaced grid — much denser than what replay could afford."""
    grid = []
    e = float(lo)
    while e <= hi:
        grid.append(int(round(e)))
        e *= np.sqrt(2.0)
    return tuple(dict.fromkeys(grid))


def profile_pgm_size_model(
    keys: np.ndarray, sample_eps: Sequence[int] = (16, 64, 256, 1024)
) -> Tuple[fit.PowerLawFit, float]:
    """Build a few PGMs, fit M_idx(eps) = a*eps^-b + c (§V-B)."""
    t0 = time.perf_counter()
    sizes = [pgm.build_pgm(keys, e).size_bytes for e in sample_eps]
    model = fit.fit_power_law(list(sample_eps), sizes)
    return model, time.perf_counter() - t0


def cam_tune_uniform_eps(
    workload: Workload,
    size_model: fit.PowerLawFit,
    system: System,
    eps_grid: Sequence[int],
    sample_rate: float = 1.0,
) -> Tuple[int, Dict[int, cam.CamEstimate], float]:
    """Shared grid tuner for any uniformly error-bounded family.

    One batched ``estimate_grid`` call prices the entire eps grid; the
    session itself drops infeasible candidates (no room for even one buffer
    page) into ``GridResult.skipped`` and raises when none remain.
    Returns (best_eps, estimates, grid_seconds).
    """
    session = CostSession(system)
    cands = [
        GridCandidate(knob=int(e), eps=int(e), size_bytes=float(size_model(e)))
        for e in eps_grid
    ]
    res = session.estimate_grid(cands, workload, sample_rate=sample_rate)
    return int(res.best_knob), dict(res.estimates), res.seconds


def cam_tune_pgm(
    keys: np.ndarray,
    positions: np.ndarray,
    memory_budget: float,
    geom: cam.CamGeometry,
    policy: str = "lru",
    eps_grid: Optional[Sequence[int]] = None,
    sample_eps: Sequence[int] = (16, 64, 256, 1024),
    sample_rate: float = 1.0,
) -> PGMTuneResult:
    t0 = time.perf_counter()
    size_model, _ = profile_pgm_size_model(keys, sample_eps)
    grid = tuple(eps_grid) if eps_grid is not None else default_eps_grid()
    best_eps, estimates, _ = cam_tune_uniform_eps(
        Workload.point(positions, n=len(keys)), size_model,
        System(geom, memory_budget, policy), grid, sample_rate)
    return PGMTuneResult(
        best_eps=best_eps,
        est_io=estimates[best_eps].io_per_query,
        estimates=estimates,
        size_model=size_model,
        tuning_seconds=time.perf_counter() - t0,
    )


def multicriteria_pgm_tune(
    keys: np.ndarray,
    index_space_budget: float,
    eps_grid: Optional[Sequence[int]] = None,
    sample_eps: Sequence[int] = (16, 64, 256, 1024),
    profile_lookups: int = 20_000,
) -> Tuple[int, float]:
    """Cache-oblivious baseline: the multicriteria PGM optimizer's
    time-minimization-given-space mode.

    Like the real tool, it PROFILES candidates: builds each feasible index
    and measures lookup latency (traversal + last-mile search over the
    in-memory array), picking the fastest one that fits the space budget.
    Buffer interaction is invisible to it by construction.
    Returns (eps, tuning_seconds).
    """
    t0 = time.perf_counter()
    size_model, _ = profile_pgm_size_model(keys, sample_eps)
    grid = tuple(eps_grid) if eps_grid is not None else default_eps_grid()
    feasible = [e for e in grid if float(size_model(e)) <= index_space_budget]
    if not feasible:
        feasible = [max(grid)]
    if profile_lookups:
        # The real tool builds each candidate and profiles lookups; we build
        # (real cost, reflected in tuning time) and score with the
        # deterministic in-memory cost model it optimizes: traversal levels
        # + log2 last-mile steps.  Wall-clock scoring on a noisy shared CPU
        # would just measure noise.
        rng = np.random.default_rng(0)
        probe = keys[rng.integers(0, len(keys), size=profile_lookups)]
        best, best_c = None, np.inf
        for eps in feasible[:10]:
            idx = pgm.build_pgm(keys, eps)
            idx.predict(probe)                       # the profiling pass
            cpu = 1.5 * len(idx.levels) + np.log2(2 * eps + 1)
            if cpu < best_c:
                best, best_c = eps, cpu
        return best, time.perf_counter() - t0
    return min(feasible), time.perf_counter() - t0
