"""DEPRECATED shims: CAM-based PGM tuning (paper §V-B).

Every entry point here now delegates to the ONE tuning surface,
:class:`repro.tuning.session.TuningSession` — declarative knob spaces, lazy
size models, pluggable tuner strategies, and the joint (knob x buffer-split)
search.  The shims are kept for golden equivalence: same signatures, same
result shapes, same chosen knobs on fixed seeds.  New code should build a
:class:`~repro.tuning.session.PGMBuilder` and call ``TuningSession.tune``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import cam
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.tuning import fit

__all__ = ["PGMTuneResult", "default_eps_grid", "profile_pgm_size_model",
           "cam_tune_pgm", "cam_tune_uniform_eps", "multicriteria_pgm_tune"]


def _deprecated(name: str) -> None:
    warnings.warn(
        f"repro.tuning.pgm_tuner.{name} is deprecated; use "
        "repro.tuning.session.TuningSession with a PGMBuilder",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class PGMTuneResult:
    best_eps: int
    est_io: float
    estimates: Dict[int, cam.CamEstimate]
    size_model: fit.PowerLawFit
    tuning_seconds: float


def default_eps_grid(lo: int = 4, hi: int = 4096) -> Tuple[int, ...]:
    """Dense sqrt(2)-spaced grid — much denser than what replay could afford.

    Delegates to the one implementation behind the adapters' knob metadata
    (``repro.index.adapters.sqrt2_grid``)."""
    from repro.index.adapters import sqrt2_grid

    return sqrt2_grid(lo, hi)


def profile_pgm_size_model(
    keys: np.ndarray, sample_eps: Sequence[int] = (16, 64, 256, 1024)
) -> Tuple[fit.PowerLawFit, float]:
    """Build a few PGMs, fit M_idx(eps) = a*eps^-b + c (deprecated shim over
    the lazy :class:`repro.tuning.session.PowerLawSizeModel`)."""
    _deprecated("profile_pgm_size_model")
    from repro.tuning.session import PGMBuilder

    model = PGMBuilder(keys, tuple(sample_eps)).size_model()
    fitted = model.fitted
    return fitted, model.fit_seconds


def cam_tune_uniform_eps(
    workload: Workload,
    size_model: fit.PowerLawFit,
    system: System,
    eps_grid: Sequence[int],
    sample_rate: float = 1.0,
) -> Tuple[int, Dict[int, cam.CamEstimate], float]:
    """Shared grid tuner for any uniformly error-bounded family (deprecated
    shim; ``CostSession.estimate_grid`` semantics preserved exactly).

    Returns (best_eps, estimates, grid_seconds).
    """
    _deprecated("cam_tune_uniform_eps")
    session = CostSession(system)
    cands = [
        GridCandidate(knob=int(e), eps=int(e), size_bytes=float(size_model(e)))
        for e in eps_grid
    ]
    res = session.estimate_grid(cands, workload, sample_rate=sample_rate)
    return int(res.best_knob), dict(res.estimates), res.seconds


def cam_tune_pgm(
    keys: np.ndarray,
    positions: np.ndarray,
    memory_budget: float,
    geom: cam.CamGeometry,
    policy: str = "lru",
    eps_grid: Optional[Sequence[int]] = None,
    sample_eps: Sequence[int] = (16, 64, 256, 1024),
    sample_rate: float = 1.0,
) -> PGMTuneResult:
    """Eq. 15/16 eps tuning (deprecated shim over ``TuningSession.tune``)."""
    _deprecated("cam_tune_pgm")
    from repro.tuning.session import PGMBuilder, TuningSession

    t0 = time.perf_counter()
    builder = PGMBuilder(keys, tuple(sample_eps))
    grid = tuple(int(e) for e in eps_grid) if eps_grid is not None \
        else default_eps_grid()
    res = TuningSession(System(geom, memory_budget, policy)).tune(
        builder, Workload.point(positions, n=len(keys)),
        overrides={"eps": grid}, sample_rate=sample_rate)
    return PGMTuneResult(
        best_eps=int(res.best_knob),
        est_io=res.est_io,
        estimates=res.estimates,
        size_model=res.size_model.fitted,
        tuning_seconds=time.perf_counter() - t0,
    )


def multicriteria_pgm_tune(
    keys: np.ndarray,
    index_space_budget: float,
    eps_grid: Optional[Sequence[int]] = None,
    sample_eps: Sequence[int] = (16, 64, 256, 1024),
    profile_lookups: int = 20_000,
) -> Tuple[int, float]:
    """Cache-oblivious multicriteria-PGM baseline (deprecated shim over
    ``TuningSession.tune(tuner=MulticriteriaTuner(...))``).

    Returns (eps, tuning_seconds).
    """
    _deprecated("multicriteria_pgm_tune")
    from repro.tuning.session import (MulticriteriaTuner, PGMBuilder,
                                      TuningSession)

    t0 = time.perf_counter()
    builder = PGMBuilder(keys, tuple(sample_eps))
    grid = tuple(int(e) for e in eps_grid) if eps_grid is not None \
        else default_eps_grid()
    if not profile_lookups:
        # legacy profile-free mode: the most accurate candidate that fits
        model = builder.size_model()
        feasible = [e for e in grid
                    if float(model(eps=e)) <= index_space_budget]
        return min(feasible or [max(grid)]), time.perf_counter() - t0
    # The baseline reserves half the (synthetic) budget as buffer, so a
    # budget of 2x the index space reproduces the legacy index_space_budget.
    session = TuningSession(System(cam.CamGeometry(),
                                   2.0 * index_space_budget, "lru"))
    res = session.tune(
        builder, Workload.point(np.zeros(1, np.int64), n=len(keys)),
        tuner=MulticriteriaTuner(profile_lookups=profile_lookups),
        overrides={"eps": grid})
    return int(res.best_knob), time.perf_counter() - t0
