"""Quickstart: CAM through the index-agnostic CostSession API — estimate
physical I/O for THREE disk-resident learned indexes (PGM, RMI, RadixSpline)
WITHOUT replaying the workload, and check each against ground truth.

    PYTHONPATH=src python examples/quickstart.py [--smoke]
"""
import argparse

import numpy as np

from repro.core.cam import CamGeometry
from repro.core.qerror import q_error
from repro.core.replay import replay_windows
from repro.core.session import CostSession, System
from repro.core.workload import Workload
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.adapters import PGMAdapter, RMIAdapter, RadixSplineAdapter

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized inputs (~5x below the demo default)")
args = ap.parse_args()
N, Q = (200_000, 20_000) if args.smoke else (1_000_000, 100_000)

# 1. a sorted key set ("on disk") and a skewed point-lookup workload;
#    the Workload locates true positions ONCE and caches them for every
#    estimate that follows
keys = make_dataset("books", N, seed=1)
query_keys, _ = point_workload(keys, Q, WorkloadSpec("w4", seed=3))
workload = Workload.from_keys(keys, query_keys)

# 2. the System: page geometry + a 2 MiB memory budget shared by index and
#    buffer + LRU eviction
system = System(geom=CamGeometry(c_ipp=256, page_bytes=4096),
                memory_budget_bytes=2 << 20, policy="lru")
session = CostSession(system)

# 3. three different index designs, ONE estimation surface
for adapter in (PGMAdapter.build(keys, eps=64),
                RMIAdapter.build(keys, branch=4096),
                RadixSplineAdapter.build(keys, eps=64, radix_bits=12)):
    est = session.estimate(adapter, workload)

    # ground truth: replay the actual last-mile windows through a real buffer
    lo, hi = adapter.window(query_keys)
    capacity = max(1, system.capacity_for(adapter.size_bytes))
    misses = replay_windows(lo // system.geom.c_ipp, hi // system.geom.c_ipp,
                            capacity, system.policy)
    print(f"{adapter.family:12s} ({adapter.size_bytes / 1024:7.1f} KiB, "
          f"knobs {adapter.knobs()!r}):")
    print(f"  CAM    {est.io_per_query:.4f} IO/query "
          f"(hit rate {est.hit_rate:.3f}) in "
          f"{est.estimation_seconds * 1e3:.0f} ms")
    print(f"  replay {misses.mean():.4f} IO/query   "
          f"Q-error {float(q_error(est.io_per_query, misses.mean())):.3f}\n")
