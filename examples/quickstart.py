"""Quickstart: CAM in 40 lines — estimate physical I/O for a disk-resident
PGM-index WITHOUT replaying the workload, and check it against ground truth.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import cam
from repro.core.qerror import q_error
from repro.core.replay import replay_windows
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.pgm import build_pgm

# 1. a sorted key set ("on disk") and a skewed point-lookup workload
keys = make_dataset("books", 1_000_000, seed=1)
query_keys, query_positions = point_workload(
    keys, 100_000, WorkloadSpec("w4", seed=3))

# 2. a disk-based PGM-index with error bound eps (index in memory, data paged)
eps = 64
index = build_pgm(keys, eps)
print(f"PGM eps={eps}: {index.num_segments} segments, "
      f"{index.size_bytes / 1024:.1f} KiB in memory")

# 3. CAM: replay-free physical-I/O estimate under an 8 MiB LRU page buffer
geom = cam.CamGeometry(c_ipp=256, page_bytes=4096)
budget = 8 << 20
est = cam.estimate_point_io(query_positions, eps, len(keys), geom,
                            budget, index.size_bytes, policy="lru")
print(f"CAM:    {est.io_per_query:.4f} physical I/Os per query "
      f"(hit rate {est.hit_rate:.3f}) in {est.estimation_seconds*1e3:.0f} ms")

# 4. ground truth: replay the actual last-mile windows through a real buffer
lo, hi = index.window(query_keys)
capacity = (budget - index.size_bytes) // geom.page_bytes
misses = replay_windows(lo // geom.c_ipp, hi // geom.c_ipp, capacity, "lru")
print(f"Replay: {misses.mean():.4f} physical I/Os per query")
print(f"Q-error: {float(q_error(est.io_per_query, misses.mean())):.3f}")
