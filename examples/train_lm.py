"""End-to-end training driver: a ~60M-param starcoder2-family LM trained for
a few hundred steps on CPU with the full production path — deterministic
pipeline, microbatching, checkpointing, fault-tolerant supervision.

    PYTHONPATH=src python examples/train_lm.py                  # quick demo
    PYTHONPATH=src python examples/train_lm.py --steps 300      # longer run
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.distributed.fault_tolerance import Supervisor
from repro.distributed.sharding import Recipe
from repro.launch.train import build_trainer
from repro.models.params import init_params
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
ap.add_argument("--smoke", action="store_true",
                help="CI-sized run (tiny model, a few steps)")
args = ap.parse_args()
if args.smoke:
    import tempfile
    args.steps, args.batch, args.seq = 8, 2, 32
    # fresh checkpoint dir: a resumed supervisor would train 0 new steps
    args.ckpt_dir = tempfile.mkdtemp(prefix="train_lm_smoke_")

# ~60M params: the starcoder2 wiring at 8 layers x 512 wide, 32k vocab
# (--smoke shrinks to a ~2M-param 2x128 stack so CI exercises the same
# pipeline/supervisor wiring in seconds)
if args.smoke:
    cfg = dataclasses.replace(
        get_config("starcoder2-3b"), num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=512, vocab_size=4096, head_dim=32)
else:
    cfg = dataclasses.replace(
        get_config("starcoder2-3b"), num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=2, d_ff=2048, vocab_size=32768, head_dim=64)
params = init_params(cfg, jax.random.PRNGKey(0))
n = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {cfg.name}-style, {n/1e6:.1f}M params, "
      f"{args.batch}x{args.seq} tokens/step")

recipe = Recipe(remat="block", microbatch=2)
opt_cfg = opt_mod.AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)
state = {"params": params,
         "opt_state": ts_mod.init_opt_state(params, cfg, recipe, opt_cfg)}
sup = Supervisor(build_trainer(cfg, recipe, opt_cfg), state,
                 pipe.batch_for_step, args.ckpt_dir, ckpt_every=25)

t0 = time.perf_counter()
res = sup.run(args.steps)
dt = time.perf_counter() - t0
l = res["losses"]
tok_s = args.batch * args.seq * len(l) / dt
print(f"{len(l)} steps in {dt:.0f}s ({tok_s:,.0f} tok/s) | "
      f"loss {l[0]:.3f} -> {l[-1]:.3f} | restarts={res['restarts']}")
assert l[-1] < l[0], "loss should decrease"
