"""Drift-aware serving: a live trace streams through ``ServingSession``,
which sketches the workload in a sliding window (no replay — per-batch
profile chunks merge associatively), watches TV divergence against the
sketch the deployed knob was tuned on, retunes the joint (eps x split)
search FROM THE SKETCH on drift, and rebuilds only when the Eq. 15/16
extension says steady-state I/O savings over the horizon repay the modeled
rebuild I/O (key-file scan + index write + cold-cache refill).

    PYTHONPATH=src python examples/serve_adaptive.py [--smoke]
"""
import argparse

import numpy as np

from repro.core.cam import CamGeometry
from repro.core.session import System
from repro.data.datasets import make_dataset
from repro.serving import (ServingConfig, ServingSession,
                           synthetic_drifting_trace)
from repro.tuning.session import PGMBuilder, TuningSession

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized inputs (~4x below the demo default)")
args = ap.parse_args()
N, SCALE = (50_000, 256) if args.smoke else (200_000, 1024)

keys = make_dataset("books", N, seed=1)
system = System(CamGeometry(c_ipp=256, page_bytes=4096),
                memory_budget_bytes=512 << 10, policy="lru")
serving = ServingSession(
    TuningSession(system), PGMBuilder(keys), keys,
    overrides={"eps": (8, 32, 128, 512)},
    config=ServingConfig(batch_size=SCALE, window_chunks=4,
                         drift_threshold=0.12, hysteresis=0.04,
                         horizon_queries=64 * SCALE))

# a three-act trace: stable hot points -> hot-set flash -> wide-range regime
events = synthetic_drifting_trace(keys, [
    {"events": 6 * SCALE, "mix": (0.8, 0.2, 0.0), "hot_center": 0.2,
     "hot_width": 0.05, "range_width": 16},
    {"events": 2 * SCALE, "mix": (0.8, 0.2, 0.0), "hot_center": 0.6,
     "hot_width": 0.05, "range_width": 16},
    {"events": 8 * SCALE, "mix": (0.1, 0.7, 0.2), "hot_center": 0.75,
     "hot_width": 0.4, "range_width": 2048},
], seed=7)

warmup, stream = events[:4 * SCALE], events[4 * SCALE:]
initial = serving.start(warmup)
print(f"deployed from warmup sketch: eps={initial.best_knob} "
      f"(split {initial.split:.2f}, {initial.capacity_pages} buffer pages, "
      f"est {initial.est_io:.4f} IO/q)")

for report in serving.observe(stream):
    line = (f"t={report.ts:6.0f}  batch of {report.n_queries:4d}  "
            f"TV={report.tv:.3f}")
    d = report.decision
    if d is None:
        print(line + ("  drift!" if report.drifted else ""))
        continue
    verdict = ("REBUILD" if d.switched else "keep   ")
    print(f"{line}  {verdict} eps {d.from_knob}->{d.to_knob}  "
          f"io {d.io_current:.4f}->{d.io_candidate:.4f}  "
          f"savings {d.predicted_savings:7.1f} vs rebuild "
          f"{d.rebuild_io:5.0f} IOs")

s = serving.stats
print(f"\n{s.batches} batches, {s.events} events: {s.drift_events} drift "
      f"triggers, {s.retune_evaluations} sketch-retunes, "
      f"{s.rebuilds} rebuilds")
assert s.retune_evaluations > 0, "trace should trigger at least one retune"
cur = serving.current
print(f"serving eps={cur.best_knob} at split {cur.split:.2f} "
      f"({cur.capacity_pages} pages)")
