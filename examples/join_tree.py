"""Multi-way join tree under ONE buffer budget (paper §VI, extended).

JoinTreeSession quickstart — from two-way joins to a serving-shaped plan
------------------------------------------------------------------------

A left-deep tree joins the outer stream through N inner relations.  Every
inner index is resident, so what the levels compete for is the ONE buffer
pool the memory budget leaves behind.  CAM already owns each level's miss
curve as a function of capacity — the policy-aware sorted-scan family for
sorted point probing, the IRM fixed points for INLJ — which turns pool
splitting into a batched model solve instead of trial replay:

1. **IndexModel per level** — adapt each inner relation's learned index::

       adapters = [PGMAdapter.build(keys, eps=32) for keys in inner_keys]

2. **One shared System** — the budget holds all three indexes + the pool::

       system = System(CamGeometry(), memory_budget_bytes=pool + idx_bytes,
                       policy="lfu")

3. **Bind the tree and let the model solve (split, strategies) jointly**::

       tree = JoinTreeSession(adapters, system, inner_keys)
       plan = tree.plan(outer)        # batched budget-split + strategy solve
       stats = tree.execute(plan)     # pipelined replay, level by level

``plan.fractions`` is the chosen pool split and ``plan.strategies`` the
per-level strategy.  Under frequency-based eviction the strategy crossover
is capacity-dependent (a level with enough buffer flips from range
scanning to point probes or INLJ), so the solver deliberately concentrates
the pool where the flip pays — the printed comparison shows what that buys
over a naive even split of the same pool.

    PYTHONPATH=src python examples/join_tree.py [--smoke]
"""
import argparse

from repro.core.cam import CamGeometry
from repro.core.session import System
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, join_outer_keys
from repro.index.adapters import PGMAdapter
from repro.join.tree import JoinTreeSession

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized inputs (smaller pool, one workload)")
args = ap.parse_args()
pool_pages = 512 if args.smoke else 780
workloads = ("w1",) if args.smoke else ("w1", "w2")
n, n_outer = 200_000, 800

# three inner relations sharing the join attribute (a star join): the fact
# keys, and two dimensions holding every 2nd / 3rd key
base = make_dataset("books", n, seed=1)
inner_keys = [base, base[::2].copy(), base[::3].copy()]
adapters = [PGMAdapter.build(k, eps=32) for k in inner_keys]
idx_bytes = sum(a.size_bytes for a in adapters)
system = System(CamGeometry(),
                memory_budget_bytes=pool_pages * 4096 + idx_bytes,
                policy="lfu")

tree = JoinTreeSession(adapters, system, inner_keys)
params = tree.calibrate()
print(f"3-level tree, {tree.pool_pages} shared buffer pages "
      f"(indexes {idx_bytes / 1024:.0f} KiB resident, LFU eviction)\n")

for wl in workloads:
    outer = join_outer_keys(base, n_outer, WorkloadSpec(wl, seed=9))
    plan = tree.plan(outer, grid=8, n_min=64, k_max=4096)
    stats = tree.execute(plan)
    print(f"workload {wl} ({n_outer} outer keys):")
    for lvl, (pl, st) in enumerate(zip(plan.levels, stats.per_level)):
        print(f"  level {lvl}: {pl.outer_keys.shape[0]:5d} probes  "
              f"{plan.fractions[lvl] * 100:4.1f}% pool "
              f"({plan.capacities[lvl]:4d} pages)  "
              f"{pl.strategy:11s} io={st.physical_ios}")
    print(f"  solved split: {stats.seconds:.4f}s, "
          f"io={stats.physical_ios}, matches={stats.matches} "
          f"(predicted {plan.cost.seconds:.4f}s)")

    # naive baseline: the same pool split evenly, strategies still chosen
    streams = tree.probe_streams(outer)
    even_cap = max(1, tree.pool_pages // tree.n_levels)
    even_plans = [sess.choose(streams[i], n_min=64, k_max=4096,
                              params=params, capacity=even_cap).plan
                  for i, sess in enumerate(tree.sessions)]
    even = [sess.execute(pl) for sess, pl in zip(tree.sessions, even_plans)]
    even_s = sum(st.seconds for st in even)
    even_io = sum(st.physical_ios for st in even)
    print(f"  even split:   {even_s:.4f}s, io={even_io} "
          f"({'/'.join(pl.strategy for pl in even_plans)})  "
          f"-> even/solved = {even_io / max(stats.physical_ios, 1):.2f}x "
          f"io\n")
