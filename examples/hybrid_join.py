"""CAM-guided hybrid join (paper §VI): density-aware point/range probing.

JoinSession quickstart — the three-noun API end to end
------------------------------------------------------

The join layer speaks the same three nouns as cost estimation:

1. **IndexModel** — adapt the inner relation's learned index::

       inner = PGMAdapter.build(inner_keys, eps=64)

2. **System** — where it runs (page geometry, memory budget, policy)::

       system = System(CamGeometry(), memory_budget_bytes=2 << 20,
                       policy="lru")

3. **Workload** — the outer probe stream (raw keys, or a
   ``Workload.mixed`` read blend)::

       outer = join_outer_keys(inner_keys, 100_000, WorkloadSpec("w4"))

Bind the first two in a session, then let the model pick the plan::

       session = JoinSession(inner, system, inner_keys=inner_keys)
       session.calibrate()                    # fit Eq. 17 coefficients
       result = session.choose(outer)         # CAM-predicted selection
       stats = session.execute(result.plan)   # one execution path

``session.plan(outer, strategy)`` builds any specific strategy — "inlj",
"point-only", "range-only", or "hybrid" (Algorithm 2 segments) — as a typed
plan with predicted costs; ``execute`` replays it exactly.

    PYTHONPATH=src python examples/hybrid_join.py [--smoke]
"""
import argparse

from repro.core.cam import CamGeometry
from repro.core.session import System
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, join_outer_keys
from repro.index.adapters import PGMAdapter
from repro.join.session import STRATEGIES, JoinSession

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized inputs (~5x below the demo default)")
args = ap.parse_args()
N, N_OUTER = (200_000, 20_000) if args.smoke else (1_000_000, 100_000)

inner_keys = make_dataset("books", N, seed=1)
inner = PGMAdapter.build(inner_keys, eps=64)
system = System(CamGeometry(), memory_budget_bytes=(1 << 20)
                + inner.size_bytes, policy="lru")

session = JoinSession(inner, system, inner_keys=inner_keys)
params = session.calibrate()
print(f"calibrated cost model: alpha={params.alpha:.2e} beta={params.beta:.2e}"
      f" lambda_point={params.lambda_point:.2e}"
      f" lambda_range={params.lambda_range:.2e}\n")

for wl in ("w1", "w3", "w4"):
    outer = join_outer_keys(inner_keys, N_OUTER, WorkloadSpec(wl, seed=9))
    print(f"workload {wl} ({N_OUTER // 1000}k outer x {N // 1000}k inner, "
          f"{session.capacity} buffer pages):")
    chosen = session.choose(outer, n_min=256, k_max=4096)
    for strategy in STRATEGIES:
        plan = chosen.plans[strategy]
        st = session.execute(plan)
        mark = " <- chosen" if strategy == chosen.strategy else ""
        extra = (f"  [{plan.n_range_segments}/{len(plan.segments)} "
                 f"segments ran as range]" if strategy == "hybrid" else "")
        print(f"  {st.strategy:11s} {st.seconds:7.3f}s "
              f"(predicted {plan.cost.seconds:7.3f}s)  "
              f"io={st.physical_ios:7d}  matches={st.matches}{extra}{mark}")
    print()
