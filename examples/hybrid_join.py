"""CAM-guided hybrid join (paper §VI): density-aware point/range probing.

    PYTHONPATH=src python examples/hybrid_join.py
"""
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, join_outer_keys
from repro.index.disk_layout import PageLayout
from repro.index.pgm import build_pgm
from repro.join.calibrate import calibrate
from repro.join.executors import hybrid_join, inlj, point_only, range_only

LAYOUT = PageLayout()
inner = make_dataset("books", 1_000_000, seed=1)
index = build_pgm(inner, eps=64)
capacity = (1 << 20) // LAYOUT.page_bytes

params = calibrate(index, inner, LAYOUT, capacity)
print(f"calibrated cost model: alpha={params.alpha:.2e} beta={params.beta:.2e}"
      f" lambda_point={params.lambda_point:.2e}"
      f" lambda_range={params.lambda_range:.2e}\n")

for wl in ("w1", "w3", "w4"):
    outer = join_outer_keys(inner, 100_000, WorkloadSpec(wl, seed=9))
    print(f"workload {wl} (100k outer x 1M inner, "
          f"{capacity} buffer pages):")
    for fn in (inlj, point_only, range_only):
        st = fn(index, inner, outer, LAYOUT, capacity)
        print(f"  {st.strategy:11s} {st.seconds:7.3f}s  "
              f"io={st.physical_ios:7d}  matches={st.matches}")
    st = hybrid_join(index, inner, outer, LAYOUT, capacity, params=params,
                     n_min=256, k_max=4096)
    print(f"  {st.strategy:11s} {st.seconds:7.3f}s  "
          f"io={st.physical_ios:7d}  matches={st.matches}  "
          f"[{st.n_range_segments}/{st.n_segments} segments ran as range]\n")
