"""Memory-budgeted index tuning (paper §V) through the ONE tuning surface:
``TuningSession`` runs a joint (knob x buffer-split) search over a
declarative knob space — one batched profiling pass, one batched cache-model
solve, zero per-split model calls — while the cache-oblivious baselines plug
in as ``Tuner`` strategies.  RadixSpline shows the 2-D case: the radix table
is footprint that competes with buffer pages, so ``radix_bits`` is a real
knob under a shared budget.

    PYTHONPATH=src python examples/tune_pgm.py [--smoke]
"""
import argparse

from repro.core.cam import CamGeometry
from repro.core.session import System
from repro.core.workload import Workload
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload
from repro.sim.machine import simulate_point_queries
from repro.tuning.session import (MulticriteriaTuner, PGMBuilder,
                                  RadixSplineBuilder, TuningSession)

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized inputs (~5x below the demo default)")
args = ap.parse_args()
N, Q = (200_000, 20_000) if args.smoke else (1_000_000, 100_000)

GEOM = CamGeometry()
keys = make_dataset("books", N, seed=1)
qk, qpos = point_workload(keys, Q, WorkloadSpec("w4", seed=3))
workload = Workload.point(qpos, n=len(keys), query_keys=qk)
BUDGET = int((0.25 if args.smoke else 1.0) * 2**20)  # index + buffer — tight!

print(f"memory budget: {BUDGET / 2**20:.1f} MiB (shared by index AND buffer)")
session = TuningSession(System(GEOM, BUDGET, "lru"))
builder = PGMBuilder(keys)
res = session.tune(builder, workload, sample_rate=0.3)
print(f"\nCAM joint (eps x split) search ({len(res.estimates)} candidates, "
      f"{len(res.skipped)} skipped unbuilt, {res.batched_solves} batched "
      f"solve, {res.tuning_seconds:.1f}s incl. lazy size-model fit):")
for eps in sorted(res.estimates):
    e = res.estimates[eps]
    star = " <-- eps*" if eps == res.best_knob else ""
    print(f"  eps={eps:5d}: est {e.io_per_query:.4f} IO/q "
          f"(index {float(res.size_model(eps=eps))/1024:7.0f} KiB, "
          f"h={e.hit_rate:.3f}){star}")
print(f"chosen buffer split: {res.split:.2f} of the budget "
      f"({res.capacity_pages} pages)")

base = session.tune(builder, workload, tuner=MulticriteriaTuner())
print(f"\nmulticriteria baseline (fixed 50/50 split) picks "
      f"eps={base.best_knob}")

for name, point in [("CAM", res.best), ("baseline", base.best)]:
    adapter = builder.build(point)
    cap = max(1, (BUDGET - adapter.size_bytes) // GEOM.page_bytes)
    plo, phi = adapter.probe_windows(qk, GEOM)
    _, qps, misses = simulate_point_queries(plo, phi, cap, "lru")
    print(f"{name:9s} eps={point['eps']:5d}: {qps:12,.0f} QPS "
          f"({misses} physical IOs)")

# Same session, 2-D knob space: RadixSpline's (corridor eps x radix_bits).
rs_budget = BUDGET * 2
rs = TuningSession(System(GEOM, rs_budget, "lru")).tune(
    RadixSplineBuilder(keys), workload, sample_rate=0.3,
    overrides={"eps": (16, 32, 64, 128, 256, 512, 1024),
               "radix_bits": (8, 10, 12, 14, 16)})
print(f"\nRadixSpline under {rs_budget / 2**20:.1f} MiB: "
      f"(eps*, radix_bits*)=({rs.best['eps']}, {rs.best['radix_bits']}) "
      f"(est {rs.est_io:.4f} IO/q, {rs.tuning_seconds:.1f}s) — a narrow "
      "radix table frees buffer pages under a tight shared budget")
