"""Memory-budgeted index tuning (paper §V): CAM picks eps* by trading index
footprint against buffer capacity; the cache-oblivious baseline can't.

The whole eps grid prices through ONE batched ``CostSession.estimate_grid``
call (shared page-ref state, vmapped hit-rate solves) — the same machinery
also grid-tunes RadixSpline, which had no tuning path before the CostSession
redesign.

    PYTHONPATH=src python examples/tune_pgm.py [--smoke]
"""
import argparse

from repro.core.cam import CamGeometry
from repro.core.workload import Workload
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.pgm import build_pgm
from repro.sim.machine import simulate_point_queries
from repro.tuning.pgm_tuner import cam_tune_pgm, multicriteria_pgm_tune
from repro.tuning.rs_tuner import cam_tune_radixspline

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized inputs (~5x below the demo default)")
args = ap.parse_args()
N, Q = (200_000, 20_000) if args.smoke else (1_000_000, 100_000)

GEOM = CamGeometry()
keys = make_dataset("books", N, seed=1)
qk, qpos = point_workload(keys, Q, WorkloadSpec("w4", seed=3))
workload = Workload.point(qpos, n=len(keys), query_keys=qk)
BUDGET = int((0.25 if args.smoke else 1.0) * 2**20)  # index + buffer — tight!

print(f"memory budget: {BUDGET / 2**20:.1f} MiB (shared by index AND buffer)")
res = cam_tune_pgm(keys, qpos, BUDGET, GEOM, "lru", sample_rate=0.3)
print(f"\nCAM batched grid ({len(res.estimates)} candidates, "
      f"{res.tuning_seconds:.1f}s incl. size-model fit):")
for eps in sorted(res.estimates):
    e = res.estimates[eps]
    star = " <-- eps*" if eps == res.best_eps else ""
    print(f"  eps={eps:5d}: est {e.io_per_query:.4f} IO/q "
          f"(index {float(res.size_model(eps))/1024:7.0f} KiB, "
          f"h={e.hit_rate:.3f}){star}")

base_eps, _ = multicriteria_pgm_tune(keys, index_space_budget=BUDGET // 2)
print(f"\nbaseline (fixed 50/50 split) picks eps={base_eps}")

for name, eps in [("CAM", res.best_eps), ("baseline", base_eps)]:
    idx = build_pgm(keys, eps)
    cap = max(1, (BUDGET - idx.size_bytes) // GEOM.page_bytes)
    lo, hi = idx.window(qk)
    _, qps, misses = simulate_point_queries(lo // GEOM.c_ipp, hi // GEOM.c_ipp,
                                            cap, "lru")
    print(f"{name:9s} eps={eps:5d}: {qps:12,.0f} QPS "
          f"({misses} physical IOs)")

# Same session machinery, third index family: tune RadixSpline's corridor eps
rs_budget = BUDGET * 2
rs = cam_tune_radixspline(keys, qpos, rs_budget, GEOM, "lru",
                          eps_grid=(16, 32, 64, 128, 256, 512, 1024),
                          radix_bits=12, sample_rate=0.3)
print(f"\nRadixSpline under {rs_budget / 2**20:.1f} MiB: eps*={rs.best_eps} "
      f"(est {rs.est_io:.4f} IO/q, {rs.tuning_seconds:.1f}s)")
