"""End-to-end serving driver: batched requests through prefill + decode with
greedy sampling, plus the CAM-guided KV-pool plan for the production config.

    PYTHONPATH=src python examples/serve_batched.py [--arch yi-34b]
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.planner import RequestMix, plan_kv_pool

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-34b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--new-tokens", type=int, default=12)
ap.add_argument("--smoke", action="store_true",
                help="CI-sized run (tiny batch / prompt / decode)")
args = ap.parse_args()
if args.smoke:
    args.batch, args.prompt_len, args.new_tokens = 2, 8, 4

full_cfg = get_config(args.arch)
cfg = reduced(full_cfg)                      # CPU-sized, same wiring
params = init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, max_seq=args.prompt_len + args.new_tokens + 8)

rng = np.random.default_rng(0)
shape = (args.batch, args.prompt_len)
if cfg.family == "audio":
    shape += (cfg.num_codebooks,)
prompts = rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)
res = engine.generate(prompts, max_new_tokens=args.new_tokens)
tok_s = args.batch * res.steps / max(res.decode_seconds, 1e-9)
print(f"{cfg.name} (reduced): batch={args.batch} prompt={args.prompt_len} "
      f"-> +{res.steps} tokens | prefill {res.prefill_seconds:.2f}s, "
      f"decode {res.decode_seconds:.2f}s ({tok_s:.1f} tok/s)")
print("sample:", res.tokens[0].reshape(res.tokens.shape[1], -1)[:, 0][:16], "...")

kv_bpt = 2 * full_cfg.num_layers * full_cfg.num_kv_heads * full_cfg.head_dim * 2
mix = RequestMix(n_requests=64, shared_prefix=2048, mean_context=8192,
                 decode_steps=256, kv_bytes_per_token=kv_bpt)
plan = plan_kv_pool(mix, hbm_budget_bytes=16 * 2**30,
                    weight_bytes=full_cfg.param_count() * 2 / 256)
print(f"\nCAM KV-pool plan for PRODUCTION {full_cfg.name} "
      f"(16 GiB HBM, 64 reqs, 2k shared prefix):")
print(f"  block={plan.block_tokens} tokens, pool={plan.pool_blocks} blocks, "
      f"est hit={plan.hit_rate:.3f}, "
      f"host transfer/step={plan.transfer_bytes_per_step/2**20:.1f} MiB")
for bt, cost in sorted(plan.candidates.items()):
    print(f"    candidate block={bt:4d}: est transfer {cost/2**20:9.1f} MiB/step")
