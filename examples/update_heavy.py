"""Write-path quickstart: serving an update-heavy trace with CAM-guided
merges.

WriteSession drives the full write pipeline over a live read/write op log:

1. **Trace in** — a drifting stream of point probes and inserts/updates/
   deletes (``synthetic_drifting_trace``, or any JSONL op log via
   ``parse_jsonl``);
2. **Stage** — mutations land in a memory-resident :class:`DeltaBuffer`
   instead of dirtying base pages.  Free now, but every staged entry
   steals a buffer-pool page, so probe misses creep up;
3. **Price** — each batch boundary builds ONE three-cell PriceTable (the
   live read mix at the shrunken capacity, the same mix at the restored
   capacity, and the pending merge's sorted burst) and makes ONE
   ``PricingEngine.price`` call;
4. **Decide** — :class:`CamMergeScheduler` merges when deferral's priced
   miss penalty over the horizon exceeds the burst's own I/O (Eq. 15 with
   a time axis).  Swap in ``EveryKScheduler`` / ``OnFullScheduler`` to see
   what cache-oblivious scheduling costs.

    PYTHONPATH=src python examples/update_heavy.py [--smoke]
"""
import argparse

import numpy as np

from repro.core.cam import CamGeometry
from repro.core.session import GridCandidate, System
from repro.serving.trace import synthetic_drifting_trace
from repro.write import (CamMergeScheduler, EveryKScheduler, OnFullScheduler,
                         WriteConfig, WriteSession)

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized inputs (~4x below the demo default)")
args = ap.parse_args()
SCALE, N = (250, 100_000) if args.smoke else (600, 250_000)

geom = CamGeometry(c_ipp=64, page_bytes=4096)
keys = np.sort(np.random.default_rng(1).uniform(0, 1e9, N))
system = System(geom, memory_budget_bytes=160 * geom.page_bytes,
                policy="lru")
config = WriteConfig(batch_size=SCALE, delta_capacity_entries=160 * SCALE,
                     delta_entry_bytes=192.0, horizon_batches=12.0)
candidate = GridCandidate(knob="live", eps=64, size_bytes=4096.0)

# read-mostly -> update burst -> read-mostly: the regime where merge
# timing decides the bill
events = synthetic_drifting_trace(keys, [
    {"events": 8 * SCALE, "mix": (0.9, 0.05, 0.0, 0.05, 0.0, 0.0),
     "hot_center": 0.3, "hot_width": 0.08, "hot_frac": 0.95},
    {"events": 10 * SCALE, "mix": (0.2, 0.0, 0.0, 0.25, 0.5, 0.05),
     "hot_center": 0.7, "hot_width": 0.25, "hot_frac": 0.8},
    {"events": 16 * SCALE, "mix": (0.92, 0.05, 0.0, 0.01, 0.02, 0.0),
     "hot_center": 0.3, "hot_width": 0.08, "hot_frac": 0.95},
], seed=0)
n_writes = sum(1 for e in events if e.op in ("insert", "update", "delete"))
print(f"{len(events)} events ({n_writes} writes) over {N // 1000}k keys, "
      f"{system.memory_budget_bytes // geom.page_bytes} buffer pages\n")

print(f"{'scheduler':9s} {'total I/O':>10s} {'read I/O':>10s} "
      f"{'merge I/O':>10s} {'merges':>6s} {'engine calls':>12s}")
reports = {}
for sched in (CamMergeScheduler(), EveryKScheduler(k=8), OnFullScheduler()):
    sess = WriteSession(keys, system, sched, candidate=candidate,
                        config=config)
    rep = sess.run(events)
    reports[rep.scheduler] = rep
    assert rep.engine_calls == rep.decision_events  # ONE price call/event
    print(f"{rep.scheduler:9s} {rep.total_io:10.1f} {rep.read_io:10.1f} "
          f"{rep.merge_io:10.1f} {rep.merges:6d} {rep.engine_calls:12d}")

cam, full = reports["cam"], reports["on_full"]
print(f"\nCAM-guided merging: {full.total_io / cam.total_io:.2f}x less "
      f"total I/O than merge-on-full "
      f"({cam.merges} priced merges vs {full.merges}).")
first = next(r for r in cam.records if r.merged)
print(f"first CAM merge at batch {first.batch_index}: deferral cost "
      f"{first.io_defer:.3f} io/q at C(d)={first.cap_now} vs "
      f"{first.io_merged:.3f} at C(0)={first.cap_empty}, "
      f"burst={first.merge_io:.0f} io -> '{first.reason}'")
