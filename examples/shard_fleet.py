"""Fleet sharding: a 4-node fleet prices its whole joint (shard-boundary
x per-shard eps x fleet-budget-split) space in one grouped profile pass +
one solve pass, then a hotspot develops and the rebalance gate decides —
move the boundaries when horizon I/O savings repay data movement plus
index rebuilds plus cold-buffer refill, refuse when the hotspot is a
short flash that could never amortize the move.

    PYTHONPATH=src python examples/shard_fleet.py [--smoke]
"""
import argparse

import numpy as np

from repro.core.cam import CamGeometry
from repro.core.session import System
from repro.core.workload import Workload
from repro.data.datasets import make_dataset
from repro.sharding import ShardingSession
from repro.tuning.session import PGMBuilder

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized inputs (~5x below the demo default)")
args = ap.parse_args()
N, NQ, NODE_KB, SLAB_PAGES = ((40_000, 20_000, 32, 30) if args.smoke
                              else (200_000, 100_000, 160, 150))

keys = make_dataset("books", N, seed=1)
node = System(CamGeometry(c_ipp=256, page_bytes=4096),
              memory_budget_bytes=NODE_KB << 10, policy="lru")
fleet = ShardingSession(node, PGMBuilder(keys), n_shards=4, grid=8,
                        overrides={"eps": (8, 32, 128)})
rng = np.random.default_rng(7)


def traffic(hot_slab_pages=0, hot_frac=0.92, center=0.0):
    """Uniform traffic, optionally with a hot slab at ``center``."""
    if not hot_slab_pages:
        return Workload.point(rng.integers(0, N, NQ), n=N)
    slab = hot_slab_pages * node.geom.c_ipp
    lo = min(max(0, int(center * N) - slab // 2), N - slab)
    hot = rng.integers(lo, lo + slab, int(NQ * hot_frac))
    cold = rng.integers(0, N, NQ - hot.shape[0])
    pos = np.concatenate([hot, cold])
    rng.shuffle(pos)
    return Workload.point(pos, n=N)


# ---- day 0: balanced traffic, solve the joint fleet configuration --------
plan = fleet.solve(traffic())
print(f"fleet of {plan.n_shards} nodes, "
      f"{fleet.fleet_budget_bytes / 1024:.0f} KiB pooled budget, "
      f"{len(plan.boundaries_searched)} boundary candidates, "
      f"{plan.cells_solved} cells in one solve")
print(f"  boundaries {plan.boundaries}  est {plan.io_per_query:.4f} IO/q")
for sp in plan.shards:
    print(f"    shard {sp.index}: eps={sp.knob}  share={sp.fraction:.3f}  "
          f"{sp.capacity_pages} buffer pages  "
          f"mass={plan.shard_masses[sp.index]:.2f}")

# ---- a hotspot develops: most traffic crowds into shard 0's key range ----
hot = traffic(hot_slab_pages=SLAB_PAGES)
res = fleet.rebalance(hot, plan, horizon_queries=50 * NQ)
print(f"\nhotspot: shard {res.hot_shard} is hot (TV={res.tv:.2f}); "
      f"keep boundaries -> {res.io_current:.4f} IO/q, "
      f"move -> {res.io_candidate:.4f} IO/q")
print(f"  move cost {res.move_io:.0f} IOs vs horizon savings "
      f"{res.predicted_savings:.0f} -> "
      f"{'MOVE' if res.switched else 'stay'}")
assert res.switched, "a sustained hotspot should repay the boundary move"
plan = res.plan
print(f"  new boundaries {plan.boundaries}, "
      f"shares {tuple(round(f, 3) for f in plan.fractions)}")

# ---- a short flash: the hot set blips to the far end of the key space ----
flash = fleet.rebalance(traffic(hot_slab_pages=SLAB_PAGES, center=0.8),
                        plan, horizon_queries=0.01 * NQ)
print(f"\nflash: hot set blips to shard {flash.hot_shard} for "
      f"~{0.01 * NQ:.0f} queries: savings {flash.predicted_savings:.0f} "
      f"vs move {flash.move_io:.0f} "
      f"-> {'MOVE' if flash.switched else 'REFUSED'}")
assert not flash.switched, "a flash can never amortize data movement"
print("\nthe gate moved boundaries for the sustained hotspot and refused "
      "the flash.")
