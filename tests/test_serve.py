"""Serving tests: engine generation, CAM KV-pool planner vs pool replay."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import BlockTrace, PagedKVPool
from repro.serve.planner import RequestMix, block_popularity, plan_kv_pool


def test_engine_generates_consistent_shapes():
    cfg = reduced(ARCHS["starcoder2-3b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_seq=48)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(2, 8)).astype(np.int32)
    res = engine.generate(prompts, max_new_tokens=6)
    assert res.tokens.shape == (2, 14)
    assert (res.tokens[:, :8] == prompts).all()


def test_engine_greedy_matches_full_forward():
    """First generated token must equal argmax of a full forward pass."""
    import jax.numpy as jnp

    from repro.distributed.sharding import Recipe, ShardingCtx
    from repro.models.transformer import transformer_logits

    cfg = reduced(ARCHS["yi-34b"])
    params = init_params(cfg, jax.random.PRNGKey(1))
    engine = ServeEngine(cfg, params, max_seq=32)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(2, 12)).astype(np.int32)
    res = engine.generate(prompts, max_new_tokens=1)
    ctx = ShardingCtx(None, Recipe(remat="none"))
    logits, _, _ = transformer_logits(params, cfg,
                                      {"tokens": jnp.asarray(prompts)}, ctx)
    want = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
    np.testing.assert_array_equal(res.tokens[:, 12], want)


# ---------------------------------------------------------------------------
# CAM-guided KV planner (paper Eq. 15 on the serving plane)
# ---------------------------------------------------------------------------

def _trace_for_mix(mix: RequestMix, block_tokens: int, seed=0):
    bt = BlockTrace(block_tokens)
    rng = np.random.default_rng(seed)
    schedule = []
    for step in range(mix.decode_steps):
        for rid in rng.permutation(mix.n_requests):
            schedule.append((int(rid), mix.shared_prefix, mix.mean_context))
    return bt.decode_trace(schedule)


def test_planner_hit_rate_matches_pool_replay():
    """Round-robin decode gives a CYCLIC trace: the IRM (Che) estimate
    overestimates (paper §III-C's caveat transplanted to KV paging), while
    the structural closed form lands on the replay."""
    from repro.core import cache_models
    from repro.serve.planner import structural_hit_rate
    import jax.numpy as jnp

    mix = RequestMix(n_requests=16, shared_prefix=512, mean_context=1024,
                     decode_steps=12, kv_bytes_per_token=1024)
    block_tokens = 64
    probs, refs_per_step = block_popularity(mix, block_tokens)
    n_distinct = probs.shape[0]
    pool_blocks = n_distinct // 3          # force real evictions
    est_irm = float(cache_models.hit_rate(
        "lru", pool_blocks, jnp.asarray(probs, jnp.float32),
        total_requests=refs_per_step * mix.n_requests * mix.decode_steps))
    est_struct = structural_hit_rate(mix, block_tokens, pool_blocks)
    pool = PagedKVPool(pool_blocks, block_tokens, 1024 * block_tokens)
    for ref in _trace_for_mix(mix, block_tokens):
        pool.reference(ref)
    assert abs(est_struct - pool.hit_rate) < 0.04, (est_struct, pool.hit_rate)
    assert abs(est_struct - pool.hit_rate) < abs(est_irm - pool.hit_rate)


def test_planner_picks_reasonable_block_size():
    mix = RequestMix(n_requests=64, shared_prefix=2048, mean_context=8192,
                     decode_steps=128, kv_bytes_per_token=4096)
    plan = plan_kv_pool(mix, hbm_budget_bytes=8 * 2**30,
                        weight_bytes=4 * 2**30)
    assert plan.block_tokens in plan.candidates
    # the chosen block size must be the argmin of its own candidate table
    assert plan.candidates[plan.block_tokens] == min(plan.candidates.values())


def test_planner_cost_decreases_with_budget():
    mix = RequestMix(n_requests=32, shared_prefix=1024, mean_context=4096,
                     decode_steps=64, kv_bytes_per_token=2048)
    costs = []
    for budget in (2, 4, 8):
        plan = plan_kv_pool(mix, hbm_budget_bytes=budget * 2**30,
                            weight_bytes=1 * 2**30)
        costs.append(plan.transfer_bytes_per_step)
    assert costs[0] >= costs[1] >= costs[2]
