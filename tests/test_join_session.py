"""JoinSession tests.

* Golden: vectorized ``partition_probes`` must match the legacy per-probe
  loop segment-for-segment on fixed seeds (and be materially faster).
* Plan-vs-replay physical-I/O oracle: every strategy's predicted I/O is
  checked against ground-truth buffered replay across all three cache
  policies and all three index families.
* CAM-predicted selection: ``choose`` must pick the strategy with the lowest
  replayed cost (or within 10% of it) on uniform, skewed and sparse outer
  streams — validated against exhaustive replay.
* Degenerate plans subsume the legacy executors: identical match counts,
  and RadixSpline works as a join inner through the uniform
  ``probe_windows`` protocol (no tuple-shape special cases).
"""
import time

import numpy as np
import pytest

from repro.core.cam import CamGeometry
from repro.core.qerror import q_error
from repro.core.session import PlanCost, System
from repro.core.workload import Workload, locate
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, join_outer_keys
from repro.index.adapters import (PGMAdapter, RadixSplineAdapter, RMIAdapter,
                                  wrap_index)
from repro.index.pgm import build_pgm
from repro.join.hybrid import (JoinCostParams, partition_probes,
                               partition_probes_loop)
from repro.join.session import STRATEGIES, JoinSession

GEOM = CamGeometry()
POLICIES = ("lru", "fifo", "lfu")


def _adapter(family, keys):
    if family == "pgm":
        return PGMAdapter.build(keys, eps=32)
    if family == "rmi":
        return RMIAdapter.build(keys, branch=256)
    return RadixSplineAdapter.build(keys, eps=32)


@pytest.fixture(scope="module")
def world():
    keys = make_dataset("books", 200_000, seed=5)
    outer = join_outer_keys(keys, 15_000, WorkloadSpec("w4", seed=9))
    return keys, outer


def _session(keys, family="pgm", policy="lru", budget=2 << 20):
    inner = _adapter(family, keys)
    system = System(GEOM, memory_budget_bytes=budget + inner.size_bytes,
                    policy=policy)
    return JoinSession(inner, system, inner_keys=keys)


# ---------------------------------------------------------------------------
# Vectorized Algorithm 2 vs the legacy loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n_min,k_max,thrash", [
    (0, 64, 512, False), (1, 1024, 8192, False), (2, 128, 4096, True),
    (3, 17, 100, False), (4, 1, 10**9, False),
])
def test_partition_vectorized_matches_loop_golden(seed, n_min, k_max, thrash):
    rng = np.random.default_rng(seed)
    lo = np.sort(rng.integers(0, 50_000, size=20_000))
    hi = lo + rng.integers(0, 4, size=20_000)
    p = JoinCostParams()
    vec = partition_probes(lo, hi, p, n_min=n_min, k_max=k_max, thrash=thrash)
    ref = partition_probes_loop(lo, hi, p, n_min=n_min, k_max=k_max,
                                thrash=thrash)
    assert vec == ref


def test_partition_vectorized_matches_loop_dense_sparse():
    p = JoinCostParams()
    dense = np.repeat(np.arange(200), 40)
    assert (partition_probes(dense, dense, p, n_min=64, k_max=10**9)
            == partition_probes_loop(dense, dense, p, n_min=64, k_max=10**9))
    sparse = np.arange(0, 3_000_000, 5000)
    assert (partition_probes(sparse, sparse, p, n_min=64, k_max=10**9)
            == partition_probes_loop(sparse, sparse, p, n_min=64, k_max=10**9))


def test_partition_vectorized_speedup_at_1m():
    """Acceptance: >= 5x over the Python loop at 1M probes, same segments."""
    rng = np.random.default_rng(7)
    n = 1_000_000
    lo = np.sort(rng.integers(0, 2_000_000, size=n))
    hi = lo + rng.integers(0, 3, size=n)
    p = JoinCostParams()
    partition_probes(lo[:1000], hi[:1000], p)      # warm numpy
    t0 = time.perf_counter()
    vec = partition_probes(lo, hi, p, n_min=1024, k_max=8192)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    ref = partition_probes_loop(lo, hi, p, n_min=1024, k_max=8192)
    t_loop = time.perf_counter() - t0
    assert vec == ref
    assert t_loop / t_vec >= 5.0, (t_loop, t_vec)


# ---------------------------------------------------------------------------
# Plan-vs-replay physical-I/O oracle (3 policies x 3 families)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ("pgm", "rmi", "radixspline"))
@pytest.mark.parametrize("policy", POLICIES)
def test_plan_io_matches_replay(world, family, policy):
    keys, outer = world
    s = _session(keys, family, policy)
    for strategy in STRATEGIES:
        plan = s.plan(outer, strategy, n_min=128, k_max=4096)
        st = s.execute(plan)
        assert isinstance(plan.cost, PlanCost)
        assert st.strategy == strategy
        assert q_error(plan.cost.physical_ios, max(st.physical_ios, 1)) < 2.0, \
            (strategy, plan.cost.physical_ios, st.physical_ios)


def test_sorted_point_plan_io_is_sharp(world):
    """For the sorted point stream the Theorem III.1 composition should be
    nearly exact, not just within the oracle band."""
    keys, outer = world
    s = _session(keys)
    plan = s.plan(outer, "point-only")
    st = s.execute(plan)
    assert abs(plan.cost.physical_ios - st.physical_ios) \
        <= 0.05 * st.physical_ios


@pytest.mark.parametrize("policy", POLICIES)
def test_join_and_cost_session_share_sorted_model(world, policy):
    """The planner's point-probe miss pricing and CostSession's sorted
    estimate must be the SAME number on the same stream — the two layers no
    longer carry divergent sorted-scan models."""
    from repro.core.session import CostSession
    keys, outer = world
    s = _session(keys, "pgm", policy)
    plan = s.plan(outer, "point-only", n_min=128, k_max=4096)
    probe = np.sort(outer)
    plo, phi = s.inner.probe_windows(probe, GEOM)
    wl = Workload.sorted_stream(plo * GEOM.c_ipp, phi * GEOM.c_ipp,
                                n=len(keys))
    est = CostSession(s.system).estimate(s.inner, wl)
    pred = est.io_per_query * wl.n_queries
    assert abs(plan.cost.physical_ios - pred) < 1e-5 * max(pred, 1.0), \
        (policy, plan.cost.physical_ios, pred)


# ---------------------------------------------------------------------------
# CAM-predicted plan selection vs exhaustive replay
# ---------------------------------------------------------------------------

def _replay_all(s, outer, **kw):
    return {st: s.execute(s.plan(outer, st, **kw)) for st in STRATEGIES}


@pytest.mark.parametrize("wl", ("w1", "w2"))   # uniform and zipf-skewed
def test_choose_within_10pct_of_replayed_best(world, wl):
    keys, _ = world
    outer = join_outer_keys(keys, 15_000, WorkloadSpec(wl, seed=9))
    s = _session(keys)
    s.calibrate()
    res = s.choose(outer, n_min=128, k_max=4096)
    stats = _replay_all(s, outer, n_min=128, k_max=4096)
    best = min(stats, key=lambda k: stats[k].seconds)
    assert stats[res.strategy].seconds <= 1.10 * stats[best].seconds, \
        (res.strategy, best, {k: v.seconds for k, v in stats.items()})


def test_choose_prefers_points_on_sparse_stream(world):
    """A probe stream far sparser than the page grid must NOT pick the
    full-span range scan; selection still tracks the replayed best."""
    keys, _ = world
    outer = keys[::4000].copy()                # 50 probes over ~780 pages
    s = _session(keys)
    s.calibrate()
    res = s.choose(outer, n_min=128, k_max=4096)
    stats = _replay_all(s, outer, n_min=128, k_max=4096)
    best = min(stats, key=lambda k: stats[k].seconds)
    assert res.strategy != "range-only"
    assert stats[res.strategy].seconds <= 1.10 * stats[best].seconds


def test_choose_handles_mixed_workload(world):
    """Workload.mixed outer streams (sorted-run / point read blends) flow
    through planning, selection and execution."""
    keys, _ = world
    qk = join_outer_keys(keys, 8_000, WorkloadSpec("w4", seed=9))
    run = keys[50_000:58_000]
    mixed = Workload.mixed(
        Workload.point(locate(keys, qk), n=len(keys), query_keys=qk),
        Workload.point(locate(keys, run), n=len(keys), query_keys=run))
    s = _session(keys)
    res = s.choose(mixed, n_min=128, k_max=4096)
    assert set(res.plans) == set(STRATEGIES)   # candidates kept for reuse
    st = s.execute(res.plan)
    assert st.logical_refs > 0
    oracle = int(np.isin(np.concatenate([qk, run]), keys).sum())
    assert st.matches == oracle


# ---------------------------------------------------------------------------
# Degenerate plans subsume the executors; uniform probe_windows protocol
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ("pgm", "rmi", "radixspline"))
def test_all_strategies_match_oracle_per_family(world, family):
    keys, outer = world
    s = _session(keys, family)
    oracle = int(np.isin(outer, keys).sum())
    for strategy in STRATEGIES:
        st = s.execute(s.plan(outer, strategy, n_min=128))
        assert st.matches == oracle, (family, strategy)


def test_wrap_index_accepts_raw_and_adapted(world):
    keys, outer = world
    raw = build_pgm(keys, eps=32)
    w = wrap_index(raw)
    assert w.family == "pgm"
    assert wrap_index(w) is w
    plo, phi = w.probe_windows(outer[:100], GEOM)
    assert plo.shape == phi.shape == (100,)
    assert (plo <= phi).all()
    assert int(phi.max()) < GEOM.num_pages(len(keys))
    with pytest.raises(TypeError):
        wrap_index(object())


def test_probe_windows_uniform_across_families(world):
    """The 2-tuple/3-tuple window() special case is gone: every family
    yields identically-shaped page intervals (RadixSpline as join inner
    used to break silently here)."""
    keys, outer = world
    q = np.sort(outer[:500])
    for family in ("pgm", "rmi", "radixspline"):
        plo, phi = _adapter(family, keys).probe_windows(q, GEOM)
        assert plo.dtype == np.int64 and phi.dtype == np.int64
        assert plo.shape == phi.shape == (500,)
        assert (plo <= phi).all() and (plo >= 0).all()


def test_hybrid_plan_not_worse_than_pure(world):
    keys, outer = world
    s = _session(keys)
    s.calibrate()
    stats = _replay_all(s, outer, n_min=128, k_max=4096)
    assert stats["hybrid"].seconds <= 1.15 * min(
        stats["point-only"].seconds, stats["range-only"].seconds)
