"""PricingEngine / PriceTable / executor tests.

* Executor golden equivalence: the fused DeviceExecutor (interpret mode off
  TPU) is float32-equivalent to the HostExecutor — per-cell hit rates,
  distinct pages and the argmin winner — across 3 policies x 4 workload
  kinds (point / range / sorted / mixed) and on grouped (sharded-style)
  profiles.
* Structural one-engine-call-per-solve: estimate_grid, the tuner's joint
  (knob x split) search and the join cost curve each run EXACTLY one
  ``engine.price`` (the tree's single call is pinned in test_join_tree.py,
  the sharded fleet's in test_sharding.py).
* Dispatch: explicit executor arg > REPRO_ENGINE_EXECUTOR > engine default;
  unknown names, empty tables, detached tables and bad objectives raise.
* PriceTable algebra: concat span offsetting, duplicate-knob and
  mixed-profiles rejection, subset rehydration.
"""
import numpy as np
import pytest

from repro.core.cam import CamGeometry
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload, range_workload
from repro.engine import (DeviceExecutor, HostExecutor, PriceTable,
                          PricingEngine)

GEOM = CamGeometry()
BUDGET = 3 << 20
POLICIES = ("lru", "fifo", "lfu")
EPS_GRID = (8, 16, 32, 64)
SPLITS = (0.25, 0.5, 0.75)


@pytest.fixture(scope="module")
def world():
    keys = make_dataset("books", 50_000, seed=1)
    n = len(keys)
    qk, qpos = point_workload(keys, 5_000, WorkloadSpec("w4", seed=3))
    rlo, rhi, rlop, rhip = range_workload(keys, 2_000,
                                          WorkloadSpec("w1", seed=5), 64)
    wls = {
        "point": Workload.point(qpos, n=n, query_keys=qk),
        "range": Workload.range_scan(rlop, rhip, n=n),
        "sorted": Workload.sorted_stream(np.sort(rlop), np.sort(rhip), n=n),
        "mixed": Workload.mixed(Workload.point(qpos, n=n),
                                Workload.sorted_stream(np.sort(rlop),
                                                       np.sort(rhip), n=n)),
    }
    return keys, wls


def _cands():
    return [GridCandidate(eps, 65_536.0, eps=eps) for eps in EPS_GRID]


def _table(sess, wl):
    prof = sess.grid_profiles(_cands(), wl)
    return PriceTable.from_profiles(
        prof, {kn: {} for kn in prof.knobs}, splits=SPLITS,
        budget_bytes=float(BUDGET), page_bytes=GEOM.page_bytes)


def _assert_equivalent(sol_h, sol_d):
    dh = np.max(np.abs(sol_h.hit_rates - sol_d.hit_rates))
    assert dh < 2e-6, dh                       # float32 summation-order only
    assert np.array_equal(sol_h.distinct, sol_d.distinct)
    # winners agree up to objective ties at float32 resolution
    assert np.isclose(sol_h.objective[sol_d.best_cell],
                      sol_h.objective[sol_h.best_cell],
                      rtol=1e-5, atol=1e-12)
    assert sol_h.executor == "host" and sol_d.executor == "device"


# ---------------------------------------------------------------------------
# Golden equivalence: fused device executor vs host reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("kind", ("point", "range", "sorted", "mixed"))
def test_executors_agree_across_policies_and_kinds(world, policy, kind):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, policy))
    tab = _table(sess, wls[kind])
    assert len(tab) > len(EPS_GRID)            # splits really enumerated
    eng = PricingEngine(sess)
    _assert_equivalent(eng.price(tab, executor="host"),
                       eng.price(tab, executor="device"))


@pytest.mark.parametrize("policy", POLICIES)
def test_executors_agree_on_grouped_profiles(world, policy):
    """Sharded-style (group, knob) profiles: padded histograms, concatenated
    rows — the fleet table shape — solve identically on both executors."""
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, policy))
    prof = sess.grid_profiles_grouped(
        [("s0", _cands(), wls["point"]),
         ("s1", _cands()[:2], wls["mixed"])])
    tab = PriceTable.from_profiles(
        prof, {kn: {} for kn in prof.knobs}, splits=SPLITS,
        budget_bytes=float(BUDGET), page_bytes=GEOM.page_bytes)
    eng = PricingEngine(sess)
    _assert_equivalent(eng.price(tab, executor="host"),
                       eng.price(tab, executor="device"))


def test_executors_agree_on_seconds_objective(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])
    eng = PricingEngine(sess)
    sol_h = eng.price(tab, objective="seconds", executor="host")
    sol_d = eng.price(tab, objective="seconds", executor="device")
    _assert_equivalent(sol_h, sol_d)
    assert sol_h.objective_name == "seconds"


# ---------------------------------------------------------------------------
# Structural: every session runs EXACTLY one engine call per solve
# ---------------------------------------------------------------------------

def test_estimate_grid_is_one_engine_call(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    assert sess.engine.calls == 0
    sess.estimate_grid(_cands(), wls["point"])
    assert sess.engine.calls == 1
    sess.estimate_grid(_cands(), wls["mixed"])
    assert sess.engine.calls == 2


def test_tuner_joint_search_is_one_engine_call(world):
    from repro.tuning.session import PGMBuilder, TuningSession
    keys, wls = world
    ts = TuningSession(System(GEOM, BUDGET, "lru"),
                       splits=tuple(i / 8 for i in range(1, 8)))
    assert ts.cost.engine.calls == 0
    res = ts.tune(PGMBuilder(keys), wls["point"],
                  overrides={"eps": EPS_GRID})
    assert ts.cost.engine.calls == 1
    assert res.batched_solves == 1


def test_join_cost_curve_is_one_engine_call(world):
    from repro.index.adapters import PGMAdapter
    from repro.join.session import JoinSession
    keys, wls = world
    adapter = PGMAdapter.build(keys, eps=32)
    system = System(GEOM, (1 << 20) + adapter.size_bytes, "lfu")
    s = JoinSession(adapter, system, inner_keys=keys)
    outer = np.asarray(keys[::7])
    s.cost_curve(outer, np.array([4, 16, 64, 256]), n_min=128)
    assert s._cost_session.engine.calls == 1


# ---------------------------------------------------------------------------
# Capacity dtype: exact compares above float32's 2^24 integer range
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_large_capacity_thrash_flip_exact_on_both_executors(policy):
    """Regression: a 2^24-page buffer one page below a 2^24 + 1 Thm III.1
    premise must thrash on BOTH executors — float32 capacity arithmetic
    would round the two equal and skip the regime entirely."""
    import dataclasses

    import jax.numpy as jnp

    from repro.core.session import GridProfiles, SortedScanPart

    cov = jnp.zeros((32,), jnp.float32).at[:16].set(2.0)   # R=32, N=16
    sp = SortedScanPart(32.0, 16.0, 2**24 + 1, cov, 0.0)
    prof = GridProfiles(
        knobs=("k",), counts=jnp.zeros((1, 32), jnp.float32),
        totals=np.zeros(1), dacs=np.ones(1), sizes=np.zeros(1),
        caps=np.array([2**25]), sparts=(sp,), skipped=(), scale=1.0,
        n_queries=32)
    tab = PriceTable.from_cells(
        prof, [("k", 0, np.array([2**24, 2**24 + 1]))])
    eng = PricingEngine(CostSession(System(GEOM, BUDGET, policy)))
    for ex in ("host", "device"):
        sol = eng.price(tab, executor=ex)
        assert sol.hit_rates[0] == 0.0, (ex, sol.hit_rates)   # thrash
        assert sol.hit_rates[1] == pytest.approx(0.5), ex     # modeled
        assert sol.best_cell == 1, ex


# ---------------------------------------------------------------------------
# Dispatch and validation
# ---------------------------------------------------------------------------

def test_dispatch_precedence(world, engine_executor):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])

    # constructor default
    engine_executor(None)
    assert PricingEngine(sess, executor="host").price(tab).executor == "host"
    # env var beats constructor default
    engine_executor("device")
    eng = PricingEngine(sess, executor="host")
    assert eng.price(tab).executor == "device"
    # explicit argument beats the env var
    assert eng.price(tab, executor="host").executor == "host"
    # executor instances pass straight through
    assert eng.price(tab, executor=HostExecutor()).executor == "host"
    assert eng.price(tab,
                     executor=DeviceExecutor(interpret=True)
                     ).executor == "device"


def test_engine_rejects_bad_inputs(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])
    eng = PricingEngine(sess)
    with pytest.raises(ValueError):
        eng.price(tab, executor="gpu-ish")
    with pytest.raises(ValueError):
        eng.price(tab, objective="latency")
    empty = PriceTable(np.zeros(0, np.int64), np.zeros(0, np.int64),
                       np.zeros(0), {}, {}, tab.profiles)
    with pytest.raises(ValueError):
        eng.price(empty)
    detached = PriceTable(tab.rows, tab.caps, tab.fracs, tab.spans,
                          tab.points_of, None)
    with pytest.raises(ValueError):
        eng.price(detached)


# ---------------------------------------------------------------------------
# PriceTable algebra
# ---------------------------------------------------------------------------

def test_concat_offsets_spans_and_rejects_duplicates(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    prof = sess.grid_profiles(_cands(), wls["point"])
    t1 = PriceTable.from_cells(prof, [("a", 0, np.array([4, 8])),
                                      ("b", 1, np.array([16]))])
    t2 = PriceTable.from_cells(prof, [("c", 2, np.array([32, 64, 128]))])
    cat = PriceTable.concat([t1, t2])
    assert len(cat) == 6
    assert cat.spans == {"a": (0, 2), "b": (2, 3), "c": (3, 6)}
    assert np.array_equal(cat.rows, [0, 0, 1, 2, 2, 2])
    with pytest.raises(ValueError):
        PriceTable.concat([t1, t1])            # duplicate knob keys
    other = sess.grid_profiles(_cands()[:2], wls["point"])
    with pytest.raises(ValueError):            # mixed GridProfiles objects
        PriceTable.concat([t1, PriceTable.from_cells(
            other, [("z", 0, np.array([4]))])])


def test_subset_rehydrates_singleton_spans(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])
    eng = PricingEngine(sess)
    sol = eng.price(tab, executor="host")
    sel = [a for kn, (a, b) in sorted(tab.spans.items())]
    sub = sol.subset(sel)
    assert len(sub.table) == len(sel)
    assert all(b - a == 1 for a, b in sub.table.spans.values())
    assert set(sub.table.spans) == set(tab.spans)
    # the sliced solution re-ranks within the slice
    assert sub.best_cell == int(np.argmin(sol.objective[sel]))


# ---------------------------------------------------------------------------
# Device-resident profiling: host-vs-device oracle across index families
# ---------------------------------------------------------------------------

from _hyp import given, settings, st  # noqa: E402
from repro.tuning.session import (PGMBuilder, RMIBuilder,  # noqa: E402
                                  RadixSplineBuilder)

# RMI is the only family routed through the mixed-eps pass (point_ref_eps
# is point-only); uniform-eps families profile identically on either
# executor, which the oracle asserts bit-for-bit.
FAMILY_KINDS = {"pgm": ("point", "range", "mixed"),
                "rmi": ("point",),
                "radixspline": ("point", "range", "mixed")}


@pytest.fixture(scope="module")
def family_cands(world):
    keys = world[0]
    pgm, rmi = PGMBuilder(keys), RMIBuilder(keys)
    rs = RadixSplineBuilder(keys)
    return {
        "pgm": [pgm.candidate({"eps": e}, 65_536.0) for e in (16, 64)],
        "rmi": [rmi.candidate({"branch": b}, 0.0) for b in (64, 256)],
        "radixspline": [rs.candidate({"eps": e, "radix_bits": 10}, 65_536.0)
                        for e in (32, 128)],
    }


@pytest.mark.parametrize("family", ("pgm", "rmi", "radixspline"))
@pytest.mark.parametrize("policy", POLICIES)
def test_device_profiling_oracle(world, family_cands, family, policy):
    """grid_profiles(executor="device") is golden-equivalent to the host
    bincount path — exact where the mass is integer or the mixed-eps pass
    is bypassed, <= 2e-6 normalized on the RMI float32 matmul path — and
    the device-born profiles price identically through BOTH executors."""
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, policy))
    eng = PricingEngine(sess)
    for kind in FAMILY_KINDS[family]:
        cands = family_cands[family]
        ph = sess.grid_profiles(cands, wls[kind], executor="host")
        pd = sess.grid_profiles(cands, wls[kind], executor="device")
        assert ph.knobs == pd.knobs
        ch = np.asarray(ph.counts, np.float64)
        cd = np.asarray(pd.counts, np.float64)
        if family == "rmi":
            scale = max(1.0, float(ch.max()))
            assert np.max(np.abs(ch - cd)) / scale < 2e-6, kind
            assert np.max(np.abs(ph.totals - pd.totals)
                          / np.maximum(ph.totals, 1.0)) < 2e-6, kind
        else:
            assert np.array_equal(ch, cd), kind
            assert np.array_equal(ph.totals, pd.totals), kind
        # solved hit rates agree through BOTH pricing executors
        hh, ndh = sess.solve_profiles(ph, ph.caps)
        hd, ndd = sess.solve_profiles(pd, pd.caps)
        assert np.max(np.abs(np.asarray(hh) - np.asarray(hd))) < 2e-6, kind
        assert np.array_equal(np.round(ndh), np.round(ndd)), kind
        tab = PriceTable.from_profiles(
            pd, {kn: {} for kn in pd.knobs}, splits=SPLITS,
            budget_bytes=float(BUDGET), page_bytes=GEOM.page_bytes)
        _assert_equivalent(eng.price(tab, executor="host"),
                           eng.price(tab, executor="device"))


def test_profile_dispatch_precedence(world, family_cands, engine_executor,
                                     monkeypatch):
    """The profile side obeys the SAME precedence as the price side:
    explicit executor arg > REPRO_ENGINE_EXECUTOR > backend auto rule."""
    import jax

    from repro.core import page_ref as _pr
    from repro.kernels import profile_grid as _dpg

    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    cands = family_cands["rmi"]
    calls = {"host": 0, "device": 0}
    real_h = _pr.point_page_refs_mixed_eps_grid
    real_d = _dpg.point_page_refs_mixed_eps_grid

    def spy(side, real):
        def wrapped(*a, **k):
            calls[side] += 1
            return real(*a, **k)
        return wrapped

    monkeypatch.setattr(_pr, "point_page_refs_mixed_eps_grid",
                        spy("host", real_h))
    monkeypatch.setattr(_dpg, "point_page_refs_mixed_eps_grid",
                        spy("device", real_d))

    engine_executor("device")                       # env forces device
    sess.grid_profiles(cands, wls["point"])
    assert calls == {"host": 0, "device": 1}

    engine_executor("host")                         # explicit arg beats env
    sess.grid_profiles(cands, wls["point"], executor="device")
    assert calls == {"host": 0, "device": 2}
    sess.grid_profiles(cands, wls["point"])         # env alone -> host
    assert calls == {"host": 1, "device": 2}

    engine_executor(None)                           # auto: by backend
    sess.grid_profiles(cands, wls["point"])
    auto = "device" if jax.default_backend() == "tpu" else "host"
    assert calls[auto] == (3 if auto == "device" else 2)

    with pytest.raises(ValueError, match="executor"):
        sess.grid_profiles(cands, wls["point"], executor="gpu-ish")


# ---------------------------------------------------------------------------
# Multi-policy tables: policy as a knob, one launch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ("host", "device"))
def test_cross_policies_matches_single_policy_solves(world, executor):
    """One multi-policy solve == three single-policy solves, per policy
    block bit-for-bit, with identical per-policy winners and a global
    winner equal to the best of the three."""
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["mixed"])    # sorted part: exercises lfu coverage
    n = len(tab)
    multi = tab.cross_policies(POLICIES)
    assert len(multi) == 3 * n
    sol = PricingEngine(sess).price(tab.cross_policies(POLICIES),
                                    executor=executor)
    best_by_policy = {}
    for j, pol in enumerate(POLICIES):
        single = PricingEngine(CostSession(System(GEOM, BUDGET, pol))).price(
            tab, executor=executor)
        blk = slice(j * n, (j + 1) * n)
        assert np.array_equal(sol.hit_rates[blk], single.hit_rates), pol
        assert np.array_equal(sol.distinct[blk], single.distinct), pol
        assert int(np.argmin(sol.objective[blk])) == single.best_cell, pol
        best_by_policy[pol] = single.objective[single.best_cell]
        for kn, (a, b) in tab.spans.items():
            assert multi.spans[(pol, kn)] == (a + j * n, b + j * n)
            assert multi.points_of[(pol, kn)]["policy"] == pol
    assert sol.objective[sol.best_cell] == min(best_by_policy.values())


def test_cross_policies_validation(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])
    with pytest.raises(ValueError):
        tab.cross_policies(())
    with pytest.raises(ValueError):
        tab.cross_policies(("lru", "lru"))
    with pytest.raises(ValueError):
        tab.cross_policies(("arc",))
    with pytest.raises(ValueError):               # no double-crossing
        tab.cross_policies(("lru",)).cross_policies(("fifo",))


def test_pols_column_survives_concat_and_subset(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    prof = sess.grid_profiles(_cands(), wls["point"])
    plain = PriceTable.from_cells(prof, [("a", 0, np.array([4, 8]))])
    multi = PriceTable.from_cells(
        prof, [("b", 1, np.array([16, 32]))]).cross_policies(("lru", "lfu"))
    cat = PriceTable.concat([plain, multi])
    # plain cells carry -1 (session default); crossed cells their policy id
    assert cat.pols is not None
    assert cat.pols.tolist() == [-1, -1, 0, 0, 2, 2]
    sub = cat.subset([1, 3, 5])
    assert sub.pols.tolist() == [-1, 0, 2]
    # all-default concat keeps pols=None (no phantom policy column)
    plain2 = PriceTable.from_cells(prof, [("c", 2, np.array([64]))])
    assert PriceTable.concat([plain, plain2]).pols is None


# ---------------------------------------------------------------------------
# PriceTable algebra — property tests (skip cleanly without hypothesis)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prof_point(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    return sess, sess.grid_profiles(_cands(), wls["point"])


def _rand_table(prof, rng, tag, n_knobs):
    cells = []
    for j in range(n_knobs):
        caps = rng.integers(2, 5000, rng.integers(1, 4))
        cells.append((f"{tag}{j}", int(rng.integers(0, len(prof.knobs))),
                      np.sort(caps)))
    return PriceTable.from_cells(prof, cells)


@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_concat_offset_invariant(prof_point, seed, n1, n2):
    """concat keeps every span's cells, in order, at a pure offset."""
    _, prof = prof_point
    rng = np.random.default_rng(seed)
    t1 = _rand_table(prof, rng, "a", n1)
    t2 = _rand_table(prof, rng, "b", n2)
    cat = PriceTable.concat([t1, t2])
    assert len(cat) == len(t1) + len(t2)
    assert np.array_equal(cat.rows, np.concatenate([t1.rows, t2.rows]))
    assert np.array_equal(cat.caps, np.concatenate([t1.caps, t2.caps]))
    for kn, (a, b) in t1.spans.items():
        assert cat.spans[kn] == (a, b)
    for kn, (a, b) in t2.spans.items():
        assert cat.spans[kn] == (a + len(t1), b + len(t1))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_solution_subset_roundtrip(prof_point, seed):
    """PriceSolution.subset re-ranks the slice consistently with the full
    solve, and subsetting a crossed table keeps each cell's policy id."""
    sess, prof = prof_point
    rng = np.random.default_rng(seed)
    tab = _rand_table(prof, rng, "k", 3).cross_policies(("lru", "lfu"))
    sol = PricingEngine(sess).price(tab, executor="host")
    sel = np.sort(rng.choice(len(tab), size=rng.integers(1, len(tab) + 1),
                             replace=False))
    sub = sol.subset(sel)
    assert np.array_equal(sub.hit_rates, sol.hit_rates[sel])
    assert sub.best_cell == int(np.argmin(sol.objective[sel]))
    assert np.array_equal(sub.table.pols, tab.pols[sel])


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_from_cells_matches_degenerate_from_profiles(prof_point, seed):
    """from_profiles with no splits degenerates to one max-capacity cell
    per knob — exactly what from_cells builds from profiles.caps."""
    _, prof = prof_point
    rng = np.random.default_rng(seed)
    knobs = [kn for kn in prof.knobs if rng.integers(0, 2)] or [prof.knobs[0]]
    tp = PriceTable.from_profiles(
        prof, {kn: {} for kn in knobs}, splits=(),
        budget_bytes=float(BUDGET), page_bytes=GEOM.page_bytes)
    row_of = {kn: i for i, kn in enumerate(prof.knobs)}
    tc = PriceTable.from_cells(
        prof, [(kn, row_of[kn], np.asarray([prof.caps[row_of[kn]]]))
               for kn in knobs])
    assert np.array_equal(tp.rows, tc.rows)
    assert np.array_equal(tp.caps, tc.caps)
    assert tp.spans == tc.spans
    assert tp.pols is None and tc.pols is None
