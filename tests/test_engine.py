"""PricingEngine / PriceTable / executor tests.

* Executor golden equivalence: the fused DeviceExecutor (interpret mode off
  TPU) is float32-equivalent to the HostExecutor — per-cell hit rates,
  distinct pages and the argmin winner — across 3 policies x 4 workload
  kinds (point / range / sorted / mixed) and on grouped (sharded-style)
  profiles.
* Structural one-engine-call-per-solve: estimate_grid, the tuner's joint
  (knob x split) search and the join cost curve each run EXACTLY one
  ``engine.price`` (the tree's single call is pinned in test_join_tree.py,
  the sharded fleet's in test_sharding.py).
* Dispatch: explicit executor arg > REPRO_ENGINE_EXECUTOR > engine default;
  unknown names, empty tables, detached tables and bad objectives raise.
* PriceTable algebra: concat span offsetting, duplicate-knob and
  mixed-profiles rejection, subset rehydration.
"""
import numpy as np
import pytest

from repro.core.cam import CamGeometry
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload, range_workload
from repro.engine import (DeviceExecutor, HostExecutor, PriceTable,
                          PricingEngine)

GEOM = CamGeometry()
BUDGET = 3 << 20
POLICIES = ("lru", "fifo", "lfu")
EPS_GRID = (8, 16, 32, 64)
SPLITS = (0.25, 0.5, 0.75)


@pytest.fixture(scope="module")
def world():
    keys = make_dataset("books", 50_000, seed=1)
    n = len(keys)
    qk, qpos = point_workload(keys, 5_000, WorkloadSpec("w4", seed=3))
    rlo, rhi, rlop, rhip = range_workload(keys, 2_000,
                                          WorkloadSpec("w1", seed=5), 64)
    wls = {
        "point": Workload.point(qpos, n=n, query_keys=qk),
        "range": Workload.range_scan(rlop, rhip, n=n),
        "sorted": Workload.sorted_stream(np.sort(rlop), np.sort(rhip), n=n),
        "mixed": Workload.mixed(Workload.point(qpos, n=n),
                                Workload.sorted_stream(np.sort(rlop),
                                                       np.sort(rhip), n=n)),
    }
    return keys, wls


def _cands():
    return [GridCandidate(eps, 65_536.0, eps=eps) for eps in EPS_GRID]


def _table(sess, wl):
    prof = sess.grid_profiles(_cands(), wl)
    return PriceTable.from_profiles(
        prof, {kn: {} for kn in prof.knobs}, splits=SPLITS,
        budget_bytes=float(BUDGET), page_bytes=GEOM.page_bytes)


def _assert_equivalent(sol_h, sol_d):
    dh = np.max(np.abs(sol_h.hit_rates - sol_d.hit_rates))
    assert dh < 2e-6, dh                       # float32 summation-order only
    assert np.array_equal(sol_h.distinct, sol_d.distinct)
    # winners agree up to objective ties at float32 resolution
    assert np.isclose(sol_h.objective[sol_d.best_cell],
                      sol_h.objective[sol_h.best_cell],
                      rtol=1e-5, atol=1e-12)
    assert sol_h.executor == "host" and sol_d.executor == "device"


# ---------------------------------------------------------------------------
# Golden equivalence: fused device executor vs host reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("kind", ("point", "range", "sorted", "mixed"))
def test_executors_agree_across_policies_and_kinds(world, policy, kind):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, policy))
    tab = _table(sess, wls[kind])
    assert len(tab) > len(EPS_GRID)            # splits really enumerated
    eng = PricingEngine(sess)
    _assert_equivalent(eng.price(tab, executor="host"),
                       eng.price(tab, executor="device"))


@pytest.mark.parametrize("policy", POLICIES)
def test_executors_agree_on_grouped_profiles(world, policy):
    """Sharded-style (group, knob) profiles: padded histograms, concatenated
    rows — the fleet table shape — solve identically on both executors."""
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, policy))
    prof = sess.grid_profiles_grouped(
        [("s0", _cands(), wls["point"]),
         ("s1", _cands()[:2], wls["mixed"])])
    tab = PriceTable.from_profiles(
        prof, {kn: {} for kn in prof.knobs}, splits=SPLITS,
        budget_bytes=float(BUDGET), page_bytes=GEOM.page_bytes)
    eng = PricingEngine(sess)
    _assert_equivalent(eng.price(tab, executor="host"),
                       eng.price(tab, executor="device"))


def test_executors_agree_on_seconds_objective(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])
    eng = PricingEngine(sess)
    sol_h = eng.price(tab, objective="seconds", executor="host")
    sol_d = eng.price(tab, objective="seconds", executor="device")
    _assert_equivalent(sol_h, sol_d)
    assert sol_h.objective_name == "seconds"


# ---------------------------------------------------------------------------
# Structural: every session runs EXACTLY one engine call per solve
# ---------------------------------------------------------------------------

def test_estimate_grid_is_one_engine_call(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    assert sess.engine.calls == 0
    sess.estimate_grid(_cands(), wls["point"])
    assert sess.engine.calls == 1
    sess.estimate_grid(_cands(), wls["mixed"])
    assert sess.engine.calls == 2


def test_tuner_joint_search_is_one_engine_call(world):
    from repro.tuning.session import PGMBuilder, TuningSession
    keys, wls = world
    ts = TuningSession(System(GEOM, BUDGET, "lru"),
                       splits=tuple(i / 8 for i in range(1, 8)))
    assert ts.cost.engine.calls == 0
    res = ts.tune(PGMBuilder(keys), wls["point"],
                  overrides={"eps": EPS_GRID})
    assert ts.cost.engine.calls == 1
    assert res.batched_solves == 1


def test_join_cost_curve_is_one_engine_call(world):
    from repro.index.adapters import PGMAdapter
    from repro.join.session import JoinSession
    keys, wls = world
    adapter = PGMAdapter.build(keys, eps=32)
    system = System(GEOM, (1 << 20) + adapter.size_bytes, "lfu")
    s = JoinSession(adapter, system, inner_keys=keys)
    outer = np.asarray(keys[::7])
    s.cost_curve(outer, np.array([4, 16, 64, 256]), n_min=128)
    assert s._cost_session.engine.calls == 1


# ---------------------------------------------------------------------------
# Capacity dtype: exact compares above float32's 2^24 integer range
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_large_capacity_thrash_flip_exact_on_both_executors(policy):
    """Regression: a 2^24-page buffer one page below a 2^24 + 1 Thm III.1
    premise must thrash on BOTH executors — float32 capacity arithmetic
    would round the two equal and skip the regime entirely."""
    import dataclasses

    import jax.numpy as jnp

    from repro.core.session import GridProfiles, SortedScanPart

    cov = jnp.zeros((32,), jnp.float32).at[:16].set(2.0)   # R=32, N=16
    sp = SortedScanPart(32.0, 16.0, 2**24 + 1, cov, 0.0)
    prof = GridProfiles(
        knobs=("k",), counts=jnp.zeros((1, 32), jnp.float32),
        totals=np.zeros(1), dacs=np.ones(1), sizes=np.zeros(1),
        caps=np.array([2**25]), sparts=(sp,), skipped=(), scale=1.0,
        n_queries=32)
    tab = PriceTable.from_cells(
        prof, [("k", 0, np.array([2**24, 2**24 + 1]))])
    eng = PricingEngine(CostSession(System(GEOM, BUDGET, policy)))
    for ex in ("host", "device"):
        sol = eng.price(tab, executor=ex)
        assert sol.hit_rates[0] == 0.0, (ex, sol.hit_rates)   # thrash
        assert sol.hit_rates[1] == pytest.approx(0.5), ex     # modeled
        assert sol.best_cell == 1, ex


# ---------------------------------------------------------------------------
# Dispatch and validation
# ---------------------------------------------------------------------------

def test_dispatch_precedence(world, monkeypatch):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])

    # constructor default
    assert PricingEngine(sess, executor="host").price(tab).executor == "host"
    # env var beats constructor default
    monkeypatch.setenv("REPRO_ENGINE_EXECUTOR", "device")
    eng = PricingEngine(sess, executor="host")
    assert eng.price(tab).executor == "device"
    # explicit argument beats the env var
    assert eng.price(tab, executor="host").executor == "host"
    # executor instances pass straight through
    assert eng.price(tab, executor=HostExecutor()).executor == "host"
    assert eng.price(tab,
                     executor=DeviceExecutor(interpret=True)
                     ).executor == "device"


def test_engine_rejects_bad_inputs(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])
    eng = PricingEngine(sess)
    with pytest.raises(ValueError):
        eng.price(tab, executor="gpu-ish")
    with pytest.raises(ValueError):
        eng.price(tab, objective="latency")
    empty = PriceTable(np.zeros(0, np.int64), np.zeros(0, np.int64),
                       np.zeros(0), {}, {}, tab.profiles)
    with pytest.raises(ValueError):
        eng.price(empty)
    detached = PriceTable(tab.rows, tab.caps, tab.fracs, tab.spans,
                          tab.points_of, None)
    with pytest.raises(ValueError):
        eng.price(detached)


# ---------------------------------------------------------------------------
# PriceTable algebra
# ---------------------------------------------------------------------------

def test_concat_offsets_spans_and_rejects_duplicates(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    prof = sess.grid_profiles(_cands(), wls["point"])
    t1 = PriceTable.from_cells(prof, [("a", 0, np.array([4, 8])),
                                      ("b", 1, np.array([16]))])
    t2 = PriceTable.from_cells(prof, [("c", 2, np.array([32, 64, 128]))])
    cat = PriceTable.concat([t1, t2])
    assert len(cat) == 6
    assert cat.spans == {"a": (0, 2), "b": (2, 3), "c": (3, 6)}
    assert np.array_equal(cat.rows, [0, 0, 1, 2, 2, 2])
    with pytest.raises(ValueError):
        PriceTable.concat([t1, t1])            # duplicate knob keys
    other = sess.grid_profiles(_cands()[:2], wls["point"])
    with pytest.raises(ValueError):            # mixed GridProfiles objects
        PriceTable.concat([t1, PriceTable.from_cells(
            other, [("z", 0, np.array([4]))])])


def test_subset_rehydrates_singleton_spans(world):
    keys, wls = world
    sess = CostSession(System(GEOM, BUDGET, "lru"))
    tab = _table(sess, wls["point"])
    eng = PricingEngine(sess)
    sol = eng.price(tab, executor="host")
    sel = [a for kn, (a, b) in sorted(tab.spans.items())]
    sub = sol.subset(sel)
    assert len(sub.table) == len(sel)
    assert all(b - a == 1 for a, b in sub.table.spans.values())
    assert set(sub.table.spans) == set(tab.spans)
    # the sliced solution re-ranks within the slice
    assert sub.best_cell == int(np.argmin(sol.objective[sel]))
