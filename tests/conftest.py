"""Shared fixtures.

``engine_executor`` is THE way a test forces the engine/profiling
dispatch: it scopes ``REPRO_ENGINE_EXECUTOR`` through monkeypatch so the
forcing can never leak into another test (a bare ``os.environ`` write
would).  The env var is read per call by both dispatch sites —
``engine.table.PricingEngine._resolve`` (price side) and
``core.session._resolve_profile_executor`` (profile side) — so one
fixture steers both halves of the chained profile→price path.
"""
import pytest


@pytest.fixture
def engine_executor(monkeypatch):
    """Force (or clear) the executor env override for this test only.

    Returns a setter: ``engine_executor("device")`` pins both the pricing
    and the profiling dispatch; ``engine_executor(None)`` restores the
    auto rule (device iff the default jax backend is TPU).
    """
    def force(name):
        if name is None:
            monkeypatch.delenv("REPRO_ENGINE_EXECUTOR", raising=False)
        else:
            monkeypatch.setenv("REPRO_ENGINE_EXECUTOR", name)
    return force
