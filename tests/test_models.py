"""Model-zoo correctness: every arch smoke (reduced config, fwd+loss+decode),
blockwise==dense attention, chunked RWKV/Mamba2 == stepwise recurrence,
prefill==decode consistency, MoE routing invariants, M-RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, reduced
from repro.configs.base import ShapeSpec
from repro.distributed.sharding import Recipe, ShardingCtx
from repro.models import layers, mamba2, model as M, moe, rwkv
from repro.models.attention import blockwise_attention, dense_attention
from repro.models.params import init_params

CTX = ShardingCtx(None, Recipe(remat="none"))
KEY = jax.random.PRNGKey(0)
B, S = 2, 64
DECODE_SHAPE = ShapeSpec("tiny_decode", "decode", S, B)


def _batch(cfg, seq=S, train=True):
    extra = 1 if train else 0
    if cfg.family == "audio":
        toks = jax.random.randint(KEY, (B, seq + extra, cfg.num_codebooks),
                                  0, cfg.vocab_size)
    else:
        toks = jax.random.randint(KEY, (B, seq + extra), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_vision_tokens, cfg.vision_patch_dim))
        batch["positions_3d"] = jnp.broadcast_to(
            jnp.arange(seq)[None, None], (3, B, seq)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_and_decode(arch):
    """Required per-arch smoke: reduced config, one train step's loss + one
    decode step on CPU; asserts shapes + no NaNs."""
    cfg = reduced(ARCHS[arch])
    params = init_params(cfg, KEY)
    loss = M.loss_fn(params, cfg, _batch(cfg), CTX)
    assert loss.shape == () and bool(jnp.isfinite(loss)), arch

    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         M.cache_specs(cfg, DECODE_SHAPE))
    batch = _batch(cfg, train=False)
    dbatch = {"tokens": batch["tokens"][:, :1],
              "lengths": jnp.full((B,), 3, jnp.int32)}
    logits, new_cache = M.decode_fn(params, cfg, dbatch, cache, CTX)
    want_v = cfg.vocab_size
    if cfg.family == "audio":
        assert logits.shape == (B, cfg.num_codebooks, want_v)
    else:
        assert logits.shape == (B, want_v)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_prefill(arch):
    cfg = reduced(ARCHS[arch])
    shape = ShapeSpec("tiny_prefill", "prefill", S, B)
    params = init_params(cfg, KEY, jnp.float32)
    batch = _batch(cfg, train=False)
    logits, cache = M.prefill_fn(params, cfg, batch, CTX)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_blockwise_equals_dense():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 96, 6, 32))
    k = jax.random.normal(ks[1], (2, 96, 3, 32))
    v = jax.random.normal(ks[2], (2, 96, 3, 32))
    a = dense_attention(q, k, v, causal=True)
    b_ = blockwise_attention(q, k, v, causal=True, block_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_rwkv_chunked_equals_stepwise():
    """The chunked WKV prefill must equal running the O(1) recurrence token
    by token — validates the log-space chunk algebra."""
    cfg = reduced(ARCHS["rwkv6-3b"])
    params = init_params(cfg, KEY)
    blk = jax.tree.map(lambda x: x[0], params["blocks"])
    x = jax.random.normal(KEY, (B, cfg.chunk_size * 2, cfg.d_model)) * 0.1

    h_full, (tm, cm, att) = rwkv.rwkv_block(x, blk, cfg, CTX)

    h_steps = []
    tm_p = jnp.zeros((B, cfg.d_model), x.dtype)
    cm_p = jnp.zeros((B, cfg.d_model), x.dtype)
    att_p = jnp.zeros((B, cfg.num_heads, cfg.ssm_head_dim, cfg.ssm_head_dim),
                      jnp.float32)
    for t in range(x.shape[1]):
        h_t, (tm_p, cm_p, att_p) = rwkv.rwkv_block_decode(
            x[:, t:t + 1], blk, cfg, CTX, tm_p, cm_p, att_p)
        h_steps.append(h_t)
    h_seq = jnp.concatenate(h_steps, axis=1)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h_seq),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(att), np.asarray(att_p),
                               atol=5e-4, rtol=1e-3)


def test_mamba2_chunked_equals_stepwise():
    cfg = reduced(ARCHS["zamba2-2.7b"])
    params = init_params(cfg, KEY)
    blk = jax.tree.map(lambda x: x[0, 0], params["mamba"])
    x = jax.random.normal(KEY, (B, cfg.chunk_size * 2, cfg.d_model)) * 0.1

    h_full, (conv, ssm) = mamba2.mamba2_block(x, blk, cfg, CTX)

    din = cfg.expand * cfg.d_model
    conv_p = jnp.zeros((B, cfg.conv_width - 1, din), x.dtype)
    ssm_p = jnp.zeros((B, din // cfg.ssm_head_dim, cfg.ssm_head_dim,
                       cfg.ssm_state_dim), jnp.float32)
    outs = []
    for t in range(x.shape[1]):
        h_t, (conv_p, ssm_p) = mamba2.mamba2_block_decode(
            x[:, t:t + 1], blk, cfg, CTX, conv_p, ssm_p)
        outs.append(h_t)
    h_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h_seq),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(ssm), np.asarray(ssm_p),
                               atol=5e-4, rtol=1e-3)


def test_transformer_prefill_decode_consistency():
    """decode(prefill(tokens[:-1]) cache, tokens[-1]) logits must match a
    full forward over the whole sequence at the last position."""
    cfg = reduced(ARCHS["yi-34b"])
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, 17), 0, cfg.vocab_size)
    from repro.models.transformer import transformer_logits

    full_logits, _, _ = transformer_logits(params, cfg, {"tokens": toks}, CTX)
    _, cache = M.prefill_fn(params, cfg, {"tokens": toks[:, :-1]}, CTX)
    # grow cache to hold the new token
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0))), cache)
    dec_logits, _ = M.decode_fn(
        params, cfg, {"tokens": toks[:, -1:],
                      "lengths": jnp.full((B,), 16, jnp.int32)}, cache, CTX)
    # bf16 residual stream + bf16 cache storage: paths differ in rounding
    # order only (corr > 0.9999 checked during bring-up).
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, -1]),
                               atol=6e-2, rtol=5e-2)


def test_moe_routing_invariants():
    d, e, f, topk = 16, 4, 32, 2
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (2, 8, d))
    router = jax.random.normal(ks[1], (d, e)) * 0.1
    gate = jax.random.normal(ks[2], (e, d, f)) * 0.1
    up = jax.random.normal(ks[3], (e, d, f)) * 0.1
    down = jax.random.normal(ks[4], (e, f, d)) * 0.1
    out, aux = moe.moe_block(x, router, gate, up, down, topk, 8.0, None)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) >= 0.99  # E*sum(f*p) >= 1
    # with huge capacity nothing drops: output must equal dense top-k compute
    logits = jnp.einsum("bsd,de->bse", x, router)
    probs = jax.nn.softmax(logits, -1)
    g_v, g_i = jax.lax.top_k(probs, topk)
    g_v = g_v / g_v.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for kk in range(topk):
        idx = g_i[..., kk]
        w_g = gate[idx]
        w_u = up[idx]
        w_d = down[idx]
        h = jax.nn.silu(jnp.einsum("bsd,bsdf->bsf", x, w_g)) \
            * jnp.einsum("bsd,bsdf->bsf", x, w_u)
        ref += g_v[..., kk:kk + 1] * jnp.einsum("bsf,bsfd->bsd", h, w_d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_mrope_sections_match_rope_when_positions_equal():
    """With identical t/h/w position ids, M-RoPE must reduce to plain RoPE."""
    x = jax.random.normal(KEY, (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8)).astype(jnp.int32)
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    a = layers.rope(x, pos, theta=1e4)
    b_ = layers.mrope(x, pos3, (4, 6, 6), theta=1e4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_int8_kv_cache_decode_close_to_bf16():
    """The quantized decode cache (per-(token,head) absmax scales) must track
    the bf16 cache closely — the C1 §Perf optimization's correctness check."""
    import jax.numpy as jnp
    from repro.models import model as M2
    from repro.models.transformer import init_kv_cache

    cfg = reduced(ARCHS["musicgen-medium"])
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, 1, cfg.num_codebooks), 0, cfg.vocab_size)
    lengths = jnp.full((B,), 9, jnp.int32)
    rngk = jax.random.split(KEY, 4)

    base = init_kv_cache(cfg, B, 32, jnp.bfloat16)
    kvals = jax.random.normal(rngk[0], base["k"].shape, jnp.float32) * 0.5
    vvals = jax.random.normal(rngk[1], base["v"].shape, jnp.float32) * 0.5
    cache_bf16 = {"k": kvals.astype(jnp.bfloat16),
                  "v": vvals.astype(jnp.bfloat16)}
    # quantize the same contents
    ksc = jnp.maximum(jnp.max(jnp.abs(kvals), -1), 1e-6) / 127.0
    vsc = jnp.maximum(jnp.max(jnp.abs(vvals), -1), 1e-6) / 127.0
    cache_q = {
        "k": jnp.clip(jnp.round(kvals / ksc[..., None]), -127, 127).astype(jnp.int8),
        "v": jnp.clip(jnp.round(vvals / vsc[..., None]), -127, 127).astype(jnp.int8),
        "k_scale": ksc, "v_scale": vsc,
    }
    batch = {"tokens": toks, "lengths": lengths}
    logits_a, _ = M2.decode_fn(params, cfg, batch, cache_bf16, CTX)
    logits_b, new_cache = M2.decode_fn(params, cfg, batch, cache_q, CTX)
    assert "k_scale" in new_cache and new_cache["k"].dtype == jnp.int8
    diff = float(jnp.max(jnp.abs(logits_a - logits_b)))
    scale = float(jnp.max(jnp.abs(logits_a))) + 1e-6
    assert diff / scale < 0.08, (diff, scale)
