"""CostSession API tests.

* Golden equivalence: the session pipeline must reproduce the seed CAM math
  (an inline re-implementation of Algorithm 1 from the raw kernels) to 1e-6,
  and the deprecated ``cam.estimate_*`` shims must agree exactly.
* Grid equivalence: ``estimate_grid`` (one jitted pass) must match the
  candidate-by-candidate loop.
* Estimator-vs-replay oracle: ONE parametrized test runs all three index
  families (PGM, RMI, RadixSpline) through the same session and checks the
  estimate against ground-truth trace replay.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache_models, cam, dac, page_ref
from repro.core.qerror import q_error
from repro.core.replay import replay_windows
from repro.core.session import (CostSession, GridCandidate, System,
                                UniformEpsModel, UnsupportedWorkloadError)
from repro.core.workload import Workload, locate
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload, range_workload
from repro.index.adapters import (ADAPTERS, PGMAdapter, RMIAdapter,
                                  RadixSplineAdapter)
from repro.tuning.session import TuningSession, builder_for

GEOM = cam.CamGeometry()
BUDGET = 3 << 20


@pytest.fixture(scope="module")
def world():
    keys = make_dataset("books", 200_000, seed=1)
    qk, qpos = point_workload(keys, 20_000, WorkloadSpec("w4", seed=3))
    return keys, qk, qpos


def _seed_point_oracle(positions, eps, n, geom, budget, index_bytes, policy):
    """The seed repo's estimate_point_io math, re-derived from raw kernels."""
    counts, total = page_ref.point_page_refs(
        jnp.asarray(positions, jnp.int32), int(eps), geom.c_ipp,
        geom.num_pages(n))
    e_dac = float(dac.expected_dac(eps, geom.c_ipp, geom.strategy))
    capv = cam.capacity_pages(budget, index_bytes, geom.page_bytes)
    n_distinct = float(jnp.sum(counts > 0))
    if capv <= 0:
        h = 0.0
    else:
        probs = counts / jnp.maximum(float(total), 1e-30)
        h = float(cache_models.hit_rate(policy, capv, probs,
                                        total_requests=float(total),
                                        distinct_pages=n_distinct))
    return (1.0 - h) * e_dac, h


# ---------------------------------------------------------------------------
# Golden equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eps", [16, 128])
@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu"])
def test_session_matches_seed_math_point(world, eps, policy):
    keys, qk, qpos = world
    n = len(keys)
    io_ref, h_ref = _seed_point_oracle(qpos, eps, n, GEOM, BUDGET, 65_536,
                                       policy)
    session = CostSession(System(GEOM, BUDGET, policy))
    est = session.estimate(UniformEpsModel(eps, n, 65_536.0),
                           Workload.point(qpos, n=n))
    assert abs(est.io_per_query - io_ref) < 1e-6
    assert abs(est.hit_rate - h_ref) < 1e-6


@pytest.mark.parametrize("eps", [16, 128])
def test_legacy_shims_equal_session(world, eps):
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, "lru"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = cam.estimate_point_io(qpos, eps, n, GEOM, BUDGET, 65_536,
                                       policy="lru", sample_rate=0.5)
    new = session.estimate(UniformEpsModel(eps, n, 65_536.0),
                           Workload.point(qpos, n=n), sample_rate=0.5)
    assert abs(legacy.io_per_query - new.io_per_query) < 1e-6
    assert abs(legacy.hit_rate - new.hit_rate) < 1e-6
    assert legacy.capacity_pages == new.capacity_pages
    assert abs(legacy.total_refs - new.total_refs) < 1e-3


def test_legacy_range_and_sorted_shims(world):
    keys, qk, qpos = world
    n = len(keys)
    _, _, lo_pos, hi_pos = range_workload(keys, 5_000, WorkloadSpec("w4", seed=3))
    session = CostSession(System(GEOM, BUDGET, "lru"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy_r = cam.estimate_range_io(lo_pos, hi_pos, 64, n, GEOM, BUDGET,
                                         65_536)
        wlo = np.sort(qpos)
        legacy_s = cam.estimate_sorted_io(wlo - 64, wlo + 64, 64, n, GEOM,
                                          BUDGET, 65_536)
    new_r = session.estimate(UniformEpsModel(64, n, 65_536.0),
                             Workload.range_scan(lo_pos, hi_pos, n=n))
    new_s = session.estimate(UniformEpsModel(64, n, 65_536.0),
                             Workload.sorted_stream(wlo - 64, wlo + 64, n=n))
    assert abs(legacy_r.io_per_query - new_r.io_per_query) < 1e-6
    assert abs(legacy_s.io_per_query - new_s.io_per_query) < 1e-6
    assert new_s.policy == "sorted-closed-form"


def test_locate_once_matches_generator_positions(world):
    keys, qk, qpos = world
    wl = Workload.from_keys(keys, qk)
    np.testing.assert_array_equal(wl.positions, locate(keys, qk))
    assert wl.n == len(keys)
    # generator positions ARE ranks of the drawn keys, so locating the keys
    # again must land on a position holding the same key
    np.testing.assert_array_equal(keys[wl.positions], keys[qpos])


def test_workload_sample_preserves_order_and_scale(world):
    _, qk, qpos = world
    wl = Workload.point(qpos, n=200_000, query_keys=qk)
    s = wl.sample(0.25, seed=7)
    assert s.n_queries == round(0.25 * wl.n_queries)
    assert s.base_queries == wl.n_queries
    assert abs(s.scale - 4.0) < 1e-9
    # order-preserving: sampled positions appear in original relative order
    sel = cam.sample_workload(qpos, 0.25, seed=7)
    np.testing.assert_array_equal(s.positions, sel)
    assert s.query_keys is not None and len(s.query_keys) == s.n_queries


# ---------------------------------------------------------------------------
# Grid equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu"])
def test_estimate_grid_matches_single_loop(world, policy):
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, policy))
    wl = Workload.point(qpos, n=n)
    grid = (8, 16, 32, 64, 128, 256, 512, 1024)
    sizes = {e: 2e9 / e for e in grid}   # synthetic shrinking footprint
    cands = [GridCandidate(knob=e, eps=e, size_bytes=sizes[e]) for e in grid]
    res = session.estimate_grid(cands, wl)
    kept = [e for e in grid if sizes[e] < BUDGET - GEOM.page_bytes]
    assert set(res.estimates) == set(kept)
    assert {s.knob for s in res.skipped} == set(grid) - set(kept)
    for e in kept:
        single = session.estimate(UniformEpsModel(e, n, sizes[e]), wl)
        g = res.estimates[e]
        tol = 1e-4 * max(single.io_per_query, 1e-3)
        assert abs(g.io_per_query - single.io_per_query) < tol, (e, policy)
        assert g.capacity_pages == single.capacity_pages


def test_estimate_grid_range_and_mixed(world):
    keys, qk, qpos = world
    n = len(keys)
    _, _, lo_pos, hi_pos = range_workload(keys, 5_000, WorkloadSpec("w4", seed=3))
    session = CostSession(System(GEOM, BUDGET, "lru"))
    wl = Workload.mixed(Workload.point(qpos, n=n),
                        Workload.range_scan(lo_pos, hi_pos, n=n))
    cands = [GridCandidate(knob=e, eps=e, size_bytes=65_536.0)
             for e in (32, 128)]
    res = session.estimate_grid(cands, wl)
    for e in (32, 128):
        single = session.estimate(UniformEpsModel(e, n, 65_536.0), wl)
        g = res.estimates[e]
        assert abs(g.io_per_query - single.io_per_query) \
            < 1e-4 * max(single.io_per_query, 1e-3)
    # mixed E[DAC] interpolates between the pure shapes' request volumes
    assert res.estimates[32].dac > 1.0


def test_estimate_grid_infeasible_budget_raises(world):
    keys, _, qpos = world
    session = CostSession(System(GEOM, 8192, "lru"))
    cands = [GridCandidate(knob=64, eps=64, size_bytes=1e9)]
    with pytest.raises(ValueError, match="memory budget too small"):
        session.estimate_grid(cands, Workload.point(qpos, n=len(keys)))


# ---------------------------------------------------------------------------
# Estimator vs replay — the shared oracle across ALL THREE families
# ---------------------------------------------------------------------------

_BUILDERS = {
    "pgm": lambda keys: PGMAdapter.build(keys, 64),
    "rmi": lambda keys: RMIAdapter.build(keys, 1024),
    "radixspline": lambda keys: RadixSplineAdapter.build(keys, 64),
}


@pytest.mark.parametrize("family", sorted(_BUILDERS))
@pytest.mark.parametrize("policy", ["lru", "fifo"])
def test_estimator_matches_replay_all_families(world, family, policy):
    """The paper's index-agnosticism claim, enforced: one session, one
    workload, three designs — every estimate must track ground truth."""
    keys, qk, qpos = world
    adapter = _BUILDERS[family](keys)
    assert adapter.family == family and family in ADAPTERS
    assert adapter.knobs()
    # 2 MiB keeps capacity well below the page count: the IRM steady state is
    # the regime CAM models (near-full caching is compulsory-miss noise).
    system = System(GEOM, 2 << 20, policy)
    est = CostSession(system).estimate(
        adapter, Workload.point(qpos, n=len(keys), query_keys=qk))
    cap = max(1, system.capacity_for(adapter.size_bytes))
    lo, hi = adapter.window(qk)
    misses = replay_windows(lo // GEOM.c_ipp, hi // GEOM.c_ipp, cap, policy)
    assert float(q_error(est.io_per_query, misses.mean())) < 1.4, family


# ---------------------------------------------------------------------------
# Sorted streams: policy-aware model vs replay, grid equivalence, typed skips
# ---------------------------------------------------------------------------

def _sorted_stream_workload(adapter, qk, n):
    """Sorted probe stream through an adapter's own windows (position space)."""
    plo, phi = adapter.probe_windows(np.sort(qk), GEOM)
    return (Workload.sorted_stream(plo * GEOM.c_ipp, phi * GEOM.c_ipp, n=n),
            plo, phi)


@pytest.mark.parametrize("family", sorted(_BUILDERS))
@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu"])
@pytest.mark.parametrize("cap", [384, 512])
def test_sorted_estimator_matches_replay(world, family, policy, cap):
    """Sorted-stream oracle across 3 policies x 3 families x 2 capacities:
    Theorem III.1 is exact under recency eviction; the frequency-aware
    closed form must track LFU replay within q-error < 2 (the regime the
    recency form gets systematically wrong)."""
    keys, qk, qpos = world
    adapter = _BUILDERS[family](keys)
    wl, plo, phi = _sorted_stream_workload(adapter, qk, len(keys))
    system = System(GEOM, cap * GEOM.page_bytes + adapter.size_bytes, policy)
    assert system.capacity_for(adapter.size_bytes) == cap
    est = CostSession(system).estimate(adapter, wl)
    actual = replay_windows(plo, phi, cap, policy).sum()
    pred = est.io_per_query * wl.n_queries
    if policy == "lfu":
        assert float(q_error(pred, max(actual, 1))) < 2.0, (family, pred, actual)
        assert est.policy == "sorted-lfu"
        assert pred >= est.distinct_pages - 1e-3   # compulsory floor
    else:
        # exact: one compulsory miss per distinct page
        assert abs(pred - actual) < 1e-3 * max(actual, 1), (family, pred, actual)
        assert est.policy == "sorted-closed-form"


def test_sorted_lfu_estimate_corrects_recency_form(world):
    """The bug this PR fixes: under LFU the recency closed form is
    systematically optimistic; the policy-aware estimate must sit strictly
    above it and strictly closer to replay."""
    keys, qk, qpos = world
    adapter = _BUILDERS["pgm"](keys)
    wl, plo, phi = _sorted_stream_workload(adapter, qk, len(keys))
    cap = 384
    system = System(GEOM, cap * GEOM.page_bytes + adapter.size_bytes, "lfu")
    est = CostSession(system).estimate(adapter, wl)
    pred = est.io_per_query * wl.n_queries
    compulsory = est.distinct_pages
    actual = replay_windows(plo, phi, cap, "lfu").sum()
    assert actual > 1.5 * compulsory          # LFU really does miss more
    assert pred > compulsory                  # model no longer optimistic
    assert abs(pred - actual) < abs(compulsory - actual)


@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_estimate_grid_sorted_matches_single(world, policy):
    """Batched sorted grid (one vmapped solve) == per-candidate estimates."""
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, policy))
    wlo = np.sort(qpos)
    wl = Workload.sorted_stream(wlo - 64, wlo + 64, n=n)
    grid = (8, 64, 256, 1024)
    sizes = {e: 2e9 / e for e in grid}
    cands = [GridCandidate(knob=e, eps=e, size_bytes=sizes[e]) for e in grid]
    res = session.estimate_grid(cands, wl)
    kept = [e for e in grid if sizes[e] < BUDGET - GEOM.page_bytes]
    assert set(res.estimates) == set(kept)
    assert {s.knob for s in res.skipped} == set(grid) - set(kept)
    for e in kept:
        single = session.estimate(UniformEpsModel(e, n, sizes[e]), wl)
        g = res.estimates[e]
        assert abs(g.io_per_query - single.io_per_query) \
            < 1e-4 * max(single.io_per_query, 1e-3), (e, policy)
        assert abs(g.hit_rate - single.hit_rate) < 1e-4
        assert g.capacity_pages == single.capacity_pages
        assert g.policy == single.policy


@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_estimate_grid_sorted_rmi_backed(world, policy):
    """Index-backed (RMI) candidates join a sorted grid — no uniform eps."""
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, policy))
    rmi = RMIAdapter.build(keys, 1024)
    wl, _, _ = _sorted_stream_workload(rmi, qk, n)
    cands = [GridCandidate(knob="rmi", size_bytes=rmi.size_bytes, index=rmi),
             GridCandidate(knob=64, eps=64, size_bytes=65_536.0)]
    res = session.estimate_grid(cands, wl)
    single = session.estimate(rmi, wl)
    g = res.estimates["rmi"]
    assert abs(g.io_per_query - single.io_per_query) \
        < 1e-4 * max(single.io_per_query, 1e-3)
    assert g.policy == single.policy


@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_estimate_grid_mixed_with_sorted(world, policy):
    """Mixed workloads containing sorted parts grid-estimate (they used to
    hard-raise) and match the per-candidate composition."""
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, policy))
    wlo = np.sort(qpos[:5000])
    wl = Workload.mixed(Workload.point(qpos, n=n),
                        Workload.sorted_stream(wlo - 64, wlo + 64, n=n))
    cands = [GridCandidate(knob=e, eps=e, size_bytes=65_536.0)
             for e in (32, 128)]
    res = session.estimate_grid(cands, wl)
    for e in (32, 128):
        single = session.estimate(UniformEpsModel(e, n, 65_536.0), wl)
        g = res.estimates[e]
        assert abs(g.io_per_query - single.io_per_query) \
            < 1e-4 * max(single.io_per_query, 1e-3), (e, policy)
        assert abs(g.hit_rate - single.hit_rate) < 1e-4
        assert abs(g.total_refs - single.total_refs) \
            < 1e-3 * max(single.total_refs, 1.0)
    # the sorted part contributes request mass beyond the point part
    point_only = session.estimate(UniformEpsModel(32, n, 65_536.0),
                                  Workload.point(qpos, n=n))
    assert res.estimates[32].total_refs > point_only.total_refs


@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_estimate_grid_all_sorted_mixed_with_backed(world, policy):
    """Regression: a MIXED workload whose parts are ALL sorted (an empty IRM
    part) must grid-estimate with index-backed candidates present, matching
    the per-candidate composition."""
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, policy))
    wlo = np.sort(qpos)
    wl = Workload.mixed(
        Workload.sorted_stream(wlo[:8000] - 64, wlo[:8000] + 64, n=n),
        Workload.sorted_stream(wlo[8000:] - 16, wlo[8000:] + 16, n=n))
    pgm = PGMAdapter.build(keys, 64)
    cands = [GridCandidate(knob="pgm", size_bytes=pgm.size_bytes, index=pgm),
             GridCandidate(knob=32, eps=32, size_bytes=65_536.0)]
    res = session.estimate_grid(cands, wl)
    for knob, model in (("pgm", pgm), (32, UniformEpsModel(32, n, 65_536.0))):
        single = session.estimate(model, wl)
        g = res.estimates[knob]
        assert abs(g.io_per_query - single.io_per_query) \
            < 1e-4 * max(single.io_per_query, 1e-3), (knob, policy)
        assert abs(g.hit_rate - single.hit_rate) < 1e-4


def test_grid_accepts_legacy_coverageless_sorted_profile(world):
    """Back-compat: a third-party IndexModel that prices a workload as a
    legacy pure-sorted profile (counts=None, (R, N) fields only, no
    sorted_part/coverage) must grid-estimate through the recency closed
    form, matching the single-candidate path."""
    from repro.core.session import PageRefProfile

    keys, qk, qpos = world
    n = len(keys)

    class LegacySortedModel:
        family = "custom-sorted"
        size_bytes = 1024.0

        def knobs(self):
            return {}

        def page_ref_profile(self, workload, geom):
            return PageRefProfile(counts=None, total_refs=5000.0,
                                  expected_dac=1.0, sorted_stream=True,
                                  distinct_pages=700.0, min_capacity=2)

    model = LegacySortedModel()
    wlo = np.sort(qpos[:4000])
    mixed = Workload.mixed(Workload.sorted_stream(wlo - 64, wlo + 64, n=n))
    for policy in ("lru", "lfu"):
        session = CostSession(System(GEOM, BUDGET, policy))
        single = session.estimate(model, mixed)
        res = session.estimate_grid(
            [GridCandidate(knob="legacy", size_bytes=model.size_bytes,
                           index=model)], mixed)
        g = res.estimates["legacy"]
        assert abs(g.hit_rate - (5000.0 - 700.0) / 5000.0) < 1e-5, policy
        assert abs(g.io_per_query - single.io_per_query) < 1e-5, policy
        assert abs(g.distinct_pages - single.distinct_pages) < 1e-6, policy


def test_grid_eps0_candidate_keeps_widest_window_premise(world):
    """An eps=0 candidate (no uniform bound) must use the widest-observed-
    window Thm III.1 premise in the grid, same as the single path — not a
    premise of 1."""
    keys, qk, qpos = world
    n = len(keys)
    wlo = np.sort(qpos)
    wl = Workload.sorted_stream(wlo - 512, wlo + 512, n=n)   # 3-5 page windows
    # capacity of 2 pages sits below the widest window: thrash regime
    size = BUDGET - 2 * GEOM.page_bytes
    session = CostSession(System(GEOM, BUDGET, "lru"))
    res = session.estimate_grid(
        [GridCandidate(knob=0, eps=0, size_bytes=float(size))], wl)
    single = session.estimate(UniformEpsModel(0, n, float(size)), wl)
    assert single.hit_rate == 0.0                    # thrash on single path
    assert res.estimates[0].hit_rate == single.hit_rate


def test_grid_skipped_records_reasons(world):
    """Regression: GridResult.skipped carries (knob, reason), both for
    budget-infeasible candidates and for profiles a candidate cannot build."""
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, "lru"))
    res = session.estimate_grid(
        [GridCandidate(knob="fat", eps=64, size_bytes=1e12),
         GridCandidate(knob=64, eps=64, size_bytes=65_536.0)],
        Workload.point(qpos, n=n))
    assert [s.knob for s in res.skipped] == ["fat"]
    assert "memory budget" in res.skipped[0].reason
    # RMI cannot profile a range workload: skipped with the typed reason,
    # the uniform-eps candidate still estimates.
    _, _, lo_pos, hi_pos = range_workload(keys, 2_000, WorkloadSpec("w4", seed=3))
    rmi = RMIAdapter.build(keys, 1024)
    res = session.estimate_grid(
        [GridCandidate(knob="rmi", size_bytes=rmi.size_bytes, index=rmi),
         GridCandidate(knob=64, eps=64, size_bytes=65_536.0)],
        Workload.range_scan(lo_pos, hi_pos, n=n))
    assert 64 in res.estimates and "rmi" not in res.estimates
    reasons = {s.knob: s.reason for s in res.skipped}
    assert "range" in reasons["rmi"]


def test_unsupported_workload_errors_are_typed(world):
    keys, qk, qpos = world
    n = len(keys)
    rmi = RMIAdapter.build(keys, 1024)
    wl = Workload.range_scan(np.array([0]), np.array([100]), n=n)
    with pytest.raises(UnsupportedWorkloadError) as ei:
        rmi.page_ref_profile(wl, GEOM)
    assert ei.value.kind == "range"
    assert isinstance(ei.value, ValueError)      # back-compat
    # a grid where NO candidate can profile the workload raises typed too
    session = CostSession(System(GEOM, BUDGET, "lru"))
    with pytest.raises(UnsupportedWorkloadError, match="no grid candidate"):
        session.estimate_grid(
            [GridCandidate(knob="rmi", size_bytes=rmi.size_bytes, index=rmi)],
            wl)


@pytest.mark.parametrize("family,overrides", [
    ("pgm", {"eps": (16, 64, 256, 1024)}),
    ("rmi", {"branch": (256, 1024, 4096)}),
    ("radixspline", {"eps": (16, 64, 256, 1024), "radix_bits": 12}),
])
def test_grid_tuning_all_families(world, family, overrides):
    """All three families grid-tune through the same TuningSession path."""
    keys, qk, qpos = world
    session = TuningSession(System(GEOM, 2 << 20, "lru"))
    res = session.tune(builder_for(family, keys),
                       Workload.point(qpos, n=len(keys), query_keys=qk),
                       overrides=overrides)
    assert res.best_knob in res.estimates
    assert res.est_io == res.estimates[res.best_knob].io_per_query
    assert all(e.io_per_query >= res.est_io - 1e-9
               for e in res.estimates.values())
