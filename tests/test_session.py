"""CostSession API tests.

* Golden equivalence: the session pipeline must reproduce the seed CAM math
  (an inline re-implementation of Algorithm 1 from the raw kernels) to 1e-6,
  and the deprecated ``cam.estimate_*`` shims must agree exactly.
* Grid equivalence: ``estimate_grid`` (one jitted pass) must match the
  candidate-by-candidate loop.
* Estimator-vs-replay oracle: ONE parametrized test runs all three index
  families (PGM, RMI, RadixSpline) through the same session and checks the
  estimate against ground-truth trace replay.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache_models, cam, dac, page_ref
from repro.core.qerror import q_error
from repro.core.replay import replay_windows
from repro.core.session import (CostSession, GridCandidate, System,
                                UniformEpsModel)
from repro.core.workload import Workload, locate
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload, range_workload
from repro.index.adapters import (ADAPTERS, PGMAdapter, RMIAdapter,
                                  RadixSplineAdapter)
from repro.tuning.pgm_tuner import cam_tune_pgm
from repro.tuning.rmi_tuner import cam_tune_rmi
from repro.tuning.rs_tuner import cam_tune_radixspline

GEOM = cam.CamGeometry()
BUDGET = 3 << 20


@pytest.fixture(scope="module")
def world():
    keys = make_dataset("books", 200_000, seed=1)
    qk, qpos = point_workload(keys, 20_000, WorkloadSpec("w4", seed=3))
    return keys, qk, qpos


def _seed_point_oracle(positions, eps, n, geom, budget, index_bytes, policy):
    """The seed repo's estimate_point_io math, re-derived from raw kernels."""
    counts, total = page_ref.point_page_refs(
        jnp.asarray(positions, jnp.int32), int(eps), geom.c_ipp,
        geom.num_pages(n))
    e_dac = float(dac.expected_dac(eps, geom.c_ipp, geom.strategy))
    capv = cam.capacity_pages(budget, index_bytes, geom.page_bytes)
    n_distinct = float(jnp.sum(counts > 0))
    if capv <= 0:
        h = 0.0
    else:
        probs = counts / jnp.maximum(float(total), 1e-30)
        h = float(cache_models.hit_rate(policy, capv, probs,
                                        total_requests=float(total),
                                        distinct_pages=n_distinct))
    return (1.0 - h) * e_dac, h


# ---------------------------------------------------------------------------
# Golden equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eps", [16, 128])
@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu"])
def test_session_matches_seed_math_point(world, eps, policy):
    keys, qk, qpos = world
    n = len(keys)
    io_ref, h_ref = _seed_point_oracle(qpos, eps, n, GEOM, BUDGET, 65_536,
                                       policy)
    session = CostSession(System(GEOM, BUDGET, policy))
    est = session.estimate(UniformEpsModel(eps, n, 65_536.0),
                           Workload.point(qpos, n=n))
    assert abs(est.io_per_query - io_ref) < 1e-6
    assert abs(est.hit_rate - h_ref) < 1e-6


@pytest.mark.parametrize("eps", [16, 128])
def test_legacy_shims_equal_session(world, eps):
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, "lru"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = cam.estimate_point_io(qpos, eps, n, GEOM, BUDGET, 65_536,
                                       policy="lru", sample_rate=0.5)
    new = session.estimate(UniformEpsModel(eps, n, 65_536.0),
                           Workload.point(qpos, n=n), sample_rate=0.5)
    assert abs(legacy.io_per_query - new.io_per_query) < 1e-6
    assert abs(legacy.hit_rate - new.hit_rate) < 1e-6
    assert legacy.capacity_pages == new.capacity_pages
    assert abs(legacy.total_refs - new.total_refs) < 1e-3


def test_legacy_range_and_sorted_shims(world):
    keys, qk, qpos = world
    n = len(keys)
    _, _, lo_pos, hi_pos = range_workload(keys, 5_000, WorkloadSpec("w4", seed=3))
    session = CostSession(System(GEOM, BUDGET, "lru"))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy_r = cam.estimate_range_io(lo_pos, hi_pos, 64, n, GEOM, BUDGET,
                                         65_536)
        wlo = np.sort(qpos)
        legacy_s = cam.estimate_sorted_io(wlo - 64, wlo + 64, 64, n, GEOM,
                                          BUDGET, 65_536)
    new_r = session.estimate(UniformEpsModel(64, n, 65_536.0),
                             Workload.range_scan(lo_pos, hi_pos, n=n))
    new_s = session.estimate(UniformEpsModel(64, n, 65_536.0),
                             Workload.sorted_stream(wlo - 64, wlo + 64, n=n))
    assert abs(legacy_r.io_per_query - new_r.io_per_query) < 1e-6
    assert abs(legacy_s.io_per_query - new_s.io_per_query) < 1e-6
    assert new_s.policy == "sorted-closed-form"


def test_locate_once_matches_generator_positions(world):
    keys, qk, qpos = world
    wl = Workload.from_keys(keys, qk)
    np.testing.assert_array_equal(wl.positions, locate(keys, qk))
    assert wl.n == len(keys)
    # generator positions ARE ranks of the drawn keys, so locating the keys
    # again must land on a position holding the same key
    np.testing.assert_array_equal(keys[wl.positions], keys[qpos])


def test_workload_sample_preserves_order_and_scale(world):
    _, qk, qpos = world
    wl = Workload.point(qpos, n=200_000, query_keys=qk)
    s = wl.sample(0.25, seed=7)
    assert s.n_queries == round(0.25 * wl.n_queries)
    assert s.base_queries == wl.n_queries
    assert abs(s.scale - 4.0) < 1e-9
    # order-preserving: sampled positions appear in original relative order
    sel = cam.sample_workload(qpos, 0.25, seed=7)
    np.testing.assert_array_equal(s.positions, sel)
    assert s.query_keys is not None and len(s.query_keys) == s.n_queries


# ---------------------------------------------------------------------------
# Grid equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu"])
def test_estimate_grid_matches_single_loop(world, policy):
    keys, qk, qpos = world
    n = len(keys)
    session = CostSession(System(GEOM, BUDGET, policy))
    wl = Workload.point(qpos, n=n)
    grid = (8, 16, 32, 64, 128, 256, 512, 1024)
    sizes = {e: 2e9 / e for e in grid}   # synthetic shrinking footprint
    cands = [GridCandidate(knob=e, eps=e, size_bytes=sizes[e]) for e in grid]
    res = session.estimate_grid(cands, wl)
    kept = [e for e in grid if sizes[e] < BUDGET - GEOM.page_bytes]
    assert set(res.estimates) == set(kept)
    assert set(res.skipped) == set(grid) - set(kept)
    for e in kept:
        single = session.estimate(UniformEpsModel(e, n, sizes[e]), wl)
        g = res.estimates[e]
        tol = 1e-4 * max(single.io_per_query, 1e-3)
        assert abs(g.io_per_query - single.io_per_query) < tol, (e, policy)
        assert g.capacity_pages == single.capacity_pages


def test_estimate_grid_range_and_mixed(world):
    keys, qk, qpos = world
    n = len(keys)
    _, _, lo_pos, hi_pos = range_workload(keys, 5_000, WorkloadSpec("w4", seed=3))
    session = CostSession(System(GEOM, BUDGET, "lru"))
    wl = Workload.mixed(Workload.point(qpos, n=n),
                        Workload.range_scan(lo_pos, hi_pos, n=n))
    cands = [GridCandidate(knob=e, eps=e, size_bytes=65_536.0)
             for e in (32, 128)]
    res = session.estimate_grid(cands, wl)
    for e in (32, 128):
        single = session.estimate(UniformEpsModel(e, n, 65_536.0), wl)
        g = res.estimates[e]
        assert abs(g.io_per_query - single.io_per_query) \
            < 1e-4 * max(single.io_per_query, 1e-3)
    # mixed E[DAC] interpolates between the pure shapes' request volumes
    assert res.estimates[32].dac > 1.0


def test_estimate_grid_infeasible_budget_raises(world):
    keys, _, qpos = world
    session = CostSession(System(GEOM, 8192, "lru"))
    cands = [GridCandidate(knob=64, eps=64, size_bytes=1e9)]
    with pytest.raises(ValueError, match="memory budget too small"):
        session.estimate_grid(cands, Workload.point(qpos, n=len(keys)))


# ---------------------------------------------------------------------------
# Estimator vs replay — the shared oracle across ALL THREE families
# ---------------------------------------------------------------------------

_BUILDERS = {
    "pgm": lambda keys: PGMAdapter.build(keys, 64),
    "rmi": lambda keys: RMIAdapter.build(keys, 1024),
    "radixspline": lambda keys: RadixSplineAdapter.build(keys, 64),
}


@pytest.mark.parametrize("family", sorted(_BUILDERS))
@pytest.mark.parametrize("policy", ["lru", "fifo"])
def test_estimator_matches_replay_all_families(world, family, policy):
    """The paper's index-agnosticism claim, enforced: one session, one
    workload, three designs — every estimate must track ground truth."""
    keys, qk, qpos = world
    adapter = _BUILDERS[family](keys)
    assert adapter.family == family and family in ADAPTERS
    assert adapter.knobs()
    # 2 MiB keeps capacity well below the page count: the IRM steady state is
    # the regime CAM models (near-full caching is compulsory-miss noise).
    system = System(GEOM, 2 << 20, policy)
    est = CostSession(system).estimate(
        adapter, Workload.point(qpos, n=len(keys), query_keys=qk))
    cap = max(1, system.capacity_for(adapter.size_bytes))
    lo, hi = adapter.window(qk)
    misses = replay_windows(lo // GEOM.c_ipp, hi // GEOM.c_ipp, cap, policy)
    assert float(q_error(est.io_per_query, misses.mean())) < 1.4, family


@pytest.mark.parametrize("family,tune", [
    ("pgm", lambda keys, qpos, qk: cam_tune_pgm(
        keys, qpos, 2 << 20, GEOM, "lru", eps_grid=(16, 64, 256, 1024))),
    ("rmi", lambda keys, qpos, qk: cam_tune_rmi(
        keys, qpos, qk, 2 << 20, GEOM, "lru",
        branch_grid=(256, 1024, 4096))),
    ("radixspline", lambda keys, qpos, qk: cam_tune_radixspline(
        keys, qpos, 2 << 20, GEOM, "lru", eps_grid=(16, 64, 256, 1024),
        radix_bits=12)),
])
def test_grid_tuning_all_families(world, family, tune):
    """All three families grid-tune through the same estimate_grid path."""
    keys, qk, qpos = world
    res = tune(keys, qpos, qk)
    knob = res.best_eps if hasattr(res, "best_eps") else res.best_branch
    assert knob in res.estimates
    assert res.est_io == res.estimates[knob].io_per_query
    assert all(e.io_per_query >= res.est_io - 1e-9
               for e in res.estimates.values())
