"""Page-reference estimator tests: LUT vs brute-force Eq. 12, DAC lemmas vs
their exact finite sums, histogram mass conservation, range diff-array."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import dac, page_ref


# ---------------------------------------------------------------------------
# Eq. 12 LUT == brute-force enumeration
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=1, max_value=100),   # eps
    st.integers(min_value=2, max_value=64),    # c_ipp
    st.integers(min_value=0, max_value=10_000),
)
def test_point_lut_matches_bruteforce(eps, c_ipp, seed):
    rng = np.random.default_rng(seed)
    lut = np.asarray(page_ref.point_lut(eps, c_ipp))
    d_radius = page_ref.lut_radius(eps, c_ipp)
    # Interior position far from boundaries.
    q_page = 10 * d_radius + 5
    for _ in range(4):
        s = int(rng.integers(0, c_ipp))
        d = int(rng.integers(-d_radius, d_radius + 1))
        r = q_page * c_ipp + s
        exact = page_ref.point_access_prob_exact(r, q_page + d, eps, c_ipp)
        assert abs(float(lut[d + d_radius, s]) - exact) < 1e-6


def test_lut_row_sums_equal_expected_dac():
    """Summing the LUT over d for every s and averaging over s must equal the
    all-at-once E[DAC] of Lemma III.2 — the two derivations are consistent."""
    for eps, c_ipp in [(8, 16), (64, 16), (13, 7), (256, 256), (1024, 512)]:
        lut = np.asarray(page_ref.point_lut(eps, c_ipp))
        mean_pages = lut.sum(axis=0).mean()
        closed = float(dac.expected_dac_all_at_once(eps, c_ipp))
        assert abs(mean_pages - closed) < 1e-4, (eps, c_ipp)


# ---------------------------------------------------------------------------
# DAC lemmas: closed forms == exact proof sums
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=2048), st.integers(min_value=2, max_value=512))
def test_lemma_iii2_all_at_once(eps, c_ipp):
    closed = float(dac.expected_dac_all_at_once(eps, c_ipp))
    exact = dac.expected_dac_all_at_once_exact(eps, c_ipp)
    assert abs(closed - exact) < 1e-6 * max(1.0, closed)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=1024), st.integers(min_value=2, max_value=256))
def test_lemma_iii3_one_by_one(eps, c_ipp):
    closed = float(dac.expected_dac_one_by_one(eps, c_ipp))
    exact = dac.expected_dac_one_by_one_exact(eps, c_ipp)
    assert abs(closed - exact) < 1e-6 * max(1.0, closed)


def test_one_by_one_saves_eps_over_cipp():
    """Remark after Lemma III.3: S1 reduces E[DAC] by exactly eps/C_ipp."""
    for eps, c_ipp in [(8, 4), (64, 32), (500, 128)]:
        gap = float(dac.expected_dac_all_at_once(eps, c_ipp)) - float(
            dac.expected_dac_one_by_one(eps, c_ipp))
        assert abs(gap - eps / c_ipp) < 1e-6


# ---------------------------------------------------------------------------
# Histogram estimators
# ---------------------------------------------------------------------------

def test_point_refs_mass_conservation_interior():
    """For interior queries, total histogram mass == Q * E[DAC]."""
    eps, c_ipp = 32, 16
    n = 100_000
    rng = np.random.default_rng(0)
    pos = rng.integers(10 * eps, n - 10 * eps, size=5000)
    counts, total = page_ref.point_page_refs(
        jnp.asarray(pos, jnp.int32), eps, c_ipp, n // c_ipp
    )
    expected = 5000 * float(dac.expected_dac_all_at_once(eps, c_ipp))
    assert abs(float(total) - expected) < 1e-2 * expected
    assert abs(float(counts.sum()) - float(total)) < 1e-3 * float(total)


def test_point_refs_match_monte_carlo():
    """Histogram ≈ Monte-Carlo simulation of the uniform-error window model."""
    eps, c_ipp, n = 24, 8, 4096
    num_pages = n // c_ipp
    rng = np.random.default_rng(1)
    pos = rng.integers(4 * eps, n - 4 * eps, size=800)
    counts, _ = page_ref.point_page_refs(jnp.asarray(pos, jnp.int32), eps, c_ipp, num_pages)
    mc = np.zeros(num_pages)
    for r in pos:
        e = rng.integers(-eps, eps + 1, size=200)
        lo = (r + e - eps) // c_ipp
        hi = (r + e + eps) // c_ipp
        for a, b in zip(lo, hi):
            mc[max(a, 0): min(b, num_pages - 1) + 1] += 1.0 / 200
    err = np.abs(np.asarray(counts) - mc).sum() / mc.sum()
    assert err < 0.05


def test_range_refs_diff_array():
    eps, c_ipp, n = 16, 8, 10_000
    num_pages = -(-n // c_ipp)
    lo = np.array([100, 500, 500, 9000])
    hi = np.array([200, 800, 600, 9999])
    counts, total = page_ref.range_page_refs(
        jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32), eps, c_ipp, num_pages, n
    )
    # Oracle: explicit loop over Eq. 14 intervals.
    oracle = np.zeros(num_pages)
    t = 0
    for a, b in zip(lo, hi):
        s = max(0, a - 2 * eps) // c_ipp
        e = min(n - 1, b + 2 * eps) // c_ipp
        oracle[s : e + 1] += 1
        t += e - s + 1
    np.testing.assert_allclose(np.asarray(counts), oracle, atol=1e-5)
    assert float(total) == t


def test_sorted_workload_rn_union():
    lo = jnp.asarray([0, 2, 10, 10, 40], jnp.int32)
    hi = jnp.asarray([3, 5, 12, 20, 41], jnp.int32)
    r, n = page_ref.sorted_workload_rn(lo, hi)
    assert float(r) == (4 + 4 + 3 + 11 + 2)
    # union: [0,5] ∪ [10,20] ∪ [40,41] = 6 + 11 + 2 = 19
    assert float(n) == 19


def test_sorted_workload_stats_matches_oracle():
    """(R, N) agree with sorted_workload_rn; coverage and the pinned
    window-junction re-touch count match an explicit python oracle."""
    rng = np.random.default_rng(9)
    lo = np.sort(rng.integers(0, 200, size=300))
    hi = lo + rng.integers(0, 3, size=300)
    num_pages = int(hi.max()) + 1
    r, n, cov, pinned = page_ref.sorted_workload_stats(
        jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32), num_pages)
    r_ref, n_ref = page_ref.sorted_workload_rn(
        jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32))
    assert float(r) == float(r_ref)
    assert float(n) == float(n_ref)
    oracle_cov = np.zeros(num_pages)
    for a, b in zip(lo, hi):
        oracle_cov[a:b + 1] += 1
    np.testing.assert_allclose(np.asarray(cov), oracle_cov, atol=1e-5)
    oracle_pinned = sum(
        1 for i in range(1, len(lo)) if lo[i] == hi[i - 1])
    assert float(pinned) == oracle_pinned
    # the junction count subsumes the width-1 repeat ("solo") statistic
    oracle_solo = sum(
        1 for i in range(1, len(lo))
        if lo[i] == hi[i] == lo[i - 1] == hi[i - 1])
    assert oracle_pinned >= oracle_solo
    assert float(jnp.sum(cov)) == float(r)   # mass conservation
