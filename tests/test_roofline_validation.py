"""Validate the analytic roofline FLOPs model against XLA cost_analysis.

XLA counts a while-loop (scan) body ONCE — demonstrated here explicitly —
so the analytic accounting is validated on L=1 configs, where "body once"
equals the whole depth.  Tolerances are loose: cost_analysis also counts
elementwise/softmax flops the analytic model deliberately excludes (<5%),
and masks/transposes add bytes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import Recipe, ShardingCtx
from repro.launch import analytic
from repro.models import model as M
from repro.models.params import param_shapes


def _xla_flops(cfg, shape):
    ctx = ShardingCtx(None, Recipe(remat="none", microbatch=1))
    p_sds = param_shapes(cfg, jnp.float32)
    batch = M.input_specs(cfg, shape)

    def loss(p, b):
        return M.loss_fn(p, cfg, b, ctx)

    grad = jax.jit(jax.value_and_grad(loss))
    ca = grad.lower(p_sds, batch).compile().cost_analysis()
    return float(ca.get("flops", 0.0))


@pytest.mark.env_limited("XLA cost-analysis FLOP accounting differs across "
                         "backends; tolerances hold on the TPU toolchain")
@pytest.mark.parametrize("arch", ["yi-34b", "qwen2-moe-a2.7b", "rwkv6-3b"])
def test_analytic_matches_xla_at_l1(arch):
    base = reduced(ARCHS[arch])
    kw = dict(num_layers=1)
    if base.family == "hybrid":
        kw["shared_attn_every"] = 1
    cfg = dataclasses.replace(base, **kw)
    shape = ShapeSpec("t", "train", 128, 4)
    xla = _xla_flops(cfg, shape)
    cost = analytic.cell_cost(cfg, shape, Recipe(remat="none", microbatch=1),
                              {"data": 1, "model": 1})
    ratio = cost.flops / xla
    assert 0.6 < ratio < 1.5, (arch, cost.flops, xla)


@pytest.mark.env_limited("XLA cost-analysis FLOP accounting differs across "
                         "backends; tolerances hold on the TPU toolchain")
def test_scan_body_counted_once_by_xla():
    """The methodology premise: cost_analysis does NOT multiply scan bodies
    by trip count, so at depth L the reported flops are ~flops(L=1)."""
    base = reduced(ARCHS["yi-34b"])
    shape = ShapeSpec("t", "train", 128, 4)
    f1 = _xla_flops(dataclasses.replace(base, num_layers=1), shape)
    f8 = _xla_flops(dataclasses.replace(base, num_layers=8), shape)
    assert f8 < 2.0 * f1        # NOT ~8x — the loop body is counted once
    # while the analytic model scales linearly, as the real machine does
    c1 = analytic.cell_cost(dataclasses.replace(base, num_layers=1), shape,
                            Recipe(remat="none"), {"data": 1, "model": 1})
    c8 = analytic.cell_cost(dataclasses.replace(base, num_layers=8), shape,
                            Recipe(remat="none"), {"data": 1, "model": 1})
    assert c8.flops > 4.0 * c1.flops
