"""Sharding-layer tests: routing invariants, the one-pass joint solve,
golden equivalence, and the rebalance gate.

The load-bearing guarantees, each gated here:

* ``Workload.split_at`` puts every point query in exactly one segment,
  splits crossing windows losslessly (rank mass preserved, piece counts
  exact), and ``Workload.concat`` of the segments reproduces a mixed
  point+range+sorted workload EXACTLY when no window crosses a cut;
* routing: per-shard page-reference totals sum to the unsharded total
  plus exactly the boundary-page overlap term RouteStats reports;
* the joint (boundary × knob × budget-share) search runs ONE grouped
  profile pass and ONE ``solve_profiles`` pass — zero per-shard model
  calls, however many boundaries/shards/splits are enumerated
  (structural);
* a 1-shard fleet is golden-equivalent (1e-9) to the single-node
  ``TuningSession`` path;
* the rebalance gate switches only when horizon savings repay the move.
"""
import dataclasses

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.cam import CamGeometry
from repro.core.session import CostSession, System
from repro.core.workload import Workload
from repro.serving.sketch import shard_page_masses
from repro.sharding import (FleetPlan, ShardedSystem, ShardingSession,
                            boundary_candidates, even_boundaries,
                            quantile_boundaries, route)
from repro.tuning.session import (CamTuner, PGMBuilder, RMIBuilder,
                                  TuningSession)

GEOM = CamGeometry(c_ipp=64, page_bytes=4096)
N_KEYS = 8192

_rng = np.random.default_rng(0)
KEYS = np.sort(_rng.uniform(0, 1e6, N_KEYS))


def _system(budget=64 * 1024, policy="lru"):
    return System(GEOM, memory_budget_bytes=budget, policy=policy)


def _point_wl(nq=2000, seed=1, n=N_KEYS):
    rng = np.random.default_rng(seed)
    return Workload.point(rng.integers(0, n, nq), n=n)


def _mixed_wl(seed=2, n=N_KEYS):
    rng = np.random.default_rng(seed)
    pts = np.sort(rng.integers(0, n, 300))
    lo = np.sort(rng.integers(0, n - 40, 120))
    hi = lo + rng.integers(0, 40, 120)
    slo = np.sort(rng.integers(0, n - 8, 150))
    return Workload.mixed(Workload.point(pts, n=n),
                          Workload.range_scan(lo, hi, n=n),
                          Workload.sorted_stream(slo, slo + 7, n=n))


def _refs(wl):
    """Logical page references at eps=0 (windows clipped, local or global)."""
    if wl.kind == "mixed":
        return sum(_refs(p) for p in wl.parts)
    if wl.positions is None or wl.n_queries == 0:
        return 0
    if wl.hi_positions is None:
        return wl.n_queries
    return int(np.sum(wl.hi_positions // GEOM.c_ipp
                      - wl.positions // GEOM.c_ipp + 1))


# ---------------------------------------------------------------------------
# Workload.split_at
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 5))
def test_split_at_point_partition(seed, n_cuts):
    """Every point query lands in exactly one segment, and the segment is
    the right one: cuts[s-1] <= p < cuts[s]."""
    rng = np.random.default_rng(seed)
    n = 4096
    pos = rng.integers(0, n, 500)
    cuts = np.sort(rng.choice(np.arange(1, n), size=n_cuts, replace=False))
    wl = Workload.point(pos, n=n)
    segs = wl.split_at(cuts)
    assert len(segs) == n_cuts + 1
    assert sum(s.n_queries for s in segs) == wl.n_queries
    edges = np.concatenate([[0], cuts, [n]])
    for s, seg in enumerate(segs):
        if seg.n_queries:
            assert np.all(seg.positions >= edges[s])
            assert np.all(seg.positions < edges[s + 1])
    merged = np.sort(np.concatenate([s.positions for s in segs]))
    assert np.array_equal(merged, np.sort(pos))


def test_split_at_mixed_concat_round_trip():
    """Regression (the ISSUE bugfix): a mixed point+range+sorted workload
    splits and ``Workload.concat``s back to the original EXACTLY when no
    window crosses a cut (position-sorted inputs, so segment grouping
    preserves order)."""
    n = N_KEYS
    cuts = np.asarray([2048, 4096, 6144])
    rng = np.random.default_rng(3)
    # windows kept strictly inside segments: lo and hi share a segment
    lo = np.sort(rng.integers(0, n - 64, 200))
    seg = np.searchsorted(cuts, lo, side="right")
    edges_hi = np.concatenate([cuts, [n]])
    hi = np.minimum(lo + rng.integers(0, 40, 200), edges_hi[seg] - 1)
    pts = np.sort(rng.integers(0, n, 300))
    wl = Workload.mixed(Workload.point(pts, n=n),
                        Workload.range_scan(lo, hi, n=n),
                        Workload.sorted_stream(lo, hi, n=n))
    back = Workload.concat(*wl.split_at(cuts))
    assert back.kind == "mixed" and len(back.parts) == 3
    by_kind = {p.kind: p for p in back.parts}
    assert np.array_equal(by_kind["point"].positions, pts)
    for kind in ("range", "sorted"):
        assert np.array_equal(by_kind[kind].positions, lo)
        assert np.array_equal(by_kind[kind].hi_positions, hi)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_split_at_crossing_windows_lossless(seed, n_cuts):
    """Crossing windows split into exactly (segments spanned) pieces and
    preserve total covered rank mass."""
    rng = np.random.default_rng(seed)
    n = 4096
    lo = rng.integers(0, n - 1, 150)
    hi = np.minimum(lo + rng.integers(0, 600, 150), n - 1)
    cuts = np.sort(rng.choice(np.arange(1, n), size=n_cuts, replace=False))
    wl = Workload.range_scan(lo, hi, n=n)
    segs = wl.split_at(cuts)
    spanned = (np.searchsorted(cuts, hi, side="right")
               - np.searchsorted(cuts, lo, side="right") + 1)
    assert sum(s.n_queries for s in segs) == int(spanned.sum())
    mass = sum(int(np.sum(s.hi_positions - s.positions + 1)) for s in segs
               if s.n_queries)
    assert mass == int(np.sum(hi - lo + 1))


def test_split_at_rejects_bad_cuts():
    wl = _point_wl()
    with pytest.raises(ValueError):
        wl.split_at([100, 100])
    with pytest.raises(ValueError):
        wl.split_at([0, 50])
    with pytest.raises(ValueError):
        wl.split_at([N_KEYS])


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def _fleet(boundaries, budget=64 * 1024, policy="lru"):
    return ShardedSystem(_system(budget, policy), N_KEYS, tuple(boundaries))


def test_route_point_exactly_one_shard():
    wl = _point_wl(3000)
    fleet = _fleet((2000, 4100, 6000))
    locals_, stats = route(wl, fleet)
    assert len(locals_) == 4
    assert sum(w.n_queries for w in locals_) == wl.n_queries
    assert stats.boundary_splits == 0
    for w, sh in zip(locals_, fleet.shards):
        if w.n_queries:
            assert np.all(w.positions >= 0)
            assert np.all(w.positions < sh.n_local)
            assert w.n == sh.n_local


def test_route_refs_sum_with_overlap_term():
    """Per-shard eps=0 page-reference totals == unsharded total + the
    boundary-page overlap RouteStats reports (mid-page cuts replicate
    their page; page-aligned cuts add nothing)."""
    wl = _mixed_wl()
    for boundaries in [(2048, 4096), (2000, 4100, 6001), (64, 8000)]:
        fleet = _fleet(boundaries)
        locals_, stats = route(wl, fleet)
        sharded = sum(_refs(w) for w in locals_)
        assert sharded == _refs(wl) + stats.boundary_page_overlap
        aligned = all(c % GEOM.c_ipp == 0 for c in boundaries)
        if aligned:
            assert stats.boundary_page_overlap == 0


def test_route_single_shard_identity():
    wl = _mixed_wl()
    locals_, stats = route(wl, _fleet(()))
    assert len(locals_) == 1
    assert stats.boundary_splits == 0 and stats.boundary_page_overlap == 0
    got, want = locals_[0], wl
    for g, w in zip(got.parts, want.parts):
        assert np.array_equal(g.positions, w.positions)
        assert g.n == w.n


def test_boundary_candidates_shapes():
    wl = _point_wl(4000)
    cands = boundary_candidates(wl, N_KEYS, 4)
    assert len(cands) >= 2                      # even + at least one quantile
    for b in cands:
        assert len(b) == 3
        assert all(0 < x < N_KEYS for x in b)
        assert all(b[i] < b[i + 1] for i in range(len(b) - 1))
    q = quantile_boundaries(wl, N_KEYS, 4)
    assert q in cands
    # a concentrated workload pulls quantile cuts into the hot range
    hot = Workload.point(np.random.default_rng(5).integers(0, 512, 4000),
                         n=N_KEYS)
    qh = quantile_boundaries(hot, N_KEYS, 4)
    assert all(c <= 512 for c in qh)


def test_sharded_system_validation():
    with pytest.raises(ValueError):
        _fleet((4096, 2048))
    with pytest.raises(ValueError):
        _fleet((0,))
    fleet = _fleet((2000, 4096))
    assert fleet.replicated_cuts == (2000,)     # 4096 is page-aligned
    shards = fleet.shards
    assert shards[0].lo_rank == 0 and shards[-1].hi_rank == N_KEYS
    assert shards[1].page_lo == 2000 // GEOM.c_ipp


# ---------------------------------------------------------------------------
# The joint solve
# ---------------------------------------------------------------------------

OVR = {"eps": (4, 64)}


def _sharding(n_shards=2, grid=4, budget=32 * 1024, policy="lru", **kw):
    return ShardingSession(_system(budget, policy), PGMBuilder(KEYS),
                           n_shards, grid=grid, overrides=OVR, **kw)


def test_solve_simplex_sanity():
    sess = _sharding(2, grid=4)
    plan = sess.solve(_point_wl(2000))
    assert isinstance(plan, FleetPlan)
    assert len(plan.shards) == 2
    assert abs(sum(plan.fractions) - 1.0) < 1e-12
    for p in plan.shards:
        assert p.fraction >= 1.0 / sess.grid
        assert p.capacity_pages >= 1
        assert p.tune is not None and p.tune.batched_solves == 1
    assert plan.fleet_io == pytest.approx(
        sum(p.est_io * p.n_queries for p in plan.shards))
    assert plan.boundaries in plan.boundaries_searched
    assert min(plan.boundary_totals) == pytest.approx(plan.fleet_io)


def test_solve_one_profile_pass_one_solve_pass():
    """Structural: the whole (boundary × shard × knob × share) search makes
    exactly ONE grouped profile pass and ONE solve pass — and never calls
    the per-candidate estimators."""
    calls = {"grouped": 0, "solve": 0, "grid": 0, "est": 0, "est_grid": 0}
    orig_grouped = CostSession.grid_profiles_grouped
    orig_solve = CostSession.solve_profiles

    def counting_grouped(self, *a, **k):
        calls["grouped"] += 1
        return orig_grouped(self, *a, **k)

    def counting_solve(self, *a, **k):
        calls["solve"] += 1
        return orig_solve(self, *a, **k)

    def forbidden(name):
        def fn(self, *a, **k):
            calls[name] += 1
            raise AssertionError(f"per-shard model call: {name}")
        return fn

    sess = _sharding(3, grid=6)
    wl = _point_wl(3000)
    cands = [even_boundaries(N_KEYS, 3), (1000, 2000), (3000, 6000)]
    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(CostSession, "grid_profiles_grouped", counting_grouped)
        mp.setattr(CostSession, "solve_profiles", counting_solve)
        mp.setattr(CostSession, "grid_profiles", forbidden("grid"))
        mp.setattr(CostSession, "estimate", forbidden("est"))
        mp.setattr(CostSession, "estimate_grid", forbidden("est_grid"))
        plan = sess.solve(wl, cands)
    assert calls == {"grouped": 1, "solve": 1, "grid": 0, "est": 0,
                     "est_grid": 0}
    assert plan.cells_solved > len(cands)       # many cells, still one solve


def test_one_shard_fleet_golden_vs_tuning_session():
    """A 1-shard fleet IS the single-node tuner: same knob, same capacity,
    same expected I/O to 1e-9."""
    wl = _point_wl(2500, seed=7)
    for policy in ("lru", "fifo", "lfu"):
        sess = ShardingSession(_system(32 * 1024, policy), PGMBuilder(KEYS),
                               1, grid=1, overrides=OVR)
        plan = sess.solve(wl)
        ref = TuningSession(_system(32 * 1024, policy)).tune(
            PGMBuilder(KEYS), wl, overrides=OVR)
        assert plan.boundaries == ()
        sp = plan.shards[0]
        assert sp.knob == ref.best_knob
        assert sp.capacity_pages == ref.capacity_pages
        assert sp.est_io == pytest.approx(ref.est_io, abs=1e-9)
        assert plan.io_per_query == pytest.approx(ref.est_io, abs=1e-9)


def test_solve_beats_even_split_under_hotspot():
    """Mini version of the benchmark gate: a hot slab wider than any single
    budget share makes the even split lose to solved boundaries."""
    rng = np.random.default_rng(11)
    nq = 4000
    slab = 1920                                 # 30 pages at c_ipp=64
    hot = rng.integers(0, slab, int(nq * 0.92))
    cold = rng.integers(0, N_KEYS, nq - hot.shape[0])
    pos = np.concatenate([hot, cold])
    rng.shuffle(pos)
    wl = Workload.point(pos, n=N_KEYS)
    sess = ShardingSession(_system(8 * 1024), PGMBuilder(KEYS), 4,
                           grid=8, overrides=OVR)
    plan = sess.solve(wl)
    even = sess.solve(wl, [even_boundaries(N_KEYS, 4)])
    assert plan.io_per_query < even.io_per_query
    # the default candidate set contains the even split, so solved can
    # never lose to it
    assert even_boundaries(N_KEYS, 4) in plan.boundaries_searched


def test_solve_rejects_index_backed_builders():
    sess = ShardingSession(_system(), RMIBuilder(KEYS), 2, grid=4,
                           overrides={"branch": (64,)})
    with pytest.raises(ValueError, match="uniform-eps"):
        sess.solve(_point_wl(500))


def test_solve_validates_inputs():
    sess = _sharding(2, grid=4)
    with pytest.raises(ValueError):
        sess.solve(_point_wl(500), [(100, 200)])     # wrong cut count
    with pytest.raises(ValueError):
        ShardingSession(_system(), PGMBuilder(KEYS), 4, grid=3)
    with pytest.raises(ValueError):
        sess.solve(Workload.point(np.asarray([1]), n=N_KEYS // 2))


# ---------------------------------------------------------------------------
# Rebalance
# ---------------------------------------------------------------------------

def _hot_wl(center, nq=4000, width=1920, frac=0.92, seed=13):
    rng = np.random.default_rng(seed)
    lo = max(0, center - width // 2)
    hot = rng.integers(lo, min(N_KEYS, lo + width), int(nq * frac))
    cold = rng.integers(0, N_KEYS, nq - hot.shape[0])
    pos = np.concatenate([hot, cold])
    rng.shuffle(pos)
    return Workload.point(pos, n=N_KEYS)


def test_rebalance_gate_accepts_then_refuses():
    sess = ShardingSession(_system(8 * 1024), PGMBuilder(KEYS), 4,
                           grid=8, overrides=OVR)
    plan = sess.solve(_point_wl(4000))          # balanced traffic
    shifted = _hot_wl(center=960)               # hot slab in shard 0
    res = sess.rebalance(shifted, plan, horizon_queries=5e7)
    assert res.hot_shard == 0
    assert res.tv > 0.2
    assert res.io_candidate <= res.io_current + 1e-12
    if res.to_boundaries != res.from_boundaries:
        assert res.move_io > 0
        assert res.switched == (res.predicted_savings > res.move_io)
        assert res.switched                     # huge horizon repays any move
        # a tiny horizon can never repay the same move
        small = sess.rebalance(shifted, plan, horizon_queries=1.0)
        assert not small.switched
    stay = sess.rebalance(shifted, res.plan if res.switched else plan,
                          horizon_queries=5e7,
                          boundary_candidates_=[
                              (res.plan if res.switched else plan).boundaries])
    assert stay.to_boundaries == stay.from_boundaries
    assert not stay.switched and stay.move_io == 0.0


def test_rebalance_from_sketch_summary():
    sess = ShardingSession(_system(8 * 1024), PGMBuilder(KEYS), 4,
                           grid=8, overrides=OVR)
    plan = sess.solve(_point_wl(4000))
    shifted = _hot_wl(center=960)
    # a synthetic sketch summary: page-popularity of the shifted traffic
    pages = shifted.positions // GEOM.c_ipp
    num_pages = GEOM.num_pages(N_KEYS)
    bins = np.minimum(pages * 32 // num_pages, 31)
    summary = {"page_pop": np.bincount(bins, minlength=32).astype(float),
               "width": np.zeros(24), "op_mix": np.asarray([1.0, 0, 0])}
    res = sess.rebalance(shifted, plan, horizon_queries=5e7,
                         summary=summary)
    assert res.hot_shard == 0
    assert abs(sum(res.shard_masses) - 1.0) < 1e-9


def test_shard_page_masses_attribution():
    num_pages, page_bins = 64, 32
    pop = np.zeros(page_bins)
    pop[0] = 3.0                                # bin 0 -> pages 0-1
    pop[10] = 1.0                               # bin 10 starts at page 20
    summary = {"page_pop": pop}
    masses = shard_page_masses(summary, boundary_pages=(10, 40),
                               num_pages=num_pages)
    assert len(masses) == 3
    assert masses == (0.75, 0.25, 0.0)
    empty = shard_page_masses({"page_pop": np.zeros(page_bins)},
                              (10, 40), num_pages)
    assert sum(empty) == 0.0


# ---------------------------------------------------------------------------
# Grouped profiles (the core/session.py extension)
# ---------------------------------------------------------------------------

def test_grid_profiles_grouped_matches_per_group():
    """The concatenated grouped profile is exactly the per-group profiles
    stacked — counts zero-padded to the widest page span — and solving the
    grouped rows equals solving each group alone."""
    cost = CostSession(_system(64 * 1024))
    from repro.core.session import GridCandidate
    cands = [GridCandidate(knob=e, size_bytes=4096.0, eps=e)
             for e in (4, 64)]
    wl_a = _point_wl(800, seed=21)
    half = Workload.point(
        np.random.default_rng(22).integers(0, N_KEYS // 2, 700),
        n=N_KEYS // 2)
    grouped = cost.grid_profiles_grouped([("a", cands, wl_a),
                                          ("b", cands, half)])
    pa = cost.grid_profiles(cands, wl_a)
    pb = cost.grid_profiles(cands, half)
    assert grouped.knobs == tuple(
        (g, kn) for g, p in (("a", pa), ("b", pb)) for kn in p.knobs)
    assert grouped.n_queries == pa.n_queries + pb.n_queries
    K = len(cands)
    width = max(pa.counts.shape[1], pb.counts.shape[1])
    assert grouped.counts.shape == (2 * K, width)
    np.testing.assert_allclose(
        np.asarray(grouped.counts[:K, :pa.counts.shape[1]]),
        np.asarray(pa.counts))
    np.testing.assert_allclose(
        np.asarray(grouped.counts[K:, :pb.counts.shape[1]]),
        np.asarray(pb.counts))
    assert np.asarray(grouped.counts[K:, pb.counts.shape[1]:]).sum() == 0
    caps = np.asarray([5, 9] * 2)
    rows = np.arange(2 * K)
    h_g, nd_g = cost.solve_profiles(grouped, caps, rows=rows)
    h_a, nd_a = cost.solve_profiles(pa, caps[:K], rows=np.arange(K))
    h_b, nd_b = cost.solve_profiles(pb, caps[K:], rows=np.arange(K))
    np.testing.assert_allclose(np.asarray(h_g),
                               np.concatenate([h_a, h_b]), atol=1e-9)
    np.testing.assert_allclose(np.asarray(nd_g),
                               np.concatenate([nd_a, nd_b]), atol=1e-9)


def test_assemble_table_index_in_split_semantics():
    """Fleet semantics: a share must house index AND buffer — shares whose
    slice can't fit one page beyond the index are dropped, and no implicit
    maximal-split row appears."""
    cost = CostSession(_system(64 * 1024))
    from repro.core.session import GridCandidate
    cands = [GridCandidate(knob=4, size_bytes=10_000.0, eps=4)]
    profiles = cost.grid_profiles(cands, _point_wl(400))
    M, pb = 64 * 1024.0, 4096.0
    tab = CamTuner.assemble_table(
        profiles, {4: {"eps": 4}}, splits=(0.125, 0.25, 0.5),
        budget_bytes=M, page_bytes=pb, index_in_split=True,
        include_max_split=False)
    # 0.125 * 64K = 8192 < 10000 + page: dropped; others kept
    assert list(tab.fracs) == [0.25, 0.5]
    assert list(tab.caps) == [int((0.25 * M - 10_000) // pb),
                              int((0.5 * M - 10_000) // pb)]
    # default semantics still lists the maximal split first
    tab_def = CamTuner.assemble_table(
        profiles, {4: {"eps": 4}}, splits=(0.25,),
        budget_bytes=M, page_bytes=pb)
    assert len(tab_def) == 2
    assert tab_def.caps[0] == int(profiles.caps[0])
