"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode — kernel body executes on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache_models import solve_che_time
from repro.kernels import ops
from repro.kernels import ref as R

KEY = jax.random.PRNGKey(7)


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 1e-4


@pytest.mark.parametrize("b,sq,skv,h,hk,d", [
    (1, 64, 64, 4, 4, 32),      # MHA
    (2, 128, 128, 4, 2, 64),    # GQA 2:1
    (2, 96, 96, 8, 1, 64),      # MQA, ragged seq vs 64-blocks
    (1, 256, 256, 4, 2, 128),   # full head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, sq, skv, h, hk, d, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hk, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hk, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64,
                              interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_matches_blockwise_xla_path():
    """The Pallas kernel and the lax.scan blockwise path must agree — the
    dry-run compiles the latter, real TPUs run the former."""
    from repro.models.attention import blockwise_attention

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                            interpret=True)
    b_ = blockwise_attention(q, k, v, causal=True, block_kv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


@pytest.mark.parametrize("b,s,h,hk,d", [
    (2, 256, 4, 2, 64),
    (3, 130, 8, 8, 32),
    (1, 512, 8, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, s, h, hk, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    lens = jnp.asarray([max(1, s // (i + 2)) for i in range(b)], jnp.int32)
    out = ops.decode_attention(q, kc, vc, lens, block_kv=64, interpret=True)
    ref = R.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("n", [100, 5000, 70000])
@pytest.mark.parametrize("k", [4, 8])
def test_che_sums_sweep(n, k):
    rng = np.random.default_rng(n)
    p = rng.zipf(1.3, n).astype(np.float64)
    p = jnp.asarray(p / p.sum(), jnp.float32)
    ts = jnp.asarray(np.logspace(0, 6, k), jnp.float32)
    out = ops.che_sums(p, ts, interpret=True)
    ref = R.che_sums_ref(p, ts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_che_solve_matches_bisection():
    rng = np.random.default_rng(3)
    p = rng.zipf(1.2, 20000).astype(np.float64)
    p = jnp.asarray(p / p.sum(), jnp.float32)
    for cap in (100.0, 2000.0, 15000.0):
        t_kernel = ops.che_solve(p, cap, iters=14, interpret=True)
        consistency = float(jnp.sum(-jnp.expm1(-p * t_kernel)))
        assert abs(consistency - cap) / cap < 1e-2
        t_ref = float(solve_che_time(p, cap))
        assert abs(float(t_kernel) - t_ref) / t_ref < 0.02
