"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode — kernel body executes on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache_models import solve_che_time
from repro.kernels import ops
from repro.kernels import ref as R

KEY = jax.random.PRNGKey(7)


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 1e-4


@pytest.mark.parametrize("b,sq,skv,h,hk,d", [
    (1, 64, 64, 4, 4, 32),      # MHA
    (2, 128, 128, 4, 2, 64),    # GQA 2:1
    (2, 96, 96, 8, 1, 64),      # MQA, ragged seq vs 64-blocks
    (1, 256, 256, 4, 2, 128),   # full head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, sq, skv, h, hk, d, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hk, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hk, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64,
                              interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_matches_blockwise_xla_path():
    """The Pallas kernel and the lax.scan blockwise path must agree — the
    dry-run compiles the latter, real TPUs run the former."""
    from repro.models.attention import blockwise_attention

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                            interpret=True)
    b_ = blockwise_attention(q, k, v, causal=True, block_kv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


@pytest.mark.parametrize("b,s,h,hk,d", [
    (2, 256, 4, 2, 64),
    (3, 130, 8, 8, 32),
    (1, 512, 8, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, s, h, hk, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    lens = jnp.asarray([max(1, s // (i + 2)) for i in range(b)], jnp.int32)
    out = ops.decode_attention(q, kc, vc, lens, block_kv=64, interpret=True)
    ref = R.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("n", [100, 5000, 70000])
@pytest.mark.parametrize("k", [4, 8])
def test_che_sums_sweep(n, k):
    rng = np.random.default_rng(n)
    p = rng.zipf(1.3, n).astype(np.float64)
    p = jnp.asarray(p / p.sum(), jnp.float32)
    ts = jnp.asarray(np.logspace(0, 6, k), jnp.float32)
    out = ops.che_sums(p, ts, interpret=True)
    ref = R.che_sums_ref(p, ts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_che_solve_matches_bisection():
    rng = np.random.default_rng(3)
    p = rng.zipf(1.2, 20000).astype(np.float64)
    p = jnp.asarray(p / p.sum(), jnp.float32)
    for cap in (100.0, 2000.0, 15000.0):
        t_kernel = ops.che_solve(p, cap, iters=14, interpret=True)
        consistency = float(jnp.sum(-jnp.expm1(-p * t_kernel)))
        assert abs(consistency - cap) / cap < 1e-2
        t_ref = float(solve_che_time(p, cap))
        assert abs(float(t_kernel) - t_ref) / t_ref < 0.02


# ---------------------------------------------------------------------------
# Mixed-eps occupancy: device banded-matmul kernel vs host bincount oracle
# ---------------------------------------------------------------------------

from repro.core import page_ref  # noqa: E402
from repro.kernels import profile_grid  # noqa: E402

C_IPP = 128


def _occupancy_pair(positions, eps_rows, num_pages):
    ch, th = page_ref.point_page_refs_mixed_eps_grid(
        positions, eps_rows, C_IPP, num_pages)
    cd, td = profile_grid.point_page_refs_mixed_eps_grid(
        positions, eps_rows, C_IPP, num_pages)
    assert np.asarray(ch).shape == np.asarray(cd).shape
    return (np.asarray(ch, np.float64), np.asarray(th, np.float64),
            np.asarray(cd, np.float64), np.asarray(td, np.float64))


def test_occupancy_exact_for_integer_mass():
    """Slots >= 2*eps from both page boundaries make every Eq. 12 LUT entry
    exactly 0 or 1, so the device float32 sums must carry the integer mass
    EXACTLY — bit-equal counts and totals, no tolerance."""
    rng = np.random.default_rng(11)
    num_pages, q = 40, 1500
    positions = rng.integers(0, num_pages, q) * C_IPP \
        + rng.integers(16, 112, q)
    eps_rows = rng.choice([1, 2, 4], size=(3, q)).astype(np.int64)
    ch, th, cd, td = _occupancy_pair(positions, eps_rows, num_pages)
    assert np.all(ch == np.round(ch))            # really integer mass
    assert np.array_equal(ch, cd)
    assert np.array_equal(th, td)


def test_occupancy_general_within_float32_tolerance():
    """Arbitrary slots + large pow2 eps classes: fractional LUT mass, so
    host float64 and device float32 accumulation differ only by summation
    order — <= 2e-6 normalized."""
    rng = np.random.default_rng(5)
    num_pages, q = 64, 4000
    positions = rng.integers(0, num_pages * C_IPP, q)
    eps_rows = rng.choice([1, 4, 16, 64, 256], size=(4, q)).astype(np.int64)
    ch, th, cd, td = _occupancy_pair(positions, eps_rows, num_pages)
    scale = max(1.0, float(ch.max()))
    assert np.max(np.abs(ch - cd)) / scale < 2e-6
    assert np.max(np.abs(th - td) / np.maximum(th, 1.0)) < 2e-6


def test_occupancy_non_pow2_eps_fallback():
    """Non-pow2 eps rows exercise the unique-rank class coding (no popcount
    shortcut); both kernels share mixed_eps_class_codes so class grouping
    is identical and the results agree."""
    rng = np.random.default_rng(9)
    num_pages, q = 32, 900
    positions = rng.integers(0, num_pages * C_IPP, q)
    eps_rows = rng.choice([3, 5, 12, 100], size=(2, q)).astype(np.int64)
    ch, th, cd, td = _occupancy_pair(positions, eps_rows, num_pages)
    scale = max(1.0, float(ch.max()))
    assert np.max(np.abs(ch - cd)) / scale < 2e-6


def test_occupancy_eps_zero_clamped_to_one():
    """eps=0 rows clamp to eps=1 on both sides (the host kernel's guard)."""
    rng = np.random.default_rng(2)
    num_pages, q = 16, 400
    positions = rng.integers(0, num_pages * C_IPP, q)
    zeros = np.zeros((1, q), np.int64)
    ones = np.ones((1, q), np.int64)
    _, _, cd0, td0 = _occupancy_pair(positions, zeros, num_pages)
    _, _, cd1, td1 = _occupancy_pair(positions, ones, num_pages)
    assert np.array_equal(cd0, cd1)
    assert np.array_equal(td0, td1)


@pytest.mark.parametrize("q,num_pages", [(100, 7), (777, 37), (513, 129)])
def test_occupancy_ragged_shapes(q, num_pages):
    """Query counts off the 512-query tile and page counts off the lane
    width pad internally; padded queries (key -1) contribute nothing and
    the output slices back to exactly (K, num_pages)."""
    rng = np.random.default_rng(q)
    positions = rng.integers(0, num_pages * C_IPP, q)
    eps_rows = rng.choice([2, 8], size=(2, q)).astype(np.int64)
    ch, th, cd, td = _occupancy_pair(positions, eps_rows, num_pages)
    assert cd.shape == (2, num_pages)
    scale = max(1.0, float(ch.max()))
    assert np.max(np.abs(ch - cd)) / scale < 2e-6
    assert np.max(np.abs(th - td) / np.maximum(th, 1.0)) < 2e-6
