"""JoinTreeSession tests.

* Budget-split monotonicity: giving a level more buffer never increases its
  predicted misses — per strategy, per policy (the curve the split solver
  trades on must be non-increasing in capacity).
* Curve-vs-plan consistency: the batched ``cost_curve`` and the scalar
  ``plan(..., capacity=...)`` prediction agree at every grid capacity.
* 3-level tree oracle: the CAM-chosen (split, strategies) plan's replayed
  total I/O is within 15% of the exhaustive-replay best over every
  (simplex split x per-level strategy) combination — 3 policies x 2 outer
  skews (uniform w1, zipf w2).
* Batched solve: planning a tree performs NO replay and exactly ONE
  engine solve for the whole tree (every level's sorted + INLJ stream at
  every candidate capacity in one PriceTable — no per-level or per-split
  model calls).
* System.with_budget_fraction / PlanCost.compose / capacity-capped
  execution semantics.
"""
from itertools import combinations

import numpy as np
import pytest

from repro.core import cache_models
from repro.core.cam import CamGeometry
from repro.core.session import PlanCost, System
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, join_outer_keys
from repro.index.adapters import PGMAdapter
from repro.join.session import STRATEGIES, JoinSession
from repro.join.tree import JoinTreeSession, TreePlan

GEOM = CamGeometry()
POLICIES = ("lru", "fifo", "lfu")
N_BASE = 80_000
N_OUTER = 6_000
GRID = 8


@pytest.fixture(scope="module")
def world():
    base = make_dataset("books", N_BASE, seed=5)
    inner_keys = [base, base[::2].copy(), base[::3].copy()]
    adapters = [PGMAdapter.build(k, eps=32) for k in inner_keys]
    outers = {wl: join_outer_keys(base, N_OUTER, WorkloadSpec(wl, seed=9))
              for wl in ("w1", "w2")}
    return base, inner_keys, adapters, outers


def _tree(adapters, inner_keys, policy, pool_bytes=1 << 20):
    idx = sum(a.size_bytes for a in adapters)
    system = System(GEOM, memory_budget_bytes=pool_bytes + idx, policy=policy)
    return JoinTreeSession(adapters, system, inner_keys)


# ---------------------------------------------------------------------------
# Budget-split monotonicity + curve-vs-plan consistency
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_miss_curves_monotone_in_capacity(world, policy):
    """More buffer never increases a level's predicted misses."""
    base, _, adapters, outers = world
    system = System(GEOM, memory_budget_bytes=(1 << 20)
                    + adapters[0].size_bytes, policy=policy)
    s = JoinSession(adapters[0], system, inner_keys=base)
    caps = np.array([2, 4, 8, 16, 32, 64, 128, 256])
    curve = s.cost_curve(outers["w2"], caps, n_min=128, k_max=4096)
    for strategy in STRATEGIES:
        ios = curve.physical_ios[strategy]
        assert (np.diff(ios) <= 1e-6).all(), (policy, strategy, ios)
        secs = curve.seconds[strategy]
        assert (np.diff(secs) <= 1e-9).all(), (policy, strategy, secs)


@pytest.mark.parametrize("policy", POLICIES)
def test_cost_curve_matches_plan_at_each_capacity(world, policy):
    """The batched curve IS plan()'s scalar prediction, capacity by
    capacity (hybrid within 10% — its curve re-prices fixed segments)."""
    base, _, adapters, outers = world
    system = System(GEOM, memory_budget_bytes=(1 << 20)
                    + adapters[0].size_bytes, policy=policy)
    s = JoinSession(adapters[0], system, inner_keys=base)
    caps = np.array([4, 32, 128, 256])
    curve = s.cost_curve(outers["w2"], caps, n_min=128, k_max=4096)
    for strategy in STRATEGIES:
        for k, cap in enumerate(caps):
            pl = s.plan(outers["w2"], strategy, n_min=128, k_max=4096,
                        capacity=int(cap))
            assert pl.capacity == int(cap)
            got = curve.physical_ios[strategy][k]
            want = pl.cost.physical_ios
            assert abs(got - want) <= 0.10 * max(want, 1.0), \
                (policy, strategy, int(cap), got, want)


def test_sorted_scan_miss_curve_matches_scalar_model():
    """The curve evaluator equals the scalar sorted_scan_misses pointwise."""
    rng = np.random.default_rng(3)
    lo = np.sort(rng.integers(0, 400, size=3_000))
    hi = lo + rng.integers(0, 2, size=3_000)
    from repro.core import page_ref
    import jax.numpy as jnp
    r, nd, cov, pinned = page_ref.sorted_workload_stats(
        jnp.asarray(lo), jnp.asarray(hi), 500)
    caps = np.array([1, 3, 10, 50, 200, 600])
    for policy in POLICIES:
        curve = np.asarray(cache_models.sorted_scan_miss_curve(
            policy, caps, total_refs=float(r), distinct_pages=float(nd),
            coverage=cov, pinned_retouches=float(pinned), min_capacity=3))
        for k, c in enumerate(caps):
            scalar = cache_models.sorted_scan_misses(
                policy, int(c), total_refs=float(r),
                distinct_pages=float(nd), coverage=cov,
                pinned_retouches=float(pinned), min_capacity=3)
            assert abs(curve[k] - scalar) <= 1e-3 * max(scalar, 1.0), \
                (policy, int(c), curve[k], scalar)


# ---------------------------------------------------------------------------
# 3-level tree oracle vs exhaustive replay (3 policies x 2 skews)
# ---------------------------------------------------------------------------

def _exhaustive_best_io(tree, streams, caps, n_levels, grid):
    """Ground truth: replay EVERY (split, strategy) combination.

    Levels are independent given the split (each probes its own pages
    against its own slice), so replay each (level, capacity, strategy)
    once and minimize the sum over the split simplex.
    """
    io = np.empty((n_levels, len(caps)))
    for lvl, sess in enumerate(tree.sessions):
        for j, cap in enumerate(caps):
            per_strategy = []
            for st in STRATEGIES:
                pl = sess.plan(streams[lvl], st, n_min=128, k_max=4096,
                               capacity=int(cap))
                per_strategy.append(sess.execute(pl).physical_ios)
            io[lvl, j] = min(per_strategy)
    bars = np.array(list(combinations(range(1, grid), n_levels - 1)))
    edges = np.concatenate(
        [np.zeros((bars.shape[0], 1), np.int64), bars,
         np.full((bars.shape[0], 1), grid)], axis=1)
    comps = np.diff(edges, axis=1)
    totals = io[np.arange(n_levels)[None, :], comps - 1].sum(axis=1)
    return float(totals.min())


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("wl", ("w1", "w2"))
def test_tree_plan_within_15pct_of_exhaustive_replay(world, policy, wl):
    base, inner_keys, adapters, outers = world
    tree = _tree(adapters, inner_keys, policy)
    outer = outers[wl]
    plan = tree.plan(outer, grid=GRID, objective="io",
                     n_min=128, k_max=4096)
    replayed = tree.execute(plan)

    streams = tree.probe_streams(outer)
    shares = np.arange(1, GRID - tree.n_levels + 2)
    caps = np.maximum(1, (shares * tree.pool_pages) // GRID)
    best = _exhaustive_best_io(tree, streams, caps, tree.n_levels, GRID)
    assert replayed.physical_ios <= 1.15 * best, \
        (policy, wl, replayed.physical_ios, best,
         plan.fractions, plan.strategies)


def test_tree_match_count_equals_numpy_oracle(world):
    base, inner_keys, adapters, outers = world
    tree = _tree(adapters, inner_keys, "lru")
    stats = tree.run(outers["w2"], grid=GRID, n_min=128, k_max=4096)
    probe = outers["w2"]
    for keys in inner_keys:
        probe = probe[np.isin(probe, keys)]
    assert stats.matches == probe.shape[0]
    assert stats.physical_ios == sum(st.physical_ios
                                     for st in stats.per_level)
    assert stats.logical_refs == sum(st.logical_refs
                                     for st in stats.per_level)


# ---------------------------------------------------------------------------
# The split solve is one batched grid — no replay, ONE engine call
# ---------------------------------------------------------------------------

def test_tree_plan_is_replay_free_and_batched(world, monkeypatch):
    base, inner_keys, adapters, outers = world
    tree = _tree(adapters, inner_keys, "lfu")

    from repro.sim.machine import BufferedDisk
    def _no_replay(self, *a, **kw):
        raise AssertionError("tree planning must not touch the disk")
    monkeypatch.setattr(BufferedDisk, "fetch_window", _no_replay)

    from repro.join.hybrid import JoinCostParams
    engine = tree._cost_session.engine
    before = engine.calls
    plan = tree.plan(outers["w1"], grid=GRID, n_min=128, k_max=4096,
                     params=JoinCostParams())   # pre-fit: no calibration run
    assert isinstance(plan, TreePlan)
    # ONE engine solve for the whole tree — every (level x stream x
    # capacity) cell in one PriceTable, NOT one solve per level or split
    assert engine.calls - before == 1
    # ... and none of the per-level sessions solved anything on the side
    for sess in tree.sessions:
        assert sess._cost_session.engine.calls == 0
    n_splits = len(list(combinations(range(1, GRID), tree.n_levels - 1)))
    assert n_splits > tree.n_levels  # the simplex is genuinely larger


# ---------------------------------------------------------------------------
# Budget views, composition, capped execution
# ---------------------------------------------------------------------------

def test_with_budget_fraction_view():
    system = System(GEOM, memory_budget_bytes=8 << 20, policy="lfu")
    view = system.with_budget_fraction(0.25, pool_bytes=4 << 20,
                                       resident_bytes=1 << 20)
    assert view.policy == "lfu" and view.geom == system.geom
    assert view.capacity_for(1 << 20) == (1 << 20) // GEOM.page_bytes
    # default pool = the full budget
    half = system.with_budget_fraction(0.5)
    assert half.memory_budget_bytes == 4 << 20
    with pytest.raises(ValueError):
        system.with_budget_fraction(1.5)


def test_plan_cost_compose():
    parts = [PlanCost("a", 1.0, 10.0, 100.0), PlanCost("b", 2.0, 5.0, 50.0)]
    total = PlanCost.compose("tree", parts)
    assert total.strategy == "tree"
    assert total.seconds == 3.0
    assert total.physical_ios == 15.0
    assert total.logical_refs == 150.0


def test_execute_honours_plan_capacity(world):
    """A plan built at an externally-capped budget replays against THAT
    buffer, not the session default — a thrash-capacity plan must read
    more pages than a roomy one on the same stream."""
    base, _, adapters, outers = world
    system = System(GEOM, memory_budget_bytes=(1 << 20)
                    + adapters[0].size_bytes, policy="lru")
    s = JoinSession(adapters[0], system, inner_keys=base)
    outer = outers["w2"]
    roomy = s.execute(s.plan(outer, "point-only", capacity=256))
    tight = s.execute(s.plan(outer, "point-only", capacity=1))
    assert tight.physical_ios > roomy.physical_ios


def test_tree_sessions_share_one_pool(world):
    base, inner_keys, adapters, _ = world
    tree = _tree(adapters, inner_keys, "lru", pool_bytes=1 << 20)
    assert tree.pool_pages == (1 << 20) // GEOM.page_bytes
    # default (pre-plan) even split: each level's session capacity is its
    # 1/L view of the ONE pool
    for sess in tree.sessions:
        assert sess.capacity == tree.pool_pages // tree.n_levels


def test_tiny_pool_never_overcommitted(world):
    """A grid finer than the pool must clamp: the chosen capacities always
    sum to at most the ONE shared pool (no 1-page floor overcommit)."""
    base, inner_keys, adapters, outers = world
    idx = sum(a.size_bytes for a in adapters)
    system = System(GEOM, memory_budget_bytes=4 * GEOM.page_bytes + idx,
                    policy="lru")
    tree = JoinTreeSession(adapters, system, inner_keys)
    assert tree.pool_pages == 4
    plan = tree.plan(outers["w1"][:500], grid=8, n_min=64)
    assert sum(plan.capacities) <= tree.pool_pages
    assert all(c >= 1 for c in plan.capacities)


def test_tree_rejects_bad_shapes(world):
    base, inner_keys, adapters, _ = world
    system = System(GEOM, memory_budget_bytes=(1 << 20)
                    + sum(a.size_bytes for a in adapters), policy="lru")
    with pytest.raises(ValueError):
        JoinTreeSession(adapters, system, inner_keys[:2])
    with pytest.raises(ValueError):
        JoinTreeSession(adapters, system, [base, None, base])
    with pytest.raises(ValueError):
        JoinTreeSession(adapters, system, inner_keys,
                        probe_maps=[lambda x: x])  # needs L-1 = 2
    tiny = System(GEOM, memory_budget_bytes=sum(a.size_bytes
                                                for a in adapters),
                  policy="lru")
    with pytest.raises(ValueError):
        JoinTreeSession(adapters, tiny, inner_keys)
    tree = _tree(adapters, inner_keys, "lru")
    with pytest.raises(ValueError):
        tree.plan(np.array([1, 2, 3]), grid=2)     # grid < n_levels
    with pytest.raises(ValueError):
        tree.plan(np.array([1, 2, 3]), objective="latency")
