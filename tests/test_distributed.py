"""Distributed runtime tests: checkpoint atomicity/restore, fault-tolerant
training equivalence, straggler flagging, elastic re-shard, int8 gradient
compression with error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data.pipeline import TokenPipeline
from repro.distributed import checkpoint as ck
from repro.distributed import compression
from repro.distributed.fault_tolerance import (FailureInjector, Supervisor)
from repro.distributed.sharding import Recipe, ShardingCtx
from repro.launch.train import build_trainer
from repro.models.params import init_params
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod

CFG = reduced(ARCHS["starcoder2-3b"])
OPT = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)


def _fresh_state(seed=0):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    recipe = Recipe(remat="none")
    opt_state = ts_mod.init_opt_state(params, CFG, recipe, OPT)
    return {"params": params, "opt_state": opt_state}, recipe


def test_checkpoint_roundtrip(tmp_path):
    state, _ = _fresh_state()
    path = ck.save_checkpoint(str(tmp_path), 3, state)
    assert os.path.exists(os.path.join(path, "meta.json"))
    step, trees = ck.restore_checkpoint(str(tmp_path))
    assert step == 3
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(trees["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_partial(tmp_path):
    state, _ = _fresh_state()
    ck.save_checkpoint(str(tmp_path), 1, state)
    # a stale .tmp dir (simulated crash mid-save) must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ck.latest_step(str(tmp_path)) == 1


def test_failure_recovery_bitwise_equivalent(tmp_path):
    """Training WITH an injected failure + restart must produce exactly the
    same final params as an uninterrupted run (deterministic pipeline)."""
    pipe = TokenPipeline(CFG.vocab_size, 4, 32, seed=1)
    state_a, recipe = _fresh_state()
    step_fn = build_trainer(CFG, recipe, OPT)
    sup_a = Supervisor(step_fn, state_a, pipe.batch_for_step,
                       str(tmp_path / "a"), ckpt_every=4)
    res_a = sup_a.run(10)

    state_b, _ = _fresh_state()
    sup_b = Supervisor(step_fn, state_b, pipe.batch_for_step,
                       str(tmp_path / "b"), ckpt_every=4,
                       injector=FailureInjector(fail_at=(6,)))
    res_b = sup_b.run(10)
    assert res_b["restarts"] == 1
    for a, b in zip(jax.tree.leaves(sup_a.state["params"]),
                    jax.tree.leaves(sup_b.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_auto_resume_from_latest(tmp_path):
    pipe = TokenPipeline(CFG.vocab_size, 4, 32, seed=2)
    state, recipe = _fresh_state()
    step_fn = build_trainer(CFG, recipe, OPT)
    sup = Supervisor(step_fn, state, pipe.batch_for_step, str(tmp_path),
                     ckpt_every=5)
    sup.run(5)   # leaves step_5 checkpoint
    state2, _ = _fresh_state(seed=9)  # different init — must be overridden
    sup2 = Supervisor(step_fn, state2, pipe.batch_for_step, str(tmp_path),
                      ckpt_every=5)
    res = sup2.run(8)
    assert res["final_step"] == 8
    assert len(res["losses"]) == 3   # only steps 5..7 executed


def test_straggler_flagging(tmp_path):
    pipe = TokenPipeline(CFG.vocab_size, 2, 16, seed=3)
    state, recipe = _fresh_state()
    step_fn = build_trainer(CFG, recipe, OPT)
    flagged = []
    sup = Supervisor(step_fn, state, pipe.batch_for_step, str(tmp_path),
                     ckpt_every=100, straggler_factor=2.5,
                     injector=FailureInjector(delays={8: 1.0}),
                     on_straggler=flagged.append)
    sup.run(10)
    assert 8 in flagged


def test_elastic_reshard_roundtrip(tmp_path):
    """Save, then restore onto mesh=None (1 device) — values unchanged."""
    from repro.distributed.elastic import reshard_params

    state, recipe = _fresh_state()
    ck.save_checkpoint(str(tmp_path), 0, {"params": state["params"]})
    _, trees = ck.restore_checkpoint(str(tmp_path))
    out = reshard_params(trees["params"], CFG, None, recipe)
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_quantization_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = compression.quantize_int8(g)
    deq = compression.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-6
    # error feedback: accumulated residual keeps the long-run mean unbiased
    ef = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        local = g + ef
        q, s = compression.quantize_int8(local)
        deq = compression.dequantize_int8(q, s)
        ef = local - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=float(s))
