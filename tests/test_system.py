"""End-to-end behaviour tests for the whole system.

Covers: the full CAM pipeline (dataset -> index -> workload -> estimate vs
replay), memory-budget tuning end-to-end, join pipeline, and a short real
training run with checkpoint-restart through the public launchers.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.core import cam
from repro.core.qerror import q_error
from repro.core.replay import replay_windows
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload, join_outer_keys
from repro.index.disk_layout import PageLayout
from repro.index.pgm import build_pgm
from repro.join.executors import hybrid_join, inlj
from repro.core.session import System
from repro.core.workload import Workload
from repro.tuning.session import PGMBuilder, TuningSession

GEOM = cam.CamGeometry()
LAYOUT = PageLayout()


@pytest.fixture(scope="module")
def world():
    keys = make_dataset("books", 500_000, seed=1)
    qk, qpos = point_workload(keys, 60_000, WorkloadSpec("w4", seed=3))
    return keys, qk, qpos


def test_cam_end_to_end_accuracy(world):
    """The headline claim: CAM matches replay (Q-error ~1.0x) replay-free."""
    keys, qk, qpos = world
    for eps in (16, 64, 256):
        idx = build_pgm(keys, eps)
        budget = 3 << 20
        est = cam.estimate_point_io(qpos, eps, len(keys), GEOM, budget,
                                    idx.size_bytes, policy="lru")
        cap = max(1, (budget - idx.size_bytes) // GEOM.page_bytes)
        wlo, whi = idx.window(qk)
        misses = replay_windows(wlo // GEOM.c_ipp, whi // GEOM.c_ipp,
                                cap, "lru")
        assert float(q_error(est.io_per_query, misses.mean())) < 1.25, eps


def test_cam_tuning_end_to_end(world):
    """CAM-chosen eps must be within 15% of the oracle-best actual I/O."""
    keys, qk, qpos = world
    budget = int(1.2 * 2**20)
    grid = (8, 16, 32, 64, 128, 256, 512)
    res = TuningSession(System(GEOM, budget, "lru")).tune(
        PGMBuilder(keys), Workload.point(qpos, n=len(keys)),
        overrides={"eps": grid})
    actual = {}
    for eps in grid:
        idx = build_pgm(keys, eps)
        if idx.size_bytes >= budget - GEOM.page_bytes:
            continue
        cap = max(1, (budget - idx.size_bytes) // GEOM.page_bytes)
        wlo, whi = idx.window(qk)
        actual[eps] = replay_windows(wlo // GEOM.c_ipp, whi // GEOM.c_ipp,
                                     cap, "lru").mean()
    best_actual = min(actual.values())
    assert actual[res.best_knob] <= 1.15 * best_actual


def test_join_end_to_end(world):
    keys, _, _ = world
    idx = build_pgm(keys, 64)
    outer = join_outer_keys(keys, 30_000, WorkloadSpec("w3", seed=7))
    cap = (1 << 20) // LAYOUT.page_bytes
    st_inlj = inlj(idx, keys, outer, LAYOUT, cap)
    st_h = hybrid_join(idx, keys, outer, LAYOUT, cap, n_min=256)
    assert st_h.matches == st_inlj.matches == int(np.isin(outer, keys).sum())
    assert st_h.seconds < st_inlj.seconds     # hotspot workload: big win


def test_training_launcher_end_to_end(tmp_path):
    """Real subprocess through the public CLI: loss decreases, checkpoint
    restart after an injected failure still completes."""
    import os

    env = dict(os.environ, PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "starcoder2-3b", "--reduced", "--steps", "8", "--batch", "4",
           "--seq", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
           "--fail-at", "5"]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                         env=env, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "restarts=1" in out.stdout
    assert "decreasing=True" in out.stdout
