"""Property-based invariants of the CAM pipeline (hypothesis)."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import cam, cache_models
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_positions

GEOM = cam.CamGeometry()
KEYS = make_dataset("wiki", 200_000, seed=11)
N = len(KEYS)


@settings(max_examples=12, deadline=None)
@given(
    st.sampled_from([8, 32, 128, 512]),              # eps
    st.sampled_from(["w1", "w2", "w4", "w6"]),
    st.integers(min_value=1, max_value=8),           # buffer MiB
    st.sampled_from(["lru", "fifo", "lfu"]),
)
def test_cam_estimate_invariants(eps, wl, mem_mb, policy):
    pos = point_positions(N, 20_000, WorkloadSpec(wl, seed=5))
    est = cam.estimate_point_io(pos, eps, N, GEOM, mem_mb << 20, 4096,
                                policy=policy, sample_rate=1.0)
    dac = 1.0 + 2.0 * eps / GEOM.c_ipp
    assert 0.0 <= est.hit_rate <= 1.0 + 1e-6
    assert -1e-6 <= est.io_per_query <= dac + 1e-6   # IO in [0, E[DAC]]
    assert abs(est.dac - dac) < 1e-4
    assert est.distinct_pages <= GEOM.num_pages(N) + 1


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([16, 128]), st.sampled_from(["lru", "fifo"]))
def test_cam_io_monotone_in_buffer(eps, policy):
    """More buffer can only reduce estimated physical I/O."""
    pos = point_positions(N, 20_000, WorkloadSpec("w4", seed=6))
    prev = np.inf
    for mem_mb in (1, 2, 4, 8):
        est = cam.estimate_point_io(pos, eps, N, GEOM, mem_mb << 20, 4096,
                                    policy=policy)
        assert est.io_per_query <= prev + 1e-6
        prev = est.io_per_query


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=2, max_value=2000), st.integers(min_value=0, max_value=99))
def test_hit_rates_monotone_in_capacity(n_pages, seed):
    rng = np.random.default_rng(seed)
    p = rng.pareto(1.1, n_pages) + 1e-6
    probs = jnp.asarray(p / p.sum(), jnp.float32)
    for fn in (cache_models.hit_rate_lru, cache_models.hit_rate_fifo,
               cache_models.hit_rate_lfu):
        h_small = float(fn(probs, max(1, n_pages // 10)))
        h_big = float(fn(probs, max(2, n_pages // 2)))
        assert h_big >= h_small - 5e-3


def test_sorted_estimator_policy_free_matches_replay_on_real_index():
    """End-to-end Thm III.1: sorted probe stream through a built PGM — the
    closed form equals replay for LRU and FIFO exactly."""
    from repro.core.replay import replay_windows
    from repro.index.pgm import build_pgm

    idx = build_pgm(KEYS, 32)
    qpos = np.sort(np.random.default_rng(0).integers(0, N, 4000))
    wlo, whi = idx.window(KEYS[qpos])
    est = cam.estimate_sorted_io(wlo, whi, 32, N, GEOM,
                                 memory_budget_bytes=64 << 20, index_bytes=0)
    plo, phi = wlo // GEOM.c_ipp, whi // GEOM.c_ipp
    for policy in ("lru", "fifo"):
        misses = replay_windows(plo, phi, est.capacity_pages, policy)
        actual_io = misses.sum() / len(qpos)
        assert abs(actual_io - est.io_per_query) < 1e-9, policy
