"""Write-path tests: estimator-vs-replay oracle for read/write mixes,
gapped-array occupancy invariants (property-based), write-kind workload
algebra, trace round-trips, executor equivalence on write tables, and the
WriteSession / merge-scheduler structural guarantees.
"""
import json

import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import cache_models as cm
from repro.core import replay
from repro.core.cam import CamGeometry
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import WRITE_KINDS, Workload
from repro.engine import PriceTable, PricingEngine
from repro.index.adapters import ALEXAdapter, BTreeAdapter
from repro.index.gapped import (GappedArray, btree_write_amp, gapped_slots,
                                gapped_write_amp, to_slot_space)
from repro.serving.trace import (TraceEvent, compile_events, iter_batches,
                                 parse_jsonl, synthetic_drifting_trace,
                                 to_jsonl)
from repro.tuning.session import ALEXBuilder, BTreeBuilder, TuningSession
from repro.write import (CamMergeScheduler, DeltaBuffer, EveryKScheduler,
                         OnFullScheduler, WriteConfig, WriteSession,
                         merge_burst_workload)
from repro.write.session import split_reads_writes

GEOM = CamGeometry()
POLICIES = ("lru", "fifo", "lfu")


def zipf_probs(n, a=1.2, seed=0):
    p = 1.0 / np.arange(1, n + 1) ** a
    rng = np.random.default_rng(seed)
    rng.shuffle(p)
    return p / p.sum()


# ---------------------------------------------------------------------------
# Estimator vs replay: the write oracle
# ---------------------------------------------------------------------------

# (name, write_frac, zipf_read, zipf_write, seed)
MIXES = [("insert_heavy", 0.8, 1.1, 1.2, 1),
         ("update_heavy", 0.6, 1.2, 1.5, 7),
         ("mixed_rw", 0.3, 1.3, 1.3, 13)]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("mix", MIXES, ids=[m[0] for m in MIXES])
def test_write_estimate_matches_iid_replay(policy, mix):
    """(1 - h) from the write-aware grid solve prices fetches + writebacks
    of an IID read/write trace within the q-error gate (dirty-eviction
    replay as ground truth)."""
    _, w_frac, a_r, a_w, seed = mix
    n_pages, cap, n_refs = 2000, 300, 120_000
    pr = zipf_probs(n_pages, a_r, seed)
    pw = zipf_probs(n_pages, a_w, seed + 1)
    rng = np.random.default_rng(seed + 2)
    is_w = rng.random(n_refs) < w_frac
    refs = np.where(is_w, rng.choice(n_pages, n_refs, p=pw),
                    rng.choice(n_pages, n_refs, p=pr))
    fetches, writebacks = replay.replay_write_refs(refs, is_w, cap, policy)
    assert writebacks > 0                      # the dirty stream is live
    actual = (fetches + writebacks) / n_refs
    rc = np.bincount(refs[~is_w], minlength=n_pages).astype(np.float64)
    wc = np.bincount(refs[is_w], minlength=n_pages).astype(np.float64)
    h, _ = cm.hit_rate_grid(
        policy, jnp.asarray(rc[None], jnp.float32),
        jnp.asarray([rc.sum()], jnp.float32),
        jnp.asarray([rc.sum()], jnp.float32),
        jnp.asarray([cap], jnp.float32),
        write_counts=jnp.asarray(wc[None], jnp.float32),
        write_refs=jnp.asarray([wc.sum()], jnp.float32),
        write_full_refs=jnp.asarray([wc.sum()], jnp.float32))
    est = 1.0 - float(h[0])
    q = max(est / actual, actual / est)
    # LFU converges slowly on finite traces (paper §VII-C caveat).
    gate = 1.3 if policy == "lfu" else 1.1
    assert q <= gate, (policy, mix[0], est, actual, q)


@pytest.mark.parametrize("policy", POLICIES)
def test_writeback_limits(policy):
    """cap >= N pins every page: zero writebacks, compulsory h; cap < 1
    flushes every write: h == -W (the documented negative floor)."""
    counts = jnp.asarray([[30.0, 20.0, 10.0]] * 2, jnp.float32)
    wcounts = jnp.asarray([[10.0, 5.0, 5.0]] * 2, jnp.float32)
    refs = jnp.asarray([60.0, 60.0], jnp.float32)
    wrefs = jnp.asarray([20.0, 20.0], jnp.float32)
    h, _ = cm.hit_rate_grid(policy, counts, refs, refs,
                            jnp.asarray([10.0, 0.0], jnp.float32),
                            write_counts=wcounts, write_refs=wrefs,
                            write_full_refs=wrefs)
    assert h[0] == pytest.approx((80.0 - 3.0) / 80.0, abs=1e-6)
    assert h[1] == pytest.approx(-20.0 / 80.0, abs=1e-6)


def test_replay_write_refs_no_final_flush():
    """Dirty pages still resident at end of trace are not charged."""
    refs = [0, 1, 2, 0, 1, 2]
    is_w = [True] * 6
    fetches, writebacks = replay.replay_write_refs(refs, is_w, 10, "lru")
    assert (fetches, writebacks) == (3, 0)
    # cap 1 evicts every dirty page except the last
    fetches, writebacks = replay.replay_write_refs(refs, is_w, 1, "lru")
    assert fetches == 6 and writebacks == 5


# ---------------------------------------------------------------------------
# Gapped-array occupancy invariants (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.5), st.integers(4, 120))
def test_gapped_inserts_never_shrink_layout(seed, gap_density, n0):
    rng = np.random.default_rng(seed)
    ga = GappedArray(n0, gap_density)
    pages, slots = ga.pages(GEOM.c_ipp), ga.slots
    for frac in rng.random(60):
        dirtied = ga.insert(float(frac) % 1.0)
        assert dirtied >= 1
        assert ga.slots >= slots and ga.pages(GEOM.c_ipp) >= pages
        pages, slots = ga.pages(GEOM.c_ipp), ga.slots
    assert ga.count == n0 + 60
    assert int(ga.occupied.sum()) == ga.count   # occupancy mirrors count


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.5), st.integers(4, 120),
       st.integers(1, 80))
def test_gapped_merge_restores_fill_bounds(seed, gap_density, n0, n_ins):
    rng = np.random.default_rng(seed)
    ga = GappedArray(n0, gap_density)
    for frac in rng.random(n_ins):
        ga.insert(float(frac) % 1.0)
    written = ga.merge()
    assert written == ga.slots == gapped_slots(ga.count, gap_density)
    fill = ga.fill_factor()
    assert fill <= 1.0 - gap_density + 1e-9
    assert fill >= (1.0 - gap_density) * ga.count / (ga.count + 1) - 1e-9


def test_write_amp_monotone_in_knobs():
    """More gaps -> cheaper inserts; fuller nodes -> pricier splits."""
    amps = [gapped_write_amp(g, GEOM.c_ipp)
            for g in (0.05, 0.1, 0.2, 0.4)]
    assert all(a >= b for a, b in zip(amps, amps[1:]))
    assert all(a >= 1.0 for a in amps)
    bamps = [btree_write_amp(f, GEOM.c_ipp)
             for f in (0.55, 0.67, 0.85, 0.95)]
    assert all(a <= b for a, b in zip(bamps, bamps[1:]))
    assert all(a >= 1.0 for a in bamps)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.5))
def test_to_slot_space_monotone_and_bounded(seed, gap_density):
    rng = np.random.default_rng(seed)
    n = 5000
    slots = gapped_slots(n, gap_density)
    pos = np.sort(rng.integers(0, n, 300))
    wl = to_slot_space(Workload.point(pos, n=n), n, slots)
    assert wl.n == slots
    assert np.all(np.diff(wl.positions) >= 0)        # order-preserving
    assert np.all((wl.positions >= 0) & (wl.positions < slots))


# ---------------------------------------------------------------------------
# Workload algebra: write kinds through split_at / concat
# ---------------------------------------------------------------------------

def test_split_at_write_kinds_concat_round_trip():
    """Extends the PR 7 mixed round-trip regression to mutating parts: a
    point+insert+update+delete+range mix splits and concats back exactly."""
    n = 8192
    cuts = np.asarray([2048, 4096, 6144])
    rng = np.random.default_rng(3)
    pts = np.sort(rng.integers(0, n, 300))
    ins = np.sort(rng.integers(0, n, 200))
    upd = np.sort(rng.integers(0, n, 150))
    dele = np.sort(rng.integers(0, n, 100))
    lo = np.sort(rng.integers(0, n - 64, 120))
    seg = np.searchsorted(cuts, lo, side="right")
    edges_hi = np.concatenate([cuts, [n]])
    hi = np.minimum(lo + rng.integers(0, 40, 120), edges_hi[seg] - 1)
    wl = Workload.mixed(Workload.point(pts, n=n),
                        Workload.insert(ins, n=n),
                        Workload.update(upd, n=n),
                        Workload.delete(dele, n=n),
                        Workload.range_scan(lo, hi, n=n))
    back = Workload.concat(*wl.split_at(cuts))
    assert back.kind == "mixed" and len(back.parts) == 5
    by_kind = {p.kind: p for p in back.parts}
    assert np.array_equal(by_kind["point"].positions, pts)
    assert np.array_equal(by_kind["insert"].positions, ins)
    assert np.array_equal(by_kind["update"].positions, upd)
    assert np.array_equal(by_kind["delete"].positions, dele)
    assert np.array_equal(by_kind["range"].positions, lo)
    assert np.array_equal(by_kind["range"].hi_positions, hi)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 5))
def test_split_at_insert_partition(seed, n_cuts):
    """Every write lands in exactly one segment, the right one."""
    rng = np.random.default_rng(seed)
    n = 4096
    pos = rng.integers(0, n, 400)
    cuts = np.sort(rng.choice(np.arange(1, n), size=n_cuts, replace=False))
    segs = Workload.insert(pos, n=n).split_at(cuts)
    assert sum(s.n_queries for s in segs) == 400
    edges = np.concatenate([[0], cuts, [n]])
    for s, seg in enumerate(segs):
        if seg.n_queries:
            assert seg.kind == "insert"
            assert np.all(seg.positions >= edges[s])
            assert np.all(seg.positions < edges[s + 1])


def test_split_reads_writes_regroups_mixed():
    n = 4096
    wl = Workload.mixed(Workload.point(np.asarray([1, 2]), n=n),
                        Workload.insert(np.asarray([3]), n=n),
                        Workload.update(np.asarray([4, 5]), n=n))
    reads, writes = split_reads_writes(wl)
    assert reads.kind == "point" and reads.n_queries == 2
    assert writes.kind == "mixed" and writes.n_queries == 3
    assert all(p.kind in WRITE_KINDS for p in writes.parts)
    r2, w2 = split_reads_writes(Workload.point(np.asarray([7]), n=n))
    assert w2 is None and r2.n_queries == 1


# ---------------------------------------------------------------------------
# Trace: JSONL round-trip and mixed-batch compile ordering
# ---------------------------------------------------------------------------

def test_trace_jsonl_round_trip_write_ops():
    events = [TraceEvent("point", key=1.5, ts=0.0),
              TraceEvent("insert", key=2.5, ts=1.0),
              TraceEvent("range", lo_key=1.0, hi_key=9.0, ts=2.0),
              TraceEvent("update", key=3.5, ts=3.0),
              TraceEvent("sorted", lo_key=2.0, hi_key=4.0, ts=4.0),
              TraceEvent("delete", key=4.5, ts=5.0)]
    back = list(parse_jsonl(to_jsonl(events).splitlines()))
    assert back == events
    # every line is valid standalone JSON with the op tag
    for line in to_jsonl(events).strip().splitlines():
        assert json.loads(line)["op"] in ("point", "range", "sorted",
                                          "insert", "update", "delete")


def test_trace_event_validation():
    with pytest.raises(ValueError):
        TraceEvent("upsert", key=1.0)
    with pytest.raises(ValueError):
        TraceEvent("insert")                    # write ops need a key
    with pytest.raises(ValueError):
        TraceEvent("range", key=1.0)            # range ops need bounds


def test_compile_events_preserves_arrival_order_per_kind():
    """Interleaved reads and writes compile into per-kind parts whose
    positions keep arrival order (the delta stages writes in trace order)."""
    keys = np.arange(100, dtype=np.float64)
    events = [TraceEvent("point", key=50.0, ts=0),
              TraceEvent("insert", key=10.0, ts=1),
              TraceEvent("point", key=20.0, ts=2),
              TraceEvent("update", key=70.0, ts=3),
              TraceEvent("insert", key=5.0, ts=4),
              TraceEvent("delete", key=90.0, ts=5)]
    wl = compile_events(events, keys)
    assert wl.kind == "mixed"
    by_kind = {p.kind: p for p in wl.parts}
    assert list(by_kind["point"].positions) == [50, 20]
    assert list(by_kind["insert"].positions) == [10, 5]
    assert list(by_kind["update"].positions) == [70]
    assert list(by_kind["delete"].positions) == [90]


def test_synthetic_trace_six_way_mix():
    keys = np.sort(np.random.default_rng(0).uniform(0, 1e6, 5000))
    events = synthetic_drifting_trace(
        keys, [{"events": 800, "mix": (0.4, 0.1, 0.1, 0.2, 0.1, 0.1)}],
        seed=4)
    ops = {e.op for e in events}
    assert {"insert", "update", "delete"} <= ops
    batches = list(iter_batches(events, 100))
    assert [len(b) for b in batches] == [100] * 8


# ---------------------------------------------------------------------------
# Delta buffer and merge bursts
# ---------------------------------------------------------------------------

def test_delta_buffer_staging_and_burst():
    n = 4096
    delta = DeltaBuffer(capacity_entries=100, entry_bytes=64.0)
    staged = delta.stage(Workload.mixed(
        Workload.point(np.asarray([1]), n=n),
        Workload.insert(np.asarray([10, 11, 500]), n=n)))
    assert staged == 3 and delta.entries == 3 and not delta.full
    assert delta.stolen_pages(4096) == 1
    delta.stage(Workload.update(np.arange(200), n=n))   # overflow accepted
    assert delta.full and delta.entries == 203
    burst = merge_burst_workload(delta.positions(), n, GEOM.c_ipp)
    assert burst.kind == "sorted"
    assert np.all(burst.hi_positions >= burst.positions)
    assert np.all(np.diff(burst.positions) > 0)
    assert delta.clear() == 203 and delta.entries == 0 and delta.merges == 1
    with pytest.raises(ValueError):
        merge_burst_workload(delta.positions(), n, GEOM.c_ipp)


def test_merge_burst_coalesces_adjacent_pages():
    c = GEOM.c_ipp
    # pages 0,1 adjacent -> one run; page 10 far -> its own run
    pos = np.asarray([0, c + 1, 10 * c + 2])
    burst = merge_burst_workload(pos, 20 * c, c)
    assert burst.n_queries == 2
    assert burst.positions[0] == 0 and burst.hi_positions[0] == 2 * c - 1


# ---------------------------------------------------------------------------
# Updatable adapters through the tuner (unchanged TuningSession)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_keys():
    return np.sort(np.random.default_rng(11).uniform(0, 1e9, 20_000))


def _rw_workload(keys, w_frac, seed=5):
    n = len(keys)
    rng = np.random.default_rng(seed)
    reads = Workload.point(rng.integers(0, n, 3000), n=n)
    writes = Workload.insert(rng.integers(0, n, int(3000 * w_frac)), n=n)
    return Workload.mixed(reads, writes)


@pytest.mark.parametrize("builder_cls,knob", [(ALEXBuilder, "gap_density"),
                                              (BTreeBuilder, "fill_factor")])
def test_updatable_builders_tune_one_solve(small_keys, builder_cls, knob):
    ts = TuningSession(System(GEOM, 4 << 20, "lru"))
    res = ts.tune(builder_cls(small_keys), _rw_workload(small_keys, 0.5))
    assert res.batched_solves == 1
    assert knob in res.best
    meta = (ALEXAdapter if knob == "gap_density"
            else BTreeAdapter).knob_metadata()[knob]
    assert res.best[knob] in meta["grid"]


def test_alex_gap_density_tracks_write_intensity(small_keys):
    """Write-heavier traffic tunes to more slack (the ALEX design point)."""
    ts = TuningSession(System(GEOM, 4 << 20, "lru"))
    g_read = ts.tune(ALEXBuilder(small_keys),
                     _rw_workload(small_keys, 0.05)).best["gap_density"]
    g_write = ts.tune(ALEXBuilder(small_keys),
                      _rw_workload(small_keys, 2.0)).best["gap_density"]
    assert g_write >= g_read


def test_adapter_profiles_write_amplification(small_keys):
    """A write-heavy mix produces a write stream scaled by the structure's
    write amplification (gapped shifts / node rewrites)."""
    n = len(small_keys)
    wl = _rw_workload(small_keys, 1.0)
    sess = CostSession(System(GEOM, 4 << 20, "lru"))
    alex = ALEXAdapter.build(small_keys, gap_density=0.1)
    bt = BTreeAdapter.build(small_keys, fill_factor=0.67)
    for adapter in (alex, bt):
        profs = sess.grid_profiles(
            [GridCandidate(knob="a", eps=adapter.eps,
                           size_bytes=adapter.size_bytes, index=adapter)], wl)
        assert profs.wparts and profs.wparts[0] is not None
        amp = float(profs.wparts[0].total_refs) / 3000.0
        assert amp >= 1.0                      # write amplification >= 1
    assert alex.slots > n and bt.slots > n


# ---------------------------------------------------------------------------
# WriteSession: structural invariants + scheduler behavior
# ---------------------------------------------------------------------------

def _session_world(policy="lru", executor=None):
    keys = np.sort(np.random.default_rng(21).uniform(0, 1e9, 30_000))
    system = System(GEOM, 80 * GEOM.page_bytes, policy)
    config = WriteConfig(batch_size=200, delta_capacity_entries=6000,
                         delta_entry_bytes=256.0, horizon_batches=12.0,
                         price_executor=executor)
    trace = synthetic_drifting_trace(keys, [
        {"events": 1000, "mix": (0.85, 0.05, 0.0, 0.1, 0.0, 0.0),
         "hot_width": 0.08, "hot_frac": 0.95},
        {"events": 1400, "mix": (0.25, 0.0, 0.0, 0.55, 0.15, 0.05),
         "hot_center": 0.7, "hot_width": 0.25, "hot_frac": 0.8},
        {"events": 1200, "mix": (0.92, 0.03, 0.0, 0.05, 0.0, 0.0),
         "hot_width": 0.08, "hot_frac": 0.95},
    ], seed=9)
    cand = GridCandidate(knob="live", eps=64, size_bytes=4096.0)
    return keys, system, config, trace, cand


def _run(scheduler, executor=None, policy="lru"):
    keys, system, config, trace, cand = _session_world(policy, executor)
    sess = WriteSession(keys, system, scheduler, candidate=cand,
                        config=config)
    return sess.run(trace)


def test_write_session_one_engine_call_per_event():
    """The headline structural invariant: every decision event is priced by
    EXACTLY one PricingEngine.price call (zero per-candidate model calls)."""
    for sched in (CamMergeScheduler(), EveryKScheduler(k=6),
                  OnFullScheduler()):
        rep = _run(sched)
        assert rep.decision_events > 0
        assert rep.engine_calls == rep.decision_events
        assert len(rep.records) == 18           # ceil(3600 / 200)


def test_cam_scheduler_merges_on_write_burst():
    rep = _run(CamMergeScheduler())
    assert rep.merges >= 1 and rep.merge_io > 0
    assert any(r.merged and r.reason in ("priced", "full")
               for r in rep.records)
    # capacity pressure is real: some record saw a shrunken pool
    assert any(r.cap_now < r.cap_empty for r in rep.records)
    assert rep.total_io == pytest.approx(rep.read_io + rep.merge_io)


def test_cam_beats_on_full_on_burst_trace():
    """The bench gate's miniature: deferring every merge to 'full' keeps
    reads paying the shrunken cache; CAM's priced flushes cost less."""
    cam = _run(CamMergeScheduler())
    on_full = _run(OnFullScheduler())
    assert cam.total_io < on_full.total_io


def test_on_full_only_merges_when_full():
    rep = _run(OnFullScheduler())
    assert all(r.reason in ("full", "no_reads_yet") for r in rep.records)
    for r in rep.records:
        if r.merged:                            # decision-time state: full
            assert r.delta_entries >= 6000


def test_every_k_period(small_keys):
    ctx_base = dict(batch_index=0, io_defer=1.0, io_merged=1.0,
                    merge_io=5.0, horizon_queries=10.0, delta_entries=5,
                    delta_full=False)
    from repro.write.scheduler import DecisionContext
    sched = EveryKScheduler(k=3)
    assert not sched.decide(DecisionContext(batches_since_merge=2,
                                            **ctx_base)).merge
    assert sched.decide(DecisionContext(batches_since_merge=3,
                                        **ctx_base)).merge


def test_cam_decision_arithmetic():
    from repro.write.scheduler import DecisionContext
    base = dict(batch_index=0, delta_entries=10, delta_full=False,
                batches_since_merge=1)
    cam = CamMergeScheduler()
    win = cam.decide(DecisionContext(io_defer=2.0, io_merged=1.0,
                                     merge_io=5.0, horizon_queries=10.0,
                                     **base))
    assert win.merge and win.benefit == pytest.approx(10.0)
    lose = cam.decide(DecisionContext(io_defer=1.1, io_merged=1.0,
                                      merge_io=5.0, horizon_queries=10.0,
                                      **base))
    assert not lose.merge
    # safety scales the burst cost: higher safety defers more
    assert not CamMergeScheduler(safety=3.0).decide(
        DecisionContext(io_defer=2.0, io_merged=1.0, merge_io=5.0,
                        horizon_queries=10.0, **base)).merge
    # a full delta always flushes, whatever the prices say
    assert cam.decide(DecisionContext(io_defer=1.0, io_merged=1.0,
                                      merge_io=1e9, horizon_queries=1.0,
                                      batch_index=0, delta_entries=99,
                                      delta_full=True,
                                      batches_since_merge=0)).merge


# ---------------------------------------------------------------------------
# Executor equivalence on write tables (host vs fused device)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_executors_agree_on_write_tables(small_keys, policy):
    """Write-stream columns solve float32-identically on both executors."""
    sess = CostSession(System(GEOM, 4 << 20, policy))
    profs = sess.grid_profiles(
        [GridCandidate(eps, 65_536.0, eps=eps) for eps in (8, 32, 64)],
        _rw_workload(small_keys, 0.7))
    assert profs.wparts
    tab = PriceTable.from_profiles(
        profs, {kn: {} for kn in profs.knobs}, splits=(0.25, 0.5, 0.75),
        budget_bytes=float(4 << 20), page_bytes=GEOM.page_bytes)
    eng = PricingEngine(sess)
    sol_h = eng.price(tab, executor="host")
    sol_d = eng.price(tab, executor="device")
    assert np.max(np.abs(sol_h.hit_rates - sol_d.hit_rates)) < 2e-6
    assert np.isclose(sol_h.objective[sol_d.best_cell],
                      sol_h.objective[sol_h.best_cell], rtol=1e-5)


def test_write_session_host_device_equivalent():
    """The scheduler's 3-cell decision tables price the same on both
    executors: identical merge decisions, near-identical ledgers."""
    rep_h = _run(CamMergeScheduler(), executor="host")
    rep_d = _run(CamMergeScheduler(), executor="device")
    assert [r.merged for r in rep_h.records] == \
        [r.merged for r in rep_d.records]
    assert rep_h.total_io == pytest.approx(rep_d.total_io, rel=1e-4)
