"""Serving-layer tests: trace frontend, sketch exactness, the no-replay
serving loop, and the rebuild-cost-aware drift decisions.

The load-bearing guarantees, each gated here:

* sketch ``to_profiles()`` equals a one-shot ``grid_profiles`` over the
  window's concatenated batches — EXACTLY (1e-9) for integer-mass
  candidates, to float32 kernel precision in general, and bit-equal on the
  solved hit rates;
* chunk merge is associative (the cross-chunk sorted junction term folds
  like a monoid) and order-independent for the commutative statistics;
* eviction after a window slide never resurrects expired events;
* the serving loop never replays or re-profiles: ``grid_profiles`` runs
  exactly once per ingested batch (on that batch only) and retune
  decisions add ZERO profiling passes — one ``solve_profiles`` each;
* sketch update cost is O(batch), independent of total trace length
  (structural + measured).
"""
import dataclasses
import time
from functools import reduce

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.cam import CamGeometry
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.serving import (ServingConfig, ServingSession, TraceEvent,
                           WindowSketch, compile_events, iter_batches,
                           parse_jsonl, synthetic_drifting_trace,
                           tv_distance)
from repro.serving.sketch import _Accum, merge_accums
from repro.serving.trace import to_jsonl
from repro.tuning.session import PGMBuilder, TuningSession, _feasibility_split

GEOM = CamGeometry(c_ipp=64, page_bytes=4096)
N_KEYS = 8192

_rng = np.random.default_rng(0)
KEYS = np.sort(_rng.uniform(0, 1e6, N_KEYS))


def _system(budget=1 << 20, policy="lru"):
    return System(GEOM, memory_budget_bytes=budget, policy=policy)


def _candidates(eps_list=(0, 4, 32)):
    return [GridCandidate(knob=e, size_bytes=2048.0 * (i + 1), eps=e)
            for i, e in enumerate(eps_list)]


def _trace(n_events=1200, seed=2):
    return synthetic_drifting_trace(KEYS, [
        {"events": n_events // 2, "mix": (0.5, 0.3, 0.2),
         "hot_center": 0.3, "range_width": 40, "sorted_run": 16},
        {"events": n_events - n_events // 2, "mix": (0.2, 0.5, 0.3),
         "hot_center": 0.7, "range_width": 200, "sorted_run": 16},
    ], seed=seed)


def _batches(events, batch=200):
    return [compile_events(b, KEYS) for b in iter_batches(events, batch)]


# ---------------------------------------------------------------------------
# Trace frontend
# ---------------------------------------------------------------------------

def test_trace_event_validation():
    with pytest.raises(ValueError):
        TraceEvent("scan", key=1.0)
    with pytest.raises(ValueError):
        TraceEvent("point")
    with pytest.raises(ValueError):
        TraceEvent("range", lo_key=1.0)       # missing hi_key


def test_jsonl_roundtrip():
    events = _trace(120)
    back = list(parse_jsonl(to_jsonl(events).splitlines()))
    assert back == events


def test_compile_events_kinds_and_order():
    events = _trace(400)
    wl = compile_events(events, KEYS)
    assert wl.kind == "mixed"
    kinds = {p.kind for p in wl.parts}
    assert kinds == {"point", "range", "sorted"}
    # sorted probes keep arrival order (the closed forms require it)
    srt = next(p for p in wl.parts if p.kind == "sorted")
    expect = [e for e in events if e.op == "sorted"]
    np.testing.assert_array_equal(
        srt.positions,
        np.minimum(np.searchsorted(KEYS, [e.lo_key for e in expect]),
                   N_KEYS - 1))
    # range bounds are ordered
    rng_part = next(p for p in wl.parts if p.kind == "range")
    assert np.all(rng_part.hi_positions >= rng_part.positions)
    # a single-op batch compiles to a bare part, not a 1-part mixed
    only_points = [e for e in events if e.op == "point"][:10]
    assert compile_events(only_points, KEYS).kind == "point"


# ---------------------------------------------------------------------------
# Workload composition (the mixed-flatten satellite)
# ---------------------------------------------------------------------------

def test_mixed_flattens_nested_parts():
    a = Workload.point(np.arange(5), n=N_KEYS)
    b = Workload.range_scan(np.arange(4), np.arange(4) + 2, n=N_KEYS)
    c = Workload.sorted_stream(np.arange(3), np.arange(3) + 1, n=N_KEYS)
    nested = Workload.mixed(Workload.mixed(a, b), c)
    assert nested.parts == (a, b, c)      # trace batches compose cleanly
    deep = Workload.mixed(Workload.mixed(Workload.mixed(a), b), c)
    assert deep.parts == (a, b, c)
    assert nested.n_queries == 12


def test_concat_merges_same_kind_parts():
    batches = _batches(_trace(600), 150)
    whole = Workload.concat(*batches)
    # one part per kind, not parts-per-batch
    assert whole.kind == "mixed"
    assert len(whole.parts) == 3
    assert whole.n_queries == sum(b.n_queries for b in batches)
    pts = np.concatenate(
        [p.positions for b in batches
         for p in (b.parts if b.kind == "mixed" else (b,))
         if p.kind == "point"])
    got = next(p for p in whole.parts if p.kind == "point")
    np.testing.assert_array_equal(got.positions, pts)


def test_concat_rejects_inconsistent_n():
    a = Workload.point(np.arange(5), n=100)
    b = Workload.point(np.arange(5), n=200)
    with pytest.raises(ValueError):
        Workload.concat(a, b)


# ---------------------------------------------------------------------------
# Sketch exactness
# ---------------------------------------------------------------------------

def _filled_sketch(batch_wls, cands, window=None, budget=1 << 20):
    cost = CostSession(_system(budget))
    sk = WindowSketch(cost, cands,
                      window_chunks=window or len(batch_wls))
    for wl in batch_wls:
        sk.update(wl)
    return cost, sk


def _assert_profiles_match(merged, oneshot, atol):
    assert merged.knobs == oneshot.knobs
    assert merged.n_queries == oneshot.n_queries
    assert merged.scale == oneshot.scale == 1.0
    np.testing.assert_allclose(np.asarray(merged.counts, np.float64),
                               np.asarray(oneshot.counts, np.float64),
                               atol=atol, rtol=0)
    np.testing.assert_allclose(merged.totals, oneshot.totals,
                               atol=atol, rtol=1e-6)
    np.testing.assert_allclose(merged.dacs, oneshot.dacs,
                               atol=atol, rtol=1e-6)
    np.testing.assert_array_equal(merged.caps, oneshot.caps)
    for sp_m, sp_o in zip(merged.sparts, oneshot.sparts):
        assert (sp_m is None) == (sp_o is None)
        if sp_m is None:
            continue
        assert sp_m.total_refs == sp_o.total_refs
        assert sp_m.distinct_pages == sp_o.distinct_pages
        assert sp_m.pinned_retouches == sp_o.pinned_retouches
        assert sp_m.min_capacity == sp_o.min_capacity
        np.testing.assert_allclose(np.asarray(sp_m.coverage),
                                   np.asarray(sp_o.coverage), atol=atol)


def test_sketch_to_profiles_matches_oneshot_exact():
    """Integer-mass candidates (eps=0): the full-window sketch equals the
    one-shot profile to 1e-9 — including the sorted coverage, the distinct
    count, and the cross-chunk pinned-junction statistic."""
    batch_wls = _batches(_trace(1200), 200)
    cands = _candidates((0,))
    cost, sk = _filled_sketch(batch_wls, cands)
    merged = sk.to_profiles()
    oneshot = cost.grid_profiles(cands, Workload.concat(*batch_wls))
    _assert_profiles_match(merged, oneshot, atol=1e-9)


def test_sketch_to_profiles_matches_oneshot_general():
    """General eps grid: equality to float32 kernel precision on the raw
    histograms, and the SOLVED hit rates agree tightly (what retuning
    actually consumes)."""
    batch_wls = _batches(_trace(1200), 200)
    cands = _candidates((0, 4, 32))
    cost, sk = _filled_sketch(batch_wls, cands)
    merged = sk.to_profiles()
    oneshot = cost.grid_profiles(cands, Workload.concat(*batch_wls))
    _assert_profiles_match(merged, oneshot, atol=1e-4)
    h_m, nd_m = cost.solve_profiles(merged, merged.caps)
    h_o, nd_o = cost.solve_profiles(oneshot, oneshot.caps)
    np.testing.assert_allclose(h_m, h_o, atol=1e-6)
    np.testing.assert_allclose(nd_m, nd_o, atol=1e-3)


def test_sketch_eviction_never_resurrects():
    """After the window slides, expired batches leave no trace: a W-chunk
    sketch that saw 6 batches equals the one-shot profile of the LAST W
    batches alone, and pages touched only by the expired prefix read 0."""
    events = _trace(1200)
    # prefix hammers a region the rest of the trace never touches
    lo = float(KEYS[100])
    prefix = [TraceEvent("point", key=lo, ts=0.0)] * 200
    batch_wls = _batches(prefix + events, 200)
    cands = _candidates((0,))
    window = 3
    cost, sk = _filled_sketch(batch_wls, cands, window=window)
    merged = sk.to_profiles()
    oneshot = cost.grid_profiles(
        cands, Workload.concat(*batch_wls[-window:]))
    _assert_profiles_match(merged, oneshot, atol=1e-9)
    # the hammered page got mass only from the expired prefix batch
    page = 100 // GEOM.c_ipp
    live_mass = sum(
        float(np.sum(np.asarray(p.positions) // GEOM.c_ipp == page))
        for wl in batch_wls[-window:]
        for p in (wl.parts if wl.kind == "mixed" else (wl,))
        if p.kind == "point")
    if live_mass == 0:
        assert float(np.asarray(merged.counts)[0, page]) == 0.0


# ---------------------------------------------------------------------------
# Merge monoid properties
# ---------------------------------------------------------------------------

def _accums(batch_wls, cands):
    _, sk = _filled_sketch(batch_wls, cands)
    return [_Accum.lift(c) for c in sk.chunks]


def _assert_accums_equal(x, y, atol=1e-9):
    assert x.n_queries == y.n_queries
    np.testing.assert_allclose(x.counts, y.counts, atol=atol)
    np.testing.assert_allclose(x.totals, y.totals, atol=atol)
    np.testing.assert_allclose(x.dac_mass, y.dac_mass, atol=atol)
    assert x.sorted_refs == y.sorted_refs
    assert x.sorted_pinned == y.sorted_pinned       # junctions fold exactly
    if x.sorted_coverage is not None:
        np.testing.assert_allclose(x.sorted_coverage, y.sorted_coverage,
                                   atol=atol)
    assert x.first_lo_page == y.first_lo_page
    assert x.last_hi_page == y.last_hi_page


def test_merge_is_associative():
    accs = _accums(_batches(_trace(800), 160), _candidates((0, 4)))
    assert len(accs) == 5
    a, b, c, d, e = accs
    left = reduce(merge_accums, [a, b, c, d, e])
    right = merge_accums(merge_accums(a, b),
                         merge_accums(c, merge_accums(d, e)))
    _assert_accums_equal(left, right)


def test_merge_order_independent_for_commutative_stats():
    """Batches without sorted traffic have no sequential statistic at all,
    so ANY merge order yields the same accumulation."""
    events = [e for e in _trace(900) if e.op != "sorted"][:600]
    accs = _accums(_batches(events, 150), _candidates((0, 4)))
    fwd = reduce(merge_accums, accs)
    rev = reduce(merge_accums, accs[::-1])
    np.testing.assert_allclose(fwd.counts, rev.counts, atol=1e-9)
    np.testing.assert_allclose(fwd.totals, rev.totals, atol=1e-9)
    np.testing.assert_allclose(fwd.dac_mass, rev.dac_mass, atol=1e-9)
    assert fwd.n_queries == rev.n_queries
    assert fwd.sorted_refs == rev.sorted_refs == 0.0


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=N_KEYS - 1),
                min_size=9, max_size=60),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_merge_associativity_property(positions, seed):
    """Hypothesis: random point/range/sorted mixes, random 3-way chunk
    grouping — the merge monoid folds identically."""
    rng = np.random.default_rng(seed)
    events = []
    for p in positions:
        op = ("point", "range", "sorted")[int(rng.integers(3))]
        if op == "point":
            events.append(TraceEvent("point", key=float(KEYS[p])))
        else:
            hi = min(N_KEYS - 1, p + int(rng.integers(1, 200)))
            events.append(TraceEvent(op, lo_key=float(KEYS[p]),
                                     hi_key=float(KEYS[hi])))
    k = len(events) // 3
    wls = [compile_events(g, KEYS)
           for g in (events[:k], events[k:2 * k], events[2 * k:])]
    a, b, c = _accums(wls, _candidates((0,)))
    _assert_accums_equal(merge_accums(merge_accums(a, b), c),
                         merge_accums(a, merge_accums(b, c)))


# ---------------------------------------------------------------------------
# tune_from_profiles ≡ tune
# ---------------------------------------------------------------------------

def test_tune_from_profiles_matches_tune():
    qpos = np.sort(_rng.integers(0, N_KEYS, 4000))
    wl = Workload.point(qpos, n=N_KEYS, query_keys=KEYS[qpos])
    ts = TuningSession(_system(256 << 10))
    builder = PGMBuilder(KEYS)
    overrides = {"eps": (8, 32, 128)}
    res = ts.tune(builder, wl, overrides=overrides)

    space = builder.knob_space(overrides)
    feasible, _ = _feasibility_split(space.points(), space,
                                     builder.size_model(), ts.system)
    cands = [builder.candidate(pt, size) for pt, size in feasible]
    profiles = ts.cost.grid_profiles(cands, wl)
    res2 = ts.tune_from_profiles(builder, profiles, overrides=overrides)

    assert res2.best_knob == res.best_knob
    assert res2.split == res.split
    assert res2.capacity_pages == res.capacity_pages
    np.testing.assert_allclose(res2.est_io, res.est_io, rtol=1e-12)
    assert set(res2.table) == set(res.table)
    assert res2.batched_solves == 1


# ---------------------------------------------------------------------------
# The serving loop: structural no-replay + O(batch) updates + decisions
# ---------------------------------------------------------------------------

def _serving(monkeypatch=None, rebuild_gate=True, horizon=16_000):
    system = _system(512 << 10)
    tuning = TuningSession(system)
    srv = ServingSession(
        tuning, PGMBuilder(KEYS), KEYS,
        overrides={"eps": (8, 32, 128)},
        config=ServingConfig(batch_size=200, window_chunks=3,
                             drift_threshold=0.12, hysteresis=0.04,
                             cooldown_batches=1, horizon_queries=horizon,
                             rebuild_gate=rebuild_gate))
    return tuning, srv


def test_serving_loop_is_sketch_only():
    """Structural: exactly ONE grid_profiles call per ingested batch (each
    seeing only that batch), and retune evaluations add solve calls but
    ZERO profiling or replay passes."""
    tuning, srv = _serving()
    cost = tuning.cost
    grid_sizes, solve_calls = [], [0]
    orig_grid, orig_solve = cost.grid_profiles, cost.solve_profiles

    def counting_grid(cands, wl, *a, **k):
        grid_sizes.append(wl.n_queries)
        return orig_grid(cands, wl, *a, **k)

    def counting_solve(*a, **k):
        solve_calls[0] += 1
        return orig_solve(*a, **k)

    cost.grid_profiles = counting_grid
    cost.solve_profiles = counting_solve

    events = _trace(1600, seed=5)
    warmup, stream = events[:400], events[400:]
    srv.start(warmup)
    warm_batches = len(grid_sizes)
    solves_after_start = solve_calls[0]
    assert warm_batches == 2 and solves_after_start == 1

    reports = srv.observe(stream)
    n_batches = len(reports)
    assert srv.stats.retune_evaluations >= 1     # the trace does drift
    # one profiling pass per batch — never a cumulative/replayed workload
    assert len(grid_sizes) == warm_batches + n_batches
    assert max(grid_sizes) <= srv.config.batch_size
    # each retune evaluation = exactly one batched solve, nothing else
    assert solve_calls[0] == solves_after_start \
        + srv.stats.retune_evaluations


def test_serving_rebuild_gate_blocks_flash_and_allows_regime_change():
    events = synthetic_drifting_trace(KEYS, [
        {"events": 600, "mix": (0.8, 0.2, 0.0), "hot_center": 0.2,
         "hot_width": 0.05, "range_width": 16},
        # flash: hot set blips, widths/mix unchanged -> optimal knob stays
        {"events": 400, "mix": (0.8, 0.2, 0.0), "hot_center": 0.6,
         "hot_width": 0.05, "range_width": 16},
        # regime change: wide ranges -> genuinely different optimum
        {"events": 1000, "mix": (0.1, 0.7, 0.2), "hot_center": 0.75,
         "hot_width": 0.4, "range_width": 2048},
    ], seed=11)
    _, srv = _serving()
    srv.start(events[:400])
    srv.observe(events[400:])
    assert srv.stats.drift_events >= 2
    assert srv.stats.retune_evaluations >= 2
    # every refused decision was refused FOR A MODELED REASON
    for d in srv.decisions:
        if not d.switched:
            assert (d.to_knob == d.from_knob
                    or d.predicted_savings <= d.rebuild_io)
        else:
            assert d.to_knob != d.from_knob
            assert d.predicted_savings > d.rebuild_io
    # the wide-range regime is worth a rebuild under this horizon
    assert srv.stats.rebuilds >= 1
    # gate-off baseline on the same trace rebuilds strictly more
    _, srv_all = _serving(rebuild_gate=False)
    srv_all.start(events[:400])
    srv_all.observe(events[400:])
    assert srv_all.stats.rebuilds > srv.stats.rebuilds


def test_sketch_update_cost_independent_of_trace_length():
    """Measured O(batch): ingesting batch #60 costs what batch #6 cost —
    the update never touches already-ingested history.  (Generous 5x bound:
    this is a smoke-level timing check; the structural guarantee above is
    the strong one.)"""
    cost = CostSession(_system())
    wl = _batches(_trace(200, seed=9), 200)[0]
    sk = WindowSketch(cost, _candidates((0, 4)), window_chunks=4)
    for _ in range(5):                            # jit warmup
        sk.update(wl)

    def med(k):
        times = []
        for _ in range(k):
            t0 = time.perf_counter()
            sk.update(wl)
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    early = med(5)
    for _ in range(45):
        sk.update(wl)
    late = med(5)
    assert sk.updates > 55
    assert late <= 5 * early + 0.05, \
        f"update slowed with trace length: {early:.4f}s -> {late:.4f}s"


def test_tv_distance_basics():
    a = {"x": np.array([1.0, 0.0]), "y": np.array([1.0, 1.0])}
    assert tv_distance(a, a) == 0.0
    b = {"x": np.array([0.0, 1.0]), "y": np.array([1.0, 1.0])}
    assert tv_distance(a, b) == 1.0
    empty = {"x": np.zeros(2), "y": np.zeros(2)}
    assert tv_distance(empty, empty) == 0.0


# ---------------------------------------------------------------------------
# Device-resident drift retune: zero host bincounts, one launch per stage
# ---------------------------------------------------------------------------

def test_drift_retune_is_device_resident(engine_executor, monkeypatch):
    """Structural: with device profiling configured, the whole serve →
    drift → retune lifecycle keeps histograms on the accelerator.  The
    host bincount kernel runs ZERO times, every sketch update is exactly
    one device occupancy launch, and every solve (the initial deploy plus
    each retune evaluation) is exactly one fused price-grid launch."""
    import repro.core.page_ref as page_ref_mod
    import repro.kernels.price_grid as price_grid_mod
    import repro.kernels.profile_grid as profile_grid_mod
    from repro.tuning.session import RMIBuilder

    engine_executor("device")             # price side: fused DeviceExecutor
    calls = {"host_bincount": 0, "profile_launch": 0, "price_launch": 0}

    def spy(key, real):
        def wrapped(*a, **k):
            calls[key] += 1
            return real(*a, **k)
        return wrapped

    monkeypatch.setattr(
        page_ref_mod, "point_page_refs_mixed_eps_grid",
        spy("host_bincount", page_ref_mod.point_page_refs_mixed_eps_grid))
    monkeypatch.setattr(
        page_ref_mod, "point_page_refs_mixed_eps",
        spy("host_bincount", page_ref_mod.point_page_refs_mixed_eps))
    monkeypatch.setattr(
        profile_grid_mod, "point_page_refs_mixed_eps_grid",
        spy("profile_launch",
            profile_grid_mod.point_page_refs_mixed_eps_grid))
    monkeypatch.setattr(price_grid_mod, "price_grid",
                        spy("price_launch", price_grid_mod.price_grid))

    tuning = TuningSession(_system(512 << 10))
    # point-only drifting trace: the mixed-eps (RMI) path is the one the
    # device occupancy kernel replaces
    events = synthetic_drifting_trace(KEYS, [
        {"events": 800, "mix": (1.0, 0.0, 0.0), "hot_center": 0.2,
         "hot_width": 0.05},
        {"events": 800, "mix": (1.0, 0.0, 0.0), "hot_center": 0.8,
         "hot_width": 0.05},
    ], seed=7)
    srv = ServingSession(
        tuning, RMIBuilder(KEYS), KEYS,
        overrides={"branch": (16, 64)},
        config=ServingConfig(batch_size=200, window_chunks=3,
                             drift_threshold=0.12, hysteresis=0.04,
                             cooldown_batches=1,
                             profile_executor="device"))
    srv.start(events[:400])
    srv.observe(events[400:])

    assert srv.stats.retune_evaluations >= 1     # the trace does drift
    assert calls["host_bincount"] == 0
    assert calls["profile_launch"] == srv.sketch.updates > 0
    assert calls["price_launch"] == 1 + srv.stats.retune_evaluations
    assert tuning.cost.engine.calls == calls["price_launch"]
