"""Dry-run pipeline smoke test: real subprocess (own XLA device count),
one representative cell per kind on the production mesh."""
import json
import os
import subprocess
import sys

import pytest


def _run(args, tmp_path, name):
    out = str(tmp_path / f"{name}.jsonl")
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", *args, "--out", out]
    res = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                         env=env, cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [json.loads(l) for l in open(out)]
    return rows


@pytest.mark.env_limited("production-mesh AOT compile needs >1 device")
@pytest.mark.parametrize("arch,shape", [
    ("starcoder2-3b", "decode_32k"),     # serve cell
    ("qwen2-moe-a2.7b", "train_4k"),     # MoE train cell (EP + mb + remat)
])
def test_dryrun_cell_compiles_on_production_mesh(arch, shape, tmp_path):
    rows = _run(["--arch", arch, "--shape", shape], tmp_path, arch)
    assert rows[-1]["status"] == "ok", rows[-1]
    mem = rows[-1]["memory"]
    assert mem["resident_plus_temp"] > 0
    assert rows[-1]["collectives"]["n_ops"] > 0


def test_dryrun_skip_rule(tmp_path):
    rows = _run(["--arch", "yi-34b", "--shape", "long_500k"], tmp_path, "skip")
    assert rows[-1]["status"] == "skipped"
    assert "sub-quadratic" in rows[-1]["reason"]
